//! Configuration system: host spec, scheduler parameters, simulation
//! parameters. JSON-loadable with code defaults matching the paper's
//! testbed (§V-A: two Xeon X5650 sockets, 12 cores, shared LLC per socket,
//! 1 Gb NIC) and the paper's scheduler constants (thr = 120%, IAS threshold
//! ≈ 1.5, 2.5% idle detection).

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Physical host description (the simulated testbed).
#[derive(Debug, Clone)]
pub struct HostSpec {
    /// Number of physical cores (paper: 12).
    pub cores: usize,
    /// Number of sockets (paper: 2 × six-core).
    pub sockets: usize,
    /// Memory bandwidth capacity per socket, in demand units (a VM's membw
    /// demand is a fraction of this).
    pub membw_per_socket: f64,
    /// Host-wide disk I/O capacity in demand units.
    pub disk_capacity: f64,
    /// Host-wide network capacity in demand units (the paper's 1 Gb port).
    pub net_capacity: f64,
    /// SMT (hyperthreading) capacity of one core when ≥ 2 vCPUs share it:
    /// effective work retired per second (X5650 is 2-way SMT; 1.25 is a
    /// typical SMT yield). A lone vCPU is still capped at 1.0.
    pub smt_yield: f64,
    /// Per-extra-co-runner context-switch progress penalty κ (time-sharing
    /// cost of stacking k vCPUs on one core: factor 1 − κ(k−1)).
    pub ctx_switch_overhead: f64,
    /// Multiplier on κ for latency-critical VMs (they additionally pay
    /// scheduling delay, §II).
    pub lc_ctx_multiplier: f64,
    /// Scheduling-delay coefficient δ for latency-critical VMs: requests
    /// arriving while a co-runner occupies the core wait for a scheduling
    /// quantum, inflating latency by ≈ 1 + δ·Σ co-runner CPU utilisation
    /// (the queueing/scheduling-delay effect of Leverich & Kozyrakis that
    /// the paper's §II discussion singles out). This is what makes blind
    /// co-location of latency-critical VMs with CPU hogs expensive — and
    /// what IAS learns to avoid through the S matrix.
    pub lc_sched_delay: f64,
    /// Socket-level (shared LLC) coupling: fraction of the pairwise
    /// interference factor applied to same-socket, different-core pairs.
    pub socket_coupling: f64,
    /// Power model: watts per active (unparked) core.
    pub watts_per_core: f64,
    /// Power model: idle watts per socket (uncore, fixed).
    pub watts_socket_idle: f64,
}

impl Default for HostSpec {
    fn default() -> Self {
        HostSpec {
            cores: 12,
            sockets: 2,
            membw_per_socket: 1.5,
            disk_capacity: 1.0,
            net_capacity: 1.0,
            smt_yield: 1.25,
            ctx_switch_overhead: 0.005,
            lc_ctx_multiplier: 2.0,
            lc_sched_delay: 0.5,
            socket_coupling: 0.25,
            watts_per_core: 15.0,
            watts_socket_idle: 20.0,
        }
    }
}

impl HostSpec {
    pub fn cores_per_socket(&self) -> usize {
        self.cores / self.sockets
    }

    pub fn socket_of(&self, core: usize) -> usize {
        core / self.cores_per_socket()
    }

    /// This host's capacity on each profiled metric axis
    /// (`[cpu_cores, diskio, netio, membw]`) — what the cluster
    /// dispatch matrix advertises per host instead of assuming a fixed
    /// 1.0 for the non-CPU metrics. CPU is in cores (load columns are
    /// Σ per-core demands); disk and net are the host-wide capacities;
    /// memory bandwidth is the whole box (`membw_per_socket × sockets`,
    /// where a VM's membw demand is a fraction of one socket).
    pub fn metric_caps(&self) -> crate::workloads::MetricVec {
        [
            self.cores as f64,
            self.disk_capacity,
            self.net_capacity,
            self.membw_per_socket * self.sockets as f64,
        ]
    }
}

/// Scheduler parameters (§IV-B).
#[derive(Debug, Clone)]
pub struct SchedParams {
    /// RAS resource-utilisation threshold `thr` (paper: 120%).
    pub ras_threshold: f64,
    /// IAS interference threshold; `None` derives it from the profiled S
    /// matrix via Eq. 5 (paper lands at 1.5).
    pub ias_threshold: Option<f64>,
    /// Scheduler re-pin interval in seconds (Alg. 1 `timeInterval`).
    pub interval: f64,
    /// Idle detection: a workload whose CPU usage over the last monitoring
    /// window is below this is idle (paper: 2.5%).
    pub idle_cpu_threshold: f64,
    /// Monitoring window length in seconds for idle detection.
    pub monitor_window: f64,
}

impl Default for SchedParams {
    fn default() -> Self {
        SchedParams {
            ras_threshold: 1.2,
            ias_threshold: None,
            interval: 30.0,
            idle_cpu_threshold: 0.025,
            monitor_window: 10.0,
        }
    }
}

/// Continuous migration-manager parameters (see `cluster::migrator` for
/// the planner that consumes them and the full grammar table).
///
/// CLI grammar: `over:under:budget[:interval][,key=value...]` — e.g.
/// `0.85:0.35:4`, `0.9:0.3:8:60` or
/// `0.85:0.35:4:30,forecast=on,payback=600`. Empty positional fields
/// keep their defaults (`::8` overrides only the budget). Keyword
/// options: `forecast=on|off`, `alpha=`, `beta=`, `horizon=`, `k=`
/// (hysteresis intervals), `payback=<secs|inf>`, `cooldown=`, `wi=`.
#[derive(Debug, Clone, PartialEq)]
pub struct MigratorParams {
    /// Overload threshold on estimated CPU load as a fraction of host
    /// CPU capacity: a host above it sheds VMs (spread).
    pub over: f64,
    /// Underload threshold on the same fraction: a host below it is a
    /// candidate for full evacuation and parking.
    pub under: f64,
    /// Max concurrent live migrations, counting in-flight transfers.
    pub budget: usize,
    /// Seconds between planning passes.
    pub interval: f64,
    /// Worst-interference threshold: a host whose `max_wi` exceeds it is
    /// treated as overloaded, and it caps destination WI headroom.
    pub wi_threshold: f64,
    /// Per-VM cooldown in seconds — a VM the planner just moved is not
    /// eligible again until this much virtual time has passed.
    pub cooldown: f64,
    /// Plan against Holt-linear forecast load instead of the current
    /// tick's summaries. Off by default: the myopic PR 8 planner, kept
    /// bit-identical (the digest gates compare against it).
    pub forecast: bool,
    /// Holt-linear level gain (EWMA smoothing factor), in (0, 1].
    pub alpha: f64,
    /// Holt-linear trend gain, in [0, 1]. 0 degrades to plain EWMA.
    pub beta: f64,
    /// Forecast horizon in seconds: classification evaluates the
    /// predicted load this far ahead of the planning pass.
    pub horizon: f64,
    /// Hysteresis band: a host must be predicted under `under` for this
    /// many consecutive planning intervals before it may be evacuated.
    pub hysteresis: usize,
    /// Payback horizon in seconds for cost-aware consolidation: a park
    /// is skipped when the copy's energy cost (transfer seconds ×
    /// source+destination draw) exceeds the parked host's saving over
    /// this window. `INFINITY` (default) disables the gate — every
    /// in-budget consolidation is treated as free, like PR 8.
    pub payback: f64,
}

impl Default for MigratorParams {
    fn default() -> Self {
        MigratorParams {
            over: 0.85,
            under: 0.35,
            budget: 4,
            interval: 30.0,
            wi_threshold: 1.5,
            cooldown: 120.0,
            forecast: false,
            alpha: 0.3,
            beta: 0.1,
            horizon: 90.0,
            hysteresis: 2,
            payback: f64::INFINITY,
        }
    }
}

impl MigratorParams {
    /// Parse the CLI grammar `over:under:budget[:interval][,key=value...]`.
    /// An empty string (bare `--migrator`) and empty positional fields
    /// keep the defaults.
    pub fn parse(spec: &str) -> Result<MigratorParams> {
        let mut p = MigratorParams::default();
        if spec.is_empty() {
            return Ok(p);
        }
        let (positional, keyed) = match spec.split_once(',') {
            Some((head, rest)) => (head, Some(rest)),
            None => (spec, None),
        };
        let num = |field: &str, name: &str| -> Result<f64> {
            field
                .parse::<f64>()
                .with_context(|| format!("migrator {name} '{field}' in '{spec}'"))
        };
        if !positional.is_empty() {
            let fields: Vec<&str> = positional.split(':').collect();
            anyhow::ensure!(
                fields.len() <= 4,
                "migrator spec '{spec}': expected over:under:budget[:interval]"
            );
            if let Some(f) = fields.first().filter(|f| !f.is_empty()) {
                p.over = num(f, "over")?;
            }
            if let Some(f) = fields.get(1).filter(|f| !f.is_empty()) {
                p.under = num(f, "under")?;
            }
            if let Some(f) = fields.get(2).filter(|f| !f.is_empty()) {
                p.budget = f
                    .parse::<usize>()
                    .with_context(|| format!("migrator budget '{f}' in '{spec}'"))?;
            }
            if let Some(f) = fields.get(3).filter(|f| !f.is_empty()) {
                p.interval = num(f, "interval")?;
            }
        }
        for kv in keyed.map(|k| k.split(',')).into_iter().flatten() {
            if kv.is_empty() {
                continue;
            }
            let (key, val) = kv.split_once('=').with_context(|| {
                format!("migrator option '{kv}' in '{spec}': expected key=value")
            })?;
            match key {
                "forecast" => {
                    p.forecast = match val {
                        "on" | "true" | "1" => true,
                        "off" | "false" | "0" => false,
                        other => anyhow::bail!("migrator forecast '{other}': expected on|off"),
                    }
                }
                "alpha" => p.alpha = num(val, "alpha")?,
                "beta" => p.beta = num(val, "beta")?,
                "horizon" => p.horizon = num(val, "horizon")?,
                "k" => {
                    p.hysteresis = val
                        .parse::<usize>()
                        .with_context(|| format!("migrator k '{val}' in '{spec}'"))?
                }
                "payback" => {
                    p.payback = if val == "inf" {
                        f64::INFINITY
                    } else {
                        num(val, "payback")?
                    }
                }
                "cooldown" => p.cooldown = num(val, "cooldown")?,
                "wi" => p.wi_threshold = num(val, "wi")?,
                other => anyhow::bail!(
                    "unknown migrator option '{other}' in '{spec}' \
                     (valid: forecast, alpha, beta, horizon, k, payback, cooldown, wi)"
                ),
            }
        }
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.over > 0.0 && self.over <= 1.5,
            "migrator over threshold {} out of (0, 1.5]",
            self.over
        );
        anyhow::ensure!(
            self.under >= 0.0 && self.under < self.over,
            "migrator under threshold {} must sit in [0, over={})",
            self.under,
            self.over
        );
        anyhow::ensure!(self.budget >= 1, "migrator budget must be >= 1");
        anyhow::ensure!(self.interval > 0.0, "migrator interval must be > 0");
        anyhow::ensure!(self.wi_threshold > 0.0, "migrator wi_threshold must be > 0");
        anyhow::ensure!(self.cooldown >= 0.0, "migrator cooldown must be >= 0");
        anyhow::ensure!(
            self.alpha > 0.0 && self.alpha <= 1.0,
            "migrator alpha {} out of (0, 1]",
            self.alpha
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.beta),
            "migrator beta {} out of [0, 1]",
            self.beta
        );
        anyhow::ensure!(
            self.horizon >= 0.0 && self.horizon.is_finite(),
            "migrator horizon must be finite and >= 0"
        );
        anyhow::ensure!(self.hysteresis >= 1, "migrator hysteresis k must be >= 1");
        anyhow::ensure!(self.payback > 0.0, "migrator payback must be > 0 (or inf)");
        Ok(())
    }
}

/// Host power-draw model behind the cluster ledger's energy integral
/// (see `metrics::ledger::ClusterLedger`). `Linear` is the PR 8
/// behavior, bit-identical by construction; `Piecewise` carries a
/// SPECpower-style utilization→watts breakpoint table, evaluated
/// against the host's CPU capacity (per-host `host_caps` vectors give
/// heterogeneous host classes different absolute utilizations for the
/// same busy-core count).
///
/// CLI grammar (`vmcd cluster --power …`): `linear` or
/// `piecewise:u=w,u=w,...` with utilizations in [0, 1], e.g.
/// `piecewise:0=40,0.5=120,1=210`.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PowerModel {
    /// `sockets × idle_watts + busy_cores × watts_per_core` — the
    /// original linear-in-busy-cores integral, kept expression-exact so
    /// default runs stay digest-identical.
    #[default]
    Linear,
    /// Utilization→watts breakpoints, linearly interpolated; clamped to
    /// the first/last point outside the table's range.
    Piecewise(PiecewiseTable),
}

/// A validated utilization→watts breakpoint table: ≥ 2 points, finite,
/// strictly increasing utilization in [0, 1], non-negative watts.
#[derive(Debug, Clone, PartialEq)]
pub struct PiecewiseTable {
    points: Vec<(f64, f64)>,
}

impl PiecewiseTable {
    /// Validate and seal a breakpoint table. Degenerate tables — fewer
    /// than two points, unsorted or duplicate utilizations, values out
    /// of range — are configuration errors, never panics.
    pub fn new(points: Vec<(f64, f64)>) -> Result<PiecewiseTable> {
        anyhow::ensure!(
            points.len() >= 2,
            "piecewise power table needs >= 2 breakpoints, got {}",
            points.len()
        );
        for &(u, w) in &points {
            anyhow::ensure!(
                u.is_finite() && (0.0..=1.0).contains(&u),
                "piecewise utilization {u} out of [0, 1]"
            );
            anyhow::ensure!(
                w.is_finite() && w >= 0.0,
                "piecewise watts {w} must be finite and >= 0"
            );
        }
        for pair in points.windows(2) {
            anyhow::ensure!(
                pair[0].0 < pair[1].0,
                "piecewise utilizations must strictly increase ({} then {})",
                pair[0].0,
                pair[1].0
            );
        }
        Ok(PiecewiseTable { points })
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Watts at utilization `u`: linear interpolation between the
    /// bracketing breakpoints, clamped to the table's ends.
    pub fn watts_at(&self, u: f64) -> f64 {
        // Validation guarantees >= 2 sorted points, but stay total.
        let Some(&(u0, w0)) = self.points.first() else {
            return 0.0;
        };
        let Some(&(un, wn)) = self.points.last() else {
            return 0.0;
        };
        if u <= u0 {
            return w0;
        }
        if u >= un {
            return wn;
        }
        for pair in self.points.windows(2) {
            let (ua, wa) = pair[0];
            let (ub, wb) = pair[1];
            if u <= ub {
                return wa + (wb - wa) * ((u - ua) / (ub - ua));
            }
        }
        wn
    }
}

impl PowerModel {
    /// Parse the CLI grammar: `linear` or `piecewise:u=w,u=w,...`.
    pub fn parse(spec: &str) -> Result<PowerModel> {
        if spec == "linear" || spec.is_empty() {
            return Ok(PowerModel::Linear);
        }
        let Some(table) = spec.strip_prefix("piecewise:") else {
            anyhow::bail!("power model '{spec}': expected linear or piecewise:u=w,...");
        };
        let mut points = Vec::new();
        for kv in table.split(',') {
            if kv.is_empty() {
                continue;
            }
            let (u, w) = kv
                .split_once('=')
                .with_context(|| format!("power breakpoint '{kv}': expected u=w"))?;
            let u: f64 = u
                .parse()
                .with_context(|| format!("power utilization '{u}' in '{spec}'"))?;
            let w: f64 = w
                .parse()
                .with_context(|| format!("power watts '{w}' in '{spec}'"))?;
            points.push((u, w));
        }
        Ok(PowerModel::Piecewise(PiecewiseTable::new(points)?))
    }

    pub fn name(&self) -> &'static str {
        match self {
            PowerModel::Linear => "linear",
            PowerModel::Piecewise(_) => "piecewise",
        }
    }

    /// Instantaneous draw of a powered host with `busy` busy cores.
    /// `cpu_cap` is the host's CPU capacity in cores (from `host_caps`
    /// for heterogeneous fleets, `host.cores` otherwise) — the
    /// utilization denominator for breakpoint tables. Parked hosts
    /// (resident == 0, busy == 0) never reach this: the ledger charges
    /// them 0 W before consulting the model.
    pub fn watts(&self, busy: usize, cpu_cap: f64, host: &HostSpec) -> f64 {
        match self {
            PowerModel::Linear => {
                host.sockets as f64 * host.watts_socket_idle + busy as f64 * host.watts_per_core
            }
            PowerModel::Piecewise(table) => {
                let u = if cpu_cap > 0.0 {
                    (busy as f64 / cpu_cap).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                table.watts_at(u)
            }
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Virtual-time tick, seconds.
    pub dt: f64,
    /// Hard wall on simulated time, seconds.
    pub max_time: f64,
    /// Master seed; every stochastic stream forks from it.
    pub seed: u64,
    /// Relative noise on per-tick demands (monitoring jitter).
    pub demand_noise: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            dt: 1.0,
            max_time: 7200.0,
            seed: 42,
            demand_noise: 0.03,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub host: HostSpec,
    pub sched: SchedParams,
    pub sim: SimParams,
    /// Continuous migration manager; `None` leaves it disabled (the
    /// cluster then behaves exactly as it did without the subsystem).
    pub migrator: Option<MigratorParams>,
    /// Host power-draw model for the cluster-scope energy integral.
    /// `Linear` (the default) is bit-identical to the PR 8 ledger.
    pub power: PowerModel,
}

impl Config {
    /// Load from a JSON file; absent fields keep their defaults.
    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let json = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        Config::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(h) = json.get("host") {
            read_usize(h, "cores", &mut cfg.host.cores);
            read_usize(h, "sockets", &mut cfg.host.sockets);
            read_f64(h, "membw_per_socket", &mut cfg.host.membw_per_socket);
            read_f64(h, "disk_capacity", &mut cfg.host.disk_capacity);
            read_f64(h, "net_capacity", &mut cfg.host.net_capacity);
            read_f64(h, "smt_yield", &mut cfg.host.smt_yield);
            read_f64(h, "ctx_switch_overhead", &mut cfg.host.ctx_switch_overhead);
            read_f64(h, "lc_ctx_multiplier", &mut cfg.host.lc_ctx_multiplier);
            read_f64(h, "lc_sched_delay", &mut cfg.host.lc_sched_delay);
            read_f64(h, "socket_coupling", &mut cfg.host.socket_coupling);
            read_f64(h, "watts_per_core", &mut cfg.host.watts_per_core);
            read_f64(h, "watts_socket_idle", &mut cfg.host.watts_socket_idle);
        }
        if let Some(s) = json.get("sched") {
            read_f64(s, "ras_threshold", &mut cfg.sched.ras_threshold);
            if let Some(v) = s.get("ias_threshold").and_then(Json::as_f64) {
                cfg.sched.ias_threshold = Some(v);
            }
            read_f64(s, "interval", &mut cfg.sched.interval);
            read_f64(s, "idle_cpu_threshold", &mut cfg.sched.idle_cpu_threshold);
            read_f64(s, "monitor_window", &mut cfg.sched.monitor_window);
        }
        if let Some(s) = json.get("sim") {
            read_f64(s, "dt", &mut cfg.sim.dt);
            read_f64(s, "max_time", &mut cfg.sim.max_time);
            if let Some(v) = s.get("seed").and_then(Json::as_f64) {
                cfg.sim.seed = v as u64;
            }
            read_f64(s, "demand_noise", &mut cfg.sim.demand_noise);
        }
        if let Some(m) = json.get("migrator").filter(|m| !matches!(m, Json::Null)) {
            let mut p = MigratorParams::default();
            read_f64(m, "over", &mut p.over);
            read_f64(m, "under", &mut p.under);
            read_usize(m, "budget", &mut p.budget);
            read_f64(m, "interval", &mut p.interval);
            read_f64(m, "wi_threshold", &mut p.wi_threshold);
            read_f64(m, "cooldown", &mut p.cooldown);
            if let Some(v) = m.get("forecast").and_then(Json::as_bool) {
                p.forecast = v;
            }
            read_f64(m, "alpha", &mut p.alpha);
            read_f64(m, "beta", &mut p.beta);
            read_f64(m, "horizon", &mut p.horizon);
            read_usize(m, "hysteresis", &mut p.hysteresis);
            // Payback: absent or null keeps the infinite default.
            if let Some(v) = m.get("payback").and_then(Json::as_f64) {
                p.payback = v;
            }
            cfg.migrator = Some(p);
        }
        if let Some(p) = json.get("power").filter(|p| !matches!(p, Json::Null)) {
            cfg.power = match p {
                Json::Str(name) => PowerModel::parse(name)?,
                obj => {
                    let arr = obj
                        .get("points")
                        .and_then(Json::as_arr)
                        .context("power model object needs a 'points' array")?;
                    let mut points = Vec::new();
                    for pt in arr {
                        let pair = pt.to_f64_vec().context("power breakpoint")?;
                        anyhow::ensure!(
                            pair.len() == 2,
                            "power breakpoint must be [utilization, watts]"
                        );
                        points.push((pair[0], pair[1]));
                    }
                    PowerModel::Piecewise(PiecewiseTable::new(points)?)
                }
            };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.host.cores >= 2, "need at least 2 cores");
        anyhow::ensure!(self.host.sockets >= 1, "need at least 1 socket");
        anyhow::ensure!(
            self.host.cores % self.host.sockets == 0,
            "cores ({}) must divide evenly into sockets ({})",
            self.host.cores,
            self.host.sockets
        );
        anyhow::ensure!(
            (0.0..0.5).contains(&self.host.ctx_switch_overhead),
            "ctx_switch_overhead out of range"
        );
        anyhow::ensure!(self.sched.ras_threshold > 0.0, "ras_threshold must be > 0");
        anyhow::ensure!(self.sim.dt > 0.0, "dt must be > 0");
        anyhow::ensure!(
            self.sched.interval >= self.sim.dt,
            "scheduler interval below simulation tick"
        );
        if let Some(m) = &self.migrator {
            m.validate()?;
        }
        Ok(())
    }

    /// Serialize (for experiment records).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "host",
                Json::from_pairs(vec![
                    ("cores", Json::Num(self.host.cores as f64)),
                    ("sockets", Json::Num(self.host.sockets as f64)),
                    ("membw_per_socket", Json::Num(self.host.membw_per_socket)),
                    ("disk_capacity", Json::Num(self.host.disk_capacity)),
                    ("net_capacity", Json::Num(self.host.net_capacity)),
                    ("smt_yield", Json::Num(self.host.smt_yield)),
                    ("ctx_switch_overhead", Json::Num(self.host.ctx_switch_overhead)),
                    ("lc_ctx_multiplier", Json::Num(self.host.lc_ctx_multiplier)),
                    ("lc_sched_delay", Json::Num(self.host.lc_sched_delay)),
                    ("socket_coupling", Json::Num(self.host.socket_coupling)),
                    ("watts_per_core", Json::Num(self.host.watts_per_core)),
                    ("watts_socket_idle", Json::Num(self.host.watts_socket_idle)),
                ]),
            ),
            (
                "sched",
                Json::from_pairs(vec![
                    ("ras_threshold", Json::Num(self.sched.ras_threshold)),
                    (
                        "ias_threshold",
                        self.sched
                            .ias_threshold
                            .map(Json::Num)
                            .unwrap_or(Json::Null),
                    ),
                    ("interval", Json::Num(self.sched.interval)),
                    ("idle_cpu_threshold", Json::Num(self.sched.idle_cpu_threshold)),
                    ("monitor_window", Json::Num(self.sched.monitor_window)),
                ]),
            ),
            (
                "sim",
                Json::from_pairs(vec![
                    ("dt", Json::Num(self.sim.dt)),
                    ("max_time", Json::Num(self.sim.max_time)),
                    ("seed", Json::Num(self.sim.seed as f64)),
                    ("demand_noise", Json::Num(self.sim.demand_noise)),
                ]),
            ),
            (
                "migrator",
                match &self.migrator {
                    Some(m) => Json::from_pairs(vec![
                        ("over", Json::Num(m.over)),
                        ("under", Json::Num(m.under)),
                        ("budget", Json::Num(m.budget as f64)),
                        ("interval", Json::Num(m.interval)),
                        ("wi_threshold", Json::Num(m.wi_threshold)),
                        ("cooldown", Json::Num(m.cooldown)),
                        ("forecast", Json::Bool(m.forecast)),
                        ("alpha", Json::Num(m.alpha)),
                        ("beta", Json::Num(m.beta)),
                        ("horizon", Json::Num(m.horizon)),
                        ("hysteresis", Json::Num(m.hysteresis as f64)),
                        (
                            "payback",
                            if m.payback.is_finite() {
                                Json::Num(m.payback)
                            } else {
                                Json::Null
                            },
                        ),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "power",
                match &self.power {
                    PowerModel::Linear => Json::Str("linear".into()),
                    PowerModel::Piecewise(t) => Json::from_pairs(vec![
                        ("model", Json::Str("piecewise".into())),
                        (
                            "points",
                            Json::Arr(
                                t.points()
                                    .iter()
                                    .map(|&(u, w)| {
                                        Json::Arr(vec![Json::Num(u), Json::Num(w)])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
        ])
    }
}

fn read_f64(json: &Json, key: &str, slot: &mut f64) {
    if let Some(v) = json.get(key).and_then(Json::as_f64) {
        *slot = v;
    }
}

fn read_usize(json: &Json, key: &str, slot: &mut usize) {
    if let Some(v) = json.get(key).and_then(Json::as_usize) {
        *slot = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = Config::default();
        assert_eq!(c.host.cores, 12);
        assert_eq!(c.host.sockets, 2);
        assert_eq!(c.host.cores_per_socket(), 6);
        assert_eq!(c.sched.ras_threshold, 1.2); // thr = 120%
        assert_eq!(c.sched.idle_cpu_threshold, 0.025); // 2.5%
        assert!(c.sched.ias_threshold.is_none()); // Eq. 5, derived
    }

    #[test]
    fn socket_mapping() {
        let h = HostSpec::default();
        assert_eq!(h.socket_of(0), 0);
        assert_eq!(h.socket_of(5), 0);
        assert_eq!(h.socket_of(6), 1);
        assert_eq!(h.socket_of(11), 1);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::default();
        c.host.cores = 24;
        c.host.sockets = 4;
        c.sched.ias_threshold = Some(1.7);
        c.sim.seed = 99;
        let j = c.to_json();
        let back = Config::from_json(&j).unwrap();
        assert_eq!(back.host.cores, 24);
        assert_eq!(back.host.sockets, 4);
        assert_eq!(back.sched.ias_threshold, Some(1.7));
        assert_eq!(back.sim.seed, 99);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"sched": {"ras_threshold": 1.4}}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.sched.ras_threshold, 1.4);
        assert_eq!(c.host.cores, 12);
    }

    #[test]
    fn migrator_grammar_parses_fields_and_defaults() {
        let d = MigratorParams::default();
        assert_eq!(MigratorParams::parse("").unwrap(), d);
        let p = MigratorParams::parse("0.9:0.3:8:60").unwrap();
        assert_eq!(p.over, 0.9);
        assert_eq!(p.under, 0.3);
        assert_eq!(p.budget, 8);
        assert_eq!(p.interval, 60.0);
        assert_eq!(p.wi_threshold, d.wi_threshold);
        // Empty fields keep defaults: override only the budget.
        let p = MigratorParams::parse("::8").unwrap();
        assert_eq!(p.over, d.over);
        assert_eq!(p.under, d.under);
        assert_eq!(p.budget, 8);
        assert!(MigratorParams::parse("0.2:0.8:4").is_err()); // under >= over
        assert!(MigratorParams::parse("0.9:0.3:0").is_err()); // zero budget
        assert!(MigratorParams::parse("a:b").is_err());
        assert!(MigratorParams::parse("1:2:3:4:5").is_err());
    }

    #[test]
    fn migrator_json_roundtrip() {
        let mut c = Config::default();
        assert!(c.migrator.is_none());
        let back = Config::from_json(&c.to_json()).unwrap();
        assert!(back.migrator.is_none(), "null migrator must stay disabled");
        c.migrator = Some(MigratorParams::parse("0.8:0.25:6:45").unwrap());
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.migrator, c.migrator);
        // Forecast/payback fields survive the roundtrip, infinite
        // payback included (serialized as null).
        c.migrator =
            Some(MigratorParams::parse("0.8:0.25:6:45,forecast=on,alpha=0.5,k=3").unwrap());
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.migrator, c.migrator);
        c.migrator = Some(MigratorParams::parse(",payback=600,horizon=120").unwrap());
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.migrator, c.migrator);
    }

    #[test]
    fn migrator_keyword_grammar_parses_forecast_and_payback() {
        let d = MigratorParams::default();
        assert!(!d.forecast);
        assert!(d.payback.is_infinite());
        let p = MigratorParams::parse("0.85:0.35:4:30,forecast=on,payback=600,k=3").unwrap();
        assert!(p.forecast);
        assert_eq!(p.payback, 600.0);
        assert_eq!(p.hysteresis, 3);
        assert_eq!(p.over, 0.85);
        // Keyword-only spec: positional defaults intact.
        let p = MigratorParams::parse(",alpha=0.5,beta=0.2,horizon=45,payback=inf").unwrap();
        assert_eq!(p.alpha, 0.5);
        assert_eq!(p.beta, 0.2);
        assert_eq!(p.horizon, 45.0);
        assert!(p.payback.is_infinite());
        assert_eq!(p.over, d.over);
        assert!(MigratorParams::parse("0.85:0.35,forecast=maybe").is_err());
        assert!(MigratorParams::parse("0.85:0.35,bogus=1").is_err());
        assert!(MigratorParams::parse(",alpha=0").is_err()); // alpha in (0, 1]
        assert!(MigratorParams::parse(",k=0").is_err()); // hysteresis >= 1
        assert!(MigratorParams::parse(",payback=0").is_err());
    }

    #[test]
    fn power_model_grammar_parses_linear_and_piecewise() {
        assert_eq!(PowerModel::parse("linear").unwrap(), PowerModel::Linear);
        assert_eq!(PowerModel::parse("").unwrap(), PowerModel::Linear);
        let p = PowerModel::parse("piecewise:0=40,0.5=120,1=210").unwrap();
        let PowerModel::Piecewise(t) = &p else {
            panic!("expected piecewise")
        };
        assert_eq!(t.points(), &[(0.0, 40.0), (0.5, 120.0), (1.0, 210.0)]);
        assert!(PowerModel::parse("quadratic").is_err());
        assert!(PowerModel::parse("piecewise:0.5").is_err());
    }

    #[test]
    fn degenerate_piecewise_tables_are_errors_not_panics() {
        // Single point.
        assert!(PiecewiseTable::new(vec![(0.0, 40.0)]).is_err());
        // Unsorted utilizations.
        assert!(PiecewiseTable::new(vec![(0.5, 120.0), (0.0, 40.0)]).is_err());
        // Duplicate utilization.
        assert!(PiecewiseTable::new(vec![(0.5, 120.0), (0.5, 130.0)]).is_err());
        // Out-of-range utilization and negative watts.
        assert!(PiecewiseTable::new(vec![(0.0, 40.0), (1.5, 200.0)]).is_err());
        assert!(PiecewiseTable::new(vec![(0.0, -1.0), (1.0, 200.0)]).is_err());
        assert!(PiecewiseTable::new(vec![(0.0, f64::NAN), (1.0, 200.0)]).is_err());
    }

    #[test]
    fn piecewise_interpolates_and_clamps() {
        let t = PiecewiseTable::new(vec![(0.0, 40.0), (0.5, 120.0), (1.0, 200.0)]).unwrap();
        assert_eq!(t.watts_at(0.0), 40.0);
        assert_eq!(t.watts_at(0.25), 80.0);
        assert_eq!(t.watts_at(0.5), 120.0);
        assert_eq!(t.watts_at(0.75), 160.0);
        assert_eq!(t.watts_at(1.0), 200.0);
        // Clamped outside the table.
        assert_eq!(t.watts_at(-0.5), 40.0);
        assert_eq!(t.watts_at(2.0), 200.0);
    }

    #[test]
    fn linear_power_matches_the_ledger_expression() {
        let host = HostSpec::default();
        // 2 sockets × 20 W idle + busy × 15 W — the PR 8 integral.
        assert_eq!(PowerModel::Linear.watts(0, 12.0, &host), 40.0);
        assert_eq!(PowerModel::Linear.watts(6, 12.0, &host), 130.0);
    }

    #[test]
    fn power_json_roundtrip() {
        let mut c = Config::default();
        assert_eq!(c.power, PowerModel::Linear);
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.power, PowerModel::Linear);
        c.power = PowerModel::parse("piecewise:0=40,0.6=150,1=220").unwrap();
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.power, c.power);
        // Degenerate tables are rejected at load time too.
        let j = Json::parse(r#"{"power": {"points": [[0.5, 100], [0.5, 120]]}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let j = Json::parse(r#"{"host": {"cores": 13, "sockets": 2}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        let j2 = Json::parse(r#"{"sim": {"dt": 0}}"#).unwrap();
        assert!(Config::from_json(&j2).is_err());
    }
}

//! The workload catalog: constants for the paper's eight benchmark classes.
//!
//! The numbers are calibrated against the qualitative descriptions in §V-B
//! (what each benchmark stresses) and tuned so the *profiled* slowdown
//! matrix S has the properties the paper reports: mean pairwise slowdown
//! ≈ 1.5 (the IAS threshold derivation, Eq. 5), heavy CPU pairs near 2.0,
//! membw pairs (jacobi-jacobi) distinctly worse than capacity effects
//! alone, and light latency-critical pairs near 1.0.

use super::perf::PerfModel;
use super::{MetricVec, NUM_METRICS};

/// The eight workload classes of the paper's evaluation (§V-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadClass {
    /// PARSEC blackscholes — FLOP-bound PDE solver (CPU-intensive batch).
    Blackscholes,
    /// Hadoop terasort — analytics batch with heavy disk and some network.
    Hadoop,
    /// PolyBench jacobi-2d — CPU + memory-bandwidth-intensive HPC batch.
    Jacobi,
    /// Apache+PHP+MySQL REST service, light JMeter pattern (latency-critical).
    LampLight,
    /// Same service under the heavy JMeter pattern.
    LampHeavy,
    /// CloudSuite media streaming, low client load.
    StreamLow,
    /// CloudSuite media streaming, medium client load.
    StreamMed,
    /// CloudSuite media streaming, high client load.
    StreamHigh,
}

/// All classes, in canonical (profiling matrix) order.
pub const ALL_CLASSES: [WorkloadClass; 8] = [
    WorkloadClass::Blackscholes,
    WorkloadClass::Hadoop,
    WorkloadClass::Jacobi,
    WorkloadClass::LampLight,
    WorkloadClass::LampHeavy,
    WorkloadClass::StreamLow,
    WorkloadClass::StreamMed,
    WorkloadClass::StreamHigh,
];

impl WorkloadClass {
    /// Canonical index into the S / U matrices.
    pub fn index(self) -> usize {
        // detlint: allow(panic): ALL_CLASSES enumerates every variant by definition
        ALL_CLASSES.iter().position(|&c| c == self).unwrap()
    }

    pub fn from_index(i: usize) -> WorkloadClass {
        ALL_CLASSES[i]
    }

    pub fn name(self) -> &'static str {
        match self {
            WorkloadClass::Blackscholes => "blackscholes",
            WorkloadClass::Hadoop => "hadoop",
            WorkloadClass::Jacobi => "jacobi",
            WorkloadClass::LampLight => "lamp-light",
            WorkloadClass::LampHeavy => "lamp-heavy",
            WorkloadClass::StreamLow => "stream-low",
            WorkloadClass::StreamMed => "stream-med",
            WorkloadClass::StreamHigh => "stream-high",
        }
    }

    pub fn from_name(name: &str) -> Option<WorkloadClass> {
        ALL_CLASSES.iter().copied().find(|c| c.name() == name)
    }
}

/// Full specification of a workload class.
#[derive(Debug, Clone, Copy)]
pub struct ClassSpec {
    pub class: WorkloadClass,
    /// Resource demand: [CPU (of one core), DiskIO (of host), NetIO (of
    /// host), MemBW (of one socket)].
    pub demand: MetricVec,
    /// Micro-architectural pressure this class exerts on co-located VMs
    /// (same metric axes; the CPU axis is unused — time-sharing is modelled
    /// by the share computation itself).
    pub pressure: MetricVec,
    /// Sensitivity of this class to co-runner pressure.
    pub sensitivity: MetricVec,
    pub perf: PerfModel,
    /// CPU fraction consumed while idle (background OS noise); below the
    /// paper's 2.5% idle threshold.
    pub idle_cpu: f64,
    /// Scheduling-quantum weight: how long this class holds the CPU per
    /// burst. Batch jobs run long quanta (1.0) — a latency-critical
    /// co-runner's request queues behind them; services yield quickly
    /// (0.1-0.3). Feeds the lc_sched_delay term of the host model.
    pub quantum: f64,
}

/// The calibrated catalog. Indexed by [`WorkloadClass::index`].
pub fn catalog() -> [ClassSpec; 8] {
    use WorkloadClass::*;
    [
        ClassSpec {
            class: Blackscholes,
            demand: [0.95, 0.01, 0.00, 0.05],
            pressure: [0.0, 0.00, 0.00, 0.05],
            sensitivity: [0.0, 0.00, 0.00, 0.25],
            perf: PerfModel::batch(300.0),
            idle_cpu: 0.01,
            quantum: 1.0,
        },
        ClassSpec {
            class: Hadoop,
            demand: [0.55, 0.50, 0.05, 0.15],
            pressure: [0.0, 0.35, 0.10, 0.15],
            sensitivity: [0.0, 0.30, 0.10, 0.20],
            perf: PerfModel::batch(420.0),
            idle_cpu: 0.015,
            quantum: 0.9,
        },
        ClassSpec {
            class: Jacobi,
            demand: [0.90, 0.00, 0.00, 0.35],
            pressure: [0.0, 0.00, 0.00, 0.50],
            sensitivity: [0.0, 0.00, 0.00, 0.45],
            perf: PerfModel::batch(360.0),
            idle_cpu: 0.01,
            quantum: 1.0,
        },
        ClassSpec {
            class: LampLight,
            demand: [0.28, 0.03, 0.02, 0.03],
            pressure: [0.0, 0.03, 0.03, 0.01],
            sensitivity: [0.0, 0.05, 0.08, 0.05],
            perf: PerfModel::latency(1.5),
            idle_cpu: 0.02,
            quantum: 0.1,
        },
        ClassSpec {
            class: LampHeavy,
            demand: [0.45, 0.10, 0.06, 0.08],
            pressure: [0.0, 0.08, 0.10, 0.04],
            sensitivity: [0.0, 0.10, 0.13, 0.08],
            perf: PerfModel::latency(1.5),
            idle_cpu: 0.02,
            quantum: 0.15,
        },
        ClassSpec {
            class: StreamLow,
            demand: [0.08, 0.02, 0.05, 0.04],
            pressure: [0.0, 0.01, 0.08, 0.02],
            sensitivity: [0.0, 0.03, 0.10, 0.03],
            perf: PerfModel::streaming(),
            idle_cpu: 0.015,
            quantum: 0.25,
        },
        ClassSpec {
            class: StreamMed,
            demand: [0.18, 0.04, 0.10, 0.06],
            pressure: [0.0, 0.03, 0.15, 0.03],
            sensitivity: [0.0, 0.03, 0.13, 0.03],
            perf: PerfModel::streaming(),
            idle_cpu: 0.015,
            quantum: 0.25,
        },
        ClassSpec {
            class: StreamHigh,
            demand: [0.30, 0.06, 0.16, 0.10],
            pressure: [0.0, 0.04, 0.23, 0.04],
            sensitivity: [0.0, 0.04, 0.15, 0.04],
            perf: PerfModel::streaming(),
            idle_cpu: 0.015,
            quantum: 0.3,
        },
    ]
}

/// Lookup a class spec.
pub fn spec_of(class: WorkloadClass) -> ClassSpec {
    catalog()[class.index()]
}

/// Pairwise interference factor the *simulator* applies to workload `a`
/// when co-located with `b` on the same core: `1 + Σ_r sens_a[r]·press_b[r]`.
/// The profiling phase measures the composite of this and time-sharing into
/// the S matrix — the schedulers only ever see S.
pub fn pair_factor(a: &ClassSpec, b: &ClassSpec) -> f64 {
    let mut extra = 0.0;
    for r in 0..NUM_METRICS {
        extra += a.sensitivity[r] * b.pressure[r];
    }
    1.0 + extra
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_roundtrip() {
        for (i, c) in ALL_CLASSES.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(WorkloadClass::from_index(i), *c);
            assert_eq!(WorkloadClass::from_name(c.name()), Some(*c));
        }
        assert_eq!(WorkloadClass::from_name("nope"), None);
    }

    #[test]
    fn catalog_order_matches_class_index() {
        for (i, spec) in catalog().iter().enumerate() {
            assert_eq!(spec.class.index(), i);
        }
    }

    #[test]
    fn demands_are_sane_fractions() {
        for spec in catalog() {
            for (r, &d) in spec.demand.iter().enumerate() {
                assert!((0.0..=1.0).contains(&d), "{:?} metric {r}: {d}", spec.class);
            }
            assert!(spec.demand[0] > 0.0, "every VM needs some CPU");
            assert!(spec.idle_cpu < 0.025, "idle noise must sit under the 2.5% threshold");
        }
    }

    #[test]
    fn jacobi_is_the_membw_hog() {
        let cat = catalog();
        let jc = &cat[WorkloadClass::Jacobi.index()];
        for spec in &cat {
            if spec.class != WorkloadClass::Jacobi {
                assert!(spec.demand[3] < jc.demand[3]);
            }
        }
    }

    #[test]
    fn pair_factor_bounds() {
        let cat = catalog();
        for a in &cat {
            for b in &cat {
                let f = pair_factor(a, b);
                assert!((1.0..1.5).contains(&f), "{:?}|{:?}: {f}", a.class, b.class);
            }
        }
    }

    #[test]
    fn jacobi_pair_is_worst_microarch_interference() {
        let cat = catalog();
        let jc = &cat[WorkloadClass::Jacobi.index()];
        let worst = pair_factor(jc, jc);
        for a in &cat {
            for b in &cat {
                assert!(pair_factor(a, b) <= worst + 1e-12);
            }
        }
    }
}

//! Per-class performance models.
//!
//! The paper evaluates each benchmark with the metric its users care about
//! (§V-B): run time for batch jobs, requests/s (≈ inverse latency) for the
//! LAMP service, delivered kbps for media streaming. All three reduce to a
//! *normalized performance* in (0, 1]: measured performance relative to the
//! same VM running isolated — exactly the quantity the paper's Figures 2, 3
//! and 6 plot, and whose inverse is the slowdown entering matrix S (Eq. 1).

/// What kind of consumer the workload is — determines both the performance
/// model and how sensitive the class is to time-sharing (latency-critical
/// workloads additionally suffer queueing/scheduling delay, §II discussion
/// of Leverich & Kozyrakis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Runs to completion; performance = T_isolated / T_measured.
    Batch,
    /// Interactive service; performance = latency_isolated / latency.
    LatencyCritical,
    /// Media streaming; performance = delivered kbps / demanded kbps.
    Streaming,
}

/// Performance model parameters for a class.
#[derive(Debug, Clone, Copy)]
pub struct PerfModel {
    pub kind: WorkloadKind,
    /// Batch: total work in seconds-at-full-speed. A VM finishes when its
    /// accumulated progress reaches this.
    pub work_units: f64,
    /// Latency-critical: queueing blow-up exponent γ — latency multiplier is
    /// (1/progress)^γ, super-linear because waiting compounds through the
    /// request queue (M/M/1-flavoured).
    pub latency_gamma: f64,
}

impl PerfModel {
    pub fn batch(work_units: f64) -> Self {
        PerfModel {
            kind: WorkloadKind::Batch,
            work_units,
            latency_gamma: 1.0,
        }
    }

    pub fn latency(gamma: f64) -> Self {
        PerfModel {
            kind: WorkloadKind::LatencyCritical,
            work_units: f64::INFINITY,
            latency_gamma: gamma,
        }
    }

    pub fn streaming() -> Self {
        PerfModel {
            kind: WorkloadKind::Streaming,
            work_units: f64::INFINITY,
            latency_gamma: 1.0,
        }
    }

    /// Instantaneous normalized performance given the progress factor the
    /// host simulator computed for this tick (achieved / demanded rate,
    /// in (0, 1]).
    pub fn tick_performance(&self, progress: f64) -> f64 {
        let p = progress.clamp(1e-6, 1.0);
        match self.kind {
            // A batch job's eventual run-time ratio is the harmonic mean of
            // per-tick progress; per tick the contribution IS the progress.
            WorkloadKind::Batch => p,
            // Latency blows up super-linearly as the service is starved.
            WorkloadKind::LatencyCritical => p.powf(self.latency_gamma),
            // Streaming throughput tracks the achieved service rate.
            WorkloadKind::Streaming => p,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn batch_perf_is_progress() {
        let m = PerfModel::batch(100.0);
        assert!(close(m.tick_performance(0.7), 0.7, 1e-12));
        assert!(close(m.tick_performance(1.0), 1.0, 1e-12));
    }

    #[test]
    fn latency_penalty_superlinear() {
        let m = PerfModel::latency(1.5);
        // Half the CPU -> worse than half the performance.
        assert!(m.tick_performance(0.5) < 0.5);
        // Full CPU -> unit performance.
        assert!(close(m.tick_performance(1.0), 1.0, 1e-12));
    }

    #[test]
    fn latency_monotone_in_progress() {
        let m = PerfModel::latency(1.5);
        let mut last = 0.0;
        for i in 1..=10 {
            let p = m.tick_performance(i as f64 / 10.0);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn progress_is_clamped() {
        let m = PerfModel::streaming();
        assert!(m.tick_performance(2.0) <= 1.0);
        assert!(m.tick_performance(-1.0) > 0.0);
    }
}

//! Arrival processes for scenario generation.
//!
//! The paper's random scenario uses a fixed 30 s inter-arrival time (§V-C.1);
//! the dynamic scenario activates pre-placed VMs in 6- or 12-job batches.
//! A Poisson process is also provided for the extension experiments.

use crate::util::rng::Rng;

/// A stream of arrival times (seconds from scenario start).
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Fixed inter-arrival gap (the paper's 30 s).
    Uniform { gap: f64 },
    /// Poisson arrivals with the given mean gap.
    Poisson { mean_gap: f64 },
    /// Everyone arrives at t = 0 (dynamic scenario placement).
    Immediate,
}

impl ArrivalProcess {
    /// Generate `n` arrival times.
    pub fn times(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match self {
            ArrivalProcess::Uniform { gap } => {
                (0..n).map(|i| i as f64 * gap).collect()
            }
            ArrivalProcess::Poisson { mean_gap } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        let at = t;
                        t += rng.exponential(*mean_gap);
                        at
                    })
                    .collect()
            }
            ArrivalProcess::Immediate => vec![0.0; n],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_thirty_seconds() {
        let mut rng = Rng::new(1);
        let ts = ArrivalProcess::Uniform { gap: 30.0 }.times(4, &mut rng);
        assert_eq!(ts, vec![0.0, 30.0, 60.0, 90.0]);
    }

    #[test]
    fn poisson_monotone_and_mean() {
        let mut rng = Rng::new(2);
        let ts = ArrivalProcess::Poisson { mean_gap: 10.0 }.times(2000, &mut rng);
        for w in ts.windows(2) {
            assert!(w[1] >= w[0]);
        }
        let mean_gap = ts.last().unwrap() / (ts.len() as f64 - 1.0);
        assert!((mean_gap - 10.0).abs() < 1.0, "mean gap {mean_gap}");
    }

    #[test]
    fn immediate_is_all_zero() {
        let mut rng = Rng::new(3);
        assert_eq!(
            ArrivalProcess::Immediate.times(3, &mut rng),
            vec![0.0, 0.0, 0.0]
        );
    }
}

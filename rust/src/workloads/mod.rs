//! The paper's workload classes (§V-B) as demand / performance models.
//!
//! Each class mirrors one of the benchmarks of the paper's evaluation:
//! PARSEC `blackscholes`, Hadoop terasort, PolyBench `jacobi-2d`, the LAMP
//! REST service under a light and a heavy JMeter pattern, and the
//! CloudSuite media-streaming server at three client loads.
//!
//! A class carries:
//! * a **demand vector** over the four monitored metrics (paper §III):
//!   CPU (fraction of one core — VMs have a single vCPU, §V-A), DiskIO and
//!   NetIO (fraction of host capacity), memory bandwidth (fraction of one
//!   socket's capacity);
//! * **pressure / sensitivity vectors** driving pairwise micro-architectural
//!   interference (the phenomenon the paper measures into matrix S — the
//!   scheduler never sees these constants, only the profiled S);
//! * a **performance model**: completion time for batch classes, request
//!   latency for latency-critical classes, delivered throughput for
//!   streaming classes — matching §V-B's metric choice per benchmark.

pub mod arrivals;
pub mod catalog;
pub mod perf;

pub use catalog::{catalog, ClassSpec, WorkloadClass, ALL_CLASSES};
pub use perf::{PerfModel, WorkloadKind};

/// Monitored metrics, in the paper's order (§III: CPU, DiskIO, NetIO,
/// Memory Bandwidth).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Cpu = 0,
    DiskIo = 1,
    NetIo = 2,
    MemBw = 3,
}

/// Number of monitored metrics (paper: M = 4).
pub const NUM_METRICS: usize = 4;

/// A demand/utilisation vector over the monitored metrics.
pub type MetricVec = [f64; NUM_METRICS];

/// Element-wise sum of metric vectors.
pub fn add(a: MetricVec, b: MetricVec) -> MetricVec {
    [a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_order_matches_paper() {
        assert_eq!(Metric::Cpu as usize, 0);
        assert_eq!(Metric::DiskIo as usize, 1);
        assert_eq!(Metric::NetIo as usize, 2);
        assert_eq!(Metric::MemBw as usize, 3);
    }

    #[test]
    fn add_vectors() {
        let a = [0.1, 0.2, 0.3, 0.4];
        let b = [0.4, 0.3, 0.2, 0.1];
        assert_eq!(add(a, b), [0.5, 0.5, 0.5, 0.5]);
    }
}

//! Pure contention arithmetic: CPU proportional sharing, context-switch
//! penalties, capacity throttling, interference composition.
//!
//! Kept as standalone functions so the physics is unit-testable without an
//! engine instance, and so the profiling tests can assert the S matrix's
//! provenance.

/// Proportional-share CPU allocation on one core. `demands[i]` is vCPU i's
/// CPU demand in (0, 1]; returns each vCPU's awarded share. If the core is
/// undersubscribed everyone gets their demand; otherwise shares scale
/// proportionally (CFS-like) against the core's effective capacity.
///
/// `smt_yield` models simultaneous multithreading: with ≥ 2 runnable vCPUs
/// a hyperthreaded core retires more than one thread's worth of work (the
/// paper's Xeon X5650 is 2-way SMT — this is what makes its thr = 120%
/// consolidation threshold cheap in practice). A single vCPU is capped at
/// 1.0 (it runs one thread).
pub fn cpu_shares(demands: &[f64], smt_yield: f64) -> Vec<f64> {
    let total: f64 = demands.iter().sum();
    let capacity = if demands.len() >= 2 { smt_yield.max(1.0) } else { 1.0 };
    if total <= capacity {
        demands.to_vec()
    } else {
        demands.iter().map(|d| d * capacity / total).collect()
    }
}

/// Context-switch progress penalty for a vCPU sharing its core with
/// `co_runners` other *active* vCPUs: factor `1 − κ_eff · co_runners`,
/// floored at 0.5 (a pathological stack of VMs cannot reverse progress).
/// Latency-critical workloads pay `lc_multiplier × κ` — the scheduling
/// delay cost the paper discusses via Leverich & Kozyrakis (§II).
pub fn ctx_penalty(co_runners: usize, kappa: f64, lc: bool, lc_multiplier: f64) -> f64 {
    let k_eff = if lc { kappa * lc_multiplier } else { kappa };
    (1.0 - k_eff * co_runners as f64).max(0.5)
}

/// Capacity throttle for one shared resource: given the total demand and
/// the capacity, the fraction of its demand each consumer achieves.
pub fn capacity_throttle(total_demand: f64, capacity: f64) -> f64 {
    if total_demand <= capacity || total_demand <= 0.0 {
        1.0
    } else {
        capacity / total_demand
    }
}

/// How strongly a throttled resource impacts a particular VM: a VM barely
/// touching the resource is barely affected. `demand` is the VM's own
/// demand on the resource; full exposure above `saturation_demand`.
pub fn throttle_impact(throttle: f64, demand: f64, saturation_demand: f64) -> f64 {
    let exposure = (demand / saturation_demand).min(1.0);
    1.0 - exposure * (1.0 - throttle)
}

/// Compose pairwise interference factors for VM `i` against each same-core
/// co-runner (`full` factors) and each same-socket/other-core neighbour
/// (`coupled` factors scaled by `socket_coupling`). Factors are ≥ 1
/// multipliers on the VM's slowdown, composed multiplicatively.
pub fn interference_slowdown(full: &[f64], coupled: &[f64], socket_coupling: f64) -> f64 {
    let mut slow = 1.0;
    for &f in full {
        slow *= f;
    }
    for &f in coupled {
        // Scale the *excess* over 1.0 by the coupling strength.
        slow *= 1.0 + socket_coupling * (f - 1.0);
    }
    slow
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn shares_undersubscribed_pass_through() {
        assert_eq!(cpu_shares(&[0.3, 0.4], 1.25), vec![0.3, 0.4]);
    }

    #[test]
    fn shares_oversubscribed_proportional_to_smt_capacity() {
        let s = cpu_shares(&[0.9, 0.9], 1.25);
        assert!(close(s[0], 0.625, 1e-12));
        assert!(close(s[1], 0.625, 1e-12));
        let s = cpu_shares(&[1.0, 0.5], 1.25);
        assert!(close(s.iter().sum::<f64>(), 1.25, 1e-12));
        assert!(close(s[0] / s[1], 2.0, 1e-12)); // proportionality kept
    }

    #[test]
    fn single_vcpu_cannot_exceed_one_thread() {
        // SMT capacity only exists with >= 2 runnable vCPUs.
        let s = cpu_shares(&[0.95], 1.25);
        assert_eq!(s, vec![0.95]);
    }

    #[test]
    fn smt_soaks_mild_oversubscription() {
        // Total demand 1.15 < 1.25: nobody is throttled (the paper's
        // thr=120% co-location "without significant degradation").
        let s = cpu_shares(&[0.55, 0.45, 0.15], 1.25);
        assert_eq!(s, vec![0.55, 0.45, 0.15]);
    }

    #[test]
    fn ctx_penalty_scales_with_corunners() {
        assert_eq!(ctx_penalty(0, 0.025, false, 2.0), 1.0);
        assert!(close(ctx_penalty(1, 0.025, false, 2.0), 0.975, 1e-12));
        assert!(close(ctx_penalty(1, 0.025, true, 2.0), 0.95, 1e-12));
        // Floor kicks in for absurd stacking.
        assert_eq!(ctx_penalty(100, 0.025, false, 2.0), 0.5);
    }

    #[test]
    fn throttle_only_over_capacity() {
        assert_eq!(capacity_throttle(0.8, 1.0), 1.0);
        assert!(close(capacity_throttle(2.0, 1.0), 0.5, 1e-12));
        assert_eq!(capacity_throttle(0.0, 1.0), 1.0);
    }

    #[test]
    fn light_users_shrug_off_throttles() {
        // 50% throttle, but the VM uses 1% of the resource.
        let impact = throttle_impact(0.5, 0.01, 0.2);
        assert!(impact > 0.97, "{impact}");
        // A heavy user takes the full hit.
        let impact = throttle_impact(0.5, 0.5, 0.2);
        assert!(close(impact, 0.5, 1e-12));
    }

    #[test]
    fn interference_composes_multiplicatively() {
        let slow = interference_slowdown(&[1.2, 1.1], &[], 0.25);
        assert!(close(slow, 1.32, 1e-12));
    }

    #[test]
    fn socket_coupling_attenuates() {
        // Same factor via socket coupling at 0.25 strength: 1 + 0.25*0.2.
        let slow = interference_slowdown(&[], &[1.2], 0.25);
        assert!(close(slow, 1.05, 1e-12));
        // No coupling -> no effect.
        assert!(close(interference_slowdown(&[], &[1.5], 0.0), 1.0, 1e-12));
    }
}

//! Discrete-event simulator of the paper's testbed.
//!
//! Substitutes (DESIGN.md §2) for the hardware the paper measured on — a
//! 2-socket / 12-core Xeon X5650 host running single-vCPU KVM VMs — which
//! is not available here. The simulator reproduces exactly the interface
//! VMCd observes and manipulates:
//!
//! * the **control surface** ([`Hypervisor`]): list domains, read per-domain
//!   stats (CPU / DiskIO / NetIO plus the perf-counter-derived memory
//!   bandwidth of Table I), pin vCPUs — mirroring the libvirt + perf calls
//!   of the paper's VM Monitor and VM Actuator;
//! * the **contention physics** that make scheduling decisions matter:
//!   proportional-share CPU time-slicing with context-switch overhead,
//!   per-socket memory-bandwidth capacity, host-wide disk/network capacity,
//!   and pairwise micro-architectural interference (what the offline
//!   profiling phase measures into matrix S).
//!
//! The engine advances in fixed virtual-time ticks (default 1 s). Nothing
//! here depends on wall-clock time; every run is deterministic given the
//! config seed.

pub mod contention;
pub mod counters;
pub mod engine;
pub mod faults;
pub mod hypervisor;
pub mod vm;

pub use engine::SimEngine;
pub use faults::FlakyHypervisor;
pub use hypervisor::{DomainStats, Hypervisor};
pub use vm::{ActivityModel, Vm, VmId, VmState};

pub use crate::config::HostSpec;

/// Convenience alias: the simulated host is just the engine.
pub type Host = SimEngine;

//! Failure injection: a hypervisor wrapper that makes a configurable
//! fraction of control-plane calls fail, for robustness testing.
//!
//! Real libvirt calls fail transiently (domain busy, timeout, migration in
//! progress); VMCd must tolerate that without aborting its scheduling
//! cycle or corrupting its placement bookkeeping.

use super::hypervisor::{DomainStats, Hypervisor};
use super::vm::VmId;
use crate::config::HostSpec;
use crate::util::rng::Rng;
use anyhow::Result;

/// Wraps a hypervisor; every `pin_vcpu` fails with probability
/// `pin_failure_prob` (deterministic per seed).
pub struct FlakyHypervisor<H: Hypervisor> {
    pub inner: H,
    pub pin_failure_prob: f64,
    rng: Rng,
    pub injected_failures: u64,
}

impl<H: Hypervisor> FlakyHypervisor<H> {
    pub fn new(inner: H, pin_failure_prob: f64, seed: u64) -> Self {
        FlakyHypervisor {
            inner,
            pin_failure_prob,
            rng: Rng::new(seed ^ 0xF1A4),
            injected_failures: 0,
        }
    }
}

impl<H: Hypervisor> Hypervisor for FlakyHypervisor<H> {
    fn now(&self) -> f64 {
        self.inner.now()
    }

    fn host_spec(&self) -> &HostSpec {
        self.inner.host_spec()
    }

    fn list_domains(&self) -> Vec<VmId> {
        self.inner.list_domains()
    }

    fn domain_stats(&self, id: VmId) -> Option<DomainStats> {
        self.inner.domain_stats(id)
    }

    fn pin_vcpu(&mut self, id: VmId, core: usize) -> Result<()> {
        if self.rng.chance(self.pin_failure_prob) {
            self.injected_failures += 1;
            anyhow::bail!("injected transient failure pinning {id:?} -> core {core}");
        }
        self.inner.pin_vcpu(id, core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::testkit;
    use crate::vmcd::scheduler::{self, Policy};
    use crate::vmcd::Daemon;
    use crate::workloads::WorkloadClass;

    fn engine(n: u32) -> SimEngine {
        let cfg = testkit::quiet_config();
        let vms = (0..n)
            .map(|i| {
                let class = if i % 2 == 0 {
                    WorkloadClass::Blackscholes
                } else {
                    WorkloadClass::LampLight
                };
                let mut vm = Vm::new(VmId(i), class, 0.0, ActivityModel::AlwaysOn);
                vm.state = VmState::Running;
                vm.started = Some(0.0);
                vm.pinned = Some(i as usize % 12);
                vm
            })
            .collect();
        SimEngine::new(cfg, vms)
    }

    #[test]
    fn injects_the_requested_failure_rate() {
        let mut flaky = FlakyHypervisor::new(engine(1), 0.5, 7);
        let mut fails = 0;
        for i in 0..200 {
            if flaky.pin_vcpu(VmId(0), i % 12).is_err() {
                fails += 1;
            }
        }
        assert!((60..140).contains(&fails), "{fails}");
        assert_eq!(flaky.injected_failures, fails);
    }

    #[test]
    fn daemon_survives_flaky_actuation() {
        // 30% of pins fail; the daemon must keep cycling, never abort, and
        // the host must keep making progress.
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
        let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        let mut flaky = FlakyHypervisor::new(engine(8), 0.3, 11);

        for _ in 0..200 {
            daemon.maybe_cycle(&mut flaky).unwrap(); // must never Err
            flaky.inner.step();
        }
        assert!(daemon.cycles >= 6);
        assert!(daemon.pin_failures > 0, "no failures were exercised");
        assert!(flaky.injected_failures > 0);
        // Work still progressed on every batch VM.
        for vm in &flaky.inner.vms {
            if vm.class == WorkloadClass::Blackscholes {
                assert!(
                    vm.work_done > 0.0 || vm.state == VmState::Finished,
                    "{:?} starved",
                    vm.id
                );
            }
        }
    }

    #[test]
    fn zero_probability_is_transparent() {
        let mut flaky = FlakyHypervisor::new(engine(2), 0.0, 3);
        for i in 0..50 {
            flaky.pin_vcpu(VmId(0), i % 12).unwrap();
        }
        assert_eq!(flaky.injected_failures, 0);
    }
}

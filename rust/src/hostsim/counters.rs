//! Synthetic hardware performance counters — the Table I substitution.
//!
//! The paper measures per-socket memory bandwidth with the uncore events
//! `UNC_QMC_NORMAL_READS`, `UNC_QMC_NORMAL_WRITES` and `OFFCORE_RESPONSE`
//! (requests serviced by DRAM), following A-DRM [4]. The real counters are
//! unavailable here, so the simulator *synthesises* them from its
//! memory-bandwidth ledger at the same granularity, and the VM Monitor
//! *inverts* them back to a bandwidth fraction exactly the way the paper's
//! monitor does — keeping the full counter → bandwidth code path honest.

/// Cache line size in bytes (the unit of a QMC read/write event).
pub const CACHE_LINE: f64 = 64.0;

/// Peak DRAM bandwidth per socket in bytes/s used for counter synthesis.
/// (X5650: 3 × DDR3-1333 channels ≈ 32 GB/s; the absolute value only needs
/// to be consistent between synthesis and inversion.)
pub const SOCKET_BW_BYTES: f64 = 32.0e9;

/// Fraction of DRAM traffic that is reads (typical 2:1 read:write mix).
pub const READ_FRACTION: f64 = 2.0 / 3.0;

/// Raw counter snapshot for one VM.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PerfCounters {
    /// UNC_QMC_NORMAL_READS — memory read events.
    pub mem_reads: u64,
    /// UNC_QMC_NORMAL_WRITES — memory write events.
    pub mem_writes: u64,
    /// OFFCORE_RESPONSE — requests serviced by DRAM.
    pub offcore: u64,
}

/// Synthesise counter *increments* for one tick given the membw fraction
/// (of socket capacity) actually consumed.
pub fn synthesize(membw_fraction: f64, dt: f64) -> PerfCounters {
    let bytes = membw_fraction.max(0.0) * SOCKET_BW_BYTES * dt;
    let lines = bytes / CACHE_LINE;
    let reads = (lines * READ_FRACTION) as u64;
    let writes = (lines * (1.0 - READ_FRACTION)) as u64;
    PerfCounters {
        mem_reads: reads,
        mem_writes: writes,
        // OFFCORE_RESPONSE counts DRAM-serviced requests — reads dominate.
        offcore: reads + writes / 2,
    }
}

/// Invert counters to a bandwidth fraction — what the VM Monitor computes
/// per VM (paper §III, following [4]).
pub fn bandwidth_fraction(delta: PerfCounters, dt: f64) -> f64 {
    if dt <= 0.0 {
        return 0.0;
    }
    let bytes = (delta.mem_reads + delta.mem_writes) as f64 * CACHE_LINE;
    bytes / (SOCKET_BW_BYTES * dt)
}

impl PerfCounters {
    pub fn add(&mut self, inc: PerfCounters) {
        self.mem_reads += inc.mem_reads;
        self.mem_writes += inc.mem_writes;
        self.offcore += inc.offcore;
    }

    pub fn delta_since(&self, earlier: PerfCounters) -> PerfCounters {
        PerfCounters {
            mem_reads: self.mem_reads - earlier.mem_reads,
            mem_writes: self.mem_writes - earlier.mem_writes,
            offcore: self.offcore - earlier.offcore,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_synthesis_inversion() {
        for frac in [0.0, 0.1, 0.55, 1.0] {
            let c = synthesize(frac, 1.0);
            let back = bandwidth_fraction(c, 1.0);
            assert!(
                (back - frac).abs() < 1e-6,
                "frac {frac} came back as {back}"
            );
        }
    }

    #[test]
    fn read_write_mix() {
        let c = synthesize(0.5, 1.0);
        let ratio = c.mem_reads as f64 / (c.mem_writes as f64);
        assert!((ratio - 2.0).abs() < 0.01, "read:write {ratio}");
        assert!(c.offcore > 0);
    }

    #[test]
    fn accumulate_and_delta() {
        let mut total = PerfCounters::default();
        let before = total;
        total.add(synthesize(0.3, 1.0));
        total.add(synthesize(0.3, 1.0));
        let delta = total.delta_since(before);
        let bw = bandwidth_fraction(delta, 2.0);
        assert!((bw - 0.3).abs() < 1e-6, "bw {bw}");
    }

    #[test]
    fn zero_dt_guard() {
        assert_eq!(bandwidth_fraction(synthesize(0.5, 1.0), 0.0), 0.0);
    }
}

//! The hypervisor control surface — the simulated stand-in for libvirt.
//!
//! VMCd (the monitor, actuator and schedulers) is written entirely against
//! this trait, mirroring the libvirt API calls the paper's daemon makes
//! (§III): domain enumeration, per-domain resource statistics (plus the
//! perf-counter window for memory bandwidth, Table I), and vCPU pinning.
//! `SimEngine` implements it; a real libvirt binding could implement it
//! identically.

use super::counters::PerfCounters;
use super::vm::VmId;
use crate::config::HostSpec;
use crate::workloads::{MetricVec, WorkloadClass};
use anyhow::Result;

/// Per-domain statistics as the monitor sees them.
#[derive(Debug, Clone)]
pub struct DomainStats {
    pub id: VmId,
    /// The workload tag the user supplied (paper §IV-A: workloads are
    /// tagged with their profile class; tagging is external to VMCd).
    pub class: WorkloadClass,
    pub pinned: Option<usize>,
    /// Mean CPU usage over the monitoring window — the idle-detection
    /// input (< 2.5% ⇒ idle).
    pub cpu_window_avg: f64,
    /// Instantaneous measured utilisation [CPU, DiskIO, NetIO, MemBW].
    /// The MemBW entry is *derived from the counters* by the monitor, not
    /// read directly (see `counters`).
    pub util: MetricVec,
    /// Cumulative perf counters for this domain.
    pub counters: PerfCounters,
    pub running: bool,
}

/// The control surface VMCd drives.
pub trait Hypervisor {
    /// Current host time (seconds).
    fn now(&self) -> f64;

    /// The physical host description.
    fn host_spec(&self) -> &HostSpec;

    /// Enumerate resident (arrived, unfinished) domains.
    fn list_domains(&self) -> Vec<VmId>;

    /// Statistics for one domain; `None` if it does not exist or has left.
    fn domain_stats(&self, id: VmId) -> Option<DomainStats>;

    /// Pin a domain's vCPU to a physical core.
    fn pin_vcpu(&mut self, id: VmId, core: usize) -> Result<()>;
}

//! Simulated virtual machines: lifecycle, activity phases, accounting.

use crate::workloads::{catalog::spec_of, ClassSpec, WorkloadClass, WorkloadKind};

/// Opaque VM identifier (stable across the run).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VmId(pub u32);

/// Lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VmState {
    /// Scheduled to arrive later (random scenario: 30 s inter-arrival).
    NotArrived,
    /// Resident on the host.
    Running,
    /// Batch job completed; no longer consumes resources.
    Finished,
}

/// Activity phases — drives the idle/running distinction the paper's
/// dynamic scenario exercises (§V-C.3: VMs "become active in 12- or 6-job
/// batches"; §III: idle = CPU below 2.5% over the last window).
#[derive(Debug, Clone)]
pub enum ActivityModel {
    /// Active from arrival to completion / scenario end.
    AlwaysOn,
    /// Periodic duty cycle (web service with busy/quiet periods).
    OnOff { period: f64, duty: f64, phase: f64 },
    /// Explicit active windows `(start, end)` in scenario time — used by
    /// the dynamic scenario's activation batches.
    Windows(Vec<(f64, f64)>),
}

impl ActivityModel {
    /// Is the workload in an active phase at time `t`?
    pub fn is_active(&self, t: f64) -> bool {
        match self {
            ActivityModel::AlwaysOn => true,
            ActivityModel::OnOff { period, duty, phase } => {
                let pos = (t + phase).rem_euclid(*period) / period;
                pos < *duty
            }
            ActivityModel::Windows(ws) => ws.iter().any(|&(s, e)| t >= s && t < e),
        }
    }
}

/// A simulated single-vCPU VM (the paper assumes one virtual core per VM,
/// §V-A).
#[derive(Debug, Clone)]
pub struct Vm {
    pub id: VmId,
    pub class: WorkloadClass,
    pub spec: ClassSpec,
    pub arrival: f64,
    pub activity: ActivityModel,
    pub state: VmState,
    /// Physical core this VM's vCPU is pinned on (None until first placed).
    pub pinned: Option<usize>,
    /// Migration stop-and-copy window: the VM makes no progress while
    /// `t < paused_until` (cluster layer).
    pub paused_until: f64,

    // ---- progress / performance accounting ----
    /// Batch: accumulated work (seconds at full speed).
    pub work_done: f64,
    pub started: Option<f64>,
    /// First instant the workload was actually active (batch jobs in the
    /// dynamic scenario are placed early but activate later; performance
    /// is measured from activation).
    pub work_started: Option<f64>,
    pub finished: Option<f64>,
    /// Service classes: sum of per-tick normalized performance samples
    /// (active ticks only).
    pub perf_sum: f64,
    pub perf_ticks: u64,
    /// CPU seconds actually consumed.
    pub cpu_seconds: f64,

    // ---- monitoring window (for the 2.5% idle detection) ----
    recent_cpu: Vec<f64>,
    recent_pos: usize,
    recent_len: usize,

    // ---- synthetic perf counters (Table I substitution) ----
    pub ctr_mem_reads: u64,
    pub ctr_mem_writes: u64,
    pub ctr_offcore: u64,

    /// Last tick's measured utilisation (what the hypervisor reports).
    pub last_util: [f64; 4],
}

impl Vm {
    pub fn new(id: VmId, class: WorkloadClass, arrival: f64, activity: ActivityModel) -> Vm {
        Vm {
            id,
            class,
            spec: spec_of(class),
            arrival,
            activity,
            state: VmState::NotArrived,
            pinned: None,
            paused_until: 0.0,
            work_done: 0.0,
            started: None,
            work_started: None,
            finished: None,
            perf_sum: 0.0,
            perf_ticks: 0,
            cpu_seconds: 0.0,
            recent_cpu: Vec::new(),
            recent_pos: 0,
            recent_len: 0,
            ctr_mem_reads: 0,
            ctr_mem_writes: 0,
            ctr_offcore: 0,
            last_util: [0.0; 4],
        }
    }

    /// Is the VM demanding resources at time `t`? Batch jobs are active
    /// until complete; services follow their activity model.
    pub fn is_active(&self, t: f64) -> bool {
        if self.state != VmState::Running {
            return false;
        }
        if t < self.paused_until {
            return false;
        }
        match self.spec.perf.kind {
            // Batch jobs additionally respect their activation window (the
            // dynamic scenario places VMs early and activates them in
            // batches, §V-C.3).
            WorkloadKind::Batch => {
                self.work_done < self.spec.perf.work_units && self.activity.is_active(t)
            }
            _ => self.activity.is_active(t),
        }
    }

    /// Record this tick's CPU usage into the monitoring ring buffer.
    pub fn record_cpu(&mut self, usage: f64, window_ticks: usize) {
        if self.recent_cpu.len() != window_ticks {
            self.recent_cpu.resize(window_ticks, usage);
            self.recent_pos = 0;
            self.recent_len = self.recent_cpu.len().min(self.recent_len.max(1));
        }
        self.recent_cpu[self.recent_pos] = usage;
        self.recent_pos = (self.recent_pos + 1) % window_ticks;
        self.recent_len = (self.recent_len + 1).min(window_ticks);
    }

    /// Average CPU usage over the monitoring window — the quantity the
    /// paper's idle detection compares against 2.5%.
    pub fn cpu_window_avg(&self) -> f64 {
        if self.recent_len == 0 {
            return 0.0;
        }
        self.recent_cpu.iter().take(self.recent_len).sum::<f64>() / self.recent_len as f64
    }

    /// Final normalized performance of the VM (1.0 = isolated speed).
    pub fn normalized_perf(&self) -> Option<f64> {
        match self.spec.perf.kind {
            WorkloadKind::Batch => {
                let end = self.finished?;
                let start = self.work_started.or(self.started)?;
                let elapsed = end - start;
                if elapsed <= 0.0 {
                    return None;
                }
                Some((self.spec.perf.work_units / elapsed).min(1.0))
            }
            _ => {
                if self.perf_ticks == 0 {
                    return None;
                }
                Some(self.perf_sum / self.perf_ticks as f64)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mkvm(class: WorkloadClass) -> Vm {
        Vm::new(VmId(0), class, 0.0, ActivityModel::AlwaysOn)
    }

    #[test]
    fn onoff_duty_cycle() {
        let m = ActivityModel::OnOff {
            period: 100.0,
            duty: 0.3,
            phase: 0.0,
        };
        assert!(m.is_active(0.0));
        assert!(m.is_active(29.9));
        assert!(!m.is_active(30.1));
        assert!(!m.is_active(99.0));
        assert!(m.is_active(100.5)); // wraps
    }

    #[test]
    fn windows_model() {
        let m = ActivityModel::Windows(vec![(10.0, 20.0), (50.0, 60.0)]);
        assert!(!m.is_active(5.0));
        assert!(m.is_active(15.0));
        assert!(!m.is_active(30.0));
        assert!(m.is_active(55.0));
        assert!(!m.is_active(60.0)); // end-exclusive
    }

    #[test]
    fn batch_active_until_work_done() {
        let mut vm = mkvm(WorkloadClass::Blackscholes);
        vm.state = VmState::Running;
        assert!(vm.is_active(0.0));
        vm.work_done = vm.spec.perf.work_units;
        assert!(!vm.is_active(0.0));
    }

    #[test]
    fn not_arrived_is_inactive() {
        let vm = mkvm(WorkloadClass::LampLight);
        assert_eq!(vm.state, VmState::NotArrived);
        assert!(!vm.is_active(0.0));
    }

    #[test]
    fn cpu_window_average() {
        let mut vm = mkvm(WorkloadClass::LampLight);
        for _ in 0..5 {
            vm.record_cpu(0.10, 10);
        }
        assert!((vm.cpu_window_avg() - 0.10).abs() < 1e-12);
        for _ in 0..10 {
            vm.record_cpu(0.02, 10);
        }
        // Window fully refreshed with idle samples.
        assert!(vm.cpu_window_avg() < 0.025);
    }

    #[test]
    fn batch_normalized_perf_from_times() {
        let mut vm = mkvm(WorkloadClass::Blackscholes);
        vm.started = Some(0.0);
        vm.work_started = Some(0.0);
        vm.finished = Some(vm.spec.perf.work_units * 2.0); // ran at half speed
        let perf = vm.normalized_perf().unwrap();
        assert!((perf - 0.5).abs() < 1e-9);
    }

    #[test]
    fn service_normalized_perf_is_sample_mean() {
        let mut vm = mkvm(WorkloadClass::LampHeavy);
        vm.perf_sum = 4.5;
        vm.perf_ticks = 5;
        assert!((vm.normalized_perf().unwrap() - 0.9).abs() < 1e-12);
    }
}

//! The simulation engine: advances virtual time, applies the contention
//! physics, accounts CPU time / energy / performance, and exposes the
//! [`Hypervisor`] control surface to VMCd.

use super::contention::{
    capacity_throttle, cpu_shares, ctx_penalty, throttle_impact,
};
use super::counters::{self, PerfCounters};
use super::hypervisor::{DomainStats, Hypervisor};
use super::vm::{Vm, VmId, VmState};
use crate::config::Config;
use crate::metrics::Ledger;
use crate::util::rng::Rng;
use crate::workloads::catalog::pair_factor;
use crate::workloads::{WorkloadKind, NUM_METRICS};
use anyhow::Result;

/// Demand level above which a VM is considered fully exposed to a
/// throttled shared resource (see `contention::throttle_impact`).
const SATURATION_DEMAND: f64 = 0.2;

/// Background CPU share threshold deciding whether a pinned VM keeps its
/// core busy (unparked). Idle VMs' background noise (1–2%) exceeds this, so
/// a core holding only idle VMs is still powered — which is exactly why the
/// schedulers consolidate idle VMs onto core 0.
const BUSY_CPU_FLOOR: f64 = 0.005;

/// The simulated host.
pub struct SimEngine {
    pub cfg: Config,
    pub vms: Vec<Vm>,
    /// Virtual time, seconds.
    pub t: f64,
    pub ledger: Ledger,
    /// Extra host-wide NetIO demand injected by external activity (live
    /// migrations in the cluster layer).
    pub external_net_load: f64,
    rng: Rng,
    /// Ticks per monitoring window (idle detection).
    window_ticks: usize,
}

impl SimEngine {
    pub fn new(cfg: Config, vms: Vec<Vm>) -> SimEngine {
        let window_ticks = (cfg.sched.monitor_window / cfg.sim.dt).round().max(1.0) as usize;
        let rng = Rng::new(cfg.sim.seed ^ 0xE6E6_5146_1A5C_0FFA);
        SimEngine {
            cfg,
            vms,
            t: 0.0,
            ledger: Ledger::new(),
            external_net_load: 0.0,
            rng,
            window_ticks,
        }
    }

    /// Remove a VM (cluster live migration). Returns the VM state intact.
    pub fn remove_vm(&mut self, id: VmId) -> Option<Vm> {
        let idx = self.idx(id)?;
        Some(self.vms.remove(idx))
    }

    /// Insert a VM arriving from another host (cluster live migration).
    pub fn insert_vm(&mut self, vm: Vm) {
        debug_assert!(
            self.idx(vm.id).is_none(),
            "duplicate VmId {:?} on host",
            vm.id
        );
        self.vms.push(vm);
    }

    /// Index of a VM by id.
    fn idx(&self, id: VmId) -> Option<usize> {
        self.vms.iter().position(|vm| vm.id == id)
    }

    /// VMs that arrived at or before `t` and become resident now. Returns
    /// the newly-arrived ids (the driver hands them to the daemon for
    /// initial placement).
    pub fn process_arrivals(&mut self) -> Vec<VmId> {
        let t = self.t;
        let mut arrived = Vec::new();
        for vm in &mut self.vms {
            if vm.state == VmState::NotArrived && vm.arrival <= t {
                vm.state = VmState::Running;
                vm.started = Some(t);
                arrived.push(vm.id);
            }
        }
        arrived
    }

    /// All batch jobs finished?
    pub fn all_batch_done(&self) -> bool {
        self.vms.iter().all(|vm| {
            vm.spec.perf.kind != WorkloadKind::Batch || vm.state == VmState::Finished
        })
    }

    /// Any VM not yet arrived?
    pub fn arrivals_pending(&self) -> bool {
        self.vms.iter().any(|vm| vm.state == VmState::NotArrived)
    }

    /// Advance one tick: apply contention, progress workloads, account.
    pub fn step(&mut self) {
        let dt = self.cfg.sim.dt;
        let cores = self.cfg.host.cores;
        let noise = self.cfg.sim.demand_noise;

        // ---- gather per-core active sets and their noisy demands ----
        // (indices into self.vms)
        let mut core_active: Vec<Vec<usize>> = vec![Vec::new(); cores];
        let mut core_has_resident: Vec<bool> = vec![false; cores];
        let mut demands: Vec<[f64; NUM_METRICS]> = vec![[0.0; NUM_METRICS]; self.vms.len()];
        let mut active_flags = vec![false; self.vms.len()];

        for i in 0..self.vms.len() {
            let vm = &self.vms[i];
            if vm.state != VmState::Running {
                continue;
            }
            let Some(core) = vm.pinned else { continue };
            if core >= cores {
                continue;
            }
            core_has_resident[core] = true;
            let active = vm.is_active(self.t);
            active_flags[i] = active;
            if active {
                let mut d = vm.spec.demand;
                if noise > 0.0 {
                    for slot in d.iter_mut() {
                        if *slot > 0.0 {
                            let jitter = self.rng.normal_with(1.0, noise);
                            *slot = (*slot * jitter).clamp(0.0, 1.0);
                        }
                    }
                }
                demands[i] = d;
                core_active[core].push(i);
            }
        }

        // ---- CPU shares per core ----
        let mut share = vec![0.0f64; self.vms.len()];
        for members in core_active.iter() {
            if members.is_empty() {
                continue;
            }
            let d: Vec<f64> = members.iter().map(|&i| demands[i][0]).collect();
            let s = cpu_shares(&d, self.cfg.host.smt_yield);
            for (pos, &i) in members.iter().enumerate() {
                share[i] = s[pos];
            }
        }

        // ---- shared-resource totals and throttles ----
        let sockets = self.cfg.host.sockets;
        let mut socket_membw = vec![0.0f64; sockets];
        let mut disk_total = 0.0;
        let mut net_total = 0.0;
        for (core, members) in core_active.iter().enumerate() {
            let sk = self.cfg.host.socket_of(core);
            for &i in members {
                // I/O and membw track the share of CPU the VM actually got
                // (a starved VM issues fewer requests).
                let cpu_ratio = if demands[i][0] > 0.0 {
                    (share[i] / demands[i][0]).min(1.0)
                } else {
                    1.0
                };
                disk_total += demands[i][1] * cpu_ratio;
                net_total += demands[i][2] * cpu_ratio;
                socket_membw[sk] += demands[i][3] * cpu_ratio;
            }
        }
        let f_disk = capacity_throttle(disk_total, self.cfg.host.disk_capacity);
        let f_net = capacity_throttle(
            net_total + self.external_net_load,
            self.cfg.host.net_capacity,
        );
        let f_mem: Vec<f64> = socket_membw
            .iter()
            .map(|&d| capacity_throttle(d, self.cfg.host.membw_per_socket))
            .collect();

        // ---- per-VM progress ----
        let kappa = self.cfg.host.ctx_switch_overhead;
        let lc_mult = self.cfg.host.lc_ctx_multiplier;
        let coupling = self.cfg.host.socket_coupling;
        let mut progress = vec![0.0f64; self.vms.len()];
        let mut membw_used = vec![0.0f64; self.vms.len()];

        for (core, members) in core_active.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let sk = self.cfg.host.socket_of(core);
            for &i in members {
                let vm = &self.vms[i];
                let lc = vm.spec.perf.kind == WorkloadKind::LatencyCritical;
                let co = members.len() - 1;
                let ctx = ctx_penalty(co, kappa, lc, lc_mult);

                // Scheduling delay for latency-critical VMs: requests queue
                // behind co-runner bursts (Leverich & Kozyrakis, §II).
                // Long-quantum co-runners (batch hogs) hurt far more than
                // quickly-yielding services — weight co-runner CPU by the
                // class's scheduling-quantum length.
                let sched_delay = if lc {
                    let co_pressure: f64 = members
                        .iter()
                        .filter(|&&j| j != i)
                        .map(|&j| demands[j][0] * self.vms[j].spec.quantum)
                        .sum();
                    1.0 / (1.0 + self.cfg.host.lc_sched_delay * co_pressure)
                } else {
                    1.0
                };

                // Pairwise interference, composed inline (hot path: no
                // per-VM allocation). Same-core co-runners at full
                // strength; same-socket neighbours attenuated by the LLC
                // coupling factor — semantics identical to
                // `contention::interference_slowdown`.
                let mut interf = 1.0;
                for &j in members {
                    if j != i {
                        interf *= pair_factor(&vm.spec, &self.vms[j].spec);
                    }
                }
                for (c2, m2) in core_active.iter().enumerate() {
                    if c2 == core || self.cfg.host.socket_of(c2) != sk {
                        continue;
                    }
                    for &j in m2 {
                        let pf = pair_factor(&vm.spec, &self.vms[j].spec);
                        interf *= 1.0 + coupling * (pf - 1.0);
                    }
                }

                let cpu_ratio = if demands[i][0] > 0.0 {
                    (share[i] / demands[i][0]).min(1.0)
                } else {
                    1.0
                };
                let t_disk = throttle_impact(f_disk, demands[i][1], SATURATION_DEMAND);
                let t_net = throttle_impact(f_net, demands[i][2], SATURATION_DEMAND);
                let t_mem = throttle_impact(f_mem[sk], demands[i][3], SATURATION_DEMAND);
                let io_factor = t_disk.min(t_net).min(t_mem);

                let p = (cpu_ratio * ctx * sched_delay * io_factor / interf).clamp(0.0, 1.0);
                progress[i] = p;
                membw_used[i] = demands[i][3] * cpu_ratio * f_mem[sk];
            }
        }

        // ---- apply progress, accounting, counters ----
        let window_ticks = self.window_ticks;
        let t_now = self.t;
        for i in 0..self.vms.len() {
            let idle_cpu = self.vms[i].spec.idle_cpu;
            let vm = &mut self.vms[i];
            if vm.state != VmState::Running {
                continue;
            }
            let active = active_flags[i];
            let cpu_used = if active { share[i] } else { idle_cpu };
            vm.record_cpu(cpu_used, window_ticks);
            vm.cpu_seconds += cpu_used * dt;
            vm.last_util = if active {
                [
                    cpu_used,
                    demands[i][1],
                    demands[i][2],
                    membw_used[i],
                ]
            } else {
                [idle_cpu, 0.0, 0.0, 0.0]
            };

            let inc = counters::synthesize(vm.last_util[3], dt);
            vm.ctr_mem_reads += inc.mem_reads;
            vm.ctr_mem_writes += inc.mem_writes;
            vm.ctr_offcore += inc.offcore;

            if !active {
                continue;
            }
            match vm.spec.perf.kind {
                WorkloadKind::Batch => {
                    if vm.work_started.is_none() {
                        vm.work_started = Some(t_now);
                    }
                    vm.work_done += progress[i] * dt;
                    if vm.work_done >= vm.spec.perf.work_units {
                        vm.state = VmState::Finished;
                        vm.finished = Some(t_now + dt);
                    }
                }
                _ => {
                    vm.perf_sum += vm.spec.perf.tick_performance(progress[i]);
                    vm.perf_ticks += 1;
                }
            }
        }

        // ---- busy-core accounting (the CPU-time-consumed metric) ----
        let mut busy = 0usize;
        for core in 0..cores {
            let has_loaded_vm = self.vms.iter().any(|vm| {
                vm.state == VmState::Running
                    && vm.pinned == Some(core)
                    && (if vm.is_active(t_now) {
                        true
                    } else {
                        vm.spec.idle_cpu > BUSY_CPU_FLOOR
                    })
            });
            if has_loaded_vm && core_has_resident[core] {
                busy += 1;
            }
        }
        self.ledger.record_tick(t_now, busy, dt, &self.cfg.host);

        self.t += dt;
    }

    /// Snapshot of currently-busy core count (for tests).
    pub fn busy_cores(&self) -> usize {
        let cores = self.cfg.host.cores;
        (0..cores)
            .filter(|&core| {
                self.vms.iter().any(|vm| {
                    vm.state == VmState::Running && vm.pinned == Some(core)
                })
            })
            .count()
    }
}

impl Hypervisor for SimEngine {
    fn now(&self) -> f64 {
        self.t
    }

    fn host_spec(&self) -> &crate::config::HostSpec {
        &self.cfg.host
    }

    fn list_domains(&self) -> Vec<VmId> {
        self.vms
            .iter()
            .filter(|vm| vm.state == VmState::Running)
            .map(|vm| vm.id)
            .collect()
    }

    fn domain_stats(&self, id: VmId) -> Option<DomainStats> {
        let vm = self.vms.iter().find(|vm| vm.id == id)?;
        if vm.state != VmState::Running {
            return None;
        }
        Some(DomainStats {
            id: vm.id,
            class: vm.class,
            pinned: vm.pinned,
            cpu_window_avg: vm.cpu_window_avg(),
            util: vm.last_util,
            counters: PerfCounters {
                mem_reads: vm.ctr_mem_reads,
                mem_writes: vm.ctr_mem_writes,
                offcore: vm.ctr_offcore,
            },
            running: true,
        })
    }

    fn pin_vcpu(&mut self, id: VmId, core: usize) -> Result<()> {
        anyhow::ensure!(
            core < self.cfg.host.cores,
            "core {core} out of range (host has {})",
            self.cfg.host.cores
        );
        let idx = self
            .idx(id)
            .ok_or_else(|| anyhow::anyhow!("unknown vm {id:?}"))?;
        anyhow::ensure!(
            self.vms[idx].state == VmState::Running,
            "vm {id:?} is not resident"
        );
        if self.vms[idx].pinned != Some(core) {
            self.vms[idx].pinned = Some(core);
            self.ledger.repin_count += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::vm::ActivityModel;
    use crate::workloads::WorkloadClass;

    fn quiet_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        cfg
    }

    fn running_vm(id: u32, class: WorkloadClass, core: usize) -> Vm {
        let mut vm = Vm::new(VmId(id), class, 0.0, ActivityModel::AlwaysOn);
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm.pinned = Some(core);
        vm
    }

    #[test]
    fn isolated_batch_runs_at_full_speed() {
        let cfg = quiet_cfg();
        let vm = running_vm(0, WorkloadClass::Blackscholes, 0);
        let work = vm.spec.perf.work_units;
        let mut eng = SimEngine::new(cfg, vec![vm]);
        let mut steps = 0;
        while eng.vms[0].state == VmState::Running && steps < 10_000 {
            eng.step();
            steps += 1;
        }
        assert_eq!(eng.vms[0].state, VmState::Finished);
        let perf = eng.vms[0].normalized_perf().unwrap();
        assert!(perf > 0.99, "isolated perf {perf}");
        assert!((eng.vms[0].finished.unwrap() - work).abs() <= 2.0);
    }

    #[test]
    fn copinned_cpu_hogs_halve() {
        let cfg = quiet_cfg();
        let a = running_vm(0, WorkloadClass::Blackscholes, 3);
        let b = running_vm(1, WorkloadClass::Blackscholes, 3);
        let mut eng = SimEngine::new(cfg, vec![a, b]);
        for _ in 0..10 {
            eng.step();
        }
        // Two 0.95-demand VMs share one SMT core: each progresses at
        // ~1.25/1.9 ≈ 0.66 (2-way SMT soaks part of the oversubscription).
        let p0 = eng.vms[0].work_done / eng.t;
        assert!(p0 < 0.70, "progress {p0}");
        assert!(p0 > 0.55, "progress {p0}");
    }

    #[test]
    fn separate_cores_no_cpu_contention() {
        let cfg = quiet_cfg();
        let a = running_vm(0, WorkloadClass::Blackscholes, 0);
        let b = running_vm(1, WorkloadClass::Blackscholes, 1);
        let mut eng = SimEngine::new(cfg, vec![a, b]);
        for _ in 0..10 {
            eng.step();
        }
        let p0 = eng.vms[0].work_done / eng.t;
        assert!(p0 > 0.95, "progress {p0}");
    }

    #[test]
    fn jacobi_pair_same_socket_interferes_more_than_cross_socket() {
        let cfg = quiet_cfg();
        // Same socket (cores 0,1) vs cross socket (cores 0,6).
        let mut same = SimEngine::new(
            cfg.clone(),
            vec![
                running_vm(0, WorkloadClass::Jacobi, 0),
                running_vm(1, WorkloadClass::Jacobi, 1),
            ],
        );
        let mut cross = SimEngine::new(
            cfg,
            vec![
                running_vm(0, WorkloadClass::Jacobi, 0),
                running_vm(1, WorkloadClass::Jacobi, 6),
            ],
        );
        for _ in 0..50 {
            same.step();
            cross.step();
        }
        assert!(
            same.vms[0].work_done < cross.vms[0].work_done,
            "same-socket membw contention must hurt: same {} cross {}",
            same.vms[0].work_done,
            cross.vms[0].work_done
        );
    }

    #[test]
    fn idle_vm_stays_under_idle_threshold() {
        let cfg = quiet_cfg();
        let mut vm = Vm::new(
            VmId(0),
            WorkloadClass::LampLight,
            0.0,
            ActivityModel::Windows(vec![]), // never active
        );
        vm.state = VmState::Running;
        vm.pinned = Some(0);
        let mut eng = SimEngine::new(cfg, vec![vm]);
        for _ in 0..20 {
            eng.step();
        }
        assert!(eng.vms[0].cpu_window_avg() < 0.025);
    }

    #[test]
    fn busy_core_accounting_counts_idle_parking() {
        let cfg = quiet_cfg();
        // One active on core 1, one idle parked on core 0.
        let active = running_vm(0, WorkloadClass::Blackscholes, 1);
        let mut idle = Vm::new(
            VmId(1),
            WorkloadClass::LampLight,
            0.0,
            ActivityModel::Windows(vec![]),
        );
        idle.state = VmState::Running;
        idle.pinned = Some(0);
        let mut eng = SimEngine::new(cfg, vec![active, idle]);
        eng.step();
        // Both cores count: core 1 runs work, core 0 is held by the idle VM.
        let (_, busy) = eng.ledger.busy_series.points[0];
        assert_eq!(busy, 2.0);
    }

    #[test]
    fn arrivals_by_time() {
        let cfg = quiet_cfg();
        let mut vm = Vm::new(VmId(0), WorkloadClass::Hadoop, 30.0, ActivityModel::AlwaysOn);
        vm.state = VmState::NotArrived;
        let mut eng = SimEngine::new(cfg, vec![vm]);
        assert!(eng.process_arrivals().is_empty());
        for _ in 0..31 {
            eng.step();
        }
        let arrived = eng.process_arrivals();
        assert_eq!(arrived, vec![VmId(0)]);
        assert_eq!(eng.vms[0].state, VmState::Running);
    }

    #[test]
    fn hypervisor_surface() {
        let cfg = quiet_cfg();
        let vm = running_vm(0, WorkloadClass::Hadoop, 2);
        let mut eng = SimEngine::new(cfg, vec![vm]);
        eng.step();
        let doms = eng.list_domains();
        assert_eq!(doms.len(), 1);
        let stats = eng.domain_stats(doms[0]).unwrap();
        assert_eq!(stats.pinned, Some(2));
        assert!(stats.util[0] > 0.4, "cpu util {}", stats.util[0]);
        assert!(stats.counters.mem_reads > 0);
        // Re-pin through the control surface.
        eng.pin_vcpu(VmId(0), 5).unwrap();
        assert_eq!(eng.vms[0].pinned, Some(5));
        assert_eq!(eng.ledger.repin_count, 1);
        assert!(eng.pin_vcpu(VmId(0), 99).is_err());
        assert!(eng.pin_vcpu(VmId(7), 0).is_err());
    }

    #[test]
    fn lamp_copinned_with_hog_degrades_latency() {
        let cfg = quiet_cfg();
        let lamp = running_vm(0, WorkloadClass::LampHeavy, 0);
        let hog = running_vm(1, WorkloadClass::Blackscholes, 0);
        let mut eng = SimEngine::new(cfg, vec![lamp, hog]);
        for _ in 0..30 {
            eng.step();
        }
        let perf = eng.vms[0].normalized_perf().unwrap();
        assert!(perf < 0.75, "lamp should suffer: {perf}");
        // And isolated it would not.
        let cfg2 = quiet_cfg();
        let lamp2 = running_vm(0, WorkloadClass::LampHeavy, 0);
        let mut eng2 = SimEngine::new(cfg2, vec![lamp2]);
        for _ in 0..30 {
            eng2.step();
        }
        assert!(eng2.vms[0].normalized_perf().unwrap() > 0.99);
    }
}

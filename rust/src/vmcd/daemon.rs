//! The General Scheduler loop — paper Algorithm 1, event-driven, with
//! decision decoupled from actuation.
//!
//! The paper re-derives the whole placement every `timeInterval`; early
//! versions of this daemon mirrored that by rebuilding a fresh
//! [`PlacementState`] from a monitor snapshot on every cycle *and* every
//! arrival. The daemon now owns one **long-lived** state for the host's
//! whole lifetime and mutates it through [`SchedEvent`]s:
//!
//! * [`SchedEvent::Arrival`] — place the newcomer immediately (§III) via
//!   `SelectPinning`, or adopt an already-pinned domain discovered by the
//!   first poll;
//! * [`SchedEvent::Departure`] — `PlacementState::remove` the member in
//!   O(members);
//! * [`SchedEvent::IdleTransition`] — park on core 0 ("considered to
//!   consume zero resources") and remove from the running state;
//! * [`SchedEvent::WakeTransition`] — re-enter via `SelectPinning`;
//! * [`SchedEvent::Tick`] — the periodic Alg. 1 re-pin pass, expressed as
//!   remove+place deltas per running workload instead of a rebuild;
//! * [`SchedEvent::ActuationComplete`] — an actuation backend finished a
//!   pin: the daemon's *observed* pinning catches up with its intent.
//!
//! **No handler touches the hypervisor's control surface.** Handlers see
//! a read-only `&dyn Hypervisor` (stats, clock) and emit typed
//! [`ActuationCommand`]s into the daemon's [`ActuationQueue`]; an
//! [`Actuate`] backend enforces them — immediately
//! ([`actuator::Inline`](super::actuator::Inline), bit-identical to the
//! coupled design), N ticks later under a budget
//! ([`actuator::Deferred`](super::actuator::Deferred)), or on a worker
//! thread ([`actuator::Threaded`](super::actuator::Threaded)). With a
//! lagging backend the placement *intent* (the state plus each
//! resident's intended core) and the *observed* pinning diverge and
//! reconcile through completion events — the paper's actuation latency
//! as a first-class knob.
//!
//! [`Daemon::step`] polls the monitor **once** per simulator step and
//! diffs the snapshot into lifecycle events (the old design polled in
//! both `on_arrival` and `run_cycle`). The full from-scratch rebuild
//! survives only as the `debug_assert!` reconciliation path
//! ([`Daemon::state_matches_rebuild`]).

use super::actuator::{Actuate, ActuationCommand, ActuationQueue, ActuationReport, Inline};
use super::monitor::{Monitor, MonitorSnapshot};
use super::scheduler::{PlacementState, Policy, Scheduler};
use crate::config::SchedParams;
use crate::hostsim::{Hypervisor, VmId};
use crate::workloads::WorkloadClass;
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Core reserved for consolidated idle workloads (Alg. 1 line 7).
pub const IDLE_CORE: usize = 0;

/// A scheduling-relevant change in the host's VM population. The daemon
/// derives these by diffing monitor snapshots ([`Daemon::step`]), and
/// embedders can inject them directly ([`Daemon::handle_event`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedEvent {
    /// A domain became resident and needs an initial pinning (or, if it
    /// already carries one, adoption into the placement state).
    Arrival(VmId),
    /// A resident domain left the host (finished or migrated away).
    Departure(VmId),
    /// A running workload's windowed CPU fell below the idle threshold.
    IdleTransition(VmId),
    /// An idle workload became active again.
    WakeTransition(VmId),
    /// An actuation backend enforced one pin — the feedback edge of the
    /// command queue. Books the observed pinning; never re-decides.
    ActuationComplete { vm: VmId, core: usize },
    /// The periodic Alg. 1 re-pin + idle-consolidation pass.
    Tick,
}

/// What the daemon knows about one resident domain.
#[derive(Debug, Clone)]
struct Resident {
    class: WorkloadClass,
    /// Intended core: the placement-state position for running
    /// workloads, the parking core for idle ones. Under a lagging
    /// actuation backend the enacted pinning trails this; the command
    /// queue's FIFO order guarantees it converges once drained.
    core: usize,
    idle: bool,
    /// When the daemon started tracking the domain. A freshly-placed
    /// workload's monitoring window is empty (average 0), so idle
    /// transitions are suppressed until one full window has elapsed —
    /// the paper's 2.5% rule is defined over a complete window.
    since: f64,
}

/// The VMCd daemon, generic over the scheduler so a natively-scored
/// daemon (`Daemon<dyn Scheduler + Send>`) can move to a cluster worker
/// thread while an XLA-backed `Daemon<dyn Scheduler>` stays put.
pub struct Daemon<S: ?Sized + Scheduler = dyn Scheduler> {
    pub params: SchedParams,
    pub monitor: Monitor,
    last_cycle: Option<f64>,
    /// Cycles run (reporting).
    pub cycles: u64,
    /// Transient actuation failures tolerated (reporting).
    pub pin_failures: u64,
    /// Lifecycle (non-Tick, non-completion) events handled (reporting).
    pub events_handled: u64,
    /// Actuation completions booked (reporting).
    pub completions: u64,
    /// The long-lived placement state, created by the constructor (the
    /// core count is a construction input, so there is no `Option`
    /// dance and no unwraps on every touch — the detlint burn-down).
    state: PlacementState,
    /// Current idle-core reservation, so `sync_reservation` only touches
    /// the state's `allowed` set on actual flips.
    reserved: bool,
    /// Events queued from outside the daemon's own poll loop (an
    /// embedder, a remote controller): see [`Self::enqueue`].
    pending: VecDeque<SchedEvent>,
    residents: BTreeMap<VmId, Resident>,
    /// Commands decided but not yet absorbed by the backend.
    queue: ActuationQueue,
    /// The enforcement backend (default [`Inline`]).
    actuation: Box<dyn Actuate>,
    /// Enacted pinnings as reported by actuation completions — the
    /// daemon's belief of what the hypervisor actually runs, distinct
    /// from its intent while commands are in flight.
    observed: BTreeMap<VmId, usize>,
    pub scheduler: Box<S>,
}

impl<S: ?Sized + Scheduler> Daemon<S> {
    /// Build a daemon for a host with `cores` CPU cores. The placement
    /// state is created here — init produces the state directly instead
    /// of threading an `Option` through every handler.
    pub fn new(params: SchedParams, scheduler: Box<S>, cores: usize) -> Daemon<S> {
        let monitor = Monitor::new(params.idle_cpu_threshold);
        let state = scheduler.new_state(cores, false);
        Daemon {
            params,
            monitor,
            last_cycle: None,
            cycles: 0,
            pin_failures: 0,
            events_handled: 0,
            completions: 0,
            state,
            reserved: false,
            pending: VecDeque::new(),
            residents: BTreeMap::new(),
            queue: ActuationQueue::new(),
            actuation: Box::new(Inline::new()),
            observed: BTreeMap::new(),
            scheduler,
        }
    }

    /// [`Self::new`] with an explicit actuation backend.
    pub fn with_actuation(
        params: SchedParams,
        scheduler: Box<S>,
        cores: usize,
        actuation: Box<dyn Actuate>,
    ) -> Daemon<S> {
        let mut daemon = Daemon::new(params, scheduler, cores);
        daemon.actuation = actuation;
        daemon
    }

    /// Swap the actuation backend (before the first step: in-flight
    /// commands of the old backend are dropped with it).
    pub fn set_actuation(&mut self, actuation: Box<dyn Actuate>) {
        self.actuation = actuation;
    }

    pub fn policy(&self) -> Policy {
        self.scheduler.policy()
    }

    /// Name of the active actuation backend.
    pub fn actuation_name(&self) -> &'static str {
        self.actuation.name()
    }

    /// Atomic pins decided but not yet enforced (queued + staged in the
    /// backend).
    pub fn in_flight(&self) -> usize {
        self.queue.len() + self.actuation.in_flight()
    }

    /// Actuation commands emitted over the daemon's lifetime.
    pub fn commands_issued(&self) -> u64 {
        self.queue.pushed
    }

    /// Real hypervisor pin calls the backend performed.
    pub fn pin_calls(&self) -> u64 {
        self.actuation.counters().0
    }

    /// Dedup-skipped no-op pins.
    pub fn pin_noops(&self) -> u64 {
        self.actuation.counters().1
    }

    /// The enacted pinning last reported for `id` (None until its first
    /// completion — e.g. an adopted domain that never needed a command).
    pub fn observed_pinning(&self, id: VmId) -> Option<usize> {
        self.observed.get(&id).copied()
    }

    /// The intended core of a tracked resident (placement intent).
    pub fn intended_pinning(&self, id: VmId) -> Option<usize> {
        self.residents.get(&id).map(|r| r.core)
    }

    /// The long-lived placement state.
    pub fn placement_state(&self) -> &PlacementState {
        &self.state
    }

    fn has_idle(&self) -> bool {
        self.residents.values().any(|r| r.idle)
    }

    /// Recompute the idle-core reservation from the tracked idle set.
    /// Touches the state's `allowed` set only when the flag flips.
    fn sync_reservation(&mut self) {
        let reserve = self.scheduler.dynamic() && self.has_idle();
        if reserve == self.reserved {
            return;
        }
        self.reserved = reserve;
        self.state.set_idle_reservation(reserve);
    }

    /// Queue an event for the next [`Self::step`] without touching the
    /// hypervisor now — the injection surface for embedders that run
    /// outside the daemon's poll loop. The cluster bus deliberately does
    /// *not* use it: bus deliveries go through the immediate
    /// `handle_event` path so strict per-host inbox ordering is
    /// preserved. Queued events are handled at the start of the step,
    /// *before* the monitor diff, so queued bookkeeping lands ahead of
    /// lifecycle detection and is never double-derived from the same
    /// snapshot.
    pub fn enqueue(&mut self, ev: SchedEvent) {
        self.pending.push_back(ev);
    }

    /// Queued events not yet drained.
    pub fn pending_events(&self) -> usize {
        self.pending.len()
    }

    /// One daemon step: drain queued events, poll the monitor **once**,
    /// diff the snapshot into lifecycle events and handle them, run the
    /// Alg. 1 Tick if the interval has elapsed, then run one actuation
    /// pass (absorb this step's commands, advance the backend one tick,
    /// book completions). Returns whether the Tick ran.
    pub fn step(&mut self, hv: &mut dyn Hypervisor) -> Result<bool> {
        while let Some(ev) = self.pending.pop_front() {
            self.apply_event(hv, ev)?;
        }
        self.drain_lifecycle(hv)?;
        let t = hv.now();
        let due = match self.last_cycle {
            None => true,
            Some(t0) => t - t0 >= self.params.interval - 1e-9,
        };
        if due {
            self.apply_event(hv, SchedEvent::Tick)?;
        }
        self.pump(hv)?;
        let report = self.actuation.on_step(hv);
        self.book(hv, report)?;
        Ok(due)
    }

    /// Back-compat alias for [`Self::step`].
    pub fn maybe_cycle(&mut self, hv: &mut dyn Hypervisor) -> Result<bool> {
        self.step(hv)
    }

    /// Force a full pass now: drain lifecycle events, then Tick, then
    /// push the resulting commands into the backend. (The old
    /// rebuild-per-cycle entry point, kept for drivers and tests that
    /// want an immediate cycle.) Does **not** advance a latency
    /// backend's clock — only [`Self::step`] does.
    pub fn run_cycle(&mut self, hv: &mut dyn Hypervisor) -> Result<()> {
        self.drain_lifecycle(hv)?;
        self.apply_event(hv, SchedEvent::Tick)?;
        self.pump(hv)
    }

    /// Place a newly-arrived workload immediately (§III: "as new
    /// workloads are forwarded to VMCd, they are pinned to CPU cores as
    /// resource availability allows"). The domain list (no monitor poll)
    /// is reconciled first — departures drained, unknown co-residents
    /// adopted — so the placement decision sees the real occupancy, not
    /// ghosts of VMs that finished since the last step.
    pub fn on_arrival(&mut self, hv: &mut dyn Hypervisor, id: VmId) -> Result<()> {
        // Static schedulers don't track occupancy, so there is nothing
        // to reconcile before placing.
        if self.scheduler.dynamic() {
            let domains = hv.list_domains();
            let untracked_self = usize::from(!self.residents.contains_key(&id));
            // Reconcile only when the tracked view visibly disagrees with
            // the live set beyond the arriving VM itself: an arrival
            // burst still pays list_domains (O(residents)) each, but
            // skips the per-arrival set build, departure diff, and
            // per-domain stats probes. (A numerically balanced
            // ghost+unknown pair slips this gate; the next step's poll
            // diff corrects it.)
            if domains.len() != self.residents.len() + untracked_self {
                let live: BTreeSet<VmId> = domains.into_iter().collect();
                let gone: Vec<VmId> = self
                    .residents
                    .keys()
                    .filter(|&&r| !live.contains(&r))
                    .copied()
                    .collect();
                for g in gone {
                    self.apply_event(hv, SchedEvent::Departure(g))?;
                }
                for other in live {
                    if other != id && !self.residents.contains_key(&other) {
                        self.apply_event(hv, SchedEvent::Arrival(other))?;
                    }
                }
            }
        }
        self.apply_event(hv, SchedEvent::Arrival(id))?;
        let failures_before = self.pin_failures;
        self.pump(hv)?;
        // A dynamic scheduler self-heals through the next Tick's re-pin
        // pass, so its pin failures are tolerated. A static policy (RRS)
        // has no retry path — surface an arrival-pin failure to the
        // caller like the pre-queue actuator did. (Under a latency
        // backend the failure shows up at a later step instead, where it
        // can only be counted.)
        if !self.scheduler.dynamic() && self.pin_failures > failures_before {
            anyhow::bail!("static-policy arrival pin failed for {id:?} (no Tick retry path)");
        }
        Ok(())
    }

    /// Poll once and apply every lifecycle delta since the last poll.
    fn drain_lifecycle(&mut self, hv: &dyn Hypervisor) -> Result<()> {
        // RRS is static: no idle detection, no monitoring ("unable to
        // detect whether a workload is in running state or idle", §V-C.1).
        if !self.scheduler.dynamic() {
            return Ok(());
        }
        let snap = self.monitor.poll(hv);
        let live: BTreeSet<VmId> = snap.domains.iter().map(|d| d.id).collect();
        self.actuation.retain(&live);
        self.queue.retain_live(&live);
        self.observed.retain(|id, _| live.contains(id));
        for ev in self.diff(&snap, &live) {
            self.apply_event(hv, ev)?;
        }
        Ok(())
    }

    /// Snapshot → events: departures first (freeing cores), then unknown
    /// domains, then idle/wake flips. `live` is the snapshot's id set, so
    /// the per-step departure scan is O(residents · log domains) rather
    /// than quadratic.
    ///
    /// A VmId reused for a *different workload class* between polls is
    /// caught as Departure + Arrival; same-class reuse within one poll
    /// interval is indistinguishable from the old domain by id alone.
    fn diff(&self, snap: &MonitorSnapshot, live: &BTreeSet<VmId>) -> Vec<SchedEvent> {
        let mut events = Vec::new();
        for &id in self.residents.keys() {
            if !live.contains(&id) {
                events.push(SchedEvent::Departure(id));
            }
        }
        for d in &snap.domains {
            match self.residents.get(&d.id) {
                None => events.push(SchedEvent::Arrival(d.id)),
                Some(r) if r.class != d.class => {
                    events.push(SchedEvent::Departure(d.id));
                    events.push(SchedEvent::Arrival(d.id));
                }
                Some(r) => {
                    let window_warm =
                        snap.t - r.since >= self.params.monitor_window - 1e-9;
                    if !r.idle && d.idle && window_warm {
                        events.push(SchedEvent::IdleTransition(d.id));
                    } else if r.idle && !d.idle {
                        events.push(SchedEvent::WakeTransition(d.id));
                    }
                }
            }
        }
        events
    }

    /// Apply one event and immediately push any resulting commands into
    /// the actuation backend — the embedder surface (the cluster bus
    /// routes `ClusterEvent::Sched` deliveries here).
    pub fn handle_event(&mut self, hv: &mut dyn Hypervisor, ev: SchedEvent) -> Result<()> {
        self.apply_event(hv, ev)?;
        self.pump(hv)
    }

    /// Apply one event to the long-lived state. Pure decision code: the
    /// hypervisor is read-only here, every pinning consequence is a
    /// typed command in [`Self::queue`] for the backend to enforce.
    fn apply_event(&mut self, hv: &dyn Hypervisor, ev: SchedEvent) -> Result<()> {
        if !matches!(ev, SchedEvent::Tick | SchedEvent::ActuationComplete { .. }) {
            self.events_handled += 1;
        }
        match ev {
            SchedEvent::Arrival(id) => self.on_arrival_event(hv, id),
            SchedEvent::Departure(id) => {
                self.on_departure(id);
                Ok(())
            }
            SchedEvent::IdleTransition(id) => {
                self.on_idle(id);
                Ok(())
            }
            SchedEvent::WakeTransition(id) => {
                self.on_wake(id);
                Ok(())
            }
            SchedEvent::ActuationComplete { vm, core } => {
                self.on_actuation_complete(vm, core);
                Ok(())
            }
            SchedEvent::Tick => self.on_tick(hv),
        }
    }

    /// Absorb this pass's queued commands into the backend and book what
    /// completed. Called at the end of every public entry point.
    fn pump(&mut self, hv: &mut dyn Hypervisor) -> Result<()> {
        let report = self.actuation.submit(hv, &mut self.queue);
        self.book(hv, report)
    }

    /// Fold one actuation report into the daemon: count tolerated
    /// failures (the intent is kept; the next Tick's re-pin retries) and
    /// feed every completion back as a [`SchedEvent::ActuationComplete`].
    fn book(&mut self, hv: &dyn Hypervisor, report: ActuationReport) -> Result<()> {
        self.pin_failures += report.failures;
        for (vm, core) in report.completions {
            self.apply_event(hv, SchedEvent::ActuationComplete { vm, core })?;
        }
        Ok(())
    }

    fn on_actuation_complete(&mut self, vm: VmId, core: usize) {
        self.completions += 1;
        self.observed.insert(vm, core);
    }

    fn on_arrival_event(&mut self, hv: &dyn Hypervisor, id: VmId) -> Result<()> {
        if self.residents.contains_key(&id) {
            return Ok(()); // duplicate arrival: already tracked
        }
        let stats = hv
            .domain_stats(id)
            .ok_or_else(|| anyhow::anyhow!("arrival {id:?} not visible to the hypervisor"))?;
        let class = stats.class;
        // A static scheduler (RRS) never monitors, so departures would
        // never be drained: pin the newcomer without tracking it, or the
        // resident table and placement state grow with every arrival for
        // the host's whole lifetime.
        if !self.scheduler.dynamic() {
            if stats.pinned.is_none() {
                let core = self.scheduler.select_pinning(&self.state, class);
                self.queue.pin(id, core);
            }
            return Ok(());
        }
        let now = hv.now();
        match stats.pinned {
            // Adoption: a pre-existing resident (first poll after daemon
            // start, or a VM migrated in). Trust the live pinning and the
            // monitor's idle rule (its window belongs to a live history);
            // the next Tick re-pins it like any other workload. No
            // command: there is nothing to enforce.
            Some(core) => {
                let idle = self.monitor.is_idle(stats.cpu_window_avg);
                if !idle {
                    self.state.place(core, class);
                }
                self.residents.insert(
                    id,
                    Resident {
                        class,
                        core,
                        idle,
                        since: now - self.params.monitor_window,
                    },
                );
                self.sync_reservation();
                Ok(())
            }
            // Fresh arrival: decide immediately. Its monitoring window is
            // empty, so it is treated as running — and `since` suppresses
            // idle transitions — until one full window has elapsed. The
            // pin itself is a command; under a lagging backend the VM
            // stalls unpinned until enforcement lands (the actuation-lag
            // cost the Deferred backend measures).
            None => {
                let core = self.scheduler.select_pinning(&self.state, class);
                self.state.place(core, class);
                self.residents.insert(
                    id,
                    Resident {
                        class,
                        core,
                        idle: false,
                        since: now,
                    },
                );
                self.queue.pin(id, core);
                Ok(())
            }
        }
    }

    fn on_departure(&mut self, id: VmId) {
        let Some(r) = self.residents.remove(&id) else {
            return;
        };
        self.observed.remove(&id);
        if !r.idle {
            let removed = self.state.remove(r.core, r.class);
            debug_assert!(removed, "departing {id:?} missing from placement state");
        }
        self.sync_reservation();
    }

    fn on_idle(&mut self, id: VmId) {
        if !self.scheduler.dynamic() {
            return;
        }
        let Some(r) = self.residents.get_mut(&id) else {
            return;
        };
        if r.idle {
            return;
        }
        let (class, core) = (r.class, r.core);
        r.idle = true;
        r.core = IDLE_CORE;
        let removed = self.state.remove(core, class);
        debug_assert!(removed, "idling {id:?} missing from placement state");
        self.sync_reservation();
        // Alg. 1 lines 6-7: the park is a command; the backend enforces.
        self.queue.park(id);
    }

    fn on_wake(&mut self, id: VmId) {
        if !self.scheduler.dynamic() {
            return;
        }
        let Some(r) = self.residents.get_mut(&id) else {
            return;
        };
        if !r.idle {
            return;
        }
        let class = r.class;
        // The waking VM leaves the idle set *before* the reservation is
        // recomputed: if it was the last idle workload, core 0 reopens.
        r.idle = false;
        self.sync_reservation();
        let core = self.scheduler.select_pinning(&self.state, class);
        self.state.place(core, class);
        if let Some(r) = self.residents.get_mut(&id) {
            r.core = core;
        }
        self.queue.pin(id, core);
    }

    /// The periodic pass: park idle workloads, then re-pin every running
    /// workload through `SelectPinning` — each as a remove+place delta on
    /// the long-lived state, in stable (VmId) order so decisions are
    /// deterministic. The decisions leave as one
    /// [`ActuationCommand::ApplyPlan`] (plus a park per idle workload);
    /// enforcement is the backend's problem.
    ///
    /// Deliberate divergence from the paper's Algorithm 1: the paper
    /// re-derives the whole placement from an empty state (VM k's
    /// decision sees only VMs 1..k-1), whereas this pass *refines* the
    /// current placement (each decision sees all other residents where
    /// they stand). Individual pinnings can differ from a from-scratch
    /// greedy pass; the first-fit core scan still compacts toward
    /// low-index cores, so the consolidation behaviour the paper
    /// evaluates is preserved — that trade is the point of the
    /// event-driven redesign (no O(members²) rebuild per cycle).
    fn on_tick(&mut self, hv: &dyn Hypervisor) -> Result<()> {
        // The Tick owns the interval clock, so every entry point
        // (`step`'s gate, `run_cycle`, a directly-injected event) resets
        // it consistently and cycles never double-run on one tick.
        self.last_cycle = Some(hv.now());
        self.cycles += 1;
        // RRS is static: no idle detection, no re-pinning.
        if !self.scheduler.dynamic() {
            return Ok(());
        }
        self.sync_reservation();

        let idle_ids: Vec<VmId> = self
            .residents
            .iter()
            .filter(|(_, r)| r.idle)
            .map(|(&id, _)| id)
            .collect();
        for id in idle_ids {
            if let Some(r) = self.residents.get_mut(&id) {
                r.core = IDLE_CORE;
            }
            self.queue.park(id);
        }

        let running_ids: Vec<VmId> = self
            .residents
            .iter()
            .filter(|(_, r)| !r.idle)
            .map(|(&id, _)| id)
            .collect();
        let mut plan = Vec::with_capacity(running_ids.len());
        for id in running_ids {
            let (class, old_core) = {
                let r = &self.residents[&id];
                (r.class, r.core)
            };
            let removed = self.state.remove(old_core, class);
            debug_assert!(removed, "running {id:?} missing from placement state");
            let core = self.scheduler.select_pinning(&self.state, class);
            self.state.place(core, class);
            if let Some(r) = self.residents.get_mut(&id) {
                r.core = core;
            }
            plan.push((id, core));
        }
        if !plan.is_empty() {
            self.queue.push(ActuationCommand::ApplyPlan(plan));
        }
        debug_assert!(
            self.state_matches_rebuild(1e-6),
            "long-lived placement state drifted from the event deltas"
        );
        Ok(())
    }

    /// Rebuild a fresh placement state from the resident table — the old
    /// per-cycle path, demoted to a reconciliation reference.
    pub fn rebuild_state(&self) -> PlacementState {
        let reserve = self.scheduler.dynamic() && self.has_idle();
        let mut rebuilt = self.scheduler.new_state(self.state.cores.len(), reserve);
        for r in self.residents.values() {
            if !r.idle {
                rebuilt.place(r.core, r.class);
            }
        }
        rebuilt
    }

    /// Does the long-lived state agree with a from-scratch rebuild — same
    /// `allowed` set, same per-core membership (as multisets), and cached
    /// aggregates within `tol` of a re-sum?
    pub fn state_matches_rebuild(&self, tol: f64) -> bool {
        let state = &self.state;
        let rebuilt = self.rebuild_state();
        if state.allowed != rebuilt.allowed {
            return false;
        }
        for (a, b) in state.cores.iter().zip(rebuilt.cores.iter()) {
            let mut x = a.clone();
            let mut y = b.clone();
            x.sort_unstable();
            y.sort_unstable();
            if x != y {
                return false;
            }
        }
        state.cache_matches_rebuild(tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::profiling::ProfileBank;
    use crate::vmcd::actuator::Deferred;
    use crate::vmcd::scheduler;
    use crate::workloads::WorkloadClass;

    fn setup(policy: Policy, vms: Vec<Vm>) -> (SimEngine, Daemon) {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let bank = ProfileBank::generate(&cfg);
        let sched = scheduler::build(policy, &bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        (SimEngine::new(cfg, vms), daemon)
    }

    fn resident(id: u32, class: WorkloadClass, active: bool) -> Vm {
        let activity = if active {
            ActivityModel::AlwaysOn
        } else {
            ActivityModel::Windows(vec![])
        };
        let mut vm = Vm::new(VmId(id), class, 0.0, activity);
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm.pinned = Some((id as usize) % 12);
        vm
    }

    #[test]
    fn idle_workloads_parked_on_core0() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false), // idle
            resident(2, WorkloadClass::LampLight, false), // idle
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        // Warm the monitoring window.
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(eng.vms[1].pinned, Some(IDLE_CORE));
        assert_eq!(eng.vms[2].pinned, Some(IDLE_CORE));
        // The running workload is NOT on the idle core.
        assert_ne!(eng.vms[0].pinned, Some(IDLE_CORE));
    }

    #[test]
    fn rrs_never_repins() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false),
        ];
        let (mut eng, mut daemon) = setup(Policy::Rrs, vms);
        let before: Vec<_> = eng.vms.iter().map(|v| v.pinned).collect();
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        let after: Vec<_> = eng.vms.iter().map(|v| v.pinned).collect();
        assert_eq!(before, after);
        assert_eq!(eng.ledger.repin_count, 0);
    }

    #[test]
    fn interval_gating() {
        let vms = vec![resident(0, WorkloadClass::Hadoop, true)];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        assert!(daemon.step(&mut eng).unwrap()); // first is immediate
        assert!(!daemon.step(&mut eng).unwrap()); // gated
        for _ in 0..31 {
            eng.step();
        }
        assert!(daemon.step(&mut eng).unwrap()); // 30 s later
    }

    #[test]
    fn arrival_placed_immediately() {
        let mut arriving = Vm::new(
            VmId(5),
            WorkloadClass::Jacobi,
            0.0,
            ActivityModel::AlwaysOn,
        );
        arriving.state = VmState::NotArrived;
        let vms = vec![resident(0, WorkloadClass::Blackscholes, true), arriving];
        let (mut eng, mut daemon) = setup(Policy::Ias, vms);
        for _ in 0..5 {
            eng.step();
        }
        let ids = eng.process_arrivals();
        assert_eq!(ids, vec![VmId(5)]);
        daemon.on_arrival(&mut eng, VmId(5)).unwrap();
        let pinned = eng.vms[1].pinned.unwrap();
        // IAS must not co-pin jacobi with the blackscholes hog (S > thr).
        assert_ne!(Some(pinned), eng.vms[0].pinned);
    }

    #[test]
    fn single_core_host_with_idle_reservation_does_not_panic() {
        // Regression: a 1-core host with an idle workload used to leave
        // the policies with an empty `allowed` set and panic the cycle.
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        cfg.host.cores = 1;
        let bank = ProfileBank::generate(&cfg);
        let sched = scheduler::build(Policy::Ias, &bank, cfg.sched.ras_threshold, None);
        let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);

        let mut running = Vm::new(
            VmId(0),
            WorkloadClass::Blackscholes,
            0.0,
            ActivityModel::AlwaysOn,
        );
        running.state = VmState::Running;
        running.started = Some(0.0);
        running.pinned = Some(0);
        let mut idle = Vm::new(
            VmId(1),
            WorkloadClass::LampLight,
            0.0,
            ActivityModel::Windows(vec![]),
        );
        idle.state = VmState::Running;
        idle.started = Some(0.0);
        idle.pinned = Some(0);

        let mut eng = SimEngine::new(cfg, vec![running, idle]);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        // Both end up on the only core; the point is that the cycle ran.
        assert_eq!(eng.vms[0].pinned, Some(0));
        assert_eq!(eng.vms[1].pinned, Some(IDLE_CORE));
    }

    #[test]
    fn ras_consolidates_complementary_running_vms() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::StreamLow, true),
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(
            eng.vms[0].pinned, eng.vms[1].pinned,
            "complementary pair should share a core"
        );
    }

    #[test]
    fn departures_are_removed_from_the_long_lived_state() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::Hadoop, true),
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(daemon.placement_state().placed(), 2);
        // Force-finish one VM: the next step must emit a Departure.
        eng.vms[0].state = VmState::Finished;
        daemon.step(&mut eng).unwrap();
        assert_eq!(daemon.placement_state().placed(), 1);
        assert!(daemon.state_matches_rebuild(1e-9));
    }

    #[test]
    fn queued_events_drain_at_the_start_of_step() {
        let vms = vec![resident(0, WorkloadClass::Blackscholes, true)];
        let (mut eng, mut daemon) = setup(Policy::Ias, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(daemon.placement_state().placed(), 1);
        // Queue a departure from outside the poll loop: nothing happens
        // until the next step, which drains it before the monitor diff.
        daemon.enqueue(SchedEvent::Departure(VmId(0)));
        assert_eq!(daemon.pending_events(), 1);
        assert_eq!(daemon.placement_state().placed(), 1);
        daemon.step(&mut eng).unwrap();
        assert_eq!(daemon.pending_events(), 0);
        // The member left via the queued event; the same step's poll then
        // re-adopts the still-live domain (it never actually departed),
        // so the state stays reconciled either way.
        assert!(daemon.state_matches_rebuild(1e-9));
    }

    #[test]
    fn events_counter_tracks_lifecycle_churn() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false),
        ];
        let (mut eng, mut daemon) = setup(Policy::Ias, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        // Two adoptions at least; Ticks and actuation completions are
        // not counted as lifecycle events.
        assert!(daemon.events_handled >= 2, "{}", daemon.events_handled);
        let before = daemon.events_handled;
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(daemon.events_handled, before, "steady state emits no events");
    }

    #[test]
    fn completions_track_observed_pinning() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false), // idle
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        // Inline enforces within the pass: the park and the re-pin plan
        // complete immediately and the observed map matches the intent.
        assert!(daemon.completions >= 2, "{}", daemon.completions);
        assert_eq!(daemon.observed_pinning(VmId(1)), Some(IDLE_CORE));
        assert_eq!(
            daemon.observed_pinning(VmId(0)),
            daemon.intended_pinning(VmId(0))
        );
        assert_eq!(daemon.in_flight(), 0);
        assert!(daemon.commands_issued() >= 2);
        assert!(daemon.pin_calls() + daemon.pin_noops() >= 2);
    }

    #[test]
    fn deferred_actuation_lags_then_reconciles() {
        // The tentpole behaviour: under Deferred{latency 2} the decision
        // (intent) is immediate but enforcement lands ticks later, so
        // the engine runs unpinned in between; once the queue drains the
        // observed pinning equals the intent.
        let mut arriving = Vm::new(
            VmId(0),
            WorkloadClass::Jacobi,
            0.0,
            ActivityModel::AlwaysOn,
        );
        arriving.state = VmState::NotArrived;
        let (mut eng, mut daemon) = setup(Policy::Ias, vec![arriving]);
        daemon.set_actuation(Box::new(Deferred::new(2, 0)));
        assert_eq!(daemon.actuation_name(), "deferred");
        let ids = eng.process_arrivals();
        assert_eq!(ids, vec![VmId(0)]);
        daemon.on_arrival(&mut eng, VmId(0)).unwrap();
        // Intent recorded; enforcement in flight; engine untouched.
        let intent = daemon.intended_pinning(VmId(0)).unwrap();
        assert_eq!(eng.vms[0].pinned, None, "deferred pin must not land yet");
        assert!(daemon.in_flight() >= 1);
        assert_eq!(daemon.observed_pinning(VmId(0)), None);
        // Step until the backend drains (the first step also runs a
        // Tick, whose re-pin plan joins the staged queue).
        let mut drained = false;
        for _ in 0..10 {
            daemon.step(&mut eng).unwrap();
            eng.step();
            if daemon.in_flight() == 0 {
                drained = true;
                break;
            }
        }
        assert!(drained, "deferred queue never drained");
        let final_intent = daemon.intended_pinning(VmId(0)).unwrap();
        assert_eq!(eng.vms[0].pinned, Some(final_intent));
        assert_eq!(daemon.observed_pinning(VmId(0)), Some(final_intent));
        assert!(daemon.completions >= 1);
        // A lone VM on an empty host decides the same core every pass,
        // so the Tick's re-pin confirms rather than moves the arrival
        // decision.
        assert_eq!(final_intent, intent);
    }

    #[test]
    fn deferred_budget_spreads_a_tick_over_steps() {
        // Six running residents re-pinned by the first Tick, budget 2:
        // the plan takes 3 steps to enforce.
        let vms: Vec<Vm> = (0..6)
            .map(|i| resident(i, WorkloadClass::Hadoop, true))
            .collect();
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        daemon.set_actuation(Box::new(Deferred::new(0, 2)));
        for _ in 0..12 {
            eng.step();
        }
        daemon.step(&mut eng).unwrap(); // adopts 6, Ticks, enforces 2
        assert_eq!(daemon.in_flight(), 4);
        daemon.step(&mut eng).unwrap();
        assert_eq!(daemon.in_flight(), 2);
        daemon.step(&mut eng).unwrap();
        assert_eq!(daemon.in_flight(), 0);
    }
}

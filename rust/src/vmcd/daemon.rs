//! The General Scheduler loop — paper Algorithm 1.
//!
//! Every `timeInterval` seconds the daemon:
//! 1. polls the monitor for idle vs running workloads (idle = CPU below
//!    2.5% over the last monitoring window),
//! 2. pins every idle workload on core 0 ("considered to consume zero
//!    resources"),
//! 3. re-pins every running workload through the policy's `SelectPinning`.
//!
//! New arrivals are placed immediately (§III: "as new workloads are
//! forwarded to VMCd, they are pinned to CPU cores as resource
//! availability allows").

use super::actuator::Actuator;
use super::monitor::Monitor;
use super::scheduler::{Policy, Scheduler};
use crate::config::SchedParams;
use crate::hostsim::{Hypervisor, VmId};
use anyhow::Result;

/// Core reserved for consolidated idle workloads (Alg. 1 line 7).
pub const IDLE_CORE: usize = 0;

pub struct Daemon {
    pub params: SchedParams,
    pub scheduler: Box<dyn Scheduler>,
    pub monitor: Monitor,
    pub actuator: Actuator,
    last_cycle: Option<f64>,
    /// Cycles run (reporting).
    pub cycles: u64,
    /// Transient actuation failures tolerated (reporting).
    pub pin_failures: u64,
}

impl Daemon {
    pub fn new(params: SchedParams, scheduler: Box<dyn Scheduler>) -> Daemon {
        let monitor = Monitor::new(params.idle_cpu_threshold);
        Daemon {
            params,
            scheduler,
            monitor,
            actuator: Actuator::new(),
            last_cycle: None,
            cycles: 0,
            pin_failures: 0,
        }
    }

    pub fn policy(&self) -> Policy {
        self.scheduler.policy()
    }

    /// Place a newly-arrived workload immediately.
    pub fn on_arrival(&mut self, hv: &mut dyn Hypervisor, id: VmId) -> Result<()> {
        let snap = self.monitor.poll(hv);
        let cores = hv.host_spec().cores;

        // Build the placement state from live pinnings of *running*
        // workloads (idle ones are parked and "consume zero resources").
        // `new_state` attaches the policy's score cache so every `place`
        // below is a delta update, not a deferred O(members²) re-sum.
        let has_idle = snap.domains.iter().any(|d| d.idle && d.id != id);
        let mut state = self
            .scheduler
            .new_state(cores, has_idle && self.scheduler.dynamic());
        for d in &snap.domains {
            if d.id == id || d.idle {
                continue;
            }
            if let Some(core) = d.pinned {
                state.place(core, d.class);
            }
        }
        let class = snap
            .domains
            .iter()
            .find(|d| d.id == id)
            .map(|d| d.class)
            .ok_or_else(|| anyhow::anyhow!("arrival {id:?} not visible to monitor"))?;
        let core = self.scheduler.select_pinning(&state, class);
        self.actuator.pin(hv, id, core)
    }

    /// Run a cycle if the interval has elapsed. Returns true if it ran.
    pub fn maybe_cycle(&mut self, hv: &mut dyn Hypervisor) -> Result<bool> {
        let t = hv.now();
        let due = match self.last_cycle {
            None => true,
            Some(t0) => t - t0 >= self.params.interval - 1e-9,
        };
        if !due {
            return Ok(false);
        }
        self.last_cycle = Some(t);
        self.run_cycle(hv)?;
        Ok(true)
    }

    /// One full Alg. 1 pass.
    pub fn run_cycle(&mut self, hv: &mut dyn Hypervisor) -> Result<()> {
        self.cycles += 1;

        // RRS is static: no idle detection, no re-pinning.
        if !self.scheduler.dynamic() {
            return Ok(());
        }

        let snap = self.monitor.poll(hv);
        let live: Vec<VmId> = snap.domains.iter().map(|d| d.id).collect();
        self.actuator.retain(&live);

        let cores = hv.host_spec().cores;
        let idle: Vec<_> = snap
            .domains
            .iter()
            .filter(|d| d.idle)
            .cloned()
            .collect();
        let running: Vec<_> = snap
            .domains
            .iter()
            .filter(|d| !d.idle)
            .cloned()
            .collect();

        // Alg. 1 lines 6-7: park idle workloads on core 0. Individual pin
        // failures (libvirt calls fail transiently in production) must not
        // abort the cycle: log, count, and carry on — the VM keeps its old
        // pinning until the next cycle.
        for d in &idle {
            if let Err(e) = self.actuator.pin(hv, d.id, IDLE_CORE) {
                self.pin_failures += 1;
                log::warn!("pin {:?} -> idle core failed: {e}", d.id);
            }
        }

        // Alg. 1 lines 8-10: re-pin running workloads via SelectPinning.
        // Stable order (arrival id) so decisions are deterministic.
        let mut running = running;
        running.sort_by_key(|d| d.id);
        let mut state = self.scheduler.new_state(cores, !idle.is_empty());
        for d in &running {
            let core = self.scheduler.select_pinning(&state, d.class);
            // The placement state tracks the INTENDED placement even if the
            // actuation fails — subsequent decisions stay consistent, and
            // the failed VM is retried next cycle.
            state.place(core, d.class);
            if let Err(e) = self.actuator.pin(hv, d.id, core) {
                self.pin_failures += 1;
                log::warn!("pin {:?} -> core {core} failed: {e}", d.id);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::profiling::ProfileBank;
    use crate::vmcd::scheduler;
    use crate::workloads::WorkloadClass;

    fn setup(policy: Policy, vms: Vec<Vm>) -> (SimEngine, Daemon) {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let bank = ProfileBank::generate(&cfg);
        let sched = scheduler::build(policy, &bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched);
        (SimEngine::new(cfg, vms), daemon)
    }

    fn resident(id: u32, class: WorkloadClass, active: bool) -> Vm {
        let activity = if active {
            ActivityModel::AlwaysOn
        } else {
            ActivityModel::Windows(vec![])
        };
        let mut vm = Vm::new(VmId(id), class, 0.0, activity);
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm.pinned = Some((id as usize) % 12);
        vm
    }

    #[test]
    fn idle_workloads_parked_on_core0() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false), // idle
            resident(2, WorkloadClass::LampLight, false), // idle
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        // Warm the monitoring window.
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(eng.vms[1].pinned, Some(IDLE_CORE));
        assert_eq!(eng.vms[2].pinned, Some(IDLE_CORE));
        // The running workload is NOT on the idle core.
        assert_ne!(eng.vms[0].pinned, Some(IDLE_CORE));
    }

    #[test]
    fn rrs_never_repins() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::LampLight, false),
        ];
        let (mut eng, mut daemon) = setup(Policy::Rrs, vms);
        let before: Vec<_> = eng.vms.iter().map(|v| v.pinned).collect();
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        let after: Vec<_> = eng.vms.iter().map(|v| v.pinned).collect();
        assert_eq!(before, after);
        assert_eq!(eng.ledger.repin_count, 0);
    }

    #[test]
    fn interval_gating() {
        let vms = vec![resident(0, WorkloadClass::Hadoop, true)];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        assert!(daemon.maybe_cycle(&mut eng).unwrap()); // first is immediate
        assert!(!daemon.maybe_cycle(&mut eng).unwrap()); // gated
        for _ in 0..31 {
            eng.step();
        }
        assert!(daemon.maybe_cycle(&mut eng).unwrap()); // 30 s later
    }

    #[test]
    fn arrival_placed_immediately() {
        let mut arriving = Vm::new(
            VmId(5),
            WorkloadClass::Jacobi,
            0.0,
            ActivityModel::AlwaysOn,
        );
        arriving.state = VmState::NotArrived;
        let vms = vec![resident(0, WorkloadClass::Blackscholes, true), arriving];
        let (mut eng, mut daemon) = setup(Policy::Ias, vms);
        for _ in 0..5 {
            eng.step();
        }
        let ids = eng.process_arrivals();
        assert_eq!(ids, vec![VmId(5)]);
        daemon.on_arrival(&mut eng, VmId(5)).unwrap();
        let pinned = eng.vms[1].pinned.unwrap();
        // IAS must not co-pin jacobi with the blackscholes hog (S > thr).
        assert_ne!(Some(pinned), eng.vms[0].pinned);
    }

    #[test]
    fn single_core_host_with_idle_reservation_does_not_panic() {
        // Regression: a 1-core host with an idle workload used to leave
        // the policies with an empty `allowed` set and panic the cycle.
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        cfg.host.cores = 1;
        let bank = ProfileBank::generate(&cfg);
        let sched = scheduler::build(Policy::Ias, &bank, cfg.sched.ras_threshold, None);
        let mut daemon = Daemon::new(cfg.sched.clone(), sched);

        let mut running = Vm::new(
            VmId(0),
            WorkloadClass::Blackscholes,
            0.0,
            ActivityModel::AlwaysOn,
        );
        running.state = VmState::Running;
        running.started = Some(0.0);
        running.pinned = Some(0);
        let mut idle = Vm::new(
            VmId(1),
            WorkloadClass::LampLight,
            0.0,
            ActivityModel::Windows(vec![]),
        );
        idle.state = VmState::Running;
        idle.started = Some(0.0);
        idle.pinned = Some(0);

        let mut eng = SimEngine::new(cfg, vec![running, idle]);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        // Both end up on the only core; the point is that the cycle ran.
        assert_eq!(eng.vms[0].pinned, Some(0));
        assert_eq!(eng.vms[1].pinned, Some(IDLE_CORE));
    }

    #[test]
    fn ras_consolidates_complementary_running_vms() {
        let vms = vec![
            resident(0, WorkloadClass::Blackscholes, true),
            resident(1, WorkloadClass::StreamLow, true),
        ];
        let (mut eng, mut daemon) = setup(Policy::Ras, vms);
        for _ in 0..12 {
            eng.step();
        }
        daemon.run_cycle(&mut eng).unwrap();
        assert_eq!(
            eng.vms[0].pinned, eng.vms[1].pinned,
            "complementary pair should share a core"
        );
    }
}

//! CAS — the CPU-Aware Scheduler (§IV-B.1).
//!
//! "A simpler version of RAS … taking into account only one metric, the
//! CPU utilization of incoming workloads." Used by the paper as a
//! reference point; implemented as [`super::ras::Ras`] with the CPU-only
//! mask.

use super::ras::Ras;
use super::scoring::ScoringBackend;
use crate::profiling::ProfileBank;

/// CAS is RAS restricted to the CPU metric (boxed-backend form).
pub type Cas = Ras;

/// Constructor used by the factories in `scheduler::build_with_backend`
/// and `scheduler::build_native` — generic over the backend so a
/// `NativeScoring`-backed CAS stays `Send`.
pub fn new<B: ?Sized + ScoringBackend>(bank: ProfileBank, thr: f64, backend: Box<B>) -> Ras<B> {
    Ras::cpu_only(bank, thr, backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::vmcd::scheduler::{NativeScoring, PlacementState, Policy, Scheduler};
    use crate::workloads::WorkloadClass;

    #[test]
    fn cas_reports_cas_policy() {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let bank = ProfileBank::generate(&cfg);
        let mut cas = new(bank, 1.2, Box::new(NativeScoring::new()));
        assert_eq!(cas.policy(), Policy::Cas);
        let state = PlacementState::new(2, false);
        let c = cas.select_pinning(&state, WorkloadClass::Hadoop);
        assert_eq!(c, 0);
    }
}

//! RAS — the Resource-Aware Scheduler (paper Algorithm 2).
//!
//! Scans the cores: the first core whose overload (Eq. 2) stays zero after
//! adding the workload wins; otherwise the core whose overload *increase*
//! is minimal.

use super::scoring::{Scores, ScoringBackend};
use super::{PlacementState, Policy, Scheduler};
use crate::profiling::ProfileBank;
use crate::workloads::WorkloadClass;
use std::sync::Arc;

/// Generic over the scoring backend so a natively-scored instance
/// (`Ras<NativeScoring>`) is `Send` and can shard across cluster worker
/// threads, while the default `Ras<dyn ScoringBackend>` still accepts any
/// boxed backend (the XLA one is intentionally not `Send`).
pub struct Ras<B: ?Sized + ScoringBackend = dyn ScoringBackend> {
    /// Shared with every state this scheduler builds (`new_state`).
    bank: Arc<ProfileBank>,
    /// The resource-utilisation threshold `thr` (paper: 120%).
    pub thr: f64,
    cpu_only: bool,
    /// Reused score buffer — one allocation for the scheduler's lifetime.
    scores: Scores,
    backend: Box<B>,
}

impl<B: ?Sized + ScoringBackend> Ras<B> {
    pub fn new(bank: ProfileBank, thr: f64, backend: Box<B>) -> Self {
        Ras {
            bank: Arc::new(bank),
            thr,
            backend,
            cpu_only: false,
            scores: Scores::default(),
        }
    }

    /// The CAS variant: same algorithm, CPU metric only.
    pub fn cpu_only(bank: ProfileBank, thr: f64, backend: Box<B>) -> Self {
        Ras {
            bank: Arc::new(bank),
            thr,
            backend,
            cpu_only: true,
            scores: Scores::default(),
        }
    }

    fn select(&mut self, state: &PlacementState, class: WorkloadClass) -> usize {
        self.backend.score_into(
            state,
            class,
            &self.bank,
            self.thr,
            self.cpu_only,
            &mut self.scores,
        );
        let scores = &self.scores;

        // Alg. 2 lines 2-4: first core with zero overload after placement.
        for &core in &state.allowed {
            if scores.ol_after()[core] <= 1e-12 {
                return core;
            }
        }
        // Alg. 2 lines 5-12: minimal overload increase.
        let mut best = state.allowed[0];
        let mut best_delta = f64::INFINITY;
        for &core in &state.allowed {
            let delta = scores.ol_after()[core] - scores.ol_before()[core];
            if delta < best_delta {
                best_delta = delta;
                best = core;
            }
        }
        best
    }
}

impl<B: ?Sized + ScoringBackend> Scheduler for Ras<B> {
    fn policy(&self) -> Policy {
        if self.cpu_only {
            Policy::Cas
        } else {
            Policy::Ras
        }
    }

    fn select_pinning(&mut self, state: &PlacementState, class: WorkloadClass) -> usize {
        self.select(state, class)
    }

    fn new_state(&self, cores: usize, reserve_idle_core: bool) -> PlacementState {
        PlacementState::with_shared_bank(cores, reserve_idle_core, Arc::clone(&self.bank))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::vmcd::scheduler::NativeScoring;
    use crate::workloads::WorkloadClass::*;

    fn bank() -> ProfileBank {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        ProfileBank::generate(&cfg)
    }

    fn ras(bank: &ProfileBank) -> Ras {
        Ras::new(bank.clone(), 1.2, Box::new(NativeScoring::new()))
    }

    #[test]
    fn consolidates_complementary_workloads() {
        let b = bank();
        let mut r = ras(&b);
        let mut state = PlacementState::new(12, false);
        // Blackscholes (CPU) then StreamLow (net): CPU sum ≈ 1.03 < 1.2 —
        // RAS should co-locate them on core 0.
        let c0 = r.select_pinning(&state, Blackscholes);
        assert_eq!(c0, 0);
        state.place(c0, Blackscholes);
        let c1 = r.select_pinning(&state, StreamLow);
        assert_eq!(c1, 0, "complementary workloads should consolidate");
    }

    #[test]
    fn spreads_when_threshold_would_be_crossed() {
        let b = bank();
        let mut r = ras(&b);
        let mut state = PlacementState::new(12, false);
        state.place(0, Blackscholes);
        // A second blackscholes would push CPU to ~1.9 > 1.2: overload > 0,
        // so it must go to the next empty core.
        let c = r.select_pinning(&state, Blackscholes);
        assert_eq!(c, 1);
    }

    #[test]
    fn oversubscribed_picks_min_increase() {
        let b = bank();
        let mut r = ras(&b);
        // Two cores only, both loaded; jacobi everywhere.
        let mut state = PlacementState::new(2, false);
        state.place(0, Blackscholes);
        state.place(0, Blackscholes);
        state.place(1, Blackscholes);
        // Core 1 is less overloaded; the new hog must land there.
        let c = r.select_pinning(&state, Blackscholes);
        assert_eq!(c, 1);
    }

    #[test]
    fn respects_allowed_cores() {
        let b = bank();
        let mut r = ras(&b);
        let state = PlacementState::new(4, true); // core 0 reserved
        let c = r.select_pinning(&state, Hadoop);
        assert_ne!(c, 0);
    }

    #[test]
    fn cas_ignores_net_saturation() {
        // Synthetic profile: low CPU, dominant NetIO (the §IV-B.1 case
        // that separates RAS from CAS).
        let mut b = bank();
        b.u[StreamHigh.index()] = [0.2, 0.0, 0.7, 0.0];
        let mut cas = Ras::cpu_only(b.clone(), 1.2, Box::new(NativeScoring::new()));
        let mut state = PlacementState::new(4, false);
        state.place(0, StreamHigh);
        state.place(0, StreamHigh);
        // CPU on core 0 is only 0.6; CAS happily stacks a third streamer
        // (net would be 2.1 — RAS refuses).
        let c_cas = cas.select_pinning(&state, StreamHigh);
        assert_eq!(c_cas, 0);
        let mut r = Ras::new(b, 1.2, Box::new(NativeScoring::new()));
        let c_ras = r.select_pinning(&state, StreamHigh);
        assert_ne!(c_ras, 0);
    }
}

//! Scoring backends: the per-core RAS/IAS scores a policy consults.
//!
//! Two interchangeable implementations exist:
//! * [`NativeScoring`] (here) — straight Rust over the paper's equations.
//!   On a cached [`PlacementState`] (built via
//!   [`PlacementState::with_bank`](super::PlacementState::with_bank)) it
//!   reads the per-core aggregates, evaluating every core in O(members)
//!   with no allocation; on a plain state it falls back to the
//!   from-scratch [`reference_scores_with`] evaluation of Eq. 2–4.
//! * `runtime::scoring::XlaScoring` — executes the AOT-compiled Pallas
//!   scoring kernel through PJRT (one fused call for all cores).
//!
//! The integration tests assert both produce identical decisions; the
//! `scoring_backend` bench compares their latency and quantifies the
//! incremental-vs-reference speedup.

use super::{PlacementState, ScoreCache};
use crate::interference::{core_interference, core_overload, cpu_overload};
use crate::profiling::ProfileBank;
use crate::workloads::{MetricVec, WorkloadClass, NUM_METRICS};

/// A reusable flat SoA score buffer: `lanes × width` f64 values in one
/// contiguous allocation, each lane a dense column over the scored
/// entities (cores for a [`Scores`] pass, candidate hosts for the
/// cluster dispatch matrix pass). One `ScoreBuf` is held for the
/// caller's lifetime and `reset` to any shape without reallocating once
/// it has grown to its steady-state size — the same allocation-free
/// contract as [`ScoringBackend::score_into`], and the buffer type that
/// pass shares with the cluster's batched `ArrivalPolicy::rank`.
#[derive(Debug, Clone, Default)]
pub struct ScoreBuf {
    data: Vec<f64>,
    width: usize,
}

impl ScoreBuf {
    /// Reshape to `lanes × width`, zero-filled. Keeps the allocation.
    pub fn reset(&mut self, lanes: usize, width: usize) {
        self.width = width;
        self.data.clear();
        self.data.resize(lanes * width, 0.0);
    }

    /// Entries per lane.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of lanes in the current shape.
    pub fn lanes(&self) -> usize {
        if self.width == 0 {
            0
        } else {
            self.data.len() / self.width
        }
    }

    /// One lane as a dense slice.
    pub fn lane(&self, lane: usize) -> &[f64] {
        &self.data[lane * self.width..(lane + 1) * self.width]
    }

    /// One lane, mutable.
    pub fn lane_mut(&mut self, lane: usize) -> &mut [f64] {
        let w = self.width;
        &mut self.data[lane * w..(lane + 1) * w]
    }

    /// Copy `src` into a lane (`src.len()` must equal the width).
    pub fn fill_lane(&mut self, lane: usize, src: &[f64]) {
        self.lane_mut(lane).copy_from_slice(src);
    }
}

/// Per-core scores for placing one candidate workload — four lanes
/// (RAS overload and IAS interference, each before/after placing the
/// candidate) over one flat [`ScoreBuf`].
#[derive(Debug, Clone, Default)]
pub struct Scores {
    buf: ScoreBuf,
}

/// [`Scores`] lane indices into its backing [`ScoreBuf`].
const OL_BEFORE: usize = 0;
const OL_AFTER: usize = 1;
const IC_BEFORE: usize = 2;
const IC_AFTER: usize = 3;

impl Scores {
    const LANES: usize = 4;

    /// Reshape to `cores` entries per lane, zeroed; `score_into`
    /// implementations call this so schedulers can reuse one buffer
    /// across decisions.
    pub fn reset(&mut self, cores: usize) {
        self.buf.reset(Self::LANES, cores);
    }

    /// Drop all columns (a zero-core reset).
    pub fn clear(&mut self) {
        self.buf.reset(Self::LANES, 0);
    }

    /// Number of scored cores.
    pub fn cores(&self) -> usize {
        self.buf.width()
    }

    /// Write one core's four scores.
    pub fn set(&mut self, core: usize, ol_before: f64, ol_after: f64, ic_before: f64, ic_after: f64) {
        self.buf.lane_mut(OL_BEFORE)[core] = ol_before;
        self.buf.lane_mut(OL_AFTER)[core] = ol_after;
        self.buf.lane_mut(IC_BEFORE)[core] = ic_before;
        self.buf.lane_mut(IC_AFTER)[core] = ic_after;
    }

    /// RAS overload per core, without the candidate (Eq. 2).
    pub fn ol_before(&self) -> &[f64] {
        self.buf.lane(OL_BEFORE)
    }

    /// RAS overload per core, with the candidate added to that core.
    pub fn ol_after(&self) -> &[f64] {
        self.buf.lane(OL_AFTER)
    }

    /// IAS core interference per core, without the candidate (Eq. 3+4).
    pub fn ic_before(&self) -> &[f64] {
        self.buf.lane(IC_BEFORE)
    }

    /// IAS core interference with the candidate added.
    pub fn ic_after(&self) -> &[f64] {
        self.buf.lane(IC_AFTER)
    }

    /// The backing flat buffer.
    pub fn as_buf(&self) -> &ScoreBuf {
        &self.buf
    }
}

/// A backend that evaluates the scores for all cores in one call.
///
/// The trait deliberately does not require `Send`: the XLA backend holds
/// PJRT handles and must stay on the thread that created it. The
/// schedulers are generic over the backend instead, so a
/// [`NativeScoring`]-backed scheduler is `Send` (and can shard across
/// cluster worker threads) while an XLA-backed one is pinned to the
/// caller thread by the type system.
pub trait ScoringBackend {
    /// Evaluate into a caller-owned buffer. `cpu_only` restricts the
    /// overload metric to CPU (the CAS variant). The schedulers hold one
    /// [`Scores`] and reuse it every decision, keeping the hot path
    /// allocation-free.
    fn score_into(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
        out: &mut Scores,
    );

    /// Allocating convenience wrapper around [`Self::score_into`].
    fn score(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
    ) -> Scores {
        let mut out = Scores::default();
        self.score_into(state, cand, bank, thr, cpu_only, &mut out);
        out
    }

    fn name(&self) -> &'static str;
}

/// WI-formula variant, for the ablation the paper motivates in §IV-B.2
/// (why the mean of sum and product, not sum-only or product-only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WiMode {
    /// Paper Eq. 3: (Σ + Π)/2.
    MeanSumProd,
    /// Σ only — overestimates for insensitive workloads.
    SumOnly,
    /// Π only — underestimates (predicts 1.0 for S = 1 co-runners).
    ProdOnly,
}

/// Eq. 3 from its running partials: WI is a function of the co-runner
/// slowdown sum and product only, which is what makes it incrementally
/// maintainable.
pub fn wi_from_parts(mode: WiMode, sum: f64, prod: f64) -> f64 {
    match mode {
        WiMode::MeanSumProd => 0.5 * (sum + prod),
        WiMode::SumOnly => sum,
        WiMode::ProdOnly => prod,
    }
}

fn wi_with(mode: WiMode, slowdowns: &[f64]) -> f64 {
    let sum: f64 = slowdowns.iter().sum();
    let prod: f64 = slowdowns.iter().product();
    wi_from_parts(mode, sum, prod)
}

/// Pure-Rust scoring.
#[derive(Debug)]
pub struct NativeScoring {
    wi_mode: WiMode,
}

impl Default for NativeScoring {
    fn default() -> Self {
        NativeScoring::new()
    }
}

impl NativeScoring {
    pub fn new() -> Self {
        NativeScoring {
            wi_mode: WiMode::MeanSumProd,
        }
    }

    /// Ablation constructor: swap the WI formula (benches/ablation_wi.rs).
    pub fn with_wi_mode(wi_mode: WiMode) -> Self {
        NativeScoring { wi_mode }
    }
}

fn mask_cpu(u: MetricVec) -> MetricVec {
    [u[0], 0.0, 0.0, 0.0]
}

/// The incremental hot path: one pass over the cores, each evaluated from
/// the cached aggregates in O(members) with no allocation. The caller
/// guarantees `state.cache()` is present. The candidate's U row and S
/// entries come from the cache's own bank — the same one the aggregates
/// were derived from — so a caller cannot accidentally mix two banks.
fn incremental_into(
    mode: WiMode,
    state: &PlacementState,
    cand: WorkloadClass,
    thr: f64,
    cpu_only: bool,
    out: &mut Scores,
) {
    let cache: &ScoreCache = state.cache().expect("incremental scoring needs a cached state");
    let bank = cache.bank();
    out.reset(state.cores.len());
    let ci = cand.index();
    let cu = bank.u[ci];
    for (core, members) in state.cores.iter().enumerate() {
        // ---- RAS overload (Eq. 2): threshold clip of the cached sum ----
        let lb = cache.load(core);
        let (ol_b, ol_a) = if cpu_only {
            ((lb[0] - thr).max(0.0), (lb[0] + cu[0] - thr).max(0.0))
        } else {
            let mut before = 0.0;
            let mut after = 0.0;
            for j in 0..NUM_METRICS {
                before += (lb[j] - thr).max(0.0);
                after += (lb[j] + cu[j] - thr).max(0.0);
            }
            (before, after)
        };

        // ---- IAS interference (Eq. 3+4): each member's WI (with and
        // without the candidate) comes from its cached (Σ, Π) in O(1) ----
        let parts = cache.wi_parts(core);
        let mut ic_b = 0.0f64;
        let mut ic_a = 0.0f64;
        let mut cand_sum = 0.0;
        let mut cand_prod = 1.0;
        for (pos, &m) in members.iter().enumerate() {
            let (sum, prod) = parts[pos];
            ic_b = ic_b.max(wi_from_parts(mode, sum, prod));
            let s_mc = bank.s[m][ci];
            ic_a = ic_a.max(wi_from_parts(mode, sum + s_mc, prod * s_mc));
            cand_sum += bank.s[ci][m];
            cand_prod *= bank.s[ci][m];
        }
        ic_a = ic_a.max(wi_from_parts(mode, cand_sum, cand_prod));
        out.set(core, ol_b, ol_a, ic_b, ic_a);
    }
}

/// From-scratch evaluation of Eq. 2–4 — O(cores × members²). This is the
/// specification the incremental path is tested against (the parity
/// property in `rust/tests/proptests.rs`), and the fallback for states
/// built without a bank.
fn reference_into(
    mode: WiMode,
    state: &PlacementState,
    cand: WorkloadClass,
    bank: &ProfileBank,
    thr: f64,
    cpu_only: bool,
    out: &mut Scores,
) {
    out.reset(state.cores.len());
    let ci = cand.index();

    for (core, members) in state.cores.iter().enumerate() {
        // ---- RAS overload ----
        let mut loads: Vec<MetricVec> = members.iter().map(|&m| bank.u[m]).collect();
        if cpu_only {
            for l in loads.iter_mut() {
                *l = mask_cpu(*l);
            }
        }
        let (ol_b, ol_a) = if cpu_only {
            let b = cpu_overload(&loads, thr);
            loads.push(mask_cpu(bank.u[ci]));
            (b, cpu_overload(&loads, thr))
        } else {
            let b = core_overload(&loads, thr);
            loads.push(bank.u[ci]);
            (b, core_overload(&loads, thr))
        };

        // ---- IAS interference ----
        // Before: WI of each member against its co-members.
        let wi_b: Vec<f64> = members
            .iter()
            .enumerate()
            .map(|(pos, &m)| {
                let slows: Vec<f64> = members
                    .iter()
                    .enumerate()
                    .filter(|&(p2, _)| p2 != pos)
                    .map(|(_, &m2)| bank.s[m][m2])
                    .collect();
                wi_with(mode, &slows)
            })
            .collect();
        let ic_b = core_interference(&wi_b);

        // After: every member gains the candidate as a co-runner, and
        // the candidate gets its own WI.
        let mut wi_a: Vec<f64> = members
            .iter()
            .enumerate()
            .map(|(pos, &m)| {
                let mut slows: Vec<f64> = members
                    .iter()
                    .enumerate()
                    .filter(|&(p2, _)| p2 != pos)
                    .map(|(_, &m2)| bank.s[m][m2])
                    .collect();
                slows.push(bank.s[m][ci]);
                wi_with(mode, &slows)
            })
            .collect();
        let cand_slows: Vec<f64> = members.iter().map(|&m| bank.s[ci][m]).collect();
        wi_a.push(wi_with(mode, &cand_slows));
        out.set(core, ol_b, ol_a, ic_b, core_interference(&wi_a));
    }
}

/// Public from-scratch reference (paper Eq. 3 WI formula).
pub fn reference_scores(
    state: &PlacementState,
    cand: WorkloadClass,
    bank: &ProfileBank,
    thr: f64,
    cpu_only: bool,
) -> Scores {
    reference_scores_with(WiMode::MeanSumProd, state, cand, bank, thr, cpu_only)
}

/// Public from-scratch reference with an explicit WI formula.
pub fn reference_scores_with(
    mode: WiMode,
    state: &PlacementState,
    cand: WorkloadClass,
    bank: &ProfileBank,
    thr: f64,
    cpu_only: bool,
) -> Scores {
    let mut out = Scores::default();
    reference_into(mode, state, cand, bank, thr, cpu_only, &mut out);
    out
}

impl ScoringBackend for NativeScoring {
    fn score_into(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
        out: &mut Scores,
    ) {
        if state.cache().is_some() {
            // `bank` is intentionally unused here: the cached state carries
            // the bank its aggregates were derived from.
            incremental_into(self.wi_mode, state, cand, thr, cpu_only, out)
        } else {
            reference_into(self.wi_mode, state, cand, bank, thr, cpu_only, out)
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::close;
    use crate::workloads::WorkloadClass::*;

    fn bank() -> ProfileBank {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        ProfileBank::generate(&cfg)
    }

    #[test]
    fn empty_core_scores() {
        let b = bank();
        let state = PlacementState::new(4, false);
        let mut ns = NativeScoring::new();
        let s = ns.score(&state, Blackscholes, &b, 1.2, false);
        assert_eq!(s.ol_before(), vec![0.0; 4]);
        // Alone on an empty core: no overload, WI = 0.5.
        assert_eq!(s.ol_after(), vec![0.0; 4]);
        assert_eq!(s.ic_before(), vec![0.0; 4]);
        for &ic in s.ic_after() {
            assert!(close(ic, 0.5, 1e-12), "{ic}");
        }
    }

    #[test]
    fn empty_core_scores_cached() {
        let b = bank();
        let state = PlacementState::with_bank(4, false, &b);
        let mut ns = NativeScoring::new();
        let s = ns.score(&state, Blackscholes, &b, 1.2, false);
        assert_eq!(s.ol_before(), vec![0.0; 4]);
        assert_eq!(s.ol_after(), vec![0.0; 4]);
        assert_eq!(s.ic_before(), vec![0.0; 4]);
        for &ic in s.ic_after() {
            assert!(close(ic, 0.5, 1e-12), "{ic}");
        }
    }

    #[test]
    fn overload_appears_beyond_threshold() {
        let b = bank();
        let mut state = PlacementState::new(2, false);
        state.place(0, Blackscholes); // ~0.95 cpu
        let mut ns = NativeScoring::new();
        let s = ns.score(&state, Blackscholes, &b, 1.2, false);
        assert!(close(s.ol_before()[0], 0.0, 1e-9));
        // Two blackscholes ≈ 1.9 CPU > 1.2 -> overload ≈ 0.7.
        assert!(s.ol_after()[0] > 0.5, "{}", s.ol_after()[0]);
        assert!(close(s.ol_after()[1], 0.0, 1e-9));
    }

    #[test]
    fn cpu_only_ignores_other_metrics() {
        // Synthetic profile: a class with low CPU but dominant NetIO —
        // the case separating RAS from CAS (§IV-B.1).
        let mut b = bank();
        b.u[StreamHigh.index()] = [0.2, 0.0, 0.7, 0.0];
        let mut state = PlacementState::new(1, false);
        state.place(0, StreamHigh);
        state.place(0, StreamHigh);
        let mut ns = NativeScoring::new();
        let full = ns.score(&state, StreamHigh, &b, 1.2, false);
        let cpu = ns.score(&state, StreamHigh, &b, 1.2, true);
        // Full RAS sees net saturation (3 × 0.7 = 2.1 > 1.2); CAS doesn't
        // (3 × 0.2 = 0.6 < 1.2).
        assert!(full.ol_after()[0] > 0.5, "{}", full.ol_after()[0]);
        assert!(close(cpu.ol_after()[0], 0.0, 1e-9), "{}", cpu.ol_after()[0]);
    }

    #[test]
    fn interference_grows_with_stacking() {
        let b = bank();
        let mut ns = NativeScoring::new();
        let mut state = PlacementState::new(1, false);
        let mut last = 0.0;
        for _ in 0..4 {
            let s = ns.score(&state, Jacobi, &b, 1.2, false);
            assert!(s.ic_after()[0] > last);
            last = s.ic_after()[0];
            state.place(0, Jacobi);
        }
    }

    #[test]
    fn incremental_matches_reference_on_a_fixed_state() {
        let b = bank();
        let mut cached = PlacementState::with_bank(4, false, &b);
        let mut plain = PlacementState::new(4, false);
        for &(core, class) in &[
            (0, Blackscholes),
            (0, StreamLow),
            (1, Jacobi),
            (1, Jacobi),
            (3, LampHeavy),
        ] {
            cached.place(core, class);
            plain.place(core, class);
        }
        let mut ns = NativeScoring::new();
        for cand in [Jacobi, LampLight, Hadoop] {
            for cpu_only in [false, true] {
                let fast = ns.score(&cached, cand, &b, 1.2, cpu_only);
                let slow = ns.score(&plain, cand, &b, 1.2, cpu_only);
                for core in 0..4 {
                    assert!(close(fast.ol_before()[core], slow.ol_before()[core], 1e-12));
                    assert!(close(fast.ol_after()[core], slow.ol_after()[core], 1e-12));
                    assert!(close(fast.ic_before()[core], slow.ic_before()[core], 1e-12));
                    assert!(close(fast.ic_after()[core], slow.ic_after()[core], 1e-12));
                }
            }
        }
    }

    #[test]
    fn score_into_reuses_buffer() {
        let b = bank();
        let state = PlacementState::with_bank(3, false, &b);
        let mut ns = NativeScoring::new();
        let mut out = Scores::default();
        ns.score_into(&state, Jacobi, &b, 1.2, false, &mut out);
        assert_eq!(out.ol_after().len(), 3);
        // Second call into the same buffer must not accumulate.
        ns.score_into(&state, Hadoop, &b, 1.2, false, &mut out);
        assert_eq!(out.ol_after().len(), 3);
        assert_eq!(out.ic_after().len(), 3);
    }
}

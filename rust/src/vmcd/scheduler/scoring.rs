//! Scoring backends: the per-core RAS/IAS scores a policy consults.
//!
//! Two interchangeable implementations exist:
//! * [`NativeScoring`] (here) — straight Rust over the paper's equations;
//! * `runtime::scoring::XlaScoring` — executes the AOT-compiled Pallas
//!   scoring kernel through PJRT (one fused call for all cores).
//!
//! The integration tests assert both produce identical decisions; the
//! `scoring_backend` bench compares their latency.

use super::PlacementState;
use crate::interference::{core_interference, core_overload, cpu_overload};
use crate::profiling::ProfileBank;
use crate::workloads::{MetricVec, WorkloadClass};

/// Per-core scores for placing one candidate workload.
#[derive(Debug, Clone, Default)]
pub struct Scores {
    /// RAS overload per core, without the candidate (Eq. 2).
    pub ol_before: Vec<f64>,
    /// RAS overload per core, with the candidate added to that core.
    pub ol_after: Vec<f64>,
    /// IAS core interference per core, without the candidate (Eq. 3+4).
    pub ic_before: Vec<f64>,
    /// IAS core interference with the candidate added.
    pub ic_after: Vec<f64>,
}

/// A backend that evaluates the scores for all cores in one call.
///
/// Not `Send`: the XLA backend holds PJRT handles (`Rc` internally); the
/// daemon owns its scheduler on one thread, matching VMCd's single-threaded
/// scheduler component.
pub trait ScoringBackend {
    /// `cpu_only` restricts the overload metric to CPU (the CAS variant).
    fn score(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
    ) -> Scores;

    fn name(&self) -> &'static str;
}

/// WI-formula variant, for the ablation the paper motivates in §IV-B.2
/// (why the mean of sum and product, not sum-only or product-only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WiMode {
    /// Paper Eq. 3: (Σ + Π)/2.
    MeanSumProd,
    /// Σ only — overestimates for insensitive workloads.
    SumOnly,
    /// Π only — underestimates (predicts 1.0 for S = 1 co-runners).
    ProdOnly,
}

fn wi_with(mode: WiMode, slowdowns: &[f64]) -> f64 {
    let sum: f64 = slowdowns.iter().sum();
    let prod: f64 = slowdowns.iter().product();
    match mode {
        WiMode::MeanSumProd => 0.5 * (sum + prod),
        WiMode::SumOnly => sum,
        WiMode::ProdOnly => prod,
    }
}

/// Pure-Rust scoring.
#[derive(Debug)]
pub struct NativeScoring {
    wi_mode: WiMode,
}

impl Default for NativeScoring {
    fn default() -> Self {
        NativeScoring::new()
    }
}

impl NativeScoring {
    pub fn new() -> Self {
        NativeScoring {
            wi_mode: WiMode::MeanSumProd,
        }
    }

    /// Ablation constructor: swap the WI formula (benches/ablation_wi.rs).
    pub fn with_wi_mode(wi_mode: WiMode) -> Self {
        NativeScoring { wi_mode }
    }
}

fn mask_cpu(u: MetricVec) -> MetricVec {
    [u[0], 0.0, 0.0, 0.0]
}

impl ScoringBackend for NativeScoring {
    fn score(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
    ) -> Scores {
        let ci = cand.index();
        let ncores = state.cores.len();
        let mut out = Scores {
            ol_before: Vec::with_capacity(ncores),
            ol_after: Vec::with_capacity(ncores),
            ic_before: Vec::with_capacity(ncores),
            ic_after: Vec::with_capacity(ncores),
        };

        for members in &state.cores {
            // ---- RAS overload ----
            let mut loads: Vec<MetricVec> = members.iter().map(|&m| bank.u[m]).collect();
            if cpu_only {
                for l in loads.iter_mut() {
                    *l = mask_cpu(*l);
                }
            }
            let (ol_b, ol_a) = if cpu_only {
                let b = cpu_overload(&loads, thr);
                loads.push(mask_cpu(bank.u[ci]));
                (b, cpu_overload(&loads, thr))
            } else {
                let b = core_overload(&loads, thr);
                loads.push(bank.u[ci]);
                (b, core_overload(&loads, thr))
            };
            out.ol_before.push(ol_b);
            out.ol_after.push(ol_a);

            // ---- IAS interference ----
            // Before: WI of each member against its co-members.
            let wi_b: Vec<f64> = members
                .iter()
                .enumerate()
                .map(|(pos, &m)| {
                    let slows: Vec<f64> = members
                        .iter()
                        .enumerate()
                        .filter(|&(p2, _)| p2 != pos)
                        .map(|(_, &m2)| bank.s[m][m2])
                        .collect();
                    wi_with(self.wi_mode, &slows)
                })
                .collect();
            out.ic_before.push(core_interference(&wi_b));

            // After: every member gains the candidate as a co-runner, and
            // the candidate gets its own WI.
            let mut wi_a: Vec<f64> = members
                .iter()
                .enumerate()
                .map(|(pos, &m)| {
                    let mut slows: Vec<f64> = members
                        .iter()
                        .enumerate()
                        .filter(|&(p2, _)| p2 != pos)
                        .map(|(_, &m2)| bank.s[m][m2])
                        .collect();
                    slows.push(bank.s[m][ci]);
                    wi_with(self.wi_mode, &slows)
                })
                .collect();
            let cand_slows: Vec<f64> = members.iter().map(|&m| bank.s[ci][m]).collect();
            wi_a.push(wi_with(self.wi_mode, &cand_slows));
            out.ic_after.push(core_interference(&wi_a));
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::close;
    use crate::workloads::WorkloadClass::*;

    fn bank() -> ProfileBank {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        ProfileBank::generate(&cfg)
    }

    #[test]
    fn empty_core_scores() {
        let b = bank();
        let state = PlacementState::new(4, false);
        let mut ns = NativeScoring::new();
        let s = ns.score(&state, Blackscholes, &b, 1.2, false);
        assert_eq!(s.ol_before, vec![0.0; 4]);
        // Alone on an empty core: no overload, WI = 0.5.
        assert_eq!(s.ol_after, vec![0.0; 4]);
        assert_eq!(s.ic_before, vec![0.0; 4]);
        for &ic in &s.ic_after {
            assert!(close(ic, 0.5, 1e-12), "{ic}");
        }
    }

    #[test]
    fn overload_appears_beyond_threshold() {
        let b = bank();
        let mut state = PlacementState::new(2, false);
        state.place(0, Blackscholes); // ~0.95 cpu
        let mut ns = NativeScoring::new();
        let s = ns.score(&state, Blackscholes, &b, 1.2, false);
        assert!(close(s.ol_before[0], 0.0, 1e-9));
        // Two blackscholes ≈ 1.9 CPU > 1.2 -> overload ≈ 0.7.
        assert!(s.ol_after[0] > 0.5, "{}", s.ol_after[0]);
        assert!(close(s.ol_after[1], 0.0, 1e-9));
    }

    #[test]
    fn cpu_only_ignores_other_metrics() {
        // Synthetic profile: a class with low CPU but dominant NetIO —
        // the case separating RAS from CAS (§IV-B.1).
        let mut b = bank();
        b.u[StreamHigh.index()] = [0.2, 0.0, 0.7, 0.0];
        let mut state = PlacementState::new(1, false);
        state.place(0, StreamHigh);
        state.place(0, StreamHigh);
        let mut ns = NativeScoring::new();
        let full = ns.score(&state, StreamHigh, &b, 1.2, false);
        let cpu = ns.score(&state, StreamHigh, &b, 1.2, true);
        // Full RAS sees net saturation (3 × 0.7 = 2.1 > 1.2); CAS doesn't
        // (3 × 0.2 = 0.6 < 1.2).
        assert!(full.ol_after[0] > 0.5, "{}", full.ol_after[0]);
        assert!(close(cpu.ol_after[0], 0.0, 1e-9), "{}", cpu.ol_after[0]);
    }

    #[test]
    fn interference_grows_with_stacking() {
        let b = bank();
        let mut ns = NativeScoring::new();
        let mut state = PlacementState::new(1, false);
        let mut last = 0.0;
        for _ in 0..4 {
            let s = ns.score(&state, Jacobi, &b, 1.2, false);
            assert!(s.ic_after[0] > last);
            last = s.ic_after[0];
            state.place(0, Jacobi);
        }
    }
}

//! RRS — the Round-Robin baseline (§V-C.1).
//!
//! "Iterates over the list of workloads, pinning each workload in sequence
//! on a different core. RRS is interference and resource unaware, and
//! unable to detect whether a workload is in running state or idle."

use super::{PlacementState, Policy, Scheduler};
use crate::workloads::WorkloadClass;

#[derive(Debug, Default)]
pub struct Rrs {
    next: usize,
}

impl Rrs {
    pub fn new() -> Self {
        Rrs { next: 0 }
    }
}

impl Scheduler for Rrs {
    fn policy(&self) -> Policy {
        Policy::Rrs
    }

    fn select_pinning(&mut self, state: &PlacementState, _class: WorkloadClass) -> usize {
        // RRS ignores the idle-core reservation too — it has no idle
        // detection, so it cycles over ALL physical cores.
        let cores = state.cores.len();
        let core = self.next % cores;
        self.next += 1;
        core
    }

    fn dynamic(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_over_all_cores() {
        let mut rrs = Rrs::new();
        let state = PlacementState::new(4, false);
        let picks: Vec<usize> = (0..6)
            .map(|_| rrs.select_pinning(&state, WorkloadClass::Hadoop))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn is_static() {
        assert!(!Rrs::new().dynamic());
    }
}

//! IAS — the Interference-Aware Scheduler (paper Algorithm 3).
//!
//! Scans the cores: the first core whose interference I_c (Eq. 3 + 4)
//! stays below the threshold (Eq. 5, ≈ mean of S ≈ 1.5 on the paper's
//! testbed) after adding the workload wins; otherwise the core with the
//! minimum resulting interference.

use super::scoring::{Scores, ScoringBackend};
use super::{PlacementState, Policy, Scheduler};
use crate::profiling::ProfileBank;
use crate::workloads::WorkloadClass;
use std::sync::Arc;

/// Generic over the scoring backend so a natively-scored instance
/// (`Ias<NativeScoring>`) is `Send` for the sharded cluster, while
/// `Ias<dyn ScoringBackend>` (the default) still boxes any backend.
pub struct Ias<B: ?Sized + ScoringBackend = dyn ScoringBackend> {
    /// Shared with every state this scheduler builds (`new_state`).
    bank: Arc<ProfileBank>,
    /// The interference acceptance threshold (Eq. 5).
    pub threshold: f64,
    /// Reused score buffer — one allocation for the scheduler's lifetime.
    scores: Scores,
    backend: Box<B>,
}

impl<B: ?Sized + ScoringBackend> Ias<B> {
    pub fn new(bank: ProfileBank, threshold: f64, backend: Box<B>) -> Self {
        Ias {
            bank: Arc::new(bank),
            threshold,
            backend,
            scores: Scores::default(),
        }
    }
}

impl<B: ?Sized + ScoringBackend> Scheduler for Ias<B> {
    fn policy(&self) -> Policy {
        Policy::Ias
    }

    fn select_pinning(&mut self, state: &PlacementState, class: WorkloadClass) -> usize {
        // thr argument is irrelevant to the IAS fields of the scores; pass
        // the RAS default so a shared (XLA) backend computes both.
        self.backend
            .score_into(state, class, &self.bank, 1.2, false, &mut self.scores);
        let scores = &self.scores;

        // Alg. 3 lines 2-4: first core below the interference threshold.
        for &core in &state.allowed {
            if scores.ic_after()[core] < self.threshold {
                return core;
            }
        }
        // Alg. 3 lines 5-12: min interference after placement.
        let mut best = state.allowed[0];
        let mut best_ic = f64::INFINITY;
        for &core in &state.allowed {
            if scores.ic_after()[core] < best_ic {
                best_ic = scores.ic_after()[core];
                best = core;
            }
        }
        best
    }

    fn new_state(&self, cores: usize, reserve_idle_core: bool) -> PlacementState {
        PlacementState::with_shared_bank(cores, reserve_idle_core, Arc::clone(&self.bank))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::vmcd::scheduler::NativeScoring;
    use crate::workloads::WorkloadClass::*;

    fn bank() -> ProfileBank {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        ProfileBank::generate(&cfg)
    }

    fn ias(b: &ProfileBank) -> Ias {
        let thr = b.mean_slowdown();
        Ias::new(b.clone(), thr, Box::new(NativeScoring::new()))
    }

    #[test]
    fn consolidates_light_workloads_pairwise() {
        let b = bank();
        let mut s = ias(&b);
        let mut state = PlacementState::new(12, false);
        // Light latency VMs barely interfere pairwise: the second stacks on
        // core 0. The WI product term grows with k, so the third may spill —
        // but never beyond core 1 (i.e. IAS halves the footprint at least).
        let c0 = s.select_pinning(&state, LampLight);
        assert_eq!(c0, 0);
        state.place(c0, LampLight);
        let c1 = s.select_pinning(&state, LampLight);
        assert_eq!(c1, 0, "light pair must consolidate");
        state.place(c1, LampLight);
        let c2 = s.select_pinning(&state, LampLight);
        assert!(c2 <= 1, "third light VM stays compact, got {c2}");
    }

    #[test]
    fn separates_heavy_interferers() {
        let b = bank();
        let mut s = ias(&b);
        let mut state = PlacementState::new(12, false);
        let c0 = s.select_pinning(&state, Jacobi);
        state.place(c0, Jacobi);
        // A second jacobi on the same core would blow past the threshold
        // (S[jacobi][jacobi] ≈ 2.2 > 1.5): IAS must pick another core.
        let c1 = s.select_pinning(&state, Jacobi);
        assert_ne!(c0, c1);
    }

    #[test]
    fn oversubscription_picks_min_interference() {
        let b = bank();
        let mut s = ias(&b);
        // Two cores, both over threshold; one is lighter.
        let mut state = PlacementState::new(2, false);
        state.place(0, Jacobi);
        state.place(0, Jacobi);
        state.place(1, Jacobi);
        let c = s.select_pinning(&state, Jacobi);
        assert_eq!(c, 1, "pick the less interfering core");
    }

    #[test]
    fn threshold_derived_from_bank_mean() {
        let b = bank();
        let s = ias(&b);
        assert!((1.0..1.6).contains(&s.threshold), "{}", s.threshold);
        assert!((s.threshold - b.mean_slowdown()).abs() < 1e-12);
    }

    #[test]
    fn keeps_lamp_away_from_hogs_when_possible() {
        let b = bank();
        let mut s = ias(&b);
        let mut state = PlacementState::new(3, false);
        state.place(0, Jacobi);
        state.place(1, LampLight);
        // LampHeavy: core 1 (lamp-light) interferes least; cores are
        // scanned in order and core 0 (jacobi) exceeds nothing yet…
        let c = s.select_pinning(&state, LampHeavy);
        // Must not stack on the jacobi core if its interference crosses
        // the threshold; accept either 1 or 2 but never 0 with high S.
        let s_lh_jac = b.slowdown(LampHeavy, Jacobi);
        if s_lh_jac > s.threshold {
            assert_ne!(c, 0);
        }
    }
}

//! Placement policies.
//!
//! All four schedulers implement [`Scheduler::select_pinning`] — the
//! `SelectPinning` procedure of the paper's Algorithms 2 and 3. The daemon
//! (Alg. 1) owns a [`PlacementState`] of already-placed running
//! workloads and asks the policy where to pin the next one.
//!
//! Scoring is incremental: a [`PlacementState`] built with
//! [`PlacementState::with_bank`] carries a [`ScoreCache`] of per-core
//! aggregates (composite load vectors for Eq. 2, per-member WI partials
//! for Eq. 3) that [`PlacementState::place`] keeps up to date with delta
//! updates, so one `SelectPinning` decision costs O(resident VMs) instead
//! of O(cores × members²). [`Scheduler::new_state`] hands the daemon a
//! state pre-wired with the policy's own profile bank.
//!
//! The state is also **long-lived**: [`PlacementState::remove`] reverses
//! a `place` in O(members), so an event-driven daemon mutates one state
//! across the host's whole lifetime (arrivals, departures, idle/wake
//! churn, re-pin passes) instead of rebuilding it from a monitor snapshot
//! every cycle. [`PlacementState::cache_matches_rebuild`] is the
//! reconciliation check (delta aggregates vs a from-scratch re-sum) the
//! daemon runs under `debug_assert!`.

pub mod cas;
pub mod ias;
pub mod ras;
pub mod rrs;
pub mod scoring;

use crate::profiling::ProfileBank;
use crate::workloads::{MetricVec, WorkloadClass, NUM_METRICS};
use std::sync::Arc;

pub use scoring::{NativeScoring, ScoreBuf, Scores, ScoringBackend};

/// Which policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Round-Robin Scheduler — the paper's baseline: static, interference-
    /// and resource-unaware, cannot detect idle workloads.
    Rrs,
    /// CPU-Aware Scheduler — RAS restricted to the CPU metric (§IV-B.1).
    Cas,
    /// Resource-Aware Scheduler — Alg. 2 over all four metrics.
    Ras,
    /// Interference-Aware Scheduler — Alg. 3 over the S matrix.
    Ias,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Rrs => "rrs",
            Policy::Cas => "cas",
            Policy::Ras => "ras",
            Policy::Ias => "ias",
        }
    }

    pub fn from_name(name: &str) -> Option<Policy> {
        match name.to_ascii_lowercase().as_str() {
            "rrs" => Some(Policy::Rrs),
            "cas" => Some(Policy::Cas),
            "ras" => Some(Policy::Ras),
            "ias" => Some(Policy::Ias),
            _ => None,
        }
    }

    /// [`Self::from_name`] as a `Result`: case-insensitive, and the error
    /// lists the valid names (what the CLI surfaces on a typo).
    pub fn parse(name: &str) -> anyhow::Result<Policy> {
        Policy::from_name(name).ok_or_else(|| {
            let valid: Vec<&str> = Policy::ALL.iter().map(|p| p.name()).collect();
            anyhow::anyhow!(
                "unknown policy '{name}' (valid: {})",
                valid.join(", ")
            )
        })
    }

    pub const ALL: [Policy; 4] = [Policy::Rrs, Policy::Cas, Policy::Ras, Policy::Ias];
}

/// Cached per-core scoring aggregates, maintained by
/// [`PlacementState::place`].
///
/// The aggregates make the paper's equations incremental:
/// * Eq. 2 — the composite load is a running vector sum, so the overload
///   of a core (with or without a candidate) is a threshold clip of a
///   cached vector rather than a re-sum over its members.
/// * Eq. 3 — WI is a function of `(Σ_j S[i][j], Π_j S[i][j])` over the
///   co-runners, so each member carries its running `(Σ, Π)` pair and
///   gains a co-runner in O(1).
///
/// The aggregates are derived from the bank captured at construction,
/// and the incremental scoring path reads the candidate's rows from that
/// same bank (via [`Self::bank`]), so cached scores can never mix two
/// banks.
#[derive(Debug, Clone)]
pub struct ScoreCache {
    /// Shared, not cloned: the schedulers hand their own bank to every
    /// `new_state` call, so the cache must not deep-copy the S/U matrices
    /// per decision cycle.
    bank: Arc<ProfileBank>,
    /// Per-core composite load: Σ U over the core's members.
    load: Vec<MetricVec>,
    /// Per-core WI partials, parallel to `PlacementState::cores[c]`:
    /// `(Σ_j S[m][j], Π_j S[m][j])` of member m over its co-members.
    wi: Vec<Vec<(f64, f64)>>,
}

impl ScoreCache {
    fn new(cores: usize, bank: Arc<ProfileBank>) -> ScoreCache {
        ScoreCache {
            bank,
            load: vec![[0.0; NUM_METRICS]; cores],
            wi: vec![Vec::new(); cores],
        }
    }

    /// Composite load vector of `core` (Σ U over its members).
    pub fn load(&self, core: usize) -> MetricVec {
        self.load[core]
    }

    /// WI partials `(Σ, Π)` of each member of `core` vs its co-members,
    /// in member order.
    pub fn wi_parts(&self, core: usize) -> &[(f64, f64)] {
        &self.wi[core]
    }

    /// The bank the aggregates were derived from.
    pub fn bank(&self) -> &ProfileBank {
        &self.bank
    }
}

/// The incremental placement state the daemon builds while re-pinning:
/// for each core, the class indices of the running workloads already
/// placed there this cycle, plus (when built via [`Self::with_bank`]) the
/// cached scoring aggregates.
#[derive(Debug, Clone)]
pub struct PlacementState {
    /// Per-core class indices (into [`ProfileBank::classes`]).
    pub cores: Vec<Vec<usize>>,
    /// Cores the policy may use for running workloads (excludes the idle
    /// parking core when idle workloads exist — Alg. 1 pins idle workloads
    /// on core 0 and running ones on "the rest of the server's cores").
    pub allowed: Vec<usize>,
    cache: Option<ScoreCache>,
}

impl PlacementState {
    pub fn new(cores: usize, reserve_idle_core: bool) -> PlacementState {
        PlacementState {
            cores: vec![Vec::new(); cores],
            allowed: PlacementState::allowed_cores(cores, reserve_idle_core),
            cache: None,
        }
    }

    fn allowed_cores(cores: usize, reserve_idle_core: bool) -> Vec<usize> {
        let mut allowed: Vec<usize> = if reserve_idle_core {
            (1..cores).collect()
        } else {
            (0..cores).collect()
        };
        // A 1-core host cannot afford a dedicated idle core: the policies
        // still need one legal core, so core 0 double-duties for idle and
        // running workloads.
        if allowed.is_empty() && cores > 0 {
            allowed.push(0);
        }
        allowed
    }

    /// Flip the idle-core reservation on a live state. A long-lived state
    /// crosses idle-set-empty boundaries many times (Alg. 1 reserves core
    /// 0 only while idle workloads exist); membership and cached
    /// aggregates are untouched — only the `allowed` set changes, and the
    /// next re-pin pass moves running workloads off the idle core.
    pub fn set_idle_reservation(&mut self, reserve: bool) {
        self.allowed = PlacementState::allowed_cores(self.cores.len(), reserve);
    }

    /// A state carrying the incremental [`ScoreCache`] derived from
    /// `bank`. Placements keep the cached aggregates current, so scoring
    /// backends skip the from-scratch Eq. 2–4 evaluation. Clones the bank
    /// once; hot-path callers that build states repeatedly should hold an
    /// `Arc` and use [`Self::with_shared_bank`].
    pub fn with_bank(
        cores: usize,
        reserve_idle_core: bool,
        bank: &ProfileBank,
    ) -> PlacementState {
        PlacementState::with_shared_bank(cores, reserve_idle_core, Arc::new(bank.clone()))
    }

    /// [`Self::with_bank`] without the deep copy — what
    /// [`Scheduler::new_state`] uses every arrival / re-pin cycle.
    pub fn with_shared_bank(
        cores: usize,
        reserve_idle_core: bool,
        bank: Arc<ProfileBank>,
    ) -> PlacementState {
        let mut state = PlacementState::new(cores, reserve_idle_core);
        state.cache = Some(ScoreCache::new(cores, bank));
        state
    }

    /// The cached aggregates, if this state was built with a bank.
    pub fn cache(&self) -> Option<&ScoreCache> {
        self.cache.as_ref()
    }

    /// Record a placement decided this cycle. With a cache attached this
    /// applies the delta updates: the core's load vector gains the
    /// newcomer's U row, every resident member's WI partials gain one
    /// pairwise slowdown (O(1) each), and the newcomer's own partials are
    /// accumulated over the residents.
    pub fn place(&mut self, core: usize, class: WorkloadClass) {
        let x = class.index();
        if let Some(cache) = &mut self.cache {
            let members = &self.cores[core];
            let u = cache.bank.u[x];
            for j in 0..NUM_METRICS {
                cache.load[core][j] += u[j];
            }
            let (mut sum_x, mut prod_x) = (0.0, 1.0);
            for (pos, &m) in members.iter().enumerate() {
                let s_mx = cache.bank.s[m][x];
                let part = &mut cache.wi[core][pos];
                part.0 += s_mx;
                part.1 *= s_mx;
                sum_x += cache.bank.s[x][m];
                prod_x *= cache.bank.s[x][m];
            }
            cache.wi[core].push((sum_x, prod_x));
        }
        self.cores[core].push(x);
    }

    /// Reverse a [`Self::place`] in O(members of `core`): the departing
    /// workload's U row leaves the core's composite load vector, every
    /// remaining member's WI partials drop one pairwise slowdown
    /// (`Σ -= S[m][x]`, `Π /= S[m][x]` — S entries are strictly positive
    /// slowdown ratios), and the member's own partials entry is dropped.
    ///
    /// Removes the most recent member of that class on the core (members
    /// of one class are interchangeable under Eq. 2–4). Returns `false`
    /// (state unchanged) when no such member exists.
    pub fn remove(&mut self, core: usize, class: WorkloadClass) -> bool {
        let x = class.index();
        if core >= self.cores.len() {
            return false;
        }
        let Some(pos) = self.cores[core].iter().rposition(|&m| m == x) else {
            return false;
        };
        self.cores[core].remove(pos);
        if let Some(cache) = &mut self.cache {
            let u = cache.bank.u[x];
            for j in 0..NUM_METRICS {
                cache.load[core][j] -= u[j];
            }
            cache.wi[core].remove(pos);
            for (p2, &m) in self.cores[core].iter().enumerate() {
                let s_mx = cache.bank.s[m][x];
                debug_assert!(s_mx > 0.0, "slowdown matrix entries must be positive");
                let part = &mut cache.wi[core][p2];
                part.0 -= s_mx;
                part.1 /= s_mx;
            }
        }
        true
    }

    /// Total placed running workloads.
    pub fn placed(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }

    /// Worst per-core workload interference over the current placement —
    /// Eq. 4 (`max` over members) of Eq. 3 (`WI = (Σ + Π) / 2`), read
    /// straight from the cached WI partials. 0 for uncached or empty
    /// states (an empty core has interference 0); a solo member scores
    /// the alone-value 0.5. This is what hosts publish in their cluster
    /// [`HostSummary`](crate::cluster::HostSummary) so arrival policies
    /// can see interference without touching placement state.
    pub fn max_core_wi(&self) -> f64 {
        let Some(cache) = &self.cache else { return 0.0 };
        let mut worst = 0.0f64;
        for core in 0..self.cores.len() {
            for &(sum, prod) in cache.wi_parts(core) {
                worst = worst.max(0.5 * (sum + prod));
            }
        }
        worst
    }

    /// Reconciliation: do the cached aggregates equal a from-scratch
    /// re-sum of Eq. 2–3 partials over the current membership? This is
    /// the old rebuild-per-cycle path demoted to a check; the
    /// event-driven daemon runs it under `debug_assert!` after each
    /// re-pin pass. Always `true` for uncached states.
    ///
    /// `tol` is [`crate::util::close`]'s absolute-or-relative bound: the
    /// Π partial grows like `S^members` (astronomical on crowded cores),
    /// where delta updates and a re-multiply differ by reordering ULPs —
    /// only a relative comparison is meaningful there.
    pub fn cache_matches_rebuild(&self, tol: f64) -> bool {
        let close = |a: f64, b: f64| crate::util::close(a, b, tol);
        let Some(cache) = &self.cache else { return true };
        let bank = cache.bank();
        for (core, members) in self.cores.iter().enumerate() {
            let mut load = [0.0f64; NUM_METRICS];
            for &m in members {
                for j in 0..NUM_METRICS {
                    load[j] += bank.u[m][j];
                }
            }
            let got = cache.load(core);
            for j in 0..NUM_METRICS {
                if !close(got[j], load[j]) {
                    return false;
                }
            }
            let parts = cache.wi_parts(core);
            if parts.len() != members.len() {
                return false;
            }
            for (pos, &m) in members.iter().enumerate() {
                let mut sum = 0.0;
                let mut prod = 1.0;
                for (p2, &m2) in members.iter().enumerate() {
                    if p2 != pos {
                        sum += bank.s[m][m2];
                        prod *= bank.s[m][m2];
                    }
                }
                if !close(parts[pos].0, sum) || !close(parts[pos].1, prod) {
                    return false;
                }
            }
        }
        true
    }
}

/// A placement policy.
pub trait Scheduler {
    fn policy(&self) -> Policy;

    /// Choose the core for the next running workload (the paper's
    /// `SelectPinning`). Must return a member of `state.allowed`.
    fn select_pinning(&mut self, state: &PlacementState, class: WorkloadClass) -> usize;

    /// Build the placement state this policy scores against. Scoring
    /// policies attach their profile bank so decisions run on the
    /// incremental cache; the default is a plain (uncached) state.
    fn new_state(&self, cores: usize, reserve_idle_core: bool) -> PlacementState {
        PlacementState::new(cores, reserve_idle_core)
    }

    /// Whether the policy participates in the periodic re-pin + idle
    /// consolidation loop. RRS is static: it pins at arrival and never
    /// reconsiders ("unable to detect whether a workload is in running
    /// state or idle", §V-C.1).
    fn dynamic(&self) -> bool {
        true
    }
}

/// Build a scheduler for `policy` with the native scoring backend.
pub fn build(
    policy: Policy,
    bank: &ProfileBank,
    ras_thr: f64,
    ias_thr: Option<f64>,
) -> Box<dyn Scheduler> {
    build_native(policy, bank, ras_thr, ias_thr)
}

/// The Eq. 5 defaulting rule shared by every factory: an explicit IAS
/// threshold wins, otherwise it derives from the profiled S matrix.
fn ias_threshold(bank: &ProfileBank, ias_thr: Option<f64>) -> f64 {
    ias_thr.unwrap_or_else(|| bank.mean_slowdown())
}

/// [`build`] with the `Send` bound the sharded cluster needs: the native
/// backend is plain data, so a natively-scored scheduler can move to a
/// worker thread. (XLA-backed schedulers hold PJRT handles and are
/// deliberately not `Send` — they only exist via [`build_with_backend`].)
///
/// Mirrors [`build_with_backend`]'s policy dispatch; keep the two in
/// lockstep when adding a policy.
pub fn build_native(
    policy: Policy,
    bank: &ProfileBank,
    ras_thr: f64,
    ias_thr: Option<f64>,
) -> Box<dyn Scheduler + Send> {
    let native = || Box::new(NativeScoring::new());
    match policy {
        Policy::Rrs => Box::new(rrs::Rrs::new()),
        Policy::Cas => Box::new(cas::new(bank.clone(), ras_thr, native())),
        Policy::Ras => Box::new(ras::Ras::new(bank.clone(), ras_thr, native())),
        Policy::Ias => {
            let thr = ias_threshold(bank, ias_thr);
            Box::new(ias::Ias::new(bank.clone(), thr, native()))
        }
    }
}

/// Build a scheduler with an explicit scoring backend (native or XLA).
pub fn build_with_backend(
    policy: Policy,
    bank: &ProfileBank,
    ras_thr: f64,
    ias_thr: Option<f64>,
    backend: Box<dyn ScoringBackend>,
) -> Box<dyn Scheduler> {
    match policy {
        Policy::Rrs => Box::new(rrs::Rrs::new()),
        Policy::Cas => Box::new(cas::new(bank.clone(), ras_thr, backend)),
        Policy::Ras => Box::new(ras::Ras::new(bank.clone(), ras_thr, backend)),
        Policy::Ias => {
            let thr = ias_threshold(bank, ias_thr);
            Box::new(ias::Ias::new(bank.clone(), thr, backend))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::workloads::ALL_CLASSES;

    #[test]
    fn policy_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert_eq!(Policy::from_name("IAS"), Some(Policy::Ias));
        assert_eq!(Policy::from_name("bogus"), None);
    }

    #[test]
    fn policy_parse_is_case_insensitive_and_errors_list_names() {
        assert_eq!(Policy::parse("RaS").unwrap(), Policy::Ras);
        assert_eq!(Policy::parse("IAS").unwrap(), Policy::Ias);
        let err = Policy::parse("bogus").unwrap_err().to_string();
        for name in ["bogus", "rrs", "cas", "ras", "ias"] {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn max_core_wi_matches_the_interference_reference() {
        use crate::interference::workload_interference;
        let bank = testkit::shared_bank();
        // Uncached and empty states publish 0.
        assert_eq!(PlacementState::new(4, false).max_core_wi(), 0.0);
        let mut state = PlacementState::with_bank(4, false, bank);
        assert_eq!(state.max_core_wi(), 0.0);
        // A solo member scores the alone-value 0.5.
        state.place(1, ALL_CLASSES[0]);
        assert_eq!(state.max_core_wi(), 0.5);
        // A co-scheduled pair matches the Eq. 3 reference, whichever
        // member is worse.
        state.place(1, ALL_CLASSES[2]);
        let a = ALL_CLASSES[0].index();
        let b = ALL_CLASSES[2].index();
        let want = workload_interference(&[bank.s[a][b]])
            .max(workload_interference(&[bank.s[b][a]]));
        assert!(
            (state.max_core_wi() - want).abs() < 1e-12,
            "{} vs {}",
            state.max_core_wi(),
            want
        );
    }

    #[test]
    fn placement_state_reserves_core0() {
        let s = PlacementState::new(12, true);
        assert!(!s.allowed.contains(&0));
        assert_eq!(s.allowed.len(), 11);
        let s2 = PlacementState::new(12, false);
        assert!(s2.allowed.contains(&0));
        assert_eq!(s2.allowed.len(), 12);
    }

    #[test]
    fn single_core_reservation_falls_back_to_core0() {
        // Regression: a 1-core host with idle reservation used to yield an
        // empty `allowed` set and panic every policy's select_pinning.
        let s = PlacementState::new(1, true);
        assert_eq!(s.allowed, vec![0]);
        let bank = testkit::shared_bank();
        for p in Policy::ALL {
            let mut sched = build(p, bank, 1.2, None);
            let core = sched.select_pinning(&s, WorkloadClass::Jacobi);
            assert_eq!(core, 0, "{p:?} must fall back to core 0");
        }
    }

    #[test]
    fn place_tracks_counts() {
        let mut s = PlacementState::new(4, false);
        s.place(1, WorkloadClass::Jacobi);
        s.place(1, WorkloadClass::Hadoop);
        assert_eq!(s.placed(), 2);
        assert_eq!(s.cores[1].len(), 2);
    }

    #[test]
    fn cache_aggregates_match_brute_force() {
        let bank = testkit::shared_bank();
        let mut s = PlacementState::with_bank(4, false, bank);
        let picks = [
            (0, ALL_CLASSES[0]),
            (0, ALL_CLASSES[2]),
            (1, ALL_CLASSES[2]),
            (0, ALL_CLASSES[5]),
        ];
        for &(core, class) in &picks {
            s.place(core, class);
        }
        let cache = s.cache().expect("cached state");
        for core in 0..4 {
            let members = &s.cores[core];
            // Load vector = Σ U over members.
            let mut want = [0.0f64; NUM_METRICS];
            for &m in members {
                for j in 0..NUM_METRICS {
                    want[j] += bank.u[m][j];
                }
            }
            let got = cache.load(core);
            for j in 0..NUM_METRICS {
                assert!((got[j] - want[j]).abs() < 1e-12, "core {core} metric {j}");
            }
            // WI partials = (Σ, Π) over co-members.
            let parts = cache.wi_parts(core);
            assert_eq!(parts.len(), members.len());
            for (pos, &m) in members.iter().enumerate() {
                let mut sum = 0.0;
                let mut prod = 1.0;
                for (p2, &m2) in members.iter().enumerate() {
                    if p2 != pos {
                        sum += bank.s[m][m2];
                        prod *= bank.s[m][m2];
                    }
                }
                assert!((parts[pos].0 - sum).abs() < 1e-12, "core {core} pos {pos}");
                assert!((parts[pos].1 - prod).abs() < 1e-12, "core {core} pos {pos}");
            }
        }
    }

    #[test]
    fn remove_reverses_place_exactly() {
        let bank = testkit::shared_bank();
        let mut s = PlacementState::with_bank(4, false, bank);
        s.place(0, ALL_CLASSES[0]);
        s.place(0, ALL_CLASSES[2]);
        s.place(1, ALL_CLASSES[3]);
        let before = s.clone();
        s.place(0, ALL_CLASSES[5]);
        assert!(s.remove(0, ALL_CLASSES[5]));
        assert_eq!(s.cores, before.cores);
        let (a, b) = (s.cache().unwrap(), before.cache().unwrap());
        for core in 0..4 {
            for j in 0..NUM_METRICS {
                assert!((a.load(core)[j] - b.load(core)[j]).abs() < 1e-12);
            }
            for (x, y) in a.wi_parts(core).iter().zip(b.wi_parts(core)) {
                assert!((x.0 - y.0).abs() < 1e-12 && (x.1 - y.1).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn remove_missing_member_is_a_noop() {
        let bank = testkit::shared_bank();
        let mut s = PlacementState::with_bank(2, false, bank);
        s.place(0, ALL_CLASSES[1]);
        assert!(!s.remove(0, ALL_CLASSES[2]), "class not on the core");
        assert!(!s.remove(1, ALL_CLASSES[1]), "wrong core");
        assert!(!s.remove(9, ALL_CLASSES[1]), "core out of range");
        assert_eq!(s.placed(), 1);
        assert!(s.cache_matches_rebuild(1e-12));
    }

    #[test]
    fn idle_reservation_toggles_on_a_live_state() {
        let mut s = PlacementState::new(12, false);
        s.place(0, ALL_CLASSES[0]);
        s.set_idle_reservation(true);
        assert!(!s.allowed.contains(&0));
        assert_eq!(s.allowed.len(), 11);
        // Membership survives the toggle; core 0 reopens on the way back.
        assert_eq!(s.placed(), 1);
        s.set_idle_reservation(false);
        assert!(s.allowed.contains(&0));
        // 1-core fallback holds through the setter too.
        let mut one = PlacementState::new(1, false);
        one.set_idle_reservation(true);
        assert_eq!(one.allowed, vec![0]);
    }

    #[test]
    fn cache_matches_rebuild_detects_drift() {
        let bank = testkit::shared_bank();
        let mut s = PlacementState::with_bank(3, false, bank);
        s.place(0, ALL_CLASSES[0]);
        s.place(0, ALL_CLASSES[1]);
        s.place(2, ALL_CLASSES[4]);
        assert!(s.cache_matches_rebuild(1e-9));
        // Corrupt the membership behind the cache's back.
        s.cores[0].push(ALL_CLASSES[3].index());
        assert!(!s.cache_matches_rebuild(1e-9));
    }

    #[test]
    fn build_native_schedulers_are_send() {
        fn assert_send<T: Send + ?Sized>(_: &T) {}
        let bank = testkit::shared_bank();
        for p in Policy::ALL {
            let sched = build_native(p, bank, 1.2, None);
            assert_send(sched.as_ref());
        }
    }

    #[test]
    fn scheduler_new_state_attaches_cache_for_scoring_policies() {
        let bank = testkit::shared_bank();
        for p in [Policy::Cas, Policy::Ras, Policy::Ias] {
            let sched = build(p, bank, 1.2, None);
            let state = sched.new_state(12, true);
            assert!(state.cache().is_some(), "{p:?} state must carry the cache");
            assert!(!state.allowed.contains(&0));
        }
        let rrs = build(Policy::Rrs, bank, 1.2, None);
        assert!(rrs.new_state(12, false).cache().is_none());
    }
}

//! Placement policies.
//!
//! All four schedulers implement [`Scheduler::select_pinning`] — the
//! `SelectPinning` procedure of the paper's Algorithms 2 and 3. The daemon
//! (Alg. 1) builds a [`PlacementState`] of already-placed running
//! workloads and asks the policy where to pin the next one.

pub mod cas;
pub mod ias;
pub mod ras;
pub mod rrs;
pub mod scoring;

use crate::profiling::ProfileBank;
use crate::workloads::WorkloadClass;

pub use scoring::{NativeScoring, Scores, ScoringBackend};

/// Which policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Round-Robin Scheduler — the paper's baseline: static, interference-
    /// and resource-unaware, cannot detect idle workloads.
    Rrs,
    /// CPU-Aware Scheduler — RAS restricted to the CPU metric (§IV-B.1).
    Cas,
    /// Resource-Aware Scheduler — Alg. 2 over all four metrics.
    Ras,
    /// Interference-Aware Scheduler — Alg. 3 over the S matrix.
    Ias,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Rrs => "rrs",
            Policy::Cas => "cas",
            Policy::Ras => "ras",
            Policy::Ias => "ias",
        }
    }

    pub fn from_name(name: &str) -> Option<Policy> {
        match name.to_ascii_lowercase().as_str() {
            "rrs" => Some(Policy::Rrs),
            "cas" => Some(Policy::Cas),
            "ras" => Some(Policy::Ras),
            "ias" => Some(Policy::Ias),
            _ => None,
        }
    }

    pub const ALL: [Policy; 4] = [Policy::Rrs, Policy::Cas, Policy::Ras, Policy::Ias];
}

/// The incremental placement state the daemon builds while re-pinning:
/// for each core, the class indices of the running workloads already
/// placed there this cycle.
#[derive(Debug, Clone)]
pub struct PlacementState {
    /// Per-core class indices (into [`ProfileBank::classes`]).
    pub cores: Vec<Vec<usize>>,
    /// Cores the policy may use for running workloads (excludes the idle
    /// parking core when idle workloads exist — Alg. 1 pins idle workloads
    /// on core 0 and running ones on "the rest of the server's cores").
    pub allowed: Vec<usize>,
}

impl PlacementState {
    pub fn new(cores: usize, reserve_idle_core: bool) -> PlacementState {
        let allowed = if reserve_idle_core {
            (1..cores).collect()
        } else {
            (0..cores).collect()
        };
        PlacementState {
            cores: vec![Vec::new(); cores],
            allowed,
        }
    }

    /// Record a placement decided this cycle.
    pub fn place(&mut self, core: usize, class: WorkloadClass) {
        self.cores[core].push(class.index());
    }

    /// Total placed running workloads.
    pub fn placed(&self) -> usize {
        self.cores.iter().map(|c| c.len()).sum()
    }
}

/// A placement policy.
pub trait Scheduler {
    fn policy(&self) -> Policy;

    /// Choose the core for the next running workload (the paper's
    /// `SelectPinning`). Must return a member of `state.allowed`.
    fn select_pinning(&mut self, state: &PlacementState, class: WorkloadClass) -> usize;

    /// Whether the policy participates in the periodic re-pin + idle
    /// consolidation loop. RRS is static: it pins at arrival and never
    /// reconsiders ("unable to detect whether a workload is in running
    /// state or idle", §V-C.1).
    fn dynamic(&self) -> bool {
        true
    }
}

/// Build a scheduler for `policy` with the native scoring backend.
pub fn build(policy: Policy, bank: &ProfileBank, ras_thr: f64, ias_thr: Option<f64>) -> Box<dyn Scheduler> {
    build_with_backend(policy, bank, ras_thr, ias_thr, Box::new(NativeScoring::new()))
}

/// Build a scheduler with an explicit scoring backend (native or XLA).
pub fn build_with_backend(
    policy: Policy,
    bank: &ProfileBank,
    ras_thr: f64,
    ias_thr: Option<f64>,
    backend: Box<dyn ScoringBackend>,
) -> Box<dyn Scheduler> {
    match policy {
        Policy::Rrs => Box::new(rrs::Rrs::new()),
        Policy::Cas => Box::new(cas::new(bank.clone(), ras_thr, backend)),
        Policy::Ras => Box::new(ras::Ras::new(bank.clone(), ras_thr, backend)),
        Policy::Ias => {
            let thr = ias_thr.unwrap_or_else(|| bank.mean_slowdown());
            Box::new(ias::Ias::new(bank.clone(), thr, backend))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert_eq!(Policy::from_name("IAS"), Some(Policy::Ias));
        assert_eq!(Policy::from_name("bogus"), None);
    }

    #[test]
    fn placement_state_reserves_core0() {
        let s = PlacementState::new(12, true);
        assert!(!s.allowed.contains(&0));
        assert_eq!(s.allowed.len(), 11);
        let s2 = PlacementState::new(12, false);
        assert!(s2.allowed.contains(&0));
        assert_eq!(s2.allowed.len(), 12);
    }

    #[test]
    fn place_tracks_counts() {
        let mut s = PlacementState::new(4, false);
        s.place(1, WorkloadClass::Jacobi);
        s.place(1, WorkloadClass::Hadoop);
        assert_eq!(s.placed(), 2);
        assert_eq!(s.cores[1].len(), 2);
    }
}

//! The actuation pipeline (paper §III): "a high-level abstraction to
//! libvirt API calls … can manage VMs throughout their life-cycle and
//! enforce the required CPU pinning adjustments."
//!
//! Since the command-queue redesign, **decision and enforcement are
//! separate layers**:
//!
//! * `SchedEvent` handlers *decide* — they mutate the long-lived
//!   placement state and emit typed [`ActuationCommand`]s into the
//!   daemon's [`ActuationQueue`]. No handler touches the hypervisor.
//! * An [`Actuate`] backend *enforces* — it drains the queue and applies
//!   the commands through the hypervisor (or a real-hypervisor
//!   [`PinSink`]), reporting [`ActuationReport::completions`] that the
//!   daemon feeds back as `SchedEvent::ActuationComplete` bookkeeping.
//!
//! Three backends ship:
//!
//! * [`Inline`] — drains the queue immediately within the daemon pass,
//!   bit-identical to the pre-queue design (test-gated);
//! * [`Deferred`] — commands become enforceable `latency_ticks` daemon
//!   steps after submission, at most `budget_per_tick` atomic pins per
//!   step, so placement *intent* (the daemon's state) and *observed*
//!   pinning (the engine) diverge and reconcile — the paper's §IV
//!   actuation latency made a first-class experimental knob;
//! * [`Threaded`] — forwards commands over an mpsc channel to a worker
//!   thread owning a [`PinSink`] (the seam a real libvirt connection
//!   implements), draining completions back without ever blocking the
//!   monitor loop.
//!
//! [`Actuator`] survives as the low-level dedup applier backends share
//! (skip no-op re-pins, count actuations); [`Actuate`] is the API.
//!
//! This module is one of the two sanctioned thread/channel seams of the
//! determinism contract (see `DETERMINISM.md`, rule R4): `detlint`
//! confines `std::thread`/`mpsc` to here and `cluster::pool`, and the
//! ThreadSanitizer CI job audits both seams for races. [`Threaded`]
//! stays deterministic from the daemon's point of view because
//! completions are folded back at tick boundaries in submission order,
//! never mid-decision.

use crate::hostsim::{Hypervisor, VmId};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// One typed CPU-pinning action, decided by a `SchedEvent` handler and
/// enforced later by an [`Actuate`] backend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ActuationCommand {
    /// Pin one domain's vCPU to a physical core.
    Pin { vm: VmId, core: usize },
    /// Park one domain on the idle core (Alg. 1 lines 6–7).
    Park { vm: VmId },
    /// Enforce a whole placement map — the Tick re-pin pass as one
    /// command.
    ApplyPlan(Vec<(VmId, usize)>),
}

impl ActuationCommand {
    /// The atomic `(vm, core)` pin operations this command expands to —
    /// the unit backends order, budget, and complete. Consumes the
    /// command so a Tick's `ApplyPlan` (O(residents) entries, one per
    /// daemon pass) moves its plan out instead of cloning it.
    pub fn into_atoms(self) -> Vec<(VmId, usize)> {
        match self {
            ActuationCommand::Pin { vm, core } => vec![(vm, core)],
            ActuationCommand::Park { vm } => vec![(vm, super::daemon::IDLE_CORE)],
            ActuationCommand::ApplyPlan(plan) => plan,
        }
    }
}

/// FIFO of commands the daemon's event handlers emitted and no backend
/// has absorbed yet. Strictly ordered: backends enforce atoms in
/// submission order, so the last command for a domain always wins and a
/// lagging backend converges to the final intent once it drains.
#[derive(Debug, Default)]
pub struct ActuationQueue {
    commands: VecDeque<ActuationCommand>,
    /// Commands pushed over the queue's lifetime (reporting).
    pub pushed: u64,
}

impl ActuationQueue {
    pub fn new() -> ActuationQueue {
        ActuationQueue::default()
    }

    pub fn push(&mut self, cmd: ActuationCommand) {
        self.pushed += 1;
        self.commands.push_back(cmd);
    }

    /// Shorthand for pushing a [`ActuationCommand::Pin`].
    pub fn pin(&mut self, vm: VmId, core: usize) {
        self.push(ActuationCommand::Pin { vm, core });
    }

    /// Shorthand for pushing a [`ActuationCommand::Park`].
    pub fn park(&mut self, vm: VmId) {
        self.push(ActuationCommand::Park { vm });
    }

    pub fn pop(&mut self) -> Option<ActuationCommand> {
        self.commands.pop_front()
    }

    pub fn len(&self) -> usize {
        self.commands.len()
    }

    pub fn is_empty(&self) -> bool {
        self.commands.is_empty()
    }

    /// Drop queued pins for domains that no longer exist (a VM that
    /// departed between decision and enforcement must not be re-pinned
    /// under a reused id).
    pub fn retain_live(&mut self, live: &BTreeSet<VmId>) {
        for cmd in &mut self.commands {
            if let ActuationCommand::ApplyPlan(plan) = cmd {
                plan.retain(|(vm, _)| live.contains(vm));
            }
        }
        self.commands.retain(|cmd| match cmd {
            ActuationCommand::Pin { vm, .. } | ActuationCommand::Park { vm } => live.contains(vm),
            ActuationCommand::ApplyPlan(plan) => !plan.is_empty(),
        });
    }
}

/// What one backend pass enforced.
#[derive(Debug, Clone, Default)]
pub struct ActuationReport {
    /// Atomic pins that finished this pass (dedup no-ops included): the
    /// observed pinning the daemon books via
    /// `SchedEvent::ActuationComplete`.
    pub completions: Vec<(VmId, usize)>,
    /// Transient hypervisor failures (tolerated and counted; the intent
    /// is kept and the next Tick's re-pin pass retries).
    pub failures: u64,
}

/// The actuation API — what the daemon drives instead of a concrete
/// actuator. `Send` because natively-scored daemons (and therefore their
/// backends) migrate to cluster shard-pool workers.
pub trait Actuate: Send {
    fn name(&self) -> &'static str;

    /// Absorb every queued command. [`Inline`] enforces them before
    /// returning; latency backends stage them. Called at the end of each
    /// daemon entry point that may have produced commands.
    fn submit(&mut self, hv: &mut dyn Hypervisor, queue: &mut ActuationQueue) -> ActuationReport;

    /// Advance one daemon step: enforce whatever became due (the latency
    /// clock of [`Deferred`], the completion drain of [`Threaded`]).
    fn on_step(&mut self, hv: &mut dyn Hypervisor) -> ActuationReport;

    /// Atomic pins accepted but not yet enforced.
    fn in_flight(&self) -> usize;

    /// Forget domains that left the host: dedup state and staged pins
    /// (so a VM re-using an id later is re-pinned for real).
    fn retain(&mut self, live: &BTreeSet<VmId>);

    /// Enforcement counters `(pin_calls, pin_noops)` for reporting.
    fn counters(&self) -> (u64, u64);
}

/// Low-level pin applier shared by the hypervisor-driven backends:
/// tracks the last applied pinning, skips no-op re-pins, and counts
/// actuations so experiments can report actuation overhead. Not the
/// API — daemons talk to [`Actuate`] backends, which use this inside.
#[derive(Debug, Default)]
pub struct Actuator {
    /// Last pinning this actuator applied (or observed).
    applied: BTreeMap<VmId, usize>,
    /// Actuation counters for reporting.
    pub pin_calls: u64,
    pub pin_noops: u64,
}

impl Actuator {
    pub fn new() -> Actuator {
        Actuator::default()
    }

    /// Pin `id` to `core`, skipping the hypervisor call when the domain is
    /// already there.
    pub fn pin(&mut self, hv: &mut dyn Hypervisor, id: VmId, core: usize) -> Result<()> {
        if self.applied.get(&id) == Some(&core) {
            self.pin_noops += 1;
            return Ok(());
        }
        hv.pin_vcpu(id, core)?;
        self.applied.insert(id, core);
        self.pin_calls += 1;
        Ok(())
    }

    /// Apply a whole placement map.
    pub fn apply(&mut self, hv: &mut dyn Hypervisor, plan: &[(VmId, usize)]) -> Result<()> {
        for &(id, core) in plan {
            self.pin(hv, id, core)?;
        }
        Ok(())
    }

    /// Forget domains that no longer exist (so a VM re-using an id later
    /// is re-pinned). Takes a set: the event-driven daemon calls this
    /// every step, so the scan must stay O(n log n).
    pub fn retain(&mut self, live: &BTreeSet<VmId>) {
        self.applied.retain(|id, _| live.contains(id));
    }

    /// Would `pin` dedup-skip this atom (domain already there)?
    pub fn would_noop(&self, id: VmId, core: usize) -> bool {
        self.applied.get(&id) == Some(&core)
    }

    /// Apply one atom, folding the outcome into `report`: a success or a
    /// dedup no-op completes, a failure is counted and logged (the
    /// daemon keeps its intent and the next Tick retries).
    fn apply_atom(
        &mut self,
        hv: &mut dyn Hypervisor,
        vm: VmId,
        core: usize,
        report: &mut ActuationReport,
    ) {
        match self.pin(hv, vm, core) {
            Ok(()) => report.completions.push((vm, core)),
            Err(e) => {
                report.failures += 1;
                log::warn!("pin {vm:?} -> core {core} failed: {e}");
            }
        }
    }
}

/// Synchronous backend: every submitted command is enforced before
/// `submit` returns — bit-identical to the pre-queue daemon (test-gated
/// by the Inline-vs-Deferred{0} property and the cluster bit-identity
/// suite).
#[derive(Debug, Default)]
pub struct Inline {
    applier: Actuator,
}

impl Inline {
    pub fn new() -> Inline {
        Inline::default()
    }
}

impl Actuate for Inline {
    fn name(&self) -> &'static str {
        "inline"
    }

    fn submit(&mut self, hv: &mut dyn Hypervisor, queue: &mut ActuationQueue) -> ActuationReport {
        let mut report = ActuationReport::default();
        while let Some(cmd) = queue.pop() {
            for (vm, core) in cmd.into_atoms() {
                self.applier.apply_atom(hv, vm, core, &mut report);
            }
        }
        report
    }

    fn on_step(&mut self, _hv: &mut dyn Hypervisor) -> ActuationReport {
        ActuationReport::default()
    }

    fn in_flight(&self) -> usize {
        0
    }

    fn retain(&mut self, live: &BTreeSet<VmId>) {
        self.applier.retain(live);
    }

    fn counters(&self) -> (u64, u64) {
        (self.applier.pin_calls, self.applier.pin_noops)
    }
}

/// Deferred backend: atoms become enforceable `latency_ticks` daemon
/// steps after submission and at most `budget_per_tick` real pin calls
/// are made per step (0 = unlimited; dedup no-ops are free) — real
/// placement actions have non-trivial latency, and modeling them
/// asynchronously is exactly what lets intent and enacted pinning
/// diverge under churn.
#[derive(Debug)]
pub struct Deferred {
    pub latency_ticks: u64,
    /// Max atoms enforced per step; 0 means unlimited.
    pub budget_per_tick: usize,
    /// Staged atoms `(due_tick, vm, core)` in submission order.
    staged: VecDeque<(u64, VmId, usize)>,
    /// Daemon steps seen so far (`on_step` calls completed).
    tick: u64,
    applier: Actuator,
}

impl Deferred {
    pub fn new(latency_ticks: u64, budget_per_tick: usize) -> Deferred {
        Deferred {
            latency_ticks,
            budget_per_tick,
            staged: VecDeque::new(),
            tick: 0,
            applier: Actuator::new(),
        }
    }
}

impl Actuate for Deferred {
    fn name(&self) -> &'static str {
        "deferred"
    }

    fn submit(&mut self, _hv: &mut dyn Hypervisor, queue: &mut ActuationQueue) -> ActuationReport {
        while let Some(cmd) = queue.pop() {
            for (vm, core) in cmd.into_atoms() {
                self.staged.push_back((self.tick + self.latency_ticks, vm, core));
            }
        }
        ActuationReport::default()
    }

    fn on_step(&mut self, hv: &mut dyn Hypervisor) -> ActuationReport {
        let mut report = ActuationReport::default();
        let mut budget = if self.budget_per_tick == 0 {
            usize::MAX
        } else {
            self.budget_per_tick
        };
        loop {
            let (vm, core) = match self.staged.front() {
                Some(&(due, vm, core)) if due <= self.tick => (vm, core),
                _ => break,
            };
            // The budget models real hypervisor-call latency, so dedup
            // no-ops (a Tick re-confirming an unchanged pin) are free —
            // otherwise steady-state re-pin plans would starve genuinely
            // changed pins queued behind them.
            let noop = self.applier.would_noop(vm, core);
            if !noop && budget == 0 {
                break;
            }
            let _ = self.staged.pop_front();
            self.applier.apply_atom(hv, vm, core, &mut report);
            if !noop {
                budget -= 1;
            }
        }
        self.tick += 1;
        report
    }

    fn in_flight(&self) -> usize {
        self.staged.len()
    }

    fn retain(&mut self, live: &BTreeSet<VmId>) {
        self.staged.retain(|(_, vm, _)| live.contains(vm));
        self.applier.retain(live);
    }

    fn counters(&self) -> (u64, u64) {
        (self.applier.pin_calls, self.applier.pin_noops)
    }
}

/// Where a [`Threaded`] worker enforces pins — the real-hypervisor seam.
/// A libvirt binding implements this over its own connection (libvirt
/// handles are per-thread); tests use a recording mock. The simulated
/// [`Hypervisor`] stays on the daemon thread, so `Threaded` never touches
/// the `hv` argument of the [`Actuate`] calls.
pub trait PinSink: Send {
    fn pin(&mut self, vm: VmId, core: usize) -> Result<()>;
}

impl<F: FnMut(VmId, usize) -> Result<()> + Send> PinSink for F {
    fn pin(&mut self, vm: VmId, core: usize) -> Result<()> {
        self(vm, core)
    }
}

/// Threaded backend: commands cross an mpsc channel to a worker thread
/// owning the [`PinSink`]; completions flow back and are drained
/// non-blockingly each step. A slow real actuation can therefore never
/// stall the monitor loop — the ROADMAP's async-daemon item.
pub struct Threaded {
    tx: Option<Sender<(VmId, usize)>>,
    rx: Receiver<(VmId, usize, bool)>,
    handle: Option<JoinHandle<()>>,
    sent: u64,
    done: u64,
    /// Completions the sink enforced successfully.
    ok: u64,
}

impl Threaded {
    /// Spawn the worker; errors if the OS refuses the thread (resource
    /// exhaustion) instead of panicking the daemon.
    pub fn new(mut sink: Box<dyn PinSink>) -> Result<Threaded> {
        let (tx, rx_job) = channel::<(VmId, usize)>();
        let (tx_done, rx) = channel::<(VmId, usize, bool)>();
        let handle = std::thread::Builder::new()
            .name("actuation-worker".into())
            .spawn(move || {
                while let Ok((vm, core)) = rx_job.recv() {
                    let ok = sink.pin(vm, core).is_ok();
                    if tx_done.send((vm, core, ok)).is_err() {
                        break;
                    }
                }
            })
            .context("spawn actuation worker")?;
        Ok(Threaded {
            tx: Some(tx),
            rx,
            handle: Some(handle),
            sent: 0,
            done: 0,
            ok: 0,
        })
    }

    fn book(&mut self, vm: VmId, core: usize, ok: bool, report: &mut ActuationReport) {
        self.done += 1;
        if ok {
            self.ok += 1;
            report.completions.push((vm, core));
        } else {
            report.failures += 1;
        }
    }

    /// Block until every accepted command has been enforced — teardown
    /// and test synchronisation, not the steady-state path.
    pub fn drain(&mut self) -> ActuationReport {
        let mut report = ActuationReport::default();
        while self.done < self.sent {
            match self.rx.recv() {
                Ok((vm, core, ok)) => self.book(vm, core, ok, &mut report),
                Err(_) => break,
            }
        }
        report
    }
}

impl Actuate for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn submit(&mut self, _hv: &mut dyn Hypervisor, queue: &mut ActuationQueue) -> ActuationReport {
        let mut report = ActuationReport::default();
        while let Some(cmd) = queue.pop() {
            for (vm, core) in cmd.into_atoms() {
                let accepted = self.tx.as_ref().is_some_and(|tx| tx.send((vm, core)).is_ok());
                if accepted {
                    self.sent += 1;
                } else {
                    // Worker gone (panicked sink or torn-down channel):
                    // a dropped command is a failed actuation, not a
                    // silent success — surface it like any pin failure.
                    report.failures += 1;
                    log::warn!("actuation worker rejected pin {vm:?} -> core {core}");
                }
            }
        }
        report
    }

    fn on_step(&mut self, _hv: &mut dyn Hypervisor) -> ActuationReport {
        let mut report = ActuationReport::default();
        // try_iter borrows self.rx immutably while book needs &mut self:
        // collect first (the channel batch is small — one step's worth).
        let batch: Vec<(VmId, usize, bool)> = self.rx.try_iter().collect();
        for (vm, core, ok) in batch {
            self.book(vm, core, ok, &mut report);
        }
        report
    }

    fn in_flight(&self) -> usize {
        (self.sent - self.done) as usize
    }

    fn retain(&mut self, _live: &BTreeSet<VmId>) {
        // In-flight commands already crossed the channel; the sink owns
        // its own notion of domain liveness (a real connection errors on
        // a gone domain, which comes back as a tolerated failure).
    }

    fn counters(&self) -> (u64, u64) {
        (self.ok, 0)
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        self.tx.take(); // close the job channel; the worker exits
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// The parseable actuation configuration (CLI `--actuation`, cluster
/// specs) — symmetric with `Policy::parse` and `Dispatcher::parse`.
/// [`Threaded`] is deliberately absent: it needs a live [`PinSink`], not
/// a string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActuationSpec {
    Inline,
    Deferred {
        latency_ticks: u64,
        /// Max atoms enforced per step; 0 means unlimited.
        budget_per_tick: usize,
    },
}

impl ActuationSpec {
    pub fn name(self) -> &'static str {
        match self {
            ActuationSpec::Inline => "inline",
            ActuationSpec::Deferred { .. } => "deferred",
        }
    }

    /// Parse `inline`, `deferred:N` (N ticks of latency, unlimited
    /// budget), or `deferred:N:B` (budget B atoms per tick). The error
    /// lists the valid forms.
    pub fn parse(s: &str) -> anyhow::Result<ActuationSpec> {
        let lower = s.to_ascii_lowercase();
        if lower == "inline" {
            return Ok(ActuationSpec::Inline);
        }
        if let Some(rest) = lower.strip_prefix("deferred:") {
            let mut parts = rest.splitn(2, ':');
            let latency = parts
                .next()
                .unwrap_or_default()
                .parse::<u64>()
                .map_err(|_| {
                    anyhow::anyhow!("bad latency in actuation spec '{s}' (want deferred:N)")
                })?;
            let budget = match parts.next() {
                None => 0,
                Some(b) => b.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad budget in actuation spec '{s}' (want deferred:N:B)")
                })?,
            };
            return Ok(ActuationSpec::Deferred {
                latency_ticks: latency,
                budget_per_tick: budget,
            });
        }
        anyhow::bail!(
            "unknown actuation spec '{s}' (valid: inline, deferred:N, deferred:N:B)"
        )
    }

    /// Build the backend this spec describes.
    pub fn build(self) -> Box<dyn Actuate> {
        match self {
            ActuationSpec::Inline => Box::new(Inline::new()),
            ActuationSpec::Deferred {
                latency_ticks,
                budget_per_tick,
            } => Box::new(Deferred::new(latency_ticks, budget_per_tick)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::workloads::WorkloadClass;
    use std::sync::{Arc, Mutex};

    fn engine(n: u32) -> SimEngine {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let vms = (0..n)
            .map(|i| {
                let mut vm = Vm::new(
                    VmId(i),
                    WorkloadClass::Hadoop,
                    0.0,
                    ActivityModel::AlwaysOn,
                );
                vm.state = VmState::Running;
                vm.pinned = Some(0);
                vm
            })
            .collect();
        SimEngine::new(cfg, vms)
    }

    #[test]
    fn deduplicates_noop_pins() {
        let mut eng = engine(1);
        let mut act = Actuator::new();
        act.pin(&mut eng, VmId(0), 3).unwrap();
        act.pin(&mut eng, VmId(0), 3).unwrap();
        act.pin(&mut eng, VmId(0), 4).unwrap();
        assert_eq!(act.pin_calls, 2);
        assert_eq!(act.pin_noops, 1);
        assert_eq!(eng.vms[0].pinned, Some(4));
    }

    #[test]
    fn apply_plan() {
        let mut eng = engine(3);
        let mut act = Actuator::new();
        act.apply(&mut eng, &[(VmId(0), 1), (VmId(1), 2), (VmId(2), 1)])
            .unwrap();
        assert_eq!(eng.vms[0].pinned, Some(1));
        assert_eq!(eng.vms[1].pinned, Some(2));
        assert_eq!(eng.vms[2].pinned, Some(1));
    }

    #[test]
    fn retain_forgets_dead_domains() {
        let mut eng = engine(2);
        let mut act = Actuator::new();
        act.pin(&mut eng, VmId(0), 1).unwrap();
        act.pin(&mut eng, VmId(1), 2).unwrap();
        act.retain(&BTreeSet::from([VmId(1)]));
        // VmId(0) must be re-pinned for real next time.
        act.pin(&mut eng, VmId(0), 1).unwrap();
        assert_eq!(act.pin_calls, 3);
    }

    #[test]
    fn commands_expand_to_atoms() {
        let pin = ActuationCommand::Pin {
            vm: VmId(3),
            core: 5,
        };
        assert_eq!(pin.into_atoms(), vec![(VmId(3), 5)]);
        let park = ActuationCommand::Park { vm: VmId(7) };
        assert_eq!(park.into_atoms(), vec![(VmId(7), super::super::daemon::IDLE_CORE)]);
        let plan = ActuationCommand::ApplyPlan(vec![(VmId(0), 1), (VmId(1), 2)]);
        assert_eq!(plan.into_atoms(), vec![(VmId(0), 1), (VmId(1), 2)]);
    }

    #[test]
    fn queue_retain_live_prunes_dead_targets() {
        let mut q = ActuationQueue::new();
        q.pin(VmId(0), 1);
        q.park(VmId(1));
        q.push(ActuationCommand::ApplyPlan(vec![(VmId(0), 2), (VmId(2), 3)]));
        q.push(ActuationCommand::ApplyPlan(vec![(VmId(1), 4)]));
        assert_eq!(q.len(), 4);
        assert_eq!(q.pushed, 4);
        // Only VmId(0) survives: the Park and the 1-entry plan vanish,
        // the mixed plan keeps its live half.
        q.retain_live(&BTreeSet::from([VmId(0)]));
        assert_eq!(q.pop(), Some(ActuationCommand::Pin { vm: VmId(0), core: 1 }));
        assert_eq!(
            q.pop(),
            Some(ActuationCommand::ApplyPlan(vec![(VmId(0), 2)]))
        );
        assert!(q.is_empty());
    }

    #[test]
    fn inline_backend_enforces_at_submit() {
        let mut eng = engine(2);
        let mut q = ActuationQueue::new();
        let mut backend = Inline::new();
        q.pin(VmId(0), 4);
        q.push(ActuationCommand::ApplyPlan(vec![(VmId(1), 5)]));
        let report = backend.submit(&mut eng, &mut q);
        assert!(q.is_empty());
        assert_eq!(report.completions, vec![(VmId(0), 4), (VmId(1), 5)]);
        assert_eq!(report.failures, 0);
        assert_eq!(eng.vms[0].pinned, Some(4));
        assert_eq!(eng.vms[1].pinned, Some(5));
        assert_eq!(backend.in_flight(), 0);
        assert_eq!(backend.counters(), (2, 0));
    }

    #[test]
    fn inline_backend_tolerates_and_counts_failures() {
        let mut eng = engine(1);
        let mut q = ActuationQueue::new();
        let mut backend = Inline::new();
        q.pin(VmId(0), 999); // out of range
        q.pin(VmId(0), 2);
        let report = backend.submit(&mut eng, &mut q);
        assert_eq!(report.failures, 1);
        assert_eq!(report.completions, vec![(VmId(0), 2)]);
        assert_eq!(eng.vms[0].pinned, Some(2));
    }

    #[test]
    fn deferred_applies_commands_latency_ticks_later() {
        let mut eng = engine(1);
        let mut q = ActuationQueue::new();
        let mut backend = Deferred::new(2, 0);
        q.pin(VmId(0), 6);
        assert!(backend.submit(&mut eng, &mut q).completions.is_empty());
        assert_eq!(backend.in_flight(), 1);
        // Ticks 0 and 1: still in flight (due at tick 2).
        assert!(backend.on_step(&mut eng).completions.is_empty());
        assert!(backend.on_step(&mut eng).completions.is_empty());
        assert_eq!(eng.vms[0].pinned, Some(0));
        // Tick 2: enforced.
        let report = backend.on_step(&mut eng);
        assert_eq!(report.completions, vec![(VmId(0), 6)]);
        assert_eq!(eng.vms[0].pinned, Some(6));
        assert_eq!(backend.in_flight(), 0);
    }

    #[test]
    fn deferred_budget_throttles_per_tick() {
        let mut eng = engine(3);
        let mut q = ActuationQueue::new();
        let mut backend = Deferred::new(0, 2);
        q.push(ActuationCommand::ApplyPlan(vec![
            (VmId(0), 1),
            (VmId(1), 2),
            (VmId(2), 3),
        ]));
        backend.submit(&mut eng, &mut q);
        assert_eq!(backend.in_flight(), 3);
        // Budget 2: two atoms this tick, the third next tick — FIFO.
        let r1 = backend.on_step(&mut eng);
        assert_eq!(r1.completions, vec![(VmId(0), 1), (VmId(1), 2)]);
        assert_eq!(backend.in_flight(), 1);
        let r2 = backend.on_step(&mut eng);
        assert_eq!(r2.completions, vec![(VmId(2), 3)]);
        assert_eq!(backend.in_flight(), 0);
    }

    #[test]
    fn deferred_budget_ignores_dedup_noops() {
        let mut eng = engine(2);
        let mut q = ActuationQueue::new();
        let mut backend = Deferred::new(0, 1);
        // First pass: enforce both pins (budget 1 real call per step).
        q.pin(VmId(0), 3);
        q.pin(VmId(1), 4);
        backend.submit(&mut eng, &mut q);
        backend.on_step(&mut eng);
        backend.on_step(&mut eng);
        assert_eq!(backend.in_flight(), 0);
        // Second pass: a no-op re-confirmation queued ahead of a real
        // change must not eat the budget — both land in one step.
        q.pin(VmId(0), 3); // unchanged → dedup no-op, free
        q.pin(VmId(1), 5); // real pin, costs the budget
        backend.submit(&mut eng, &mut q);
        let r = backend.on_step(&mut eng);
        assert_eq!(r.completions, vec![(VmId(0), 3), (VmId(1), 5)]);
        assert_eq!(backend.in_flight(), 0);
        assert_eq!(eng.vms[1].pinned, Some(5));
        assert_eq!(backend.counters(), (3, 1)); // 3 real calls, 1 noop
    }

    #[test]
    fn deferred_retain_drops_staged_pins_of_dead_vms() {
        let mut eng = engine(2);
        let mut q = ActuationQueue::new();
        let mut backend = Deferred::new(5, 0);
        q.pin(VmId(0), 1);
        q.pin(VmId(1), 2);
        backend.submit(&mut eng, &mut q);
        backend.retain(&BTreeSet::from([VmId(1)]));
        assert_eq!(backend.in_flight(), 1);
    }

    #[test]
    fn threaded_backend_enforces_through_the_sink() {
        let seen: Arc<Mutex<Vec<(VmId, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink_seen = seen.clone();
        let mut backend = Threaded::new(Box::new(move |vm: VmId, core: usize| -> Result<()> {
            sink_seen.lock().unwrap().push((vm, core));
            Ok(())
        }))
        .unwrap();
        let mut eng = engine(1); // untouched: Threaded never uses hv
        let mut q = ActuationQueue::new();
        q.pin(VmId(0), 3);
        q.push(ActuationCommand::ApplyPlan(vec![(VmId(1), 4)]));
        backend.submit(&mut eng, &mut q);
        // drain() blocks until the worker reports both completions.
        let report = backend.drain();
        assert_eq!(report.completions.len(), 2);
        assert_eq!(report.failures, 0);
        assert_eq!(backend.in_flight(), 0);
        assert_eq!(*seen.lock().unwrap(), vec![(VmId(0), 3), (VmId(1), 4)]);
        // The simulated hypervisor was never actuated.
        assert_eq!(eng.vms[0].pinned, Some(0));
        assert_eq!(eng.ledger.repin_count, 0);
    }

    #[test]
    fn threaded_backend_reports_sink_failures() {
        let mut backend = Threaded::new(Box::new(|vm: VmId, _core: usize| -> Result<()> {
            anyhow::ensure!(vm != VmId(1), "domain gone");
            Ok(())
        }))
        .unwrap();
        let mut eng = engine(1);
        let mut q = ActuationQueue::new();
        q.pin(VmId(0), 1);
        q.pin(VmId(1), 2);
        backend.submit(&mut eng, &mut q);
        let report = backend.drain();
        assert_eq!(report.completions, vec![(VmId(0), 1)]);
        assert_eq!(report.failures, 1);
    }

    #[test]
    fn actuation_spec_parses_and_builds() {
        assert_eq!(ActuationSpec::parse("inline").unwrap(), ActuationSpec::Inline);
        assert_eq!(ActuationSpec::parse("INLINE").unwrap(), ActuationSpec::Inline);
        assert_eq!(
            ActuationSpec::parse("deferred:3").unwrap(),
            ActuationSpec::Deferred {
                latency_ticks: 3,
                budget_per_tick: 0
            }
        );
        assert_eq!(
            ActuationSpec::parse("deferred:2:8").unwrap(),
            ActuationSpec::Deferred {
                latency_ticks: 2,
                budget_per_tick: 8
            }
        );
        for bad in ["bogus", "deferred", "deferred:x", "deferred:1:y"] {
            let err = ActuationSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains(bad), "{err}");
        }
        assert_eq!(ActuationSpec::parse("inline").unwrap().build().name(), "inline");
        assert_eq!(
            ActuationSpec::parse("deferred:1").unwrap().build().name(),
            "deferred"
        );
    }
}

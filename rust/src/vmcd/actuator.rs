//! VM Actuator (paper §III): "a high-level abstraction to libvirt API
//! calls … can manage VMs throughout their life-cycle and enforce the
//! required CPU pinning adjustments."
//!
//! Tracks intended pinnings, skips no-op re-pins, and counts actuations so
//! experiments can report actuation overhead.

use crate::hostsim::{Hypervisor, VmId};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Default)]
pub struct Actuator {
    /// Last pinning this actuator applied (or observed).
    applied: BTreeMap<VmId, usize>,
    /// Actuation counters for reporting.
    pub pin_calls: u64,
    pub pin_noops: u64,
}

impl Actuator {
    pub fn new() -> Actuator {
        Actuator::default()
    }

    /// Pin `id` to `core`, skipping the hypervisor call when the domain is
    /// already there.
    pub fn pin(&mut self, hv: &mut dyn Hypervisor, id: VmId, core: usize) -> Result<()> {
        if self.applied.get(&id) == Some(&core) {
            self.pin_noops += 1;
            return Ok(());
        }
        hv.pin_vcpu(id, core)?;
        self.applied.insert(id, core);
        self.pin_calls += 1;
        Ok(())
    }

    /// Apply a whole placement map.
    pub fn apply(&mut self, hv: &mut dyn Hypervisor, plan: &[(VmId, usize)]) -> Result<()> {
        for &(id, core) in plan {
            self.pin(hv, id, core)?;
        }
        Ok(())
    }

    /// Forget domains that no longer exist (so a VM re-using an id later
    /// is re-pinned). Takes a set: the event-driven daemon calls this
    /// every step, so the scan must stay O(n log n).
    pub fn retain(&mut self, live: &BTreeSet<VmId>) {
        self.applied.retain(|id, _| live.contains(id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::workloads::WorkloadClass;

    fn engine(n: u32) -> SimEngine {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let vms = (0..n)
            .map(|i| {
                let mut vm = Vm::new(
                    VmId(i),
                    WorkloadClass::Hadoop,
                    0.0,
                    ActivityModel::AlwaysOn,
                );
                vm.state = VmState::Running;
                vm.pinned = Some(0);
                vm
            })
            .collect();
        SimEngine::new(cfg, vms)
    }

    #[test]
    fn deduplicates_noop_pins() {
        let mut eng = engine(1);
        let mut act = Actuator::new();
        act.pin(&mut eng, VmId(0), 3).unwrap();
        act.pin(&mut eng, VmId(0), 3).unwrap();
        act.pin(&mut eng, VmId(0), 4).unwrap();
        assert_eq!(act.pin_calls, 2);
        assert_eq!(act.pin_noops, 1);
        assert_eq!(eng.vms[0].pinned, Some(4));
    }

    #[test]
    fn apply_plan() {
        let mut eng = engine(3);
        let mut act = Actuator::new();
        act.apply(&mut eng, &[(VmId(0), 1), (VmId(1), 2), (VmId(2), 1)])
            .unwrap();
        assert_eq!(eng.vms[0].pinned, Some(1));
        assert_eq!(eng.vms[1].pinned, Some(2));
        assert_eq!(eng.vms[2].pinned, Some(1));
    }

    #[test]
    fn retain_forgets_dead_domains() {
        let mut eng = engine(2);
        let mut act = Actuator::new();
        act.pin(&mut eng, VmId(0), 1).unwrap();
        act.pin(&mut eng, VmId(1), 2).unwrap();
        act.retain(&BTreeSet::from([VmId(1)]));
        // VmId(0) must be re-pinned for real next time.
        act.pin(&mut eng, VmId(0), 1).unwrap();
        assert_eq!(act.pin_calls, 3);
    }
}

//! VMCd — the VM Coordinator daemon (paper §III, Fig. 1).
//!
//! Three modules, mirroring the paper's architecture:
//! * [`monitor`] — polls the hypervisor for per-VM resource usage; derives
//!   memory bandwidth from the synthetic perf counters (Table I);
//! * [`actuator`] — applies CPU-pinning decisions through the hypervisor
//!   (the libvirt-API abstraction);
//! * [`scheduler`] — the placement policies: RRS (baseline), CAS, RAS
//!   (Alg. 2), IAS (Alg. 3);
//! * [`daemon`] — the General Scheduler loop (Alg. 1), event-driven: one
//!   long-lived placement state mutated through [`daemon::SchedEvent`]s
//!   (arrivals, departures, idle/wake transitions, periodic Tick) with
//!   the monitor polled once per step and diffed into events.

pub mod actuator;
pub mod daemon;
pub mod monitor;
pub mod scheduler;

pub use daemon::{Daemon, SchedEvent};
pub use monitor::{DomainView, Monitor, MonitorSnapshot};

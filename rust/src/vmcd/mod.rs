//! VMCd — the VM Coordinator daemon (paper §III, Fig. 1).
//!
//! Three modules, mirroring the paper's architecture:
//! * [`monitor`] — polls the hypervisor for per-VM resource usage; derives
//!   memory bandwidth from the synthetic perf counters (Table I);
//! * [`actuator`] — applies CPU-pinning decisions through the hypervisor
//!   (the libvirt-API abstraction);
//! * [`scheduler`] — the placement policies: RRS (baseline), CAS, RAS
//!   (Alg. 2), IAS (Alg. 3);
//! * [`daemon`] — the General Scheduler loop (Alg. 1): every interval,
//!   idle workloads (< 2.5% CPU over the monitoring window) are parked on
//!   core 0 and running workloads are re-pinned by the policy.

pub mod actuator;
pub mod daemon;
pub mod monitor;
pub mod scheduler;

pub use daemon::Daemon;
pub use monitor::{DomainView, Monitor, MonitorSnapshot};

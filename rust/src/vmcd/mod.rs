//! VMCd — the VM Coordinator daemon (paper §III, Fig. 1).
//!
//! Four modules, mirroring the paper's architecture with decision and
//! actuation decoupled:
//!
//! * [`monitor`] — polls the hypervisor for per-VM resource usage; derives
//!   memory bandwidth from the synthetic perf counters (Table I);
//! * [`scheduler`] — the placement policies: RRS (baseline), CAS, RAS
//!   (Alg. 2), IAS (Alg. 3);
//! * [`daemon`] — the General Scheduler loop (Alg. 1), event-driven: one
//!   long-lived placement state mutated through [`daemon::SchedEvent`]s
//!   (arrivals, departures, idle/wake transitions, periodic Tick) with
//!   the monitor polled once per step and diffed into events. Handlers
//!   *decide* only: every pinning consequence leaves as a typed
//!   [`actuator::ActuationCommand`];
//! * [`actuator`] — the enforcement side (the libvirt-API abstraction):
//!   an [`actuator::ActuationQueue`] of commands drained by a pluggable
//!   [`actuator::Actuate`] backend — synchronous
//!   ([`actuator::Inline`]), lagged/budgeted ([`actuator::Deferred`]),
//!   or worker-threaded over mpsc ([`actuator::Threaded`]) — with
//!   completions fed back as `SchedEvent::ActuationComplete`.

pub mod actuator;
pub mod daemon;
pub mod monitor;
pub mod scheduler;

pub use actuator::{Actuate, ActuationCommand, ActuationQueue, ActuationSpec};
pub use daemon::{Daemon, SchedEvent};
pub use monitor::{DomainView, Monitor, MonitorSnapshot};

//! VM Monitor (paper §III).
//!
//! Periodically polls the hypervisor for per-VM CPU / DiskIO / NetIO
//! utilisation (the libvirt path) and derives per-VM **memory bandwidth**
//! from the hardware counter deltas of Table I (`UNC_QMC_NORMAL_READS`,
//! `UNC_QMC_NORMAL_WRITES`), following A-DRM [4] — the same two-source
//! design as the paper's monitor.
//!
//! The monitor is the *read* side of the actuation pipeline: it only ever
//! sees `&dyn Hypervisor`, while enforcement flows through the
//! [`actuator`](super::actuator) backends. Under a lagging backend the
//! [`DomainView::pinned`] it reports is the *enacted* pinning, which can
//! trail the daemon's intent until the command queue drains.

use crate::hostsim::counters::{bandwidth_fraction, PerfCounters};
use crate::hostsim::{Hypervisor, VmId};
use crate::workloads::{MetricVec, WorkloadClass};
use std::collections::BTreeMap;

/// One monitored domain as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct DomainView {
    pub id: VmId,
    pub class: WorkloadClass,
    pub pinned: Option<usize>,
    /// Mean CPU over the monitoring window.
    pub cpu_window_avg: f64,
    /// [CPU, DiskIO, NetIO, MemBW] — MemBW reconstructed from counters.
    pub util: MetricVec,
    /// Idle per the paper's 2.5% rule.
    pub idle: bool,
}

/// Snapshot of all resident domains at one monitoring instant.
#[derive(Debug, Clone, Default)]
pub struct MonitorSnapshot {
    pub t: f64,
    pub domains: Vec<DomainView>,
}

impl MonitorSnapshot {
    pub fn idle_workloads(&self) -> Vec<&DomainView> {
        self.domains.iter().filter(|d| d.idle).collect()
    }

    pub fn running_workloads(&self) -> Vec<&DomainView> {
        self.domains.iter().filter(|d| !d.idle).collect()
    }
}

/// The monitor holds the previous counter snapshot per domain so it can
/// compute bandwidth from deltas (perf-style sampling).
///
/// The event-driven daemon polls **once per step** and diffs the snapshot
/// into [`SchedEvent`](super::daemon::SchedEvent)s; [`Self::poll_count`]
/// exposes the pass count so tests can pin that down (the old design
/// polled in both the arrival path and the cycle path).
#[derive(Debug, Default)]
pub struct Monitor {
    idle_threshold: f64,
    last_counters: BTreeMap<VmId, (f64, PerfCounters)>,
    polls: u64,
}

impl Monitor {
    pub fn new(idle_threshold: f64) -> Monitor {
        Monitor {
            idle_threshold,
            last_counters: BTreeMap::new(),
            polls: 0,
        }
    }

    /// Number of monitoring passes run so far.
    pub fn poll_count(&self) -> u64 {
        self.polls
    }

    /// The idle rule (paper §III): windowed CPU below the threshold.
    /// Single source of truth — the daemon's adoption path classifies
    /// through this too, so the rule cannot drift between poll-derived
    /// [`DomainView::idle`] flags and per-domain stats reads.
    pub fn is_idle(&self, cpu_window_avg: f64) -> bool {
        cpu_window_avg < self.idle_threshold
    }

    /// Poll the hypervisor: one monitoring pass.
    pub fn poll(&mut self, hv: &dyn Hypervisor) -> MonitorSnapshot {
        self.polls += 1;
        let t = hv.now();
        let mut snap = MonitorSnapshot {
            t,
            domains: Vec::new(),
        };
        let mut seen = Vec::new();
        for id in hv.list_domains() {
            let Some(stats) = hv.domain_stats(id) else {
                continue;
            };
            seen.push(id);
            // Memory bandwidth from counter deltas (Table I inversion).
            let membw = match self.last_counters.get(&id) {
                Some(&(t0, prev)) if t > t0 => {
                    bandwidth_fraction(stats.counters.delta_since(prev), t - t0)
                }
                // First observation: fall back to the instantaneous value.
                _ => stats.util[3],
            };
            self.last_counters.insert(id, (t, stats.counters));

            let util = [stats.util[0], stats.util[1], stats.util[2], membw];
            let idle = self.is_idle(stats.cpu_window_avg);
            snap.domains.push(DomainView {
                id,
                class: stats.class,
                pinned: stats.pinned,
                cpu_window_avg: stats.cpu_window_avg,
                util,
                idle,
            });
        }
        // Forget departed domains.
        self.last_counters.retain(|id, _| seen.contains(id));
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::hostsim::{ActivityModel, SimEngine, Vm, VmState};
    use crate::workloads::WorkloadClass;

    fn engine_with(class: WorkloadClass, active: bool) -> SimEngine {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        let activity = if active {
            ActivityModel::AlwaysOn
        } else {
            ActivityModel::Windows(vec![])
        };
        let mut vm = Vm::new(VmId(0), class, 0.0, activity);
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm.pinned = Some(0);
        SimEngine::new(cfg, vec![vm])
    }

    #[test]
    fn active_vm_is_not_idle() {
        let mut eng = engine_with(WorkloadClass::Hadoop, true);
        let mut mon = Monitor::new(0.025);
        for _ in 0..12 {
            eng.step();
        }
        let snap = mon.poll(&eng);
        assert_eq!(snap.domains.len(), 1);
        assert!(!snap.domains[0].idle);
        assert_eq!(snap.running_workloads().len(), 1);
    }

    #[test]
    fn inactive_vm_detected_idle() {
        let mut eng = engine_with(WorkloadClass::LampLight, false);
        let mut mon = Monitor::new(0.025);
        for _ in 0..12 {
            eng.step();
        }
        let snap = mon.poll(&eng);
        assert!(snap.domains[0].idle);
        assert_eq!(snap.idle_workloads().len(), 1);
    }

    #[test]
    fn membw_reconstructed_from_counters_matches_demand() {
        let mut eng = engine_with(WorkloadClass::Jacobi, true);
        let mut mon = Monitor::new(0.025);
        eng.step();
        let _first = mon.poll(&eng); // seeds the counter baseline
        for _ in 0..10 {
            eng.step();
        }
        let snap = mon.poll(&eng);
        let membw = snap.domains[0].util[3];
        let demand = crate::workloads::catalog::spec_of(WorkloadClass::Jacobi).demand[3];
        assert!(
            (membw - demand).abs() < 0.05,
            "counter-derived membw {membw} vs demand {demand}"
        );
    }

    #[test]
    fn departed_domains_are_forgotten() {
        let mut eng = engine_with(WorkloadClass::Blackscholes, true);
        let mut mon = Monitor::new(0.025);
        eng.step();
        mon.poll(&eng);
        assert_eq!(mon.last_counters.len(), 1);
        // Force-finish the VM.
        eng.vms[0].state = VmState::Finished;
        let snap = mon.poll(&eng);
        assert!(snap.domains.is_empty());
        assert!(mon.last_counters.is_empty());
    }
}

//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from the Rust hot path. Python is never involved at runtime.
//!
//! * [`artifacts`] — manifest parsing + artifact path resolution.
//! * [`pjrt`] — the PJRT CPU client wrapper with an executable cache.
//! * [`scoring`] — the XLA scoring backend (the fused Pallas kernel that
//!   evaluates RAS overload + IAS interference for all cores in one call).
//! * [`compute`] — the real-compute workload kernels (Black-Scholes,
//!   Jacobi) the e2e example runs inside simulated VMs.

pub mod artifacts;
pub mod compute;
pub mod pjrt;
pub mod scoring;

pub use artifacts::Manifest;
pub use pjrt::Runtime;
pub use scoring::XlaScoring;

/// Compiled shapes — MUST match python/compile/kernels/*.py.
pub mod shapes {
    /// score.py C_MAX.
    pub const C_MAX: usize = 32;
    /// score.py V_MAX.
    pub const V_MAX: usize = 64;
    /// score.py M_METRICS.
    pub const M_METRICS: usize = 4;
    /// blackscholes.py N_OPTIONS.
    pub const N_OPTIONS: usize = 65536;
    /// jacobi.py H, W.
    pub const JACOBI_H: usize = 256;
    pub const JACOBI_W: usize = 256;
    /// model.py SWEEPS_PER_CALL.
    pub const JACOBI_SWEEPS_PER_CALL: usize = 10;
}

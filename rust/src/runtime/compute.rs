//! Real-compute workload kernels: the paper's CPU-intensive benchmarks
//! (PARSEC Black-Scholes, PolyBench Jacobi) executed for real through the
//! compiled Pallas kernels.
//!
//! In real-compute mode (the `e2e_full_stack` example) a simulated VM of
//! class `Blackscholes` or `Jacobi` actually burns compute through PJRT:
//! each scheduling quantum executes kernel batches, so the whole
//! three-layer stack (rust → XLA → Pallas HLO) is exercised end-to-end.

use super::shapes::{JACOBI_H, JACOBI_W, N_OPTIONS};
use super::Runtime;
use crate::util::rng::Rng;
use anyhow::Result;

/// A Black-Scholes work unit: one PJRT call pricing `N_OPTIONS` options.
pub struct BlackscholesWork {
    spot: Vec<f32>,
    strike: Vec<f32>,
    ttm: Vec<f32>,
    rate: Vec<f32>,
    vol: Vec<f32>,
    /// Checksum of the last batch (the unit-of-work receipt).
    pub last_checksum: f64,
    pub batches_done: u64,
}

impl BlackscholesWork {
    pub fn new(seed: u64) -> BlackscholesWork {
        let mut rng = Rng::new(seed);
        let n = N_OPTIONS;
        let gen = |rng: &mut Rng, lo: f64, hi: f64| -> Vec<f32> {
            (0..n).map(|_| rng.range(lo, hi) as f32).collect()
        };
        BlackscholesWork {
            spot: gen(&mut rng, 5.0, 200.0),
            strike: gen(&mut rng, 5.0, 200.0),
            ttm: gen(&mut rng, 0.05, 3.0),
            rate: gen(&mut rng, 0.0, 0.1),
            vol: gen(&mut rng, 0.05, 0.9),
            last_checksum: 0.0,
            batches_done: 0,
        }
    }

    /// Execute one batch; returns the checksum (finite ⇒ kernel healthy).
    pub fn run_batch(&mut self, rt: &mut Runtime) -> Result<f64> {
        let outs = rt.execute_f32(
            "blackscholes",
            &[&self.spot, &self.strike, &self.ttm, &self.rate, &self.vol],
        )?;
        // outputs: call[n], put[n], checksum[1]
        let checksum = outs[2][0] as f64;
        anyhow::ensure!(checksum.is_finite(), "blackscholes checksum NaN/inf");
        self.last_checksum = checksum;
        self.batches_done += 1;
        Ok(checksum)
    }
}

/// A Jacobi work unit: a persistent grid relaxed by `SWEEPS_PER_CALL`
/// sweeps per PJRT call.
pub struct JacobiWork {
    grid: Vec<f32>,
    pub last_residual: f64,
    pub sweeps_done: u64,
}

impl JacobiWork {
    pub fn new(seed: u64) -> JacobiWork {
        let mut rng = Rng::new(seed);
        let grid = (0..JACOBI_H * JACOBI_W)
            .map(|_| rng.range(-1.0, 1.0) as f32)
            .collect();
        JacobiWork {
            grid,
            last_residual: f64::INFINITY,
            sweeps_done: 0,
        }
    }

    /// Execute one call (10 fused sweeps); the grid persists across calls.
    pub fn run_batch(&mut self, rt: &mut Runtime) -> Result<f64> {
        let outs = rt.execute_f32("jacobi", &[&self.grid])?;
        self.grid = outs[0].clone();
        let resid = outs[1][0] as f64;
        anyhow::ensure!(resid.is_finite(), "jacobi residual NaN/inf");
        self.last_residual = resid;
        self.sweeps_done += super::shapes::JACOBI_SWEEPS_PER_CALL as u64;
        Ok(resid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        match Runtime::new() {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("skipping compute test: {e}");
                None
            }
        }
    }

    #[test]
    fn blackscholes_batches_produce_stable_checksum() {
        let Some(mut rt) = runtime() else { return };
        let mut work = BlackscholesWork::new(7);
        let c1 = work.run_batch(&mut rt).unwrap();
        let c2 = work.run_batch(&mut rt).unwrap();
        // Same inputs -> same checksum up to reduction-order jitter (the
        // XLA CPU backend may parallelise the sum).
        let rel = (c1 - c2).abs() / c1.abs().max(1.0);
        assert!(rel < 1e-5, "checksums diverge: {c1} vs {c2}");
        assert!(c1 > 0.0, "sum of option prices must be positive: {c1}");
        assert_eq!(work.batches_done, 2);
    }

    #[test]
    fn jacobi_residual_decreases() {
        let Some(mut rt) = runtime() else { return };
        let mut work = JacobiWork::new(3);
        let r1 = work.run_batch(&mut rt).unwrap();
        let r2 = work.run_batch(&mut rt).unwrap();
        let r3 = work.run_batch(&mut rt).unwrap();
        assert!(r2 < r1, "relaxation must converge: {r1} -> {r2}");
        assert!(r3 < r2, "{r2} -> {r3}");
        assert_eq!(work.sweeps_done, 30);
    }
}

//! The PJRT CPU client wrapper: compile-once, execute-many.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and python/compile/aot.py).

use super::artifacts::Manifest;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A loaded PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Execution counters for reporting.
    pub executions: u64,
}

impl Runtime {
    /// Create a CPU PJRT runtime over the discovered artifacts.
    pub fn new() -> Result<Runtime> {
        let manifest = Manifest::discover()?;
        Runtime::with_manifest(manifest)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: BTreeMap::new(),
            executions: 0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Inputs are f32 literals matching the manifest
    /// signature; the output tuple is unpacked into a `Vec<Literal>`.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.prepare(name)?;
        let entry = self.manifest.entry(name)?;
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "artifact '{name}' expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{name}'"))?[0][0]
            .to_literal_sync()?;
        self.executions += 1;
        // aot.py lowers with return_tuple=True: unpack.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == entry.outputs.len(),
            "artifact '{name}' returned {} outputs, manifest says {}",
            parts.len(),
            entry.outputs.len()
        );
        Ok(parts)
    }

    /// Execute with f32 slices in/out (convenience over raw literals).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self.manifest.entry(name)?.clone();
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let spec = &entry.inputs[i];
            anyhow::ensure!(
                data.len() == spec.elements(),
                "input {i} of '{name}': {} elements, expected {}",
                data.len(),
                spec.elements()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let outs = self.execute(name, &literals)?;
        outs.into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        match Runtime::new() {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("skipping pjrt test: {e}");
                None
            }
        }
    }

    #[test]
    fn client_boots_and_compiles_score() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        rt.prepare("score").unwrap();
        // Second prepare is a cache hit (no error, no recompile).
        rt.prepare("score").unwrap();
    }

    #[test]
    fn input_arity_checked() {
        let Some(mut rt) = runtime() else { return };
        let bad = rt.execute("score", &[]);
        assert!(bad.is_err());
    }

    #[test]
    fn execute_f32_validates_lengths() {
        let Some(mut rt) = runtime() else { return };
        let short = [0.0f32; 3];
        let res = rt.execute_f32("jacobi", &[&short]);
        assert!(res.is_err());
    }
}

//! Artifact discovery: locate `artifacts/` and parse `manifest.json`
//! (written by `python -m compile.aot`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Input/output signature entry.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact (an AOT-lowered jitted function).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

/// Locate the artifacts directory: `$VMCD_ARTIFACTS`, else `./artifacts`,
/// else walking up from the current directory (so tests and examples work
/// from any cwd inside the repo).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("VMCD_ARTIFACTS") {
        let p = PathBuf::from(dir);
        anyhow::ensure!(p.join("manifest.json").exists(), "no manifest in $VMCD_ARTIFACTS");
        return Ok(p);
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found — run `make artifacts` first \
                 (or set VMCD_ARTIFACTS)"
            );
        }
    }
}

impl Manifest {
    /// Load the manifest from the default location.
    pub fn discover() -> Result<Manifest> {
        Manifest::load(&artifacts_dir()?)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let obj = json
            .as_obj()
            .context("manifest must be a json object")?;
        let mut entries = Vec::new();
        for (name, entry) in obj {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .field(key)?
                    .as_arr()
                    .context("spec list")?
                    .iter()
                    .map(|spec| {
                        Ok(TensorSpec {
                            shape: spec
                                .field("shape")?
                                .to_f64_vec()?
                                .into_iter()
                                .map(|x| x as usize)
                                .collect(),
                            dtype: spec
                                .field("dtype")?
                                .as_str()
                                .context("dtype string")?
                                .to_string(),
                        })
                    })
                    .collect()
            };
            entries.push(ArtifactEntry {
                name: name.clone(),
                file: dir.join(
                    entry
                        .field("file")?
                        .as_str()
                        .context("file must be a string")?,
                ),
                sha256: entry
                    .get("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().is_ok()
    }

    #[test]
    fn manifest_parses_and_matches_compiled_shapes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::discover().unwrap();
        let score = m.entry("score").unwrap();
        assert_eq!(score.inputs.len(), 7);
        assert_eq!(
            score.inputs[0].shape,
            vec![super::super::shapes::C_MAX, super::super::shapes::V_MAX]
        );
        assert_eq!(score.outputs.len(), 4);
        assert!(score.file.exists());

        let bs = m.entry("blackscholes").unwrap();
        assert_eq!(bs.inputs.len(), 5);
        assert_eq!(bs.inputs[0].shape, vec![super::super::shapes::N_OPTIONS]);

        let jc = m.entry("jacobi").unwrap();
        assert_eq!(
            jc.inputs[0].shape,
            vec![
                super::super::shapes::JACOBI_H,
                super::super::shapes::JACOBI_W
            ]
        );
        assert!(m.entry("nonexistent").is_err());
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec {
            shape: vec![32, 64],
            dtype: "float32".into(),
        };
        assert_eq!(t.elements(), 2048);
    }
}

//! The XLA scoring backend: the VMCd decision hot path executed as the
//! AOT-compiled fused Pallas kernel (python/compile/kernels/score.py).
//!
//! One PJRT call evaluates, for every core simultaneously, the RAS overload
//! (Eq. 2) before/after placing the candidate and the IAS core interference
//! (Eq. 3+4) before/after. Live state is padded to the compiled
//! (C_MAX × V_MAX) shapes; padding is inert by construction (assign rows 0,
//! S entries 1).

use super::shapes::{C_MAX, M_METRICS, V_MAX};
use super::Runtime;
use crate::profiling::ProfileBank;
use crate::vmcd::scheduler::{PlacementState, Scores, ScoringBackend};
use crate::workloads::WorkloadClass;

pub struct XlaScoring {
    rt: Runtime,
    /// Pre-allocated input buffers (avoid per-call allocation).
    assign: Vec<f32>,
    u: Vec<f32>,
    s: Vec<f32>,
    cand_u: Vec<f32>,
    s_vc: Vec<f32>,
    s_cv: Vec<f32>,
    thr: Vec<f32>,
}

impl XlaScoring {
    pub fn new(mut rt: Runtime) -> anyhow::Result<XlaScoring> {
        rt.prepare("score")?;
        Ok(XlaScoring {
            rt,
            assign: vec![0.0; C_MAX * V_MAX],
            u: vec![0.0; V_MAX * M_METRICS],
            s: vec![1.0; V_MAX * V_MAX],
            cand_u: vec![0.0; M_METRICS],
            s_vc: vec![1.0; V_MAX],
            s_cv: vec![1.0; V_MAX],
            thr: vec![1.2],
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl ScoringBackend for XlaScoring {
    fn score_into(
        &mut self,
        state: &PlacementState,
        cand: WorkloadClass,
        bank: &ProfileBank,
        thr: f64,
        cpu_only: bool,
        out: &mut Scores,
    ) {
        let ncores = state.cores.len();
        out.reset(ncores);
        assert!(ncores <= C_MAX, "host has more cores than the compiled kernel");

        // Collect placed VM slots: (core, class index).
        let mut slots: Vec<(usize, usize)> = Vec::new();
        for (core, members) in state.cores.iter().enumerate() {
            for &class_idx in members {
                slots.push((core, class_idx));
            }
        }
        assert!(
            slots.len() <= V_MAX,
            "more resident VMs ({}) than the compiled kernel supports ({V_MAX})",
            slots.len()
        );

        // ---- fill padded buffers ----
        self.assign.iter_mut().for_each(|x| *x = 0.0);
        self.u.iter_mut().for_each(|x| *x = 0.0);
        self.s.iter_mut().for_each(|x| *x = 1.0);
        self.s_vc.iter_mut().for_each(|x| *x = 1.0);
        self.s_cv.iter_mut().for_each(|x| *x = 1.0);

        let ci = cand.index();
        for (v, &(core, class_idx)) in slots.iter().enumerate() {
            self.assign[core * V_MAX + v] = 1.0;
            for m in 0..M_METRICS {
                let val = if cpu_only && m != 0 {
                    0.0
                } else {
                    bank.u[class_idx][m] as f32
                };
                self.u[v * M_METRICS + m] = val;
            }
            for (v2, &(_, class2)) in slots.iter().enumerate() {
                self.s[v * V_MAX + v2] = bank.s[class_idx][class2] as f32;
            }
            self.s_vc[v] = bank.s[class_idx][ci] as f32;
            self.s_cv[v] = bank.s[ci][class_idx] as f32;
        }
        for m in 0..M_METRICS {
            self.cand_u[m] = if cpu_only && m != 0 {
                0.0
            } else {
                bank.u[ci][m] as f32
            };
        }
        self.thr[0] = thr as f32;

        // ---- one fused PJRT call ----
        let outs = self
            .rt
            .execute_f32(
                "score",
                &[
                    &self.assign,
                    &self.u,
                    &self.s,
                    &self.cand_u,
                    &self.s_vc,
                    &self.s_cv,
                    &self.thr,
                ],
            )
            .expect("score kernel execution failed");

        for core in 0..ncores {
            out.set(
                core,
                outs[0][core] as f64,
                outs[1][core] as f64,
                outs[2][core] as f64,
                outs[3][core] as f64,
            );
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::vmcd::scheduler::NativeScoring;
    use crate::workloads::WorkloadClass::*;

    fn setup() -> Option<(XlaScoring, ProfileBank)> {
        let rt = match Runtime::new() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping xla scoring test: {e}");
                return None;
            }
        };
        let xs = XlaScoring::new(rt).unwrap();
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        Some((xs, ProfileBank::generate(&cfg)))
    }

    #[test]
    fn xla_matches_native_backend() {
        let Some((mut xla, bank)) = setup() else { return };
        let mut native = NativeScoring::new();

        // Cached state: native runs the incremental path, XLA the fused
        // kernel; both must agree.
        let mut state = PlacementState::with_bank(12, false, &bank);
        state.place(0, Blackscholes);
        state.place(0, StreamLow);
        state.place(1, Jacobi);
        state.place(3, LampHeavy);
        state.place(3, LampLight);

        for cand in [Jacobi, LampLight, StreamHigh, Hadoop] {
            for cpu_only in [false, true] {
                let a = xla.score(&state, cand, &bank, 1.2, cpu_only);
                let b = native.score(&state, cand, &bank, 1.2, cpu_only);
                for core in 0..12 {
                    assert!(
                        (a.ol_before()[core] - b.ol_before()[core]).abs() < 1e-4,
                        "ol_before[{core}] {cand:?}: xla {} native {}",
                        a.ol_before()[core],
                        b.ol_before()[core]
                    );
                    assert!(
                        (a.ol_after()[core] - b.ol_after()[core]).abs() < 1e-4,
                        "ol_after[{core}] {cand:?}"
                    );
                    assert!(
                        (a.ic_before()[core] - b.ic_before()[core]).abs() < 1e-3,
                        "ic_before[{core}] {cand:?}: xla {} native {}",
                        a.ic_before()[core],
                        b.ic_before()[core]
                    );
                    assert!(
                        (a.ic_after()[core] - b.ic_after()[core]).abs() < 1e-3,
                        "ic_after[{core}] {cand:?}: xla {} native {}",
                        a.ic_after()[core],
                        b.ic_after()[core]
                    );
                }
            }
        }
    }

    #[test]
    fn empty_state_scores() {
        let Some((mut xla, bank)) = setup() else { return };
        let state = PlacementState::new(12, false);
        let s = xla.score(&state, Blackscholes, &bank, 1.2, false);
        assert_eq!(s.ol_before().len(), 12);
        for core in 0..12 {
            assert!(s.ol_before()[core].abs() < 1e-6);
            assert!((s.ic_after()[core] - 0.5).abs() < 1e-4); // candidate alone
        }
    }
}

//! Static analysis over this repo's own sources.
//!
//! The only pass today is [`detlint`]: the determinism-contract lint
//! that tier-1 runs over `rust/src` (see `DETERMINISM.md` at the repo
//! root for the contract it enforces). It lives in the library — not a
//! build script or an external tool — so the `[[test]]` target that
//! drives it needs nothing beyond `cargo test`, and fixture tests can
//! exercise the rule engine directly.

pub mod detlint;

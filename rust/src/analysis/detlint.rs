//! `detlint` — the determinism-contract lint over `rust/src`.
//!
//! Every headline gate in this repo (Eq. 2–5 parity, `Single`/`Scoped`/
//! `Pool` bit-identity, Inline-vs-Deferred{0,0} actuation equivalence,
//! the migrator-off replay freeze, the two-process digest audit) assumes
//! the scheduler core is *deterministic*. This pass enforces that by
//! construction instead of by example: it walks the source tree,
//! classifies each module into a [`Tier`], and checks per-tier rules.
//!
//! | Rule | Name | Applies to | Flags |
//! |------|-------------|------------|-------|
//! | R1 | `hash-iter`  | [`Tier::Core`] | `std` `HashMap`/`HashSet` (randomized iteration order) |
//! | R2 | `wall-clock` | [`Tier::Core`] | `Instant::now`, `SystemTime`, `env::var` (OS entropy) |
//! | R3 | `panic`      | Core + Lib | `.unwrap()`, `.expect(`, `panic!`, `todo!`, `unimplemented!` |
//! | R4 | `thread`     | Core + Lib | `std::thread` / `mpsc` outside the two sanctioned seams |
//!
//! The lint is **lexical**, not semantic: it scrubs comments and string
//! literals, skips `#[cfg(test)]` items, and then matches tokens. That
//! means it cannot prove a `HashSet` is used membership-only — which is
//! deliberate: in the deterministic core, even membership-only hash
//! collections are one refactor away from an iteration-order bug, so
//! they must either be converted to `BTreeMap`/`BTreeSet` or carry an
//! inline justification:
//!
//! ```text
//! // detlint: allow(hash-iter): membership-only; keys never iterated
//! ```
//!
//! Legacy `panic` sites are tracked in the burn-down allowlist at
//! `rust/detlint.allow` (`file:line: rule` per line); entries that stop
//! matching a live violation are *stale* and fail the self-check, so the
//! list can only shrink. See `DETERMINISM.md` for the full contract and
//! how the dynamic gates (digest audit, ThreadSanitizer) relate.

use anyhow::{bail, ensure, Context, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// One determinism rule. Names double as the annotation / allowlist
/// grammar (`// detlint: allow(<name>): <why>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1: iteration-order-sensitive std hash collections in the core.
    HashIter,
    /// R2: wall-clock / OS-entropy reads in the core.
    WallClock,
    /// R3: panicking shortcuts in non-test library code.
    Panic,
    /// R4: thread spawning or channels outside the sanctioned seams.
    Thread,
}

pub const ALL_RULES: [Rule; 4] = [Rule::HashIter, Rule::WallClock, Rule::Panic, Rule::Thread];

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::Panic => "panic",
            Rule::Thread => "thread",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        ALL_RULES.into_iter().find(|r| r.name() == s)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Determinism tier of one source file (see `DETERMINISM.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Decision paths whose outputs are bit-compared run-to-run:
    /// all four rules apply.
    Core,
    /// Everything else in the library: R3 + R4 apply (panics and stray
    /// threads hurt embedders even off the decision paths).
    Lib,
    /// Process edges (CLI, bench harness, logger): exempt — timing and
    /// env reads are their job.
    Edge,
}

/// Files that ARE the process edge.
const EDGE_FILES: &[&str] = &["main.rs", "bench.rs", "util/logger.rs"];

/// Deterministic-core files (single files).
const CORE_FILES: &[&str] = &[
    "vmcd/daemon.rs",
    "cluster/bus.rs",
    "cluster/dispatch.rs",
    "cluster/pool.rs",
    "cluster/sim.rs",
    "metrics/ledger.rs",
];

/// Deterministic-core directories (every file below them).
const CORE_DIRS: &[&str] = &["vmcd/scheduler/", "cluster/migrator/", "cluster/trace/", "hostsim/"];

/// The two sanctioned thread/channel seams (R4 does not apply there;
/// the ThreadSanitizer CI job covers them dynamically instead).
const THREAD_SEAMS: &[&str] = &["cluster/pool.rs", "vmcd/actuator.rs"];

/// Classify a file by its path relative to `rust/src` (forward slashes).
pub fn tier_of(rel: &str) -> Tier {
    if EDGE_FILES.contains(&rel) {
        Tier::Edge
    } else if CORE_FILES.contains(&rel) || CORE_DIRS.iter().any(|d| rel.starts_with(d)) {
        Tier::Core
    } else {
        Tier::Lib
    }
}

pub fn is_thread_seam(rel: &str) -> bool {
    THREAD_SEAMS.contains(&rel)
}

/// One rule violation at a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Path relative to `rust/src`, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// The offending line, trimmed, for the failure message.
    pub snippet: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rust/src/{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.snippet
        )
    }
}

/// One `rust/detlint.allow` entry: suppresses exactly one (file, line,
/// rule) triple. Line-exact on purpose — edits shift the line and
/// surface the entry as stale, which is the burn-down pressure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
}

impl fmt::Display for AllowEntry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.file, self.line, self.rule)
    }
}

/// Parse the allowlist format: one `file:line: rule` per line, `#`
/// comments and blank lines ignored.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let n = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.rsplitn(3, ':').map(str::trim);
        let (rule_s, line_s, file) = match (parts.next(), parts.next(), parts.next()) {
            (Some(r), Some(l), Some(f)) if !f.is_empty() => (r, l, f),
            _ => bail!("detlint.allow line {n}: expected 'file:line: rule', got '{raw}'"),
        };
        let rule = match Rule::parse(rule_s) {
            Some(r) => r,
            None => bail!("detlint.allow line {n}: unknown rule '{rule_s}'"),
        };
        let lineno: usize = line_s
            .parse()
            .with_context(|| format!("detlint.allow line {n}: bad line number '{line_s}'"))?;
        entries.push(AllowEntry {
            file: file.to_string(),
            line: lineno,
            rule,
        });
    }
    Ok(entries)
}

/// Render violations back in allowlist format — printed on failure so a
/// deliberate carry-over is one copy-paste, never hand-typed.
pub fn render_allowlist(violations: &[Violation]) -> String {
    let mut out = String::new();
    for v in violations {
        out.push_str(&format!("{}:{}: {}\n", v.file, v.line, v.rule));
    }
    out
}

// ---------------------------------------------------------------------
// Lexical scanner
// ---------------------------------------------------------------------

/// Lexical state carried across lines: block comments AND string
/// literals, because both legally span lines in Rust — a multi-line
/// `r#"…"#` fixture whose braces leaked into `code` once corrupted the
/// `#[cfg(test)]` brace tracking badly enough to un-skip test code.
#[derive(Clone, Copy)]
enum ScrubMode {
    Code,
    BlockComment,
    /// Ordinary `"…"` string (escapes honoured).
    Str,
    /// Raw string `r##"…"##`; payload = number of `#`s in the fence.
    RawStr(usize),
}

struct Scrubber {
    mode: ScrubMode,
}

impl Scrubber {
    fn new() -> Scrubber {
        Scrubber {
            mode: ScrubMode::Code,
        }
    }

    /// Split one line into (code, comment): string/char literal contents
    /// are blanked out of `code` (the delimiting quotes stay), comment
    /// text (line and block) goes to `comment`.
    fn scrub(&mut self, line: &str) -> (String, String) {
        let mut code = String::with_capacity(line.len());
        let mut comment = String::new();
        let chars: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match self.mode {
                ScrubMode::BlockComment => {
                    if c == '*' && next == Some('/') {
                        self.mode = ScrubMode::Code;
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                    continue;
                }
                ScrubMode::Str => {
                    if c == '\\' {
                        i += 2; // skip the escaped char
                    } else {
                        if c == '"' {
                            self.mode = ScrubMode::Code;
                            code.push('"');
                        }
                        i += 1;
                    }
                    continue;
                }
                ScrubMode::RawStr(hashes) => {
                    // Close only on `"` followed by the full `#` fence.
                    if c == '"' && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#')) {
                        self.mode = ScrubMode::Code;
                        code.push('"');
                        i += 1 + hashes;
                    } else {
                        i += 1;
                    }
                    continue;
                }
                ScrubMode::Code => {}
            }
            match c {
                '/' if next == Some('/') => {
                    // Line comment: the rest is comment text.
                    comment.extend(&chars[i..]);
                    break;
                }
                '/' if next == Some('*') => {
                    self.mode = ScrubMode::BlockComment;
                    i += 2;
                }
                'r' | 'b' => {
                    // `r"…"`, `r#"…"#`, `br"…"` raw-string openers — but
                    // only where a literal can start (the previous code
                    // char is not part of an identifier).
                    let ident_prev = code
                        .chars()
                        .last()
                        .map(|p| p.is_alphanumeric() || p == '_')
                        .unwrap_or(false);
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0;
                    while chars.get(j + hashes) == Some(&'#') {
                        hashes += 1;
                    }
                    let has_r = c == 'r' || j > i + 1;
                    if !ident_prev && has_r && chars.get(j + hashes) == Some(&'"') {
                        self.mode = ScrubMode::RawStr(hashes);
                        code.push('"');
                        i = j + hashes + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                '"' => {
                    self.mode = ScrubMode::Str;
                    code.push('"');
                    i += 1;
                }
                '\'' => {
                    // Char literal vs lifetime: 'x' / '\n' are literals,
                    // 'a in generics is a lifetime.
                    if next == Some('\\') {
                        // Escaped char literal: skip to the closing quote.
                        let mut j = i + 2;
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        i = j + 1;
                    } else if chars.get(i + 2).copied() == Some('\'') {
                        i += 3; // 'x'
                    } else {
                        code.push(c); // lifetime
                        i += 1;
                    }
                }
                _ => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        (code, comment)
    }
}

/// `// detlint: allow(<rule>): <why>` — the why is mandatory.
fn parse_annotation(comment: &str) -> Option<Rule> {
    let start = comment.find("detlint: allow(")?;
    let rest = &comment[start + "detlint: allow(".len()..];
    let close = rest.find(')')?;
    let rule = Rule::parse(rest[..close].trim())?;
    let tail = rest[close + 1..].trim_start();
    let why = tail.strip_prefix(':')?.trim();
    if why.is_empty() {
        return None;
    }
    Some(rule)
}

fn token_hit(code: &str, tokens: &[&str]) -> bool {
    tokens.iter().any(|t| code.contains(t))
}

const HASH_TOKENS: &[&str] = &["HashMap", "HashSet"];
const CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime", "env::var", "RandomState"];
const PANIC_TOKENS: &[&str] = &[".unwrap()", ".expect(", "panic!", "todo!", "unimplemented!"];
const THREAD_TOKENS: &[&str] = &["std::thread", "mpsc"];

/// Which rules a line in (`tier`, seam?) must satisfy.
fn applicable(tier: Tier, seam: bool) -> Vec<Rule> {
    let mut rules = Vec::new();
    match tier {
        Tier::Edge => {}
        Tier::Core => {
            rules.extend([Rule::HashIter, Rule::WallClock, Rule::Panic]);
            if !seam {
                rules.push(Rule::Thread);
            }
        }
        Tier::Lib => {
            rules.push(Rule::Panic);
            if !seam {
                rules.push(Rule::Thread);
            }
        }
    }
    rules
}

fn rule_tokens(rule: Rule) -> &'static [&'static str] {
    match rule {
        Rule::HashIter => HASH_TOKENS,
        Rule::WallClock => CLOCK_TOKENS,
        Rule::Panic => PANIC_TOKENS,
        Rule::Thread => THREAD_TOKENS,
    }
}

/// Lint one file's source with an explicit tier/seam (fixture entry
/// point). Annotations are honoured; the allowlist is applied by
/// [`run`], not here.
pub fn lint_with_tier(rel: &str, src: &str, tier: Tier, seam: bool) -> Vec<Violation> {
    let rules = applicable(tier, seam);
    if rules.is_empty() {
        return Vec::new();
    }
    let mut scrubber = Scrubber::new();
    let mut violations = Vec::new();
    // cfg(test) tracking: `pending` after the attribute, `skip_depth`
    // while inside the test item's braces.
    let mut pending_test_attr = false;
    let mut skip_depth: i64 = 0;
    let mut in_test_item = false;
    // Annotation from an own-line comment, covering the next code line.
    let mut carried: Vec<Rule> = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let (code, comment) = scrubber.scrub(raw);
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;

        if in_test_item {
            skip_depth += opens - closes;
            if skip_depth <= 0 {
                in_test_item = false;
            }
            continue;
        }
        if code.contains("#[cfg(test)]") {
            if opens > 0 {
                skip_depth = opens - closes;
                in_test_item = skip_depth > 0;
            } else {
                pending_test_attr = true;
            }
            continue;
        }
        if pending_test_attr {
            if opens > 0 {
                skip_depth = opens - closes;
                in_test_item = skip_depth > 0;
                pending_test_attr = false;
            } else if code.contains(';') {
                pending_test_attr = false; // attribute on a use/statement
            }
            continue;
        }

        let annotation = parse_annotation(&comment);
        if code.trim().is_empty() {
            // Comment-only line: its annotation covers the next code line.
            if let Some(rule) = annotation {
                carried.push(rule);
            }
            continue;
        }
        let mut allowed = std::mem::take(&mut carried);
        allowed.extend(annotation);

        for &rule in &rules {
            if token_hit(&code, rule_tokens(rule)) && !allowed.contains(&rule) {
                violations.push(Violation {
                    file: rel.to_string(),
                    line: lineno,
                    rule,
                    snippet: raw.trim().to_string(),
                });
            }
        }
    }
    violations
}

/// Lint one file, deriving tier and seam status from its path.
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    lint_with_tier(rel, src, tier_of(rel), is_thread_seam(rel))
}

// ---------------------------------------------------------------------
// Tree runner
// ---------------------------------------------------------------------

/// Outcome of a full-tree run.
#[derive(Debug)]
pub struct LintReport {
    /// Violations not covered by the allowlist — any entry fails tier-1.
    pub violations: Vec<Violation>,
    /// Allowlist entries that no longer match a live violation — stale
    /// entries fail the self-check so the list only shrinks.
    pub stale: Vec<AllowEntry>,
    /// Violations the allowlist suppressed (the burn-down backlog).
    pub suppressed: usize,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty()
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("reading entry in {}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk `<repo_root>/rust/src`, lint every file, and apply the
/// allowlist at `<repo_root>/rust/detlint.allow` (absent = empty).
pub fn run(repo_root: &Path) -> Result<LintReport> {
    let src_root = repo_root.join("rust").join("src");
    ensure!(
        src_root.is_dir(),
        "detlint: {} is not a directory",
        src_root.display()
    );
    let allow_path = repo_root.join("rust").join("detlint.allow");
    let allow = if allow_path.is_file() {
        let text = std::fs::read_to_string(&allow_path)
            .with_context(|| format!("reading {}", allow_path.display()))?;
        parse_allowlist(&text)?
    } else {
        Vec::new()
    };

    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    // Deterministic scan order whatever the directory iteration order.
    files.sort();

    let mut raw = Vec::new();
    for path in &files {
        let rel_os = path
            .strip_prefix(&src_root)
            .with_context(|| format!("{} outside {}", path.display(), src_root.display()))?;
        let rel = rel_os
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        raw.extend(lint_source(&rel, &src));
    }

    let mut used = vec![false; allow.len()];
    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    for v in raw {
        let hit = allow
            .iter()
            .position(|a| a.file == v.file && a.line == v.line && a.rule == v.rule);
        match hit {
            Some(i) => {
                used[i] = true;
                suppressed += 1;
            }
            None => violations.push(v),
        }
    }
    let stale = allow
        .into_iter()
        .zip(used)
        .filter(|(_, u)| !u)
        .map(|(a, _)| a)
        .collect();

    Ok(LintReport {
        violations,
        stale,
        suppressed,
        files_scanned: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_match_the_contract_table() {
        assert_eq!(tier_of("vmcd/scheduler/ias.rs"), Tier::Core);
        assert_eq!(tier_of("vmcd/daemon.rs"), Tier::Core);
        assert_eq!(tier_of("cluster/migrator/planner.rs"), Tier::Core);
        assert_eq!(tier_of("cluster/trace/replay.rs"), Tier::Core);
        assert_eq!(tier_of("hostsim/engine.rs"), Tier::Core);
        assert_eq!(tier_of("metrics/ledger.rs"), Tier::Core);
        assert_eq!(tier_of("cluster/pool.rs"), Tier::Core);
        assert_eq!(tier_of("util/json.rs"), Tier::Lib);
        assert_eq!(tier_of("vmcd/actuator.rs"), Tier::Lib);
        assert_eq!(tier_of("main.rs"), Tier::Edge);
        assert_eq!(tier_of("util/logger.rs"), Tier::Edge);
        assert!(is_thread_seam("cluster/pool.rs"));
        assert!(is_thread_seam("vmcd/actuator.rs"));
        assert!(!is_thread_seam("cluster/sim.rs"));
    }

    #[test]
    fn scrubber_blanks_strings_and_comments() {
        let mut s = Scrubber::new();
        let (code, comment) = s.scrub(r#"let x = "HashMap::new()"; // HashSet here"#);
        assert!(!code.contains("HashMap"));
        assert!(comment.contains("HashSet"));
        let (code, _) = s.scrub(r#"let c = 'x'; let l: Vec<&'static str> = vec![];"#);
        assert!(code.contains("'static"));
        let mut s = Scrubber::new();
        let (code, _) = s.scrub("let a = 1; /* HashMap");
        assert!(!code.contains("HashMap"));
        assert!(matches!(s.mode, ScrubMode::BlockComment));
        let (code, _) = s.scrub("HashSet */ let b = 2;");
        assert!(!code.contains("HashSet"));
        assert!(code.contains("let b"));
    }

    #[test]
    fn scrubber_tracks_multiline_and_raw_strings() {
        // Multi-line ordinary string: the continuation line is string,
        // not code.
        let mut s = Scrubber::new();
        let (_, _) = s.scrub(r#"let x = "start of a"#);
        let (code, _) = s.scrub(r#"HashMap } } continuation"; let y = 1;"#);
        assert!(!code.contains("HashMap"));
        assert!(!code.contains('}'), "string braces must not leak: {code}");
        assert!(code.contains("let y"));

        // Raw string with a hash fence: embedded quotes and braces stay
        // inside until the full `"#` fence.
        let mut s = Scrubber::new();
        let (code, _) = s.scrub(r##"let j = r#"{"a": {"b": 1}}"#;"##);
        assert!(!code.contains('{'), "raw-string braces leaked: {code}");
        let mut s = Scrubber::new();
        let (_, _) = s.scrub(r##"let j = r#"{"multi": ["#);
        let (code, _) = s.scrub(r##"  {"HashMap": 1}]}"#; let z = 2;"##);
        assert!(!code.contains("HashMap"));
        assert!(code.contains("let z"));

        // `r` as an ordinary identifier char is not a raw-string opener.
        let mut s = Scrubber::new();
        let (code, _) = s.scrub(r#"for x in iter { body(x) }"#);
        assert!(code.contains("for x in iter"));
    }

    #[test]
    fn annotation_grammar_requires_a_reason() {
        assert_eq!(
            parse_annotation("// detlint: allow(hash-iter): membership only"),
            Some(Rule::HashIter)
        );
        assert_eq!(parse_annotation("// detlint: allow(hash-iter):"), None);
        assert_eq!(parse_annotation("// detlint: allow(hash-iter)"), None);
        assert_eq!(parse_annotation("// detlint: allow(nonsense): x"), None);
        assert_eq!(
            parse_annotation("// detlint: allow(wall-clock): events/sec only"),
            Some(Rule::WallClock)
        );
    }

    #[test]
    fn allowlist_parses_and_rejects() {
        let entries =
            parse_allowlist("# comment\n\nvmcd/daemon.rs:10: panic\nutil/json.rs:5: panic # why\n")
                .expect("well-formed allowlist parses");
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].file, "vmcd/daemon.rs");
        assert_eq!(entries[0].line, 10);
        assert_eq!(entries[0].rule, Rule::Panic);
        assert!(parse_allowlist("vmcd/daemon.rs:ten: panic").is_err());
        assert!(parse_allowlist("vmcd/daemon.rs:10: frobnicate").is_err());
        assert!(parse_allowlist("just-words").is_err());
    }
}

//! Deterministic pseudo-random number generation (splitmix64 core).
//!
//! Every stochastic component of the simulator (arrival jitter, demand
//! noise, scenario composition) draws from an explicitly-seeded [`Rng`], so
//! every experiment in EXPERIMENTS.md is exactly reproducible from its
//! recorded seed.

/// Splitmix64 PRNG — tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng {
            // Avoid the all-zeros fixed point without changing other seeds.
            state: seed ^ 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift rejection-free mapping; bias is < 2^-64 * n,
        // negligible for simulation use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponentially-distributed value with the given mean (inter-arrival
    /// gaps for Poisson processes).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.uniform().max(1e-12).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Derive an independent generator (for splitting a seed across
    /// subsystems without correlating their streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_hits_all_buckets() {
        let mut r = Rng::new(3);
        let mut hits = [0usize; 7];
        for _ in 0..7000 {
            hits[r.below(7)] += 1;
        }
        for (i, h) in hits.iter().enumerate() {
            assert!(*h > 700, "bucket {i} starved: {h}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(30.0)).sum::<f64>() / n as f64;
        assert!((mean - 30.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}

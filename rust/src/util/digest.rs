//! FNV-1a run digests for the determinism contract.
//!
//! The contract (`DETERMINISM.md`) promises *bit-identity*: the same
//! seed and spec must produce the same result down to the last float
//! bit, whatever the step mode, actuation backend, or process. A
//! digest makes that promise checkable across process boundaries —
//! `vmcd cluster … --digest` prints one hex line, and the two-process
//! audit in `rust/tests/detlint.rs` compares it between runs.
//!
//! FNV-1a (64-bit) is used because it is tiny, dependency-free, and
//! fully specified — this is a fingerprint for *equality testing of
//! trusted outputs*, not a cryptographic commitment. Floats are folded
//! via [`f64::to_bits`] so the digest inherits the repo-wide
//! bit-identity convention instead of rounding anything away.

/// Incremental 64-bit FNV-1a hasher over primitive fields.
///
/// Field order matters: `digest` is a fold, so callers must feed
/// fields in one fixed, documented order (struct declaration order by
/// convention) and never reorder them without noting the digest break.
#[derive(Debug, Clone)]
pub struct Fnv64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: FNV_OFFSET }
    }

    /// Fold raw bytes into the state.
    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Fnv64 {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Fold one `u64` (little-endian bytes).
    pub fn write_u64(&mut self, v: u64) -> &mut Fnv64 {
        self.write_bytes(&v.to_le_bytes())
    }

    /// Fold one `usize` (widened so 32- and 64-bit hosts agree).
    pub fn write_usize(&mut self, v: usize) -> &mut Fnv64 {
        self.write_u64(v as u64)
    }

    /// Fold one bool as a full byte.
    pub fn write_bool(&mut self, v: bool) -> &mut Fnv64 {
        self.write_bytes(&[v as u8])
    }

    /// Fold an `f64` by bit pattern — NaN payloads and signed zeros
    /// included, matching the `to_bits` equality used by the
    /// bit-identity tests.
    pub fn write_f64(&mut self, v: f64) -> &mut Fnv64 {
        self.write_u64(v.to_bits())
    }

    /// Finish: the current 64-bit digest.
    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Canonical FNV-1a test vectors (empty string, "a", "foobar").
        assert_eq!(Fnv64::new().finish(), 0xcbf29ce484222325);
        assert_eq!(Fnv64::new().write_bytes(b"a").finish(), 0xaf63dc4c8601ec8c);
        assert_eq!(
            Fnv64::new().write_bytes(b"foobar").finish(),
            0x85944171f73967e8
        );
    }

    #[test]
    fn field_order_changes_the_digest() {
        let ab = Fnv64::new().write_u64(1).write_u64(2).finish();
        let ba = Fnv64::new().write_u64(2).write_u64(1).finish();
        assert_ne!(ab, ba);
    }

    #[test]
    fn floats_fold_by_bit_pattern() {
        let pos = Fnv64::new().write_f64(0.0).finish();
        let neg = Fnv64::new().write_f64(-0.0).finish();
        assert_ne!(pos, neg, "signed zeros must be distinguishable");
        let a = Fnv64::new().write_f64(1.5).finish();
        let b = Fnv64::new().write_f64(1.5).finish();
        assert_eq!(a, b);
    }
}

//! Tiny `log`-crate backend writing to stderr with a monotonic timestamp.
//!
//! `RUST_LOG`-style filtering via `VMCD_LOG` (error|warn|info|debug|trace,
//! default info).

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, meta: &log::Metadata) -> bool {
        meta.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("VMCD_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    #[allow(clippy::disallowed_methods)] // process edge: log timestamps are wall time
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        level,
    });
    // Err only if a logger is already set — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

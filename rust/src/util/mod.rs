//! First-party utility modules.
//!
//! The build environment is fully offline with a fixed vendored crate set
//! (no `rand`, `serde`, `clap`, …), so the small pieces of infrastructure a
//! normal project would pull from crates.io live here instead.

pub mod cli;
pub mod digest;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;

/// Clamp a float into `[lo, hi]`.
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// `true` if two floats agree within `tol` absolutely or relatively.
#[inline]
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    let d = (a - b).abs();
    d <= tol || d <= tol * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clampf_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn close_absolute_and_relative() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6));
        assert!(close(1e9, 1e9 * (1.0 + 1e-7), 1e-6));
        assert!(!close(1.0, 2.0, 1e-6));
    }
}

//! Minimal JSON parser / writer (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar; numbers are f64 (adequate for every
//! artifact this repo reads/writes: `artifacts/manifest.json`, profile
//! banks, metric exports, scenario configs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num_array(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Mandatory-field accessor with a readable error.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Insert `key` into an object. Setting a field on a non-object is a
    /// malformed-document bug in the caller; it surfaces as an error
    /// instead of a panic so artifact writers can fail cleanly.
    pub fn set(&mut self, key: &str, val: Json) -> anyhow::Result<()> {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
                Ok(())
            }
            other => anyhow::bail!("Json::set('{key}') on non-object value {other}"),
        }
    }

    /// Collect an array of numbers.
    pub fn to_f64_vec(&self) -> anyhow::Result<Vec<f64>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    // ---- parse ----
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- write ----
    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    self.pos = end;
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(chunk) => s.push_str(chunk),
                        Err(_) => s.push('\u{fffd}'),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // detlint: allow(panic): slice is all ASCII digit/sign/dot bytes by construction
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let again = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, again, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn set_inserts_on_objects_and_errors_on_scalars() {
        let mut obj = Json::obj();
        obj.set("a", Json::Num(1.0)).unwrap();
        assert_eq!(obj.get("a").and_then(Json::as_f64), Some(1.0));
        let mut arr = Json::Arr(vec![]);
        assert!(arr.set("a", Json::Null).is_err());
        let mut num = Json::Num(2.0);
        assert!(num.set("a", Json::Null).is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::from_pairs(vec![
            ("name", Json::Str("vmcd".into())),
            ("xs", Json::num_array(&[1.0, 2.5, -3.0])),
            ("flag", Json::Bool(true)),
        ]);
        for text in [v.dump(), v.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn escapes() {
        let v = Json::Str("tab\t\"quote\" \\ back \u{1}".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""αβγ λ""#).unwrap();
        assert_eq!(v.as_str(), Some("αβγ λ"));
        let esc = Json::parse(r#""α""#).unwrap();
        assert_eq!(esc.as_str(), Some("α"));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "{} []"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn real_manifest_shape() {
        let text = r#"{"score": {"file": "score.hlo.txt",
            "inputs": [{"shape": [32, 64], "dtype": "float32"}],
            "outputs": [{"shape": [32, 1], "dtype": "float32"}]}}"#;
        let v = Json::parse(text).unwrap();
        let ins = v.get("score").unwrap().get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(
            ins[0].get("shape").unwrap().to_f64_vec().unwrap(),
            vec![32.0, 64.0]
        );
    }
}

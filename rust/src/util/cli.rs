//! Minimal command-line argument parsing (clap is not in the offline set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Option names that never take a value (so `--xla run` parses `run` as a
/// positional, not as the value of `--xla`).
const KNOWN_FLAGS: &[&str] = &["xla", "verbose", "json", "quick", "help", "real-compute"];

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&body) {
                    out.flags.push(body.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    // detlint: allow(panic): peek() one line up proved Some
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["run", "--verbose", "random", "--seed", "42"]);
        assert_eq!(a.positional, vec!["run", "random"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("seed"), Some("42"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--sr=1.5", "--policy=ias"]);
        assert_eq!(a.opt_f64("sr", 0.0).unwrap(), 1.5);
        assert_eq!(a.opt("policy"), Some("ias"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--json"]);
        assert!(a.flag("json"));
        assert_eq!(a.opt("json"), None);
    }

    #[test]
    fn numeric_errors_are_reported() {
        let a = parse(&["--sr", "abc"]);
        assert!(a.opt_f64("sr", 0.0).is_err());
        assert_eq!(a.opt_usize("cores", 12).unwrap(), 12);
    }
}

//! Summary statistics for metric series and bench results.

/// Online summary of a sample set (Welford mean/variance + retained sample
/// for exact percentiles).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let d = x - self.mean;
        self.mean += d / n;
        self.m2 += d * (x - self.mean);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n−1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Exact percentile by nearest-rank on the sorted sample (p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Geometric mean — used for normalized-performance aggregation, which is
/// the right mean for ratios (the paper reports "average performance"
/// against isolated runs).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let logsum: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (logsum / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn summary_moments() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(close(s.mean(), 3.0, 1e-12));
        assert!(close(s.stddev(), (2.5f64).sqrt(), 1e-12));
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!(close(s.median(), 3.0, 1e-12));
    }

    #[test]
    fn percentiles_nearest_rank() {
        let s = Summary::from_slice(&(0..101).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!(close(geomean(&[0.5, 2.0]), 1.0, 1e-12));
        assert!(close(geomean(&[1.0, 1.0, 1.0]), 1.0, 1e-12));
    }

    #[test]
    fn mean_empty_is_nan() {
        assert!(mean(&[]).is_nan());
        assert!(geomean(&[]).is_nan());
    }
}

//! Seeded property-testing mini-framework (proptest is not in the offline
//! crate set).
//!
//! [`check`] runs a property over `n` pseudo-random cases derived from a
//! base seed; on failure it reports the failing case seed so the exact
//! case can be replayed with [`replay`]. Shared fixtures (the profile
//! bank) are cached process-wide so the many property tests don't re-run
//! the profiling phase.

use crate::config::Config;
use crate::profiling::ProfileBank;
use crate::util::rng::Rng;
use std::sync::OnceLock;

/// Number of cases to run per property (override with VMCD_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("VMCD_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
}

/// Run `prop` over `cases` seeded RNGs. Panics (with the failing seed) on
/// the first violated property.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = splitmix(0xC0FFEE ^ case);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' failed at case {case} (seed {seed:#x}); \
                 replay with testkit::replay({seed:#x}, ...)"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

fn splitmix(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Process-wide cached profile bank over the default (noise-free) config —
/// the expensive fixture most scheduler properties need.
pub fn shared_bank() -> &'static ProfileBank {
    static BANK: OnceLock<ProfileBank> = OnceLock::new();
    BANK.get_or_init(|| {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        ProfileBank::generate(&cfg)
    })
}

/// The matching config for [`shared_bank`].
pub fn quiet_config() -> Config {
    let mut cfg = Config::default();
    cfg.sim.demand_noise = 0.0;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counter", 10, |_rng| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_panics_with_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails", 5, |rng| {
                assert!(rng.uniform() < 2.0); // passes
                assert!(false, "boom");
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn shared_bank_is_cached() {
        let a = shared_bank() as *const _;
        let b = shared_bank() as *const _;
        assert_eq!(a, b);
    }

    #[test]
    fn replay_reproduces_stream() {
        let mut first = Vec::new();
        replay(0xABCD, |rng| {
            for _ in 0..4 {
                first.push(rng.next_u64());
            }
        });
        let mut second = Vec::new();
        replay(0xABCD, |rng| {
            for _ in 0..4 {
                second.push(rng.next_u64());
            }
        });
        assert_eq!(first, second);
    }
}

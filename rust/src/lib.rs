//! # vmcd — resource- and interference-aware VM scheduling
//!
//! Reproduction of *"Improving virtual host efficiency through resource and
//! interference aware scheduling"* (Angelou et al., 2016): a per-host
//! coordinator daemon (VMCd) that dynamically re-pins VM vCPUs onto physical
//! cores to consolidate work (saving CPU-hours / energy) while avoiding
//! co-locating workloads that interfere.
//!
//! ## Layout
//!
//! * [`hostsim`] — discrete-event simulator of the paper's testbed (the
//!   2-socket / 12-core Xeon host, KVM VMs, shared-resource contention).
//!   Substitutes for the real hardware per DESIGN.md §2.
//! * [`workloads`] — the paper's workload classes (PARSEC blackscholes,
//!   Hadoop terasort, PolyBench jacobi, LAMP web serving, CloudSuite media
//!   streaming) as demand/performance models.
//! * [`interference`] — the paper's equations: core overload (Eq. 2),
//!   workload interference WI (Eq. 3), core interference (Eq. 4),
//!   IAS threshold (Eq. 5).
//! * [`profiling`] — the offline phase (§IV-A): isolated + pairwise co-run
//!   measurements producing the S (slowdown) and U (utilisation) matrices.
//! * [`vmcd`] — the daemon: monitor, actuator, and the four schedulers
//!   (RRS baseline, CAS, RAS, IAS).
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`): the XLA scoring backend and the real-compute
//!   workload kernels. Python is never on this path.
//! * [`scenarios`] — the paper's three evaluation scenarios (§V-C).
//! * [`cluster`] — the cluster layer (§III / §VI): the `ClusterEvent`
//!   bus routing all placement churn (arrivals, departures, live
//!   migrations), the persistent shard-worker pool stepping hosts, and
//!   the local-vs-global consolidation simulator over both.
//! * [`metrics`] / [`report`] — CPU-hours ledger, normalized performance,
//!   time series, and the figure/table regeneration.
//! * [`util`] — first-party RNG / JSON / stats / CLI (the build is offline;
//!   see DESIGN.md §6).
//! * [`analysis`] — static analysis over this repo's own sources: the
//!   `detlint` determinism-contract lint tier-1 runs over `rust/src`
//!   (see DETERMINISM.md).
//! * [`bench`] — the benchmark harness used by `benches/` (criterion is not
//!   available offline; this provides warmup/iteration/percentile logic).
//! * [`testkit`] — seeded property-testing mini-framework used by unit and
//!   integration tests (proptest substitute).

pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod hostsim;
pub mod interference;
pub mod metrics;
pub mod profiling;
pub mod report;
pub mod runtime;
pub mod scenarios;
pub mod testkit;
pub mod util;
pub mod vmcd;
pub mod workloads;

pub use config::Config;
pub use hostsim::{Host, HostSpec, SimEngine};
pub use profiling::ProfileBank;
pub use scenarios::{ScenarioKind, ScenarioResult};
pub use vmcd::scheduler::{Policy, Scheduler};

//! CSV / JSON export of metric series and scenario summaries.

use super::timeseries::TimeSeries;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Write a time series as a two-column CSV.
pub fn write_csv(path: &Path, header: &str, ts: &TimeSeries) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{header}")?;
    for (t, v) in &ts.points {
        writeln!(f, "{t},{v}")?;
    }
    Ok(())
}

/// Write several aligned series as one CSV: column 0 is time from the first
/// series, later columns are values (series must share timestamps).
pub fn write_multi_csv(path: &Path, labels: &[&str], series: &[&TimeSeries]) -> Result<()> {
    anyhow::ensure!(labels.len() == series.len(), "labels/series mismatch");
    anyhow::ensure!(!series.is_empty(), "no series");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "t,{}", labels.join(","))?;
    let n = series.iter().map(|s| s.points.len()).min().unwrap_or(0);
    for i in 0..n {
        let t = series[0].points[i].0;
        let vals: Vec<String> = series
            .iter()
            .map(|s| format!("{}", s.points[i].1))
            .collect();
        writeln!(f, "{t},{}", vals.join(","))?;
    }
    Ok(())
}

/// Write a JSON document.
pub fn write_json(path: &Path, json: &Json) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, json.pretty()).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_by_eye() {
        let dir = std::env::temp_dir().join("vmcd_export_test");
        let path = dir.join("ts.csv");
        let mut ts = TimeSeries::new();
        ts.push(0.0, 12.0);
        ts.push(1.0, 11.0);
        write_csv(&path, "t,busy", &ts).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("t,busy\n0,12\n"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_csv_alignment() {
        let dir = std::env::temp_dir().join("vmcd_export_multi");
        let path = dir.join("multi.csv");
        let mut a = TimeSeries::new();
        let mut b = TimeSeries::new();
        for i in 0..5 {
            a.push(i as f64, 1.0);
            b.push(i as f64, 2.0);
        }
        write_multi_csv(&path, &["rrs", "ias"], &[&a, &b]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("t,rrs,ias\n"));
        assert_eq!(text.lines().count(), 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}

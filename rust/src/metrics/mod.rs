//! Metrics: CPU-time ledger, power/energy model, time series, scenario
//! summaries and exports.
//!
//! The paper's two headline quantities (Figures 2-6) are:
//! * **CPU time consumed** — the integral over time of busy (unparked)
//!   cores: a core is busy while at least one resident VM is pinned to it
//!   and not consolidated away; parked cores drop to their lowest power
//!   state (§IV-B: "save cores so as to … allow the cores to revert to
//!   their lowest power state").
//! * **average workload performance** relative to isolated execution.

pub mod export;
pub mod ledger;
pub mod timeseries;

pub use ledger::{ClusterLedger, Ledger};
pub use timeseries::TimeSeries;

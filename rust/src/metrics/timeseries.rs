//! Sampled time series (t, value) — used for the Fig. 4/5 CPU-consumption
//! traces.

#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub points: Vec<(f64, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.points.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Integral by left Riemann sum (points must be time-ordered).
    pub fn integral(&self) -> f64 {
        let mut total = 0.0;
        for w in self.points.windows(2) {
            total += w[0].1 * (w[1].0 - w[0].0);
        }
        total
    }

    /// Mean value weighted by interval length.
    pub fn time_mean(&self) -> f64 {
        let span = match (self.points.first(), self.points.last()) {
            (Some(a), Some(b)) if b.0 > a.0 => b.0 - a.0,
            _ => return f64::NAN,
        };
        self.integral() / span
    }

    pub fn max(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.1)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Downsample to at most `n` points (for plotting/reporting).
    pub fn downsample(&self, n: usize) -> TimeSeries {
        if self.points.len() <= n || n == 0 {
            return self.clone();
        }
        let stride = self.points.len() as f64 / n as f64;
        let mut out = TimeSeries::new();
        let mut i = 0.0;
        while (i as usize) < self.points.len() {
            out.points.push(self.points[i as usize]);
            i += stride;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn integral_left_riemann() {
        let mut ts = TimeSeries::new();
        ts.push(0.0, 2.0);
        ts.push(1.0, 4.0);
        ts.push(3.0, 0.0);
        // 2*1 + 4*2 = 10
        assert!(close(ts.integral(), 10.0, 1e-12));
        assert!(close(ts.time_mean(), 10.0 / 3.0, 1e-12));
    }

    #[test]
    fn empty_and_single_point() {
        let ts = TimeSeries::new();
        assert_eq!(ts.integral(), 0.0);
        assert!(ts.time_mean().is_nan());
    }

    #[test]
    fn downsample_keeps_bounds() {
        let mut ts = TimeSeries::new();
        for i in 0..1000 {
            ts.push(i as f64, (i * 2) as f64);
        }
        let d = ts.downsample(100);
        assert!(d.len() <= 101);
        assert_eq!(d.points[0], (0.0, 0.0));
    }
}

//! The accounting ledger the engine fills in as virtual time advances.

use super::timeseries::TimeSeries;
use crate::config::{HostSpec, PowerModel};

/// Run-long accounting: busy-core integral (the paper's "CPU time
/// consumed"), energy from the power model, and the busy-core time series
/// (Figures 4/5).
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    /// ∫ busy_cores dt — core-seconds.
    pub core_busy_seconds: f64,
    /// ∫ P dt with P = sockets·P_idle + busy·P_core — joules.
    pub energy_joules: f64,
    /// (t, busy cores) sampled every tick.
    pub busy_series: TimeSeries,
    /// Number of vCPU re-pin operations the actuator performed.
    pub repin_count: u64,
    /// Number of scheduler cycles executed.
    pub sched_cycles: u64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one tick: `busy` cores active for `dt` seconds.
    pub fn record_tick(&mut self, t: f64, busy: usize, dt: f64, host: &HostSpec) {
        self.core_busy_seconds += busy as f64 * dt;
        let power = host.sockets as f64 * host.watts_socket_idle
            + busy as f64 * host.watts_per_core;
        self.energy_joules += power * dt;
        self.busy_series.push(t, busy as f64);
    }

    /// The paper's figures report CPU time in core-hours.
    pub fn core_hours(&self) -> f64 {
        self.core_busy_seconds / 3600.0
    }

    pub fn energy_wh(&self) -> f64 {
        self.energy_joules / 3600.0
    }
}

/// Cluster-scope accounting aggregated across hosts (dslab's
/// `energy_meter` + `slav_model` shape): parked-aware energy, the
/// busy-core integral, and the overload-time SLAV metric.
///
/// Two energy integrals are kept. `plugged_energy_joules` charges every
/// host the per-host power model for the whole run (the sum of the
/// per-host [`Ledger`]s, via [`ClusterLedger::absorb`]). `energy_joules`
/// is accumulated per tick by [`ClusterLedger::record_host_tick`] and
/// treats an *empty* host (no resident VMs, no busy cores) as parked at
/// 0 W — the §IV-B "lowest power state". The gap between the two is the
/// energy a consolidation/parking policy actually saves.
///
/// SLAV follows dslab's overload-time model (SLATAH): a powered host
/// spending a tick with every core busy cannot absorb more demand, so
/// that tick counts toward `overload_seconds`; `slav()` normalizes by
/// powered host time.
///
/// The powered draw comes from a pluggable [`PowerModel`]: `Linear`
/// (the default) keeps the PR 8 `sockets·P_idle + busy·P_core`
/// expression bit-exact; `Piecewise` evaluates a SPECpower-style
/// breakpoint table against the host's CPU capacity, with per-host
/// capacity overrides (`host_caps`) giving heterogeneous host classes
/// their own effective curves. The always-plugged integral
/// (`plugged_energy_joules`, absorbed from per-host [`Ledger`]s) stays
/// on the linear reference model either way, so the parked/plugged gap
/// reads against a fixed baseline.
#[derive(Debug, Clone, Default)]
pub struct ClusterLedger {
    /// Σ over hosts of ∫ busy_cores dt — core-seconds (absorbed).
    pub core_busy_seconds: f64,
    /// Σ of per-host ledger energy — every host billed full-run (joules).
    pub plugged_energy_joules: f64,
    /// Parked-aware cluster energy: empty hosts draw 0 W (joules).
    pub energy_joules: f64,
    /// Host-seconds spent with all cores busy (SLAV numerator).
    pub overload_seconds: f64,
    /// Host-seconds powered (non-empty) — SLAV denominator.
    pub active_host_seconds: f64,
    /// (t, powered hosts) sampled once per cluster tick.
    pub powered_series: TimeSeries,
    /// Draw model for powered hosts (`Linear` by default).
    power: PowerModel,
    /// Per-host CPU capacity in cores (utilization denominator for
    /// breakpoint tables). Empty = homogeneous `host.cores`.
    cpu_caps: Vec<f64>,
}

impl ClusterLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// A ledger drawing from `power`, with optional per-host CPU
    /// capacities (`host_caps` CPU column) for heterogeneous fleets.
    pub fn with_power(power: PowerModel, cpu_caps: Vec<f64>) -> Self {
        ClusterLedger {
            power,
            cpu_caps,
            ..Self::default()
        }
    }

    pub fn power_model(&self) -> &PowerModel {
        &self.power
    }

    /// CPU capacity of `host_idx` in cores — the utilization
    /// denominator the power model sees for that host.
    pub fn cpu_cap(&self, host_idx: usize, host: &HostSpec) -> f64 {
        self.cpu_caps
            .get(host_idx)
            .copied()
            .unwrap_or(host.cores as f64)
    }

    /// Account one host for one tick. A host with no residents and no
    /// busy cores is parked: it draws nothing and accrues no active
    /// time. `busy >= cores` marks the tick as overloaded.
    pub fn record_host_tick(
        &mut self,
        host_idx: usize,
        busy: usize,
        resident: usize,
        dt: f64,
        host: &HostSpec,
    ) {
        if resident == 0 && busy == 0 {
            return;
        }
        let power = self.power.watts(busy, self.cpu_cap(host_idx, host), host);
        self.energy_joules += power * dt;
        self.active_host_seconds += dt;
        if busy >= host.cores {
            self.overload_seconds += dt;
        }
    }

    /// Close a cluster tick: sample the powered-host count at `t`.
    pub fn note_tick(&mut self, t: f64, powered: usize) {
        self.powered_series.push(t, powered as f64);
    }

    /// Fold one finished per-host [`Ledger`] into the cluster totals.
    pub fn absorb(&mut self, host: &Ledger) {
        self.core_busy_seconds += host.core_busy_seconds;
        self.plugged_energy_joules += host.energy_joules;
    }

    pub fn core_hours(&self) -> f64 {
        self.core_busy_seconds / 3600.0
    }

    /// Parked-aware cluster energy in Wh.
    pub fn energy_wh(&self) -> f64 {
        self.energy_joules / 3600.0
    }

    /// Always-plugged cluster energy in Wh (sum of per-host ledgers).
    pub fn plugged_energy_wh(&self) -> f64 {
        self.plugged_energy_joules / 3600.0
    }

    /// Powered host time in hours.
    pub fn active_host_hours(&self) -> f64 {
        self.active_host_seconds / 3600.0
    }

    /// dslab-style SLATAH: overload time over powered host time.
    pub fn slav(&self) -> f64 {
        if self.active_host_seconds <= 0.0 {
            0.0
        } else {
            self.overload_seconds / self.active_host_seconds
        }
    }

    /// Time-to-converge after a load spike: seconds from the powered-host
    /// peak to the first later sample at or below half the peak. `None`
    /// when the fleet never drains that far (or never powers up).
    pub fn converge_time(&self) -> Option<f64> {
        let samples = &self.powered_series.points;
        let (peak_at, peak) = samples
            .iter()
            .fold(None, |best: Option<(f64, f64)>, &(t, v)| match best {
                Some((_, bv)) if v <= bv => best,
                _ => Some((t, v)),
            })?;
        if peak <= 0.0 {
            return None;
        }
        let target = (peak / 2.0).ceil();
        samples
            .iter()
            .find(|&&(t, v)| t > peak_at && v <= target)
            .map(|&(t, _)| t - peak_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn tick_accounting() {
        let host = HostSpec::default();
        let mut led = Ledger::new();
        led.record_tick(0.0, 6, 1.0, &host);
        led.record_tick(1.0, 4, 1.0, &host);
        assert!(close(led.core_busy_seconds, 10.0, 1e-12));
        // power: 2*20 + busy*15
        let expect = (40.0 + 90.0) + (40.0 + 60.0);
        assert!(close(led.energy_joules, expect, 1e-9));
        assert_eq!(led.busy_series.len(), 2);
    }

    #[test]
    fn cluster_ledger_parks_empty_hosts() {
        let host = HostSpec::default(); // 12 cores, 2*20 W idle + 15 W/core
        let mut led = ClusterLedger::new();
        // Tick 1: one busy host, one empty (parked) host.
        led.record_host_tick(0, 6, 3, 1.0, &host);
        led.record_host_tick(1, 0, 0, 1.0, &host);
        led.note_tick(0.0, 1);
        // Tick 2: the busy host saturates; an idle-but-resident host hums.
        led.record_host_tick(0, 12, 3, 1.0, &host);
        led.record_host_tick(1, 0, 1, 1.0, &host);
        led.note_tick(1.0, 2);
        // Energy: (40+90) + (40+180) + (40+0); the empty host free.
        assert!(close(led.energy_joules, 130.0 + 220.0 + 40.0, 1e-9));
        assert!(close(led.active_host_seconds, 3.0, 1e-12));
        assert!(close(led.overload_seconds, 1.0, 1e-12));
        assert!(close(led.slav(), 1.0 / 3.0, 1e-12));
    }

    #[test]
    fn piecewise_cluster_energy_matches_hand_computed_wh() {
        // Satellite gate: a two-segment SPECpower-style table on a
        // scripted load must integrate to the hand-computed joules.
        let host = HostSpec::default(); // 12 cores
        let table =
            crate::config::PiecewiseTable::new(vec![(0.0, 40.0), (0.5, 120.0), (1.0, 200.0)])
                .unwrap();
        let mut led =
            ClusterLedger::with_power(crate::config::PowerModel::Piecewise(table), Vec::new());
        led.record_host_tick(0, 6, 6, 1.0, &host); // u = 0.5  -> 120 W
        led.record_host_tick(0, 3, 3, 1.0, &host); // u = 0.25 -> 80 W
        led.record_host_tick(0, 12, 12, 1.0, &host); // u = 1.0 -> 200 W
        led.record_host_tick(1, 0, 0, 1.0, &host); // parked   -> 0 W
        led.record_host_tick(1, 0, 2, 1.0, &host); // idle     -> 40 W
        let joules = 120.0 + 80.0 + 200.0 + 0.0 + 40.0;
        assert!(close(led.energy_joules, joules, 1e-9));
        assert!(close(led.energy_wh(), joules / 3600.0, 1e-12));
        // Overload accounting is model-independent.
        assert!(close(led.overload_seconds, 1.0, 1e-12));
    }

    #[test]
    fn per_host_cpu_caps_change_the_utilization_denominator() {
        // A "big" host class (24-core cap) runs the same busy count at
        // half the utilization of the default 12-core class.
        let host = HostSpec::default();
        let table =
            crate::config::PiecewiseTable::new(vec![(0.0, 40.0), (1.0, 280.0)]).unwrap();
        let mut led = ClusterLedger::with_power(
            crate::config::PowerModel::Piecewise(table),
            vec![12.0, 24.0],
        );
        assert_eq!(led.cpu_cap(0, &host), 12.0);
        assert_eq!(led.cpu_cap(1, &host), 24.0);
        assert_eq!(led.cpu_cap(7, &host), 12.0, "missing cap falls back to cores");
        led.record_host_tick(0, 6, 6, 1.0, &host); // u = 0.5  -> 160 W
        led.record_host_tick(1, 6, 6, 1.0, &host); // u = 0.25 -> 100 W
        assert!(close(led.energy_joules, 260.0, 1e-9));
    }

    #[test]
    fn linear_and_one_segment_piecewise_agree() {
        // A one-segment table spanning idle→full-load draw is the same
        // line the linear model draws; the integrals agree to ULP-scale
        // rounding (the interpolation computes the same value via
        // w0 + Δw·(busy/cap) instead of idle + busy·P_core).
        let host = HostSpec::default();
        let idle = host.sockets as f64 * host.watts_socket_idle;
        let full = idle + host.cores as f64 * host.watts_per_core;
        let table = crate::config::PiecewiseTable::new(vec![(0.0, idle), (1.0, full)]).unwrap();
        let mut lin = ClusterLedger::new();
        let mut pw =
            ClusterLedger::with_power(crate::config::PowerModel::Piecewise(table), Vec::new());
        for busy in 0..=host.cores {
            lin.record_host_tick(0, busy, busy.max(1), 1.0, &host);
            pw.record_host_tick(0, busy, busy.max(1), 1.0, &host);
        }
        let ulps = 8.0 * f64::EPSILON * lin.energy_joules.abs();
        assert!(
            (lin.energy_joules - pw.energy_joules).abs() <= ulps,
            "linear {} vs one-segment piecewise {}",
            lin.energy_joules,
            pw.energy_joules
        );
    }

    #[test]
    fn cluster_ledger_absorbs_host_ledgers() {
        let host = HostSpec::default();
        let mut a = Ledger::new();
        let mut b = Ledger::new();
        a.record_tick(0.0, 6, 1.0, &host);
        b.record_tick(0.0, 4, 1.0, &host);
        let mut led = ClusterLedger::new();
        led.absorb(&a);
        led.absorb(&b);
        assert!(close(led.core_busy_seconds, 10.0, 1e-12));
        assert!(close(led.plugged_energy_joules, 130.0 + 100.0, 1e-9));
    }

    #[test]
    fn converge_time_measures_peak_to_half_drain() {
        let mut led = ClusterLedger::new();
        for (t, powered) in [(0.0, 2), (1.0, 8), (2.0, 8), (3.0, 5), (4.0, 4)] {
            led.note_tick(t, powered);
        }
        // Peak 8 at t=1; half target 4 first reached at t=4.
        assert_eq!(led.converge_time(), Some(3.0));

        let mut flat = ClusterLedger::new();
        flat.note_tick(0.0, 4);
        flat.note_tick(1.0, 4);
        assert_eq!(flat.converge_time(), None);
    }

    #[test]
    fn core_hours_conversion() {
        let host = HostSpec::default();
        let mut led = Ledger::new();
        for i in 0..3600 {
            led.record_tick(i as f64, 2, 1.0, &host);
        }
        assert!(close(led.core_hours(), 2.0, 1e-9));
    }
}

//! The accounting ledger the engine fills in as virtual time advances.

use super::timeseries::TimeSeries;
use crate::config::HostSpec;

/// Run-long accounting: busy-core integral (the paper's "CPU time
/// consumed"), energy from the power model, and the busy-core time series
/// (Figures 4/5).
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    /// ∫ busy_cores dt — core-seconds.
    pub core_busy_seconds: f64,
    /// ∫ P dt with P = sockets·P_idle + busy·P_core — joules.
    pub energy_joules: f64,
    /// (t, busy cores) sampled every tick.
    pub busy_series: TimeSeries,
    /// Number of vCPU re-pin operations the actuator performed.
    pub repin_count: u64,
    /// Number of scheduler cycles executed.
    pub sched_cycles: u64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one tick: `busy` cores active for `dt` seconds.
    pub fn record_tick(&mut self, t: f64, busy: usize, dt: f64, host: &HostSpec) {
        self.core_busy_seconds += busy as f64 * dt;
        let power = host.sockets as f64 * host.watts_socket_idle
            + busy as f64 * host.watts_per_core;
        self.energy_joules += power * dt;
        self.busy_series.push(t, busy as f64);
    }

    /// The paper's figures report CPU time in core-hours.
    pub fn core_hours(&self) -> f64 {
        self.core_busy_seconds / 3600.0
    }

    pub fn energy_wh(&self) -> f64 {
        self.energy_joules / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn tick_accounting() {
        let host = HostSpec::default();
        let mut led = Ledger::new();
        led.record_tick(0.0, 6, 1.0, &host);
        led.record_tick(1.0, 4, 1.0, &host);
        assert!(close(led.core_busy_seconds, 10.0, 1e-12));
        // power: 2*20 + busy*15
        let expect = (40.0 + 90.0) + (40.0 + 60.0);
        assert!(close(led.energy_joules, expect, 1e-9));
        assert_eq!(led.busy_series.len(), 2);
    }

    #[test]
    fn core_hours_conversion() {
        let host = HostSpec::default();
        let mut led = Ledger::new();
        for i in 0..3600 {
            led.record_tick(i as f64, 2, 1.0, &host);
        }
        assert!(close(led.core_hours(), 2.0, 1e-9));
    }
}

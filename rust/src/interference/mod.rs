//! The paper's scheduling mathematics, as pure functions.
//!
//! * [`core_overload`] — Eq. 2: the RAS composite-load-beyond-threshold
//!   metric for one core.
//! * [`workload_interference`] — Eq. 3: WI, the estimated slowdown of one
//!   workload from its co-runners (mean of sum and product of pairwise
//!   slowdowns).
//! * [`core_interference`] — Eq. 4: I_c, the worst WI on the core.
//! * [`ias_threshold`] — Eq. 5: the IAS acceptance threshold, the mean
//!   *off-diagonal* entry of S (Eq. 5 averages distinct pairs; diagonal
//!   self-slowdowns would skew it).
//!
//! These are the native scoring backend; `runtime::scoring` provides an
//! XLA-executed equivalent (the AOT-compiled Pallas kernel) and the test
//! suite asserts the two agree.

use crate::workloads::{MetricVec, NUM_METRICS};

/// Eq. 2 — core overload. `loads` are the utilisation vectors of the VMs
/// pinned on the core; `thr` is the resource-utilisation threshold (the
/// paper uses 120%).
///
/// `OL_c = Σ_j max(0, Σ_i U_c[i][j] − thr)`
pub fn core_overload(loads: &[MetricVec], thr: f64) -> f64 {
    let mut total = 0.0;
    for j in 0..NUM_METRICS {
        let composite: f64 = loads.iter().map(|u| u[j]).sum();
        total += (composite - thr).max(0.0);
    }
    total
}

/// Eq. 2 restricted to the CPU metric — what the CAS reference scheduler
/// uses (§IV-B.1: "taking into account only one metric, the CPU
/// utilization").
pub fn cpu_overload(loads: &[MetricVec], thr: f64) -> f64 {
    let composite: f64 = loads.iter().map(|u| u[0]).sum();
    (composite - thr).max(0.0)
}

/// Eq. 3 — workload interference for workload `i` on a core.
///
/// `slowdowns` holds the pairwise slowdown S[i][j] of workload `i` against
/// each *co-runner* j (self excluded — see the worked example in §IV-B.2:
/// a candidate with S = 1 against three residents must score (3 + 1)/2 = 2).
///
/// `WI = (Σ_j S[i][j] + Π_j S[i][j]) / 2`
pub fn workload_interference(slowdowns: &[f64]) -> f64 {
    let sum: f64 = slowdowns.iter().sum();
    let prod: f64 = slowdowns.iter().product();
    0.5 * (sum + prod)
}

/// Eq. 4 — core interference: the worst (maximum) WI among the workloads on
/// the core. `wi` are per-workload interference values; an empty core has
/// interference 0.
pub fn core_interference(wi: &[f64]) -> f64 {
    wi.iter().copied().fold(0.0, f64::max)
}

/// Eq. 5 — the IAS threshold: the mean *off-diagonal* entry of the
/// pairwise slowdown matrix S ("close to the average slowdown of a pair
/// of random co-scheduled workloads"). A pair of co-scheduled workloads
/// is two *distinct* residents, so the self-slowdowns S[i][i] — which are
/// among the heaviest entries — are excluded; including them inflated the
/// acceptance threshold, letting IAS co-pin pairs it should refuse. The
/// paper selects 1.5 on its testbed; with
/// fewer than two classes there are no pairs and 1.5 is the fallback.
pub fn ias_threshold(s: &[Vec<f64>]) -> f64 {
    let n = s.len();
    if n <= 1 {
        return 1.5;
    }
    let mut total = 0.0;
    for (i, row) in s.iter().enumerate() {
        for (j, &x) in row.iter().enumerate() {
            if i != j {
                total += x;
            }
        }
    }
    total / (n * (n - 1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::close;

    #[test]
    fn overload_zero_when_under_threshold() {
        let loads = [[0.5, 0.1, 0.1, 0.1], [0.5, 0.1, 0.1, 0.1]];
        assert_eq!(core_overload(&loads, 1.2), 0.0);
    }

    #[test]
    fn overload_sums_over_metrics() {
        // CPU composite 1.8 (0.6 over), DiskIO composite 1.5 (0.3 over).
        let loads = [[0.9, 0.75, 0.0, 0.0], [0.9, 0.75, 0.0, 0.0]];
        assert!(close(core_overload(&loads, 1.2), 0.9, 1e-12));
    }

    #[test]
    fn cpu_overload_ignores_other_metrics() {
        let loads = [[0.5, 9.0, 9.0, 9.0]];
        assert_eq!(cpu_overload(&loads, 1.2), 0.0);
        let loads2 = [[1.5, 0.0, 0.0, 0.0]];
        assert!(close(cpu_overload(&loads2, 1.2), 0.3, 1e-12));
    }

    #[test]
    fn paper_worked_example() {
        // §IV-B.2: new job with S = 1 against three residents -> WI = 2.
        assert!(close(workload_interference(&[1.0, 1.0, 1.0]), 2.0, 1e-12));
        // Sum-only would say 3; product-only would say 1.
    }

    #[test]
    fn wi_alone_is_half() {
        // No co-runners: (0 + empty product 1)/2 = 0.5.
        assert!(close(workload_interference(&[]), 0.5, 1e-12));
    }

    #[test]
    fn wi_product_penalises_heavy_pairs() {
        // Sub-linear slowdowns: product contributes less than the sum.
        let light = workload_interference(&[1.2, 1.2]);
        assert!(close(light, 0.5 * (2.4 + 1.44), 1e-12));
        // Past 2.0 the product term grows exponentially (paper §IV-B.2).
        let heavy = workload_interference(&[2.5, 2.5]);
        assert!(close(heavy, 0.5 * (5.0 + 6.25), 1e-12));
        assert!(heavy / light > 2.5);
    }

    #[test]
    fn core_interference_is_max() {
        assert!(close(core_interference(&[0.5, 2.0, 1.1]), 2.0, 1e-12));
        assert_eq!(core_interference(&[]), 0.0);
    }

    #[test]
    fn threshold_is_off_diagonal_mean() {
        // Off-diagonal entries are s[0][1] = 2 and s[1][0] = 1 -> mean 1.5.
        let s = vec![vec![1.0, 2.0], vec![1.0, 2.0]];
        assert!(close(ias_threshold(&s), 1.5, 1e-12));
        // The diagonal self-slowdowns must not skew the mean: a full-matrix
        // mean here would be 5.0, but the pairs average to 1.0.
        let diag_heavy = vec![vec![9.0, 1.0], vec![1.0, 9.0]];
        assert!(close(ias_threshold(&diag_heavy), 1.0, 1e-12));
        // Fallbacks: no classes / a single class have no pairs.
        assert!(close(ias_threshold(&[]), 1.5, 1e-12));
        assert!(close(ias_threshold(&[vec![3.0]]), 1.5, 1e-12));
    }
}

//! The random scenario (§V-C.1, Fig. 2).
//!
//! "A random scenario of all workload types. The server is shared between
//! batch, media streaming and latency critical benchmarks … Workloads
//! arrive with 30 seconds inter-arrival time."
//!
//! Service workloads get randomized duty cycles so higher subscription
//! ratios exhibit the idle phases whose consolidation the paper credits
//! for the SR = 2 savings ("the detection and consolidation of idle
//! workloads").

use super::spec::{ScenarioSpec, VmTemplate};
use crate::hostsim::ActivityModel;
use crate::util::rng::Rng;
use crate::workloads::arrivals::ArrivalProcess;
use crate::workloads::{WorkloadClass, ALL_CLASSES};
use anyhow::{ensure, Result};

/// Build the random scenario for a host with `cores` cores at subscription
/// ratio `sr`. Fails cleanly (instead of producing a nonsense spec) on a
/// malformed request.
pub fn build(cores: usize, sr: f64, seed: u64) -> Result<ScenarioSpec> {
    ensure!(cores > 0, "random scenario needs at least one core");
    ensure!(
        sr.is_finite() && sr > 0.0,
        "subscription ratio must be positive and finite, got {sr}"
    );
    let mut rng = Rng::new(seed ^ 0x5EED_0001);
    let n = ((cores as f64) * sr).round().max(1.0) as usize;
    let arrivals = ArrivalProcess::Uniform { gap: 30.0 }.times(n, &mut rng);

    let mut vms = Vec::with_capacity(n);
    for &arrival in arrivals.iter() {
        let class = pick_class(&mut rng);
        let activity = service_activity(class, &mut rng);
        vms.push(VmTemplate {
            class,
            arrival,
            activity,
        });
    }
    Ok(ScenarioSpec {
        name: format!("random-sr{sr}"),
        sr,
        vms,
        min_duration: 900.0,
    })
}

/// Class mix of the random scenario. Cloud tenants skew towards light
/// services with overestimated reservations (§I: "customers tend to
/// overestimate the requirements of their applications"); heavy batch HPC
/// jobs are the minority. This weighting is what gives consolidation its
/// headroom — with an all-heavy mix no scheduler could save cores.
const CLASS_WEIGHTS: [(WorkloadClass, f64); 8] = [
    (WorkloadClass::Blackscholes, 0.10),
    (WorkloadClass::Hadoop, 0.10),
    (WorkloadClass::Jacobi, 0.08),
    (WorkloadClass::LampLight, 0.22),
    (WorkloadClass::LampHeavy, 0.12),
    (WorkloadClass::StreamLow, 0.16),
    (WorkloadClass::StreamMed, 0.12),
    (WorkloadClass::StreamHigh, 0.10),
];

fn pick_class(rng: &mut Rng) -> WorkloadClass {
    let dice = rng.uniform();
    let mut acc = 0.0;
    for &(class, w) in &CLASS_WEIGHTS {
        acc += w;
        if dice < acc {
            return class;
        }
    }
    // detlint: allow(panic): ALL_CLASSES is a non-empty const table
    *ALL_CLASSES.last().unwrap()
}

/// Batch jobs run flat out; services get a random busy/quiet duty cycle.
fn service_activity(class: WorkloadClass, rng: &mut Rng) -> ActivityModel {
    use crate::workloads::WorkloadKind;
    let kind = crate::workloads::catalog::spec_of(class).perf.kind;
    match kind {
        WorkloadKind::Batch => ActivityModel::AlwaysOn,
        _ => {
            // 60–95% duty over a 2–5 minute period.
            let period = rng.range(120.0, 300.0);
            let duty = rng.range(0.6, 0.95);
            let phase = rng.range(0.0, period);
            ActivityModel::OnOff {
                period,
                duty,
                phase,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn vm_count_follows_subscription_ratio() {
        for (sr, expect) in [(0.5, 6), (1.0, 12), (1.5, 18), (2.0, 24)] {
            let spec = build(12, sr, 1).unwrap();
            assert_eq!(spec.vms.len(), expect, "sr {sr}");
        }
    }

    #[test]
    fn thirty_second_arrivals() {
        let spec = build(12, 1.0, 2).unwrap();
        for (i, vm) in spec.vms.iter().enumerate() {
            assert_eq!(vm.arrival, i as f64 * 30.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = build(12, 2.0, 7).unwrap();
        let b = build(12, 2.0, 7).unwrap();
        for (x, y) in a.vms.iter().zip(&b.vms) {
            assert_eq!(x.class, y.class);
        }
        let c = build(12, 2.0, 8).unwrap();
        let same = a
            .vms
            .iter()
            .zip(&c.vms)
            .filter(|(x, y)| x.class == y.class)
            .count();
        assert!(same < a.vms.len(), "different seeds must differ");
    }

    #[test]
    fn malformed_requests_error_instead_of_panicking() {
        assert!(build(0, 1.0, 1).is_err(), "zero cores");
        assert!(build(12, 0.0, 1).is_err(), "zero sr");
        assert!(build(12, -1.0, 1).is_err(), "negative sr");
        assert!(build(12, f64::NAN, 1).is_err(), "nan sr");
    }

    #[test]
    fn batch_jobs_always_on_services_duty_cycled() {
        let spec = build(12, 2.0, 3).unwrap();
        for vm in &spec.vms {
            let kind = crate::workloads::catalog::spec_of(vm.class).perf.kind;
            match (kind, &vm.activity) {
                (WorkloadKind::Batch, activity) => assert!(
                    matches!(activity, ActivityModel::AlwaysOn),
                    "batch VM with activity {activity:?}"
                ),
                (_, ActivityModel::OnOff { duty, .. }) => {
                    assert!((0.6..=0.95).contains(duty));
                }
                (_, other) => unreachable!("service VM with activity {other:?}"),
            }
        }
    }
}

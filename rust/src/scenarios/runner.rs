//! The scenario runner: wires engine + daemon, runs to completion, and
//! summarises the paper's metrics. [`run_cluster`] is the cluster-layer
//! counterpart: the same scenario arriving cluster-wide, dispatched and
//! stepped through the event bus + shard pool.

use super::spec::ScenarioSpec;
use crate::cluster::{ClusterResult, ClusterSim, ClusterSpec};
use crate::config::Config;
use crate::hostsim::{SimEngine, Vm, VmId, VmState};
use crate::metrics::TimeSeries;
use crate::profiling::ProfileBank;
use crate::util::stats::mean;
use crate::vmcd::scheduler::{self, Policy, ScoringBackend};
use crate::vmcd::{ActuationSpec, Daemon};
use crate::workloads::{WorkloadClass, WorkloadKind};
use anyhow::Result;

/// Everything the paper's figures need from one run.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: String,
    pub policy: Policy,
    pub sr: f64,
    /// Mean normalized performance over all workloads (1.0 = isolated).
    pub avg_perf: f64,
    /// The paper's "CPU time consumed": busy-core hours.
    pub core_hours: f64,
    pub energy_wh: f64,
    /// Virtual completion time (all batch jobs done, min duration met).
    pub completion_time: f64,
    /// Busy-core time series (Figs. 4/5).
    pub busy_series: TimeSeries,
    /// Per-class mean performance.
    pub per_class_perf: Vec<(WorkloadClass, f64)>,
    pub repin_count: u64,
    pub sched_cycles: u64,
}

impl ScenarioResult {
    /// Performance relative to a baseline run (paper figures normalise to
    /// RRS).
    pub fn perf_vs(&self, baseline: &ScenarioResult) -> f64 {
        self.avg_perf / baseline.avg_perf
    }

    /// CPU-hours saving relative to a baseline (positive = fewer hours).
    pub fn cpu_saving_vs(&self, baseline: &ScenarioResult) -> f64 {
        1.0 - self.core_hours / baseline.core_hours
    }
}

/// Run one scenario under one policy (native scoring backend, inline
/// actuation).
pub fn run_scenario(
    cfg: &Config,
    spec: &ScenarioSpec,
    policy: Policy,
    bank: &ProfileBank,
) -> Result<ScenarioResult> {
    run_scenario_with_actuation(cfg, spec, policy, bank, ActuationSpec::Inline)
}

/// Run one scenario with an explicit actuation backend — the
/// actuation-lag sensitivity surface (paper §IV): `Deferred` pins land
/// `latency_ticks` late, so RAS/IAS decisions act on a host whose
/// enacted placement trails their intent.
pub fn run_scenario_with_actuation(
    cfg: &Config,
    spec: &ScenarioSpec,
    policy: Policy,
    bank: &ProfileBank,
    actuation: ActuationSpec,
) -> Result<ScenarioResult> {
    let sched = scheduler::build(policy, bank, cfg.sched.ras_threshold, cfg.sched.ias_threshold);
    run_scenario_with(cfg, spec, policy, sched, actuation)
}

/// Run one scenario with an explicit scoring backend (e.g. XLA), inline
/// actuation.
pub fn run_scenario_with_backend(
    cfg: &Config,
    spec: &ScenarioSpec,
    policy: Policy,
    bank: &ProfileBank,
    backend: Box<dyn ScoringBackend>,
) -> Result<ScenarioResult> {
    let sched = scheduler::build_with_backend(
        policy,
        bank,
        cfg.sched.ras_threshold,
        cfg.sched.ias_threshold,
        backend,
    );
    run_scenario_with(cfg, spec, policy, sched, ActuationSpec::Inline)
}

/// Run one scenario cluster-wide: `scenario.vms` arrive on the bus, an
/// arrival policy dispatches them, hosts step under `spec.step_mode`,
/// and all migration churn flows through `ClusterEvent` routing. When
/// `spec.migrator` is set, the continuous migration manager
/// ([`crate::cluster::VmMigrator`]) consolidates the fleet as it runs.
/// The returned [`ClusterResult`] carries the cluster-scope ledger —
/// parked-aware energy (Wh), plugged energy, overload-time SLAV, and
/// active host-hours — alongside the placement counters. The one-stop
/// entry the CLI, examples, and benches share.
pub fn run_cluster(
    spec: &ClusterSpec,
    scenario: &ScenarioSpec,
    bank: &ProfileBank,
) -> Result<ClusterResult> {
    ClusterSim::new(spec.clone(), scenario, bank)?.run(bank, scenario.min_duration)
}

/// Replay a pre-recorded (or synthetic) trace cluster-wide instead of a
/// generated scenario: every [`TraceEvent`](crate::cluster::TraceEvent)
/// is published through the event bus and routed by `spec.dispatcher`.
/// With `spec.migrator` set, the replay keeps ticking after the trace
/// drains (a settle window) so consolidation can finish, and the
/// [`ReplayResult`](crate::cluster::ReplayResult) reports the
/// cluster-scope energy/SLAV ledger plus `converge_ticks` — time from
/// the powered-host peak to half-drain. The `vmcd cluster --trace`
/// entry point; see [`crate::cluster::trace`] for formats and the
/// replay contract.
pub fn run_trace(
    spec: &ClusterSpec,
    reader: &mut dyn crate::cluster::TraceReader,
    bank: &ProfileBank,
) -> Result<crate::cluster::ReplayResult> {
    crate::cluster::replay(spec, reader, bank)
}

fn run_scenario_with(
    cfg: &Config,
    spec: &ScenarioSpec,
    policy: Policy,
    sched: Box<dyn scheduler::Scheduler>,
    actuation: ActuationSpec,
) -> Result<ScenarioResult> {
    let vms: Vec<Vm> = spec
        .vms
        .iter()
        .enumerate()
        .map(|(i, t)| Vm::new(VmId(i as u32), t.class, t.arrival, t.activity.clone()))
        .collect();
    let mut engine = SimEngine::new(cfg.clone(), vms);
    let mut daemon = Daemon::with_actuation(cfg.sched.clone(), sched, cfg.host.cores, actuation.build());

    loop {
        for id in engine.process_arrivals() {
            daemon.on_arrival(&mut engine, id)?;
        }
        // One daemon step per tick: a single monitor poll diffed into
        // lifecycle events, plus the Alg. 1 Tick when the interval is due.
        daemon.step(&mut engine)?;
        engine.step();

        let done = engine.all_batch_done()
            && !engine.arrivals_pending()
            && engine.t >= spec.min_duration;
        if done || engine.t >= cfg.sim.max_time {
            break;
        }
    }

    Ok(summarise(spec, policy, &engine, &daemon))
}

fn summarise(
    spec: &ScenarioSpec,
    policy: Policy,
    engine: &SimEngine,
    daemon: &Daemon,
) -> ScenarioResult {
    let mut all_perf = Vec::new();
    let mut per_class: Vec<(WorkloadClass, Vec<f64>)> = Vec::new();
    for vm in &engine.vms {
        let perf = effective_perf(vm, engine.t);
        let Some(perf) = perf else { continue };
        all_perf.push(perf);
        match per_class.iter_mut().find(|(c, _)| *c == vm.class) {
            Some((_, v)) => v.push(perf),
            None => per_class.push((vm.class, vec![perf])),
        }
    }
    per_class.sort_by_key(|(c, _)| c.index());

    ScenarioResult {
        scenario: spec.name.clone(),
        policy,
        sr: spec.sr,
        avg_perf: mean(&all_perf),
        core_hours: engine.ledger.core_hours(),
        energy_wh: engine.ledger.energy_wh(),
        completion_time: engine.t,
        busy_series: engine.ledger.busy_series.clone(),
        per_class_perf: per_class
            .into_iter()
            .map(|(c, v)| (c, mean(&v)))
            .collect(),
        repin_count: engine.ledger.repin_count,
        sched_cycles: daemon.cycles,
    }
}

/// Performance of one VM at scenario end. Unfinished batch jobs (run hit
/// max_time) are scored by their average progress rate so far.
fn effective_perf(vm: &Vm, now: f64) -> Option<f64> {
    if vm.state == VmState::NotArrived {
        return None;
    }
    if let Some(p) = vm.normalized_perf() {
        return Some(p);
    }
    if vm.spec.perf.kind == WorkloadKind::Batch {
        let start = vm.work_started?;
        let elapsed = now - start;
        if elapsed > 0.0 && vm.work_done > 0.0 {
            return Some((vm.work_done / elapsed).clamp(0.0, 1.0));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::random;

    fn quiet_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        cfg.sim.max_time = 4000.0;
        cfg
    }

    fn bank(cfg: &Config) -> ProfileBank {
        ProfileBank::generate(cfg)
    }

    #[test]
    fn undersubscribed_random_all_policies_complete() {
        let cfg = quiet_cfg();
        let b = bank(&cfg);
        let spec = random::build(cfg.host.cores, 0.5, 42).unwrap();
        for policy in Policy::ALL {
            let r = run_scenario(&cfg, &spec, policy, &b).unwrap();
            assert!(
                r.completion_time < cfg.sim.max_time,
                "{policy:?} did not complete"
            );
            assert!(r.avg_perf > 0.5, "{policy:?} perf {}", r.avg_perf);
            assert!(r.core_hours > 0.0);
        }
    }

    #[test]
    fn ras_saves_core_hours_vs_rrs_at_low_sr() {
        let cfg = quiet_cfg();
        let b = bank(&cfg);
        let spec = random::build(cfg.host.cores, 0.5, 42).unwrap();
        let rrs = run_scenario(&cfg, &spec, Policy::Rrs, &b).unwrap();
        let ras = run_scenario(&cfg, &spec, Policy::Ras, &b).unwrap();
        let saving = ras.cpu_saving_vs(&rrs);
        assert!(
            saving > 0.15,
            "RAS must consolidate: saving {saving} (rrs {} ras {})",
            rrs.core_hours,
            ras.core_hours
        );
        let perf_ratio = ras.perf_vs(&rrs);
        assert!(perf_ratio > 0.85, "perf ratio {perf_ratio}");
    }

    #[test]
    fn zero_lag_deferred_actuation_is_bit_identical_to_inline() {
        let cfg = quiet_cfg();
        let b = bank(&cfg);
        let spec = random::build(cfg.host.cores, 1.0, 42).unwrap();
        let inline = run_scenario(&cfg, &spec, Policy::Ias, &b).unwrap();
        let deferred = run_scenario_with_actuation(
            &cfg,
            &spec,
            Policy::Ias,
            &b,
            ActuationSpec::Deferred {
                latency_ticks: 0,
                budget_per_tick: 0,
            },
        )
        .unwrap();
        assert_eq!(inline.avg_perf.to_bits(), deferred.avg_perf.to_bits());
        assert_eq!(inline.core_hours.to_bits(), deferred.core_hours.to_bits());
        assert_eq!(
            inline.completion_time.to_bits(),
            deferred.completion_time.to_bits()
        );
        assert_eq!(inline.repin_count, deferred.repin_count);
    }

    #[test]
    fn actuation_lag_costs_performance_but_completes() {
        // The new measurable scenario: pins landing late leave freshly
        // arrived VMs stalled and re-pin passes acting on stale enacted
        // state. The run must still finish, and lag cannot *improve* on
        // inline actuation beyond noise.
        let cfg = quiet_cfg();
        let b = bank(&cfg);
        let spec = random::build(cfg.host.cores, 1.0, 42).unwrap();
        let inline = run_scenario(&cfg, &spec, Policy::Ias, &b).unwrap();
        let lagged = run_scenario_with_actuation(
            &cfg,
            &spec,
            Policy::Ias,
            &b,
            ActuationSpec::Deferred {
                latency_ticks: 8,
                budget_per_tick: 4,
            },
        )
        .unwrap();
        assert!(lagged.avg_perf > 0.3, "lagged perf {}", lagged.avg_perf);
        assert!(
            lagged.avg_perf <= inline.avg_perf + 0.05,
            "lag must not beat inline: {} vs {}",
            lagged.avg_perf,
            inline.avg_perf
        );
    }

    #[test]
    fn deterministic_runs() {
        let cfg = quiet_cfg();
        let b = bank(&cfg);
        let spec = random::build(cfg.host.cores, 1.0, 9).unwrap();
        let a = run_scenario(&cfg, &spec, Policy::Ias, &b).unwrap();
        let c = run_scenario(&cfg, &spec, Policy::Ias, &b).unwrap();
        assert_eq!(a.core_hours, c.core_hours);
        assert_eq!(a.avg_perf, c.avg_perf);
        assert_eq!(a.completion_time, c.completion_time);
    }
}

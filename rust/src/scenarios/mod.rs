//! The paper's evaluation scenarios (§V-C) and the scenario runner.
//!
//! * [`random`] — §V-C.1: a random mix of all workload types, 30 s
//!   inter-arrival, subscription ratio SR ∈ {0.5, 1, 1.5, 2} (Fig. 2).
//! * [`latency`] — §V-C.2: many low-load latency-critical VMs plus a few
//!   batch / streaming workloads (Fig. 3).
//! * [`dynamic`] — §V-C.3: 24 pre-placed VMs activating in 6- or 12-job
//!   batches (Figs. 4, 5, 6).
//! * [`runner`] — drives engine + daemon to completion and summarises the
//!   paper's metrics (average normalized performance, CPU time consumed).

pub mod dynamic;
pub mod latency;
pub mod random;
pub mod runner;
pub mod spec;

pub use runner::{run_cluster, run_scenario, run_scenario_with_actuation, run_trace, ScenarioResult};
pub use spec::{ScenarioKind, ScenarioSpec, VmTemplate};

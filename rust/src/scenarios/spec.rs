//! Scenario specification types.

use crate::hostsim::ActivityModel;
use crate::workloads::WorkloadClass;

/// Which of the paper's scenarios (used by the CLI and reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    Random,
    LatencyHeavy,
    Dynamic6,
    Dynamic12,
}

impl ScenarioKind {
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::Random => "random",
            ScenarioKind::LatencyHeavy => "latency",
            ScenarioKind::Dynamic6 => "dynamic6",
            ScenarioKind::Dynamic12 => "dynamic12",
        }
    }

    pub fn from_name(name: &str) -> Option<ScenarioKind> {
        match name.to_ascii_lowercase().as_str() {
            "random" => Some(ScenarioKind::Random),
            "latency" | "latency-heavy" => Some(ScenarioKind::LatencyHeavy),
            "dynamic6" | "dynamic-6" => Some(ScenarioKind::Dynamic6),
            "dynamic12" | "dynamic-12" => Some(ScenarioKind::Dynamic12),
            _ => None,
        }
    }
}

/// One VM to create.
#[derive(Debug, Clone)]
pub struct VmTemplate {
    pub class: WorkloadClass,
    pub arrival: f64,
    pub activity: ActivityModel,
}

/// A complete scenario.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    /// Subscription ratio = VMs / cores (§V-C.1).
    pub sr: f64,
    pub vms: Vec<VmTemplate>,
    /// Minimum virtual duration even if all batch jobs finish earlier
    /// (services need time to accumulate performance samples).
    pub min_duration: f64,
}

impl ScenarioSpec {
    /// Count of VMs per class (for reporting).
    pub fn class_histogram(&self) -> Vec<(WorkloadClass, usize)> {
        let mut hist: Vec<(WorkloadClass, usize)> = Vec::new();
        for vm in &self.vms {
            match hist.iter_mut().find(|(c, _)| *c == vm.class) {
                Some((_, n)) => *n += 1,
                None => hist.push((vm.class, 1)),
            }
        }
        hist.sort_by_key(|(c, _)| c.index());
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [
            ScenarioKind::Random,
            ScenarioKind::LatencyHeavy,
            ScenarioKind::Dynamic6,
            ScenarioKind::Dynamic12,
        ] {
            assert_eq!(ScenarioKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ScenarioKind::from_name("nope"), None);
    }

    #[test]
    fn histogram_counts() {
        let spec = ScenarioSpec {
            name: "t".into(),
            sr: 0.5,
            vms: vec![
                VmTemplate {
                    class: WorkloadClass::Jacobi,
                    arrival: 0.0,
                    activity: ActivityModel::AlwaysOn,
                },
                VmTemplate {
                    class: WorkloadClass::Jacobi,
                    arrival: 30.0,
                    activity: ActivityModel::AlwaysOn,
                },
            ],
            min_duration: 100.0,
        };
        assert_eq!(spec.class_histogram(), vec![(WorkloadClass::Jacobi, 2)]);
    }
}

//! The latency-critical heavy scenario (§V-C.2, Fig. 3).
//!
//! "A large number of latency-critical but low load applications and a
//! small number of batch and media streaming workloads."

use super::spec::{ScenarioSpec, VmTemplate};
use crate::hostsim::ActivityModel;
use crate::util::rng::Rng;
use crate::workloads::arrivals::ArrivalProcess;
use crate::workloads::WorkloadClass;
use anyhow::{ensure, Result};

/// Composition: ~65% lamp-light, ~10% lamp-heavy, ~15% low/med streaming,
/// ~10% batch. Fails cleanly on a malformed request.
pub fn build(cores: usize, sr: f64, seed: u64) -> Result<ScenarioSpec> {
    ensure!(cores > 0, "latency scenario needs at least one core");
    ensure!(
        sr.is_finite() && sr > 0.0,
        "subscription ratio must be positive and finite, got {sr}"
    );
    let mut rng = Rng::new(seed ^ 0x5EED_0002);
    let n = ((cores as f64) * sr).round().max(1.0) as usize;
    let arrivals = ArrivalProcess::Uniform { gap: 30.0 }.times(n, &mut rng);

    let mut vms = Vec::with_capacity(n);
    for &arrival in arrivals.iter() {
        let dice = rng.uniform();
        let class = if dice < 0.65 {
            WorkloadClass::LampLight
        } else if dice < 0.75 {
            WorkloadClass::LampHeavy
        } else if dice < 0.83 {
            WorkloadClass::StreamLow
        } else if dice < 0.90 {
            WorkloadClass::StreamMed
        } else if dice < 0.94 {
            WorkloadClass::Blackscholes
        } else if dice < 0.97 {
            WorkloadClass::Hadoop
        } else {
            WorkloadClass::Jacobi
        };
        let kind = crate::workloads::catalog::spec_of(class).perf.kind;
        let activity = match kind {
            crate::workloads::WorkloadKind::Batch => ActivityModel::AlwaysOn,
            _ => {
                // Low-load services: longer quiet periods than the random
                // scenario (duty 50–85%).
                let period = rng.range(150.0, 360.0);
                let duty = rng.range(0.5, 0.85);
                let phase = rng.range(0.0, period);
                ActivityModel::OnOff {
                    period,
                    duty,
                    phase,
                }
            }
        };
        vms.push(VmTemplate {
            class,
            arrival,
            activity,
        });
    }
    Ok(ScenarioSpec {
        name: format!("latency-sr{sr}"),
        sr,
        vms,
        min_duration: 900.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn latency_dominates_composition() {
        let spec = build(12, 2.0, 11).unwrap();
        let lc = spec
            .vms
            .iter()
            .filter(|vm| {
                crate::workloads::catalog::spec_of(vm.class).perf.kind
                    == WorkloadKind::LatencyCritical
            })
            .count();
        assert!(
            lc * 2 > spec.vms.len(),
            "latency-critical should dominate: {lc}/{}",
            spec.vms.len()
        );
    }

    #[test]
    fn has_some_batch_and_streaming() {
        // Across a few seeds, the composition must include the minority
        // classes (the paper keeps "a small number" of them).
        let mut batch = 0;
        let mut streaming = 0;
        for seed in 0..8 {
            let spec = build(12, 2.0, seed).unwrap();
            for vm in &spec.vms {
                match crate::workloads::catalog::spec_of(vm.class).perf.kind {
                    WorkloadKind::Batch => batch += 1,
                    WorkloadKind::Streaming => streaming += 1,
                    _ => {}
                }
            }
        }
        assert!(batch > 0, "no batch VMs in any seed");
        assert!(streaming > 0, "no streaming VMs in any seed");
    }

    #[test]
    fn count_tracks_sr() {
        assert_eq!(build(12, 0.5, 1).unwrap().vms.len(), 6);
        assert_eq!(build(12, 2.0, 1).unwrap().vms.len(), 24);
        assert!(build(0, 1.0, 1).is_err(), "zero cores must error");
    }
}

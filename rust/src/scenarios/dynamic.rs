//! The dynamic scenario (§V-C.3, Figs. 4-6).
//!
//! "24 random VMs are placed in the server where they become active in
//! 12- or 6-job batches." All VMs are resident from t = 0 (RRS therefore
//! reserves the whole server for the entire run — the Fig. 4/5 flat line);
//! group g activates at g·PHASE seconds. Batch jobs, once activated, run
//! to completion; services go idle again at the end of their group's
//! phase, which is what the dynamic schedulers exploit.

use super::spec::{ScenarioSpec, VmTemplate};
use crate::hostsim::ActivityModel;
use crate::util::rng::Rng;
use crate::workloads::ALL_CLASSES;
use anyhow::{ensure, Result};

/// Phase length between activation batches (seconds).
pub const PHASE: f64 = 420.0;

/// Total VMs in the scenario (paper: 24).
pub const TOTAL_VMS: usize = 24;

/// Build the dynamic scenario with `batch_size` ∈ {6, 12}. A batch size
/// that does not evenly divide the resident VM count is a malformed
/// request and fails cleanly.
pub fn build(batch_size: usize, seed: u64) -> Result<ScenarioSpec> {
    ensure!(
        batch_size > 0 && TOTAL_VMS % batch_size == 0,
        "batch size {batch_size} must divide {TOTAL_VMS}"
    );
    let mut rng = Rng::new(seed ^ 0x5EED_0003);
    let groups = TOTAL_VMS / batch_size;

    let mut vms = Vec::with_capacity(TOTAL_VMS);
    for g in 0..groups {
        let start = g as f64 * PHASE;
        for _ in 0..batch_size {
            let class = *rng.pick(&ALL_CLASSES);
            let kind = crate::workloads::catalog::spec_of(class).perf.kind;
            let activity = match kind {
                // Batch: activate at the group phase, run to completion.
                crate::workloads::WorkloadKind::Batch => {
                    ActivityModel::Windows(vec![(start, f64::INFINITY)])
                }
                // Services: active only during their group's phase.
                _ => ActivityModel::Windows(vec![(start, start + PHASE)]),
            };
            vms.push(VmTemplate {
                class,
                arrival: 0.0,
                activity,
            });
        }
    }
    Ok(ScenarioSpec {
        name: format!("dynamic-{batch_size}"),
        sr: TOTAL_VMS as f64 / 12.0,
        vms,
        min_duration: groups as f64 * PHASE,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadKind;

    #[test]
    fn twenty_four_vms_resident_from_t0() {
        for bs in [6, 12] {
            let spec = build(bs, 1).unwrap();
            assert_eq!(spec.vms.len(), 24);
            assert!(spec.vms.iter().all(|vm| vm.arrival == 0.0));
        }
    }

    #[test]
    fn groups_activate_in_phases() {
        let spec = build(6, 2).unwrap();
        for (i, vm) in spec.vms.iter().enumerate() {
            let group = i / 6;
            let expected_start = group as f64 * PHASE;
            match &vm.activity {
                ActivityModel::Windows(ws) => {
                    assert_eq!(ws[0].0, expected_start, "vm {i}");
                }
                other => panic!("vm {i}: unexpected activity {other:?}"),
            }
        }
    }

    #[test]
    fn services_deactivate_batch_jobs_run_out() {
        let spec = build(12, 3).unwrap();
        for vm in &spec.vms {
            let kind = crate::workloads::catalog::spec_of(vm.class).perf.kind;
            if let ActivityModel::Windows(ws) = &vm.activity {
                match kind {
                    WorkloadKind::Batch => assert!(ws[0].1.is_infinite()),
                    _ => assert!((ws[0].1 - ws[0].0 - PHASE).abs() < 1e-9),
                }
            }
        }
    }

    #[test]
    fn bad_batch_size_is_an_error() {
        assert!(build(7, 1).is_err(), "non-divisor batch size");
        assert!(build(0, 1).is_err(), "zero batch size");
        let msg = format!("{:#}", build(7, 1).unwrap_err());
        assert!(msg.contains("batch size 7"), "{msg}");
    }
}

//! The paper's figures as runnable experiments.
//!
//! Every function regenerates one figure's data by running the relevant
//! scenario(s) under all four schedulers; rows report performance and CPU
//! time normalised to the RRS baseline, matching how the paper presents
//! results. Multiple seeds are averaged for the bar figures.

use super::table::{render_table, sparkline};
use crate::config::Config;
use crate::metrics::export;
use crate::profiling::ProfileBank;
use crate::scenarios::{dynamic, latency, random, run_scenario, ScenarioResult};
use crate::util::stats::mean;
use crate::vmcd::scheduler::Policy;
use anyhow::Result;
use std::path::Path;

/// One figure row: a (policy, SR) cell.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub policy: Policy,
    pub sr: f64,
    /// Mean normalized performance (1.0 = isolated).
    pub perf: f64,
    /// Performance relative to RRS at the same SR.
    pub perf_vs_rrs: f64,
    /// Core-hours consumed.
    pub core_hours: f64,
    /// CPU-time saving vs RRS (positive = fewer core-hours).
    pub cpu_saving_vs_rrs: f64,
}

/// A rendered figure.
#[derive(Debug, Clone)]
pub struct FigureData {
    pub id: &'static str,
    pub title: String,
    pub rows: Vec<FigureRow>,
    /// Fig. 4/5 time-series payload: (policy, series) pairs.
    pub series: Vec<(Policy, crate::metrics::TimeSeries)>,
}

impl FigureData {
    pub fn render(&self) -> String {
        let mut out = format!("{} — {}\n", self.id, self.title);
        if !self.rows.is_empty() {
            let rows: Vec<Vec<String>> = self
                .rows
                .iter()
                .map(|r| {
                    vec![
                        format!("{}", r.sr),
                        r.policy.name().to_string(),
                        format!("{:.3}", r.perf),
                        format!("{:+.1}%", (r.perf_vs_rrs - 1.0) * 100.0),
                        format!("{:.3}", r.core_hours),
                        format!("{:+.1}%", -r.cpu_saving_vs_rrs * 100.0),
                    ]
                })
                .collect();
            out.push_str(&render_table(
                &[
                    "SR",
                    "policy",
                    "perf",
                    "perf vs RRS",
                    "core-hours",
                    "CPU time vs RRS",
                ],
                &rows,
            ));
        }
        for (policy, ts) in &self.series {
            let values: Vec<f64> = ts.points.iter().map(|p| p.1).collect();
            out.push_str(&format!(
                "{:<4} busy cores over time: {}\n",
                policy.name(),
                sparkline(&values, 72)
            ));
        }
        out
    }

    /// Write CSV mirrors under `dir`.
    pub fn write_csv(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        if !self.rows.is_empty() {
            let mut text = String::from("sr,policy,perf,perf_vs_rrs,core_hours,cpu_saving_vs_rrs\n");
            for r in &self.rows {
                text.push_str(&format!(
                    "{},{},{},{},{},{}\n",
                    r.sr, r.policy.name(), r.perf, r.perf_vs_rrs, r.core_hours, r.cpu_saving_vs_rrs
                ));
            }
            std::fs::write(dir.join(format!("{}.csv", self.id)), text)?;
        }
        if !self.series.is_empty() {
            let labels: Vec<&str> = self.series.iter().map(|(p, _)| p.name()).collect();
            let refs: Vec<&crate::metrics::TimeSeries> =
                self.series.iter().map(|(_, s)| s).collect();
            export::write_multi_csv(
                &dir.join(format!("{}_timeseries.csv", self.id)),
                &labels,
                &refs,
            )?;
        }
        Ok(())
    }
}

/// Average figure rows across seeds for one scenario builder.
fn bar_figure<F>(
    id: &'static str,
    title: String,
    cfg: &Config,
    bank: &ProfileBank,
    srs: &[f64],
    seeds: &[u64],
    build: F,
) -> Result<FigureData>
where
    F: Fn(usize, f64, u64) -> Result<crate::scenarios::ScenarioSpec>,
{
    let mut rows = Vec::new();
    for &sr in srs {
        // policy -> per-seed results
        let mut per_policy: Vec<(Policy, Vec<ScenarioResult>)> =
            Policy::ALL.iter().map(|&p| (p, Vec::new())).collect();
        for &seed in seeds {
            let spec = build(cfg.host.cores, sr, seed)?;
            for (policy, acc) in per_policy.iter_mut() {
                acc.push(run_scenario(cfg, &spec, *policy, bank)?);
            }
        }
        let rrs_perf = mean(
            &per_policy[0].1.iter().map(|r| r.avg_perf).collect::<Vec<_>>(),
        );
        let rrs_hours = mean(
            &per_policy[0]
                .1
                .iter()
                .map(|r| r.core_hours)
                .collect::<Vec<_>>(),
        );
        for (policy, results) in &per_policy {
            let perf = mean(&results.iter().map(|r| r.avg_perf).collect::<Vec<_>>());
            let hours = mean(&results.iter().map(|r| r.core_hours).collect::<Vec<_>>());
            rows.push(FigureRow {
                policy: *policy,
                sr,
                perf,
                perf_vs_rrs: perf / rrs_perf,
                core_hours: hours,
                cpu_saving_vs_rrs: 1.0 - hours / rrs_hours,
            });
        }
    }
    Ok(FigureData {
        id,
        title,
        rows,
        series: Vec::new(),
    })
}

/// Fig. 2 — random scenario, SR ∈ {0.5, 1, 1.5, 2}.
pub fn fig2(cfg: &Config, bank: &ProfileBank, seeds: &[u64]) -> Result<FigureData> {
    bar_figure(
        "fig2",
        "Random scenario: performance and CPU time per scheduler".into(),
        cfg,
        bank,
        &[0.5, 1.0, 1.5, 2.0],
        seeds,
        random::build,
    )
}

/// Fig. 3 — latency-critical heavy scenario.
pub fn fig3(cfg: &Config, bank: &ProfileBank, seeds: &[u64]) -> Result<FigureData> {
    bar_figure(
        "fig3",
        "Latency-critical heavy scenario: performance and CPU time".into(),
        cfg,
        bank,
        &[0.5, 1.0, 1.5, 2.0],
        seeds,
        latency::build,
    )
}

/// Figs. 4/5 — dynamic scenario CPU-consumption time series
/// (`batch = 6` → Fig. 4, `batch = 12` → Fig. 5).
pub fn fig45(
    cfg: &Config,
    bank: &ProfileBank,
    batch: usize,
    seed: u64,
) -> Result<FigureData> {
    let id: &'static str = if batch == 6 { "fig4" } else { "fig5" };
    let spec = dynamic::build(batch, seed)?;
    let mut series = Vec::new();
    let mut rows = Vec::new();
    let mut rrs_ref: Option<ScenarioResult> = None;
    for policy in Policy::ALL {
        let r = run_scenario(cfg, &spec, policy, bank)?;
        series.push((policy, r.busy_series.clone()));
        if policy == Policy::Rrs {
            rrs_ref = Some(r.clone());
        }
        let base = rrs_ref.as_ref().unwrap();
        rows.push(FigureRow {
            policy,
            sr: spec.sr,
            perf: r.avg_perf,
            perf_vs_rrs: r.avg_perf / base.avg_perf,
            core_hours: r.core_hours,
            cpu_saving_vs_rrs: 1.0 - r.core_hours / base.core_hours,
        });
    }
    Ok(FigureData {
        id,
        title: format!(
            "Dynamic scenario ({batch}-job batches): CPU consumption over time"
        ),
        rows,
        series,
    })
}

/// Fig. 6 — workload performance in the dynamic scenario (both batchings
/// averaged over seeds).
pub fn fig6(cfg: &Config, bank: &ProfileBank, seeds: &[u64]) -> Result<FigureData> {
    let mut rows = Vec::new();
    for batch in [6usize, 12] {
        let mut per_policy: Vec<(Policy, Vec<ScenarioResult>)> =
            Policy::ALL.iter().map(|&p| (p, Vec::new())).collect();
        for &seed in seeds {
            let spec = dynamic::build(batch, seed)?;
            for (policy, acc) in per_policy.iter_mut() {
                acc.push(run_scenario(cfg, &spec, *policy, bank)?);
            }
        }
        let rrs_perf = mean(
            &per_policy[0].1.iter().map(|r| r.avg_perf).collect::<Vec<_>>(),
        );
        let rrs_hours = mean(
            &per_policy[0]
                .1
                .iter()
                .map(|r| r.core_hours)
                .collect::<Vec<_>>(),
        );
        for (policy, results) in &per_policy {
            let perf = mean(&results.iter().map(|r| r.avg_perf).collect::<Vec<_>>());
            let hours = mean(&results.iter().map(|r| r.core_hours).collect::<Vec<_>>());
            rows.push(FigureRow {
                policy: *policy,
                sr: (dynamic::TOTAL_VMS / batch) as f64, // group count as x
                perf,
                perf_vs_rrs: perf / rrs_perf,
                core_hours: hours,
                cpu_saving_vs_rrs: 1.0 - hours / rrs_hours,
            });
        }
    }
    Ok(FigureData {
        id: "fig6",
        title: "Dynamic scenario: workload performance per scheduler \
                (SR column = number of activation groups)"
            .into(),
        rows,
        series: Vec::new(),
    })
}

/// Table I — demonstrate the perf-counter → memory-bandwidth path: run a
/// jacobi VM, read the synthesized counters through the monitor, verify
/// the reconstructed bandwidth matches the profile.
pub fn table1(cfg: &Config) -> Result<String> {
    use crate::hostsim::{ActivityModel, Hypervisor, SimEngine, Vm, VmId, VmState};
    use crate::vmcd::Monitor;
    use crate::workloads::WorkloadClass;

    let mut quiet = cfg.clone();
    quiet.sim.demand_noise = 0.0;
    let mut vm = Vm::new(VmId(0), WorkloadClass::Jacobi, 0.0, ActivityModel::AlwaysOn);
    vm.state = VmState::Running;
    vm.started = Some(0.0);
    vm.pinned = Some(0);
    let mut eng = SimEngine::new(quiet, vec![vm]);
    let mut mon = Monitor::new(0.025);
    eng.step();
    mon.poll(&eng);
    for _ in 0..30 {
        eng.step();
    }
    let snap = mon.poll(&eng);
    let d = &snap.domains[0];
    let stats = eng.domain_stats(VmId(0)).unwrap();

    let rows = vec![
        vec![
            "UNC_QMC_NORMAL_READS".into(),
            "Memory Reads".into(),
            format!("{}", stats.counters.mem_reads),
        ],
        vec![
            "UNC_QMC_NORMAL_WRITES".into(),
            "Memory Writes".into(),
            format!("{}", stats.counters.mem_writes),
        ],
        vec![
            "OFFCORE_RESPONSE".into(),
            "Requests serviced by DRAM".into(),
            format!("{}", stats.counters.offcore),
        ],
    ];
    let mut out = String::from("Table I — performance counters (synthesized; 31 s jacobi run)\n");
    out.push_str(&render_table(&["Hardware Event", "Description", "Count"], &rows));
    out.push_str(&format!(
        "monitor-reconstructed MemBW: {:.3} of socket (profile demand {:.3})\n",
        d.util[3],
        crate::workloads::catalog::spec_of(crate::workloads::WorkloadClass::Jacobi).demand[3]
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn fig45_structure() {
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let f = fig45(&cfg, bank, 12, 5).unwrap();
        assert_eq!(f.id, "fig5");
        assert_eq!(f.series.len(), 4);
        assert_eq!(f.rows.len(), 4);
        // RRS holds all 12 cores from t=0 in the dynamic scenario.
        let rrs = &f.series[0].1;
        assert!(rrs.max() >= 12.0 - 1e-9, "rrs max {}", rrs.max());
        let render = f.render();
        assert!(render.contains("busy cores over time"));
    }

    #[test]
    fn table1_renders_counters() {
        let cfg = testkit::quiet_config();
        let t = table1(&cfg).unwrap();
        assert!(t.contains("UNC_QMC_NORMAL_READS"));
        assert!(t.contains("OFFCORE_RESPONSE"));
    }
}

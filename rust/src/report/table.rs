//! ASCII table rendering for figure reports.

/// Render rows as a fixed-width ASCII table. `headers` defines column
/// count; each row must match.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row width mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            out.push('+');
            for _ in 0..w + 2 {
                out.push('-');
            }
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (cell, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {cell:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

/// A crude terminal sparkline for time series (Figs. 4/5).
pub fn sparkline(values: &[f64], width: usize) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() {
        return String::new();
    }
    let max = values.iter().copied().fold(f64::MIN, f64::max).max(1e-12);
    let stride = (values.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && out.chars().count() < width {
        let v = values[i as usize];
        let lvl = ((v / max) * 7.0).round().clamp(0.0, 7.0) as usize;
        out.push(GLYPHS[lvl]);
        i += stride;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_and_aligns() {
        let t = render_table(
            &["policy", "perf"],
            &[
                vec!["rrs".into(), "1.00".into()],
                vec!["ias".into(), "0.93".into()],
            ],
        );
        assert!(t.contains("| policy | perf |"));
        assert!(t.contains("| ias    | 0.93 |"));
        // sep, header, sep, 2 rows, sep
        assert_eq!(t.lines().count(), 6);
    }

    #[test]
    #[should_panic]
    fn mismatched_row_panics() {
        render_table(&["a", "b"], &[vec!["x".into()]]);
    }

    #[test]
    fn sparkline_scales() {
        let s = sparkline(&[0.0, 0.5, 1.0], 3);
        assert_eq!(s.chars().count(), 3);
        assert!(s.ends_with('█'));
    }

    #[test]
    fn sparkline_empty() {
        assert_eq!(sparkline(&[], 10), "");
    }
}

//! Figure / table regeneration: runs the paper's experiments and renders
//! the same rows the paper reports (plus CSV mirrors under `results/`).
//!
//! Each `figN` function is used both by the CLI (`vmcd report figN`) and
//! by the corresponding bench target.

pub mod figures;
pub mod table;

pub use figures::{fig2, fig3, fig45, fig6, table1, FigureData, FigureRow};
pub use table::render_table;

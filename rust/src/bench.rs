//! First-party benchmark harness (criterion is not in the offline crate
//! set). Provides warmup + timed iterations + summary statistics and a
//! stable text output format shared by all `benches/*.rs` targets.
//!
//! Each paper-figure bench is a `harness = false` binary that uses
//! [`Bench`] for micro timings and prints the regenerated figure rows.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for one measured function.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup_iters: u32,
    pub measure_iters: u32,
    /// Hard cap on total measurement time.
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 3,
            measure_iters: 20,
            max_total: Duration::from_secs(60),
        }
    }
}

/// Result of a measured function.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    /// Per-iteration wall time in seconds.
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.secs.mean() * 1e3
    }

    pub fn p50_ms(&self) -> f64 {
        self.secs.median() * 1e3
    }

    pub fn p95_ms(&self) -> f64 {
        self.secs.percentile(95.0) * 1e3
    }

    /// One-line human summary.
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms/iter (p50 {:>9.3}, p95 {:>9.3}, n={})",
            self.name,
            self.mean_ms(),
            self.p50_ms(),
            self.p95_ms(),
            self.iters
        )
    }
}

/// The harness.
pub struct Bench {
    pub opts: BenchOpts,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new() -> Bench {
        // Honour quick-mode for CI: VMCD_BENCH_QUICK=1 shrinks iterations.
        let mut opts = BenchOpts::default();
        if std::env::var("VMCD_BENCH_QUICK").as_deref() == Ok("1") {
            opts.warmup_iters = 1;
            opts.measure_iters = 3;
        }
        Bench {
            opts,
            results: Vec::new(),
        }
    }

    /// Measure `f` (called once per iteration).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.opts.warmup_iters {
            f();
        }
        let mut secs = Summary::new();
        #[allow(clippy::disallowed_methods)] // process edge: benches time wall clock
        let started = Instant::now();
        let mut iters = 0;
        for _ in 0..self.opts.measure_iters {
            #[allow(clippy::disallowed_methods)] // process edge: benches time wall clock
            let t0 = Instant::now();
            f();
            secs.push(t0.elapsed().as_secs_f64());
            iters += 1;
            if started.elapsed() > self.opts.max_total {
                break;
            }
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            secs,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a header for a bench group.
    pub fn section(&self, title: &str) {
        println!("\n=== {title} ===");
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new();
        b.opts.warmup_iters = 1;
        b.opts.measure_iters = 5;
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.secs.mean() >= 0.0);
        assert!(acc > 0 || acc == 0); // keep acc alive
    }

    #[test]
    fn result_line_formats() {
        let mut b = Bench::new();
        b.opts.warmup_iters = 0;
        b.opts.measure_iters = 2;
        let r = b.run("fmt", || {});
        assert!(r.line().contains("fmt"));
    }
}

//! The host-stepping interface the cluster simulator drives.
//!
//! [`HostHandle`] decouples cluster stepping from the concrete
//! daemon/engine pairing: a host is anything that can advance one tick,
//! accept routed bus deliveries (arrivals, migrants, departures, raw
//! scheduler events, transfer network load), and publish metrics plus a
//! [`HostSummary`]. [`SimHost`] is the standard implementation — a
//! [`SimEngine`] plus an optional per-host VMCd [`Daemon`].
//!
//! `SimHost` is generic over the daemon's scheduler so the *type system*
//! decides which hosts can move to a shard worker: [`NativeHost`]
//! (`SimHost<dyn Scheduler + Send>`, natively-scored) is `Send` and can
//! be owned by a [`super::pool::ShardPool`] worker for the whole run,
//! while an XLA-backed `SimHost<dyn Scheduler>` is not `Send` (PJRT
//! handles) and must step on the caller thread behind a
//! `Box<dyn HostHandle>` ([`ClusterHost::Pinned`]).

use super::bus::HostSummary;
use crate::hostsim::{Hypervisor, SimEngine, Vm, VmId, VmState};
use crate::vmcd::daemon::SchedEvent;
use crate::vmcd::scheduler::Scheduler;
use crate::vmcd::Daemon;
use anyhow::Result;

/// Per-host summary counters drained by cluster-level reporting.
#[derive(Debug, Clone, Default)]
pub struct HostMetrics {
    /// Resident VMs (all lifecycle states still tracked by the engine).
    pub resident: usize,
    /// Cores currently holding a running VM.
    pub busy_cores: usize,
    /// Busy-core hours accumulated so far.
    pub core_hours: f64,
    /// vCPU re-pin actuations applied.
    pub repins: u64,
    /// Scheduler cycles run (0 for daemon-less hosts).
    pub cycles: u64,
    /// Tolerated actuation failures (0 for daemon-less hosts).
    pub pin_failures: u64,
    /// Pins decided but not yet enforced by the daemon's actuation
    /// backend (always 0 for daemon-less hosts and Inline actuation).
    pub actuation_in_flight: usize,
    /// VMs that completed a live migration *onto* this host. Aborted
    /// transfers never land, so the source keeps the VM and this stays
    /// flat — the bus's `migrations_failed` counts those.
    pub migrants_in: u64,
}

/// One steppable host, as the cluster layer sees it. The default
/// methods define the bus-delivery surface in terms of the required
/// ones, so every host honours the same `ClusterEvent` semantics.
pub trait HostHandle {
    /// Current host-local virtual time.
    fn now(&self) -> f64;

    /// Advance one tick: run the daemon's event step (poll, diff,
    /// lifecycle events, Tick when due, then one actuation pass — the
    /// backend absorbs the step's commands, enforces whatever is due,
    /// and feeds completions back), then the engine physics.
    fn step_host(&mut self) -> Result<()>;

    /// Inject an arriving VM (the dispatch decision is already made):
    /// insert it and give it an initial pinning via the daemon, or
    /// round-robin when the host has no daemon.
    fn inject_arrival(&mut self, vm: Vm) -> Result<()>;

    /// Inject a scheduler event directly (e.g. a forced
    /// [`SchedEvent::Tick`]). A no-op on daemon-less hosts.
    fn inject_event(&mut self, ev: SchedEvent) -> Result<()>;

    /// Accept a VM migrated in from another host. Daemon-less hosts
    /// assign a fresh round-robin core (the global strategy's in-host
    /// contract); daemon hosts keep the carried pinning and let their
    /// daemon adopt it. Prefer [`Self::accept_migrant`], which also
    /// routes the daemon-side `Arrival` bookkeeping.
    fn inject_migrated(&mut self, vm: Vm);

    /// The simulated engine — the metrics drain and the surgical surface
    /// the migration model needs (every host wraps a [`SimEngine`]; the
    /// trait abstracts the daemon/backend coupling, not the physics).
    fn engine(&self) -> &SimEngine;
    fn engine_mut(&mut self) -> &mut SimEngine;

    /// Summary counters for dashboards and reports.
    fn metrics(&self) -> HostMetrics;

    /// Worst per-core workload interference of the host daemon's
    /// placement state (Eq. 3/4); 0 for daemon-less hosts.
    fn placement_wi(&self) -> f64 {
        0.0
    }

    /// The per-tick state published on the cluster bus (the
    /// `est_cpu_load` field is filled in by the bus, which owns the
    /// profile bank).
    fn summary(&self) -> HostSummary {
        let engine = self.engine();
        HostSummary {
            resident: engine.vms.len(),
            running: engine
                .vms
                .iter()
                .filter(|vm| vm.state == VmState::Running)
                .map(|vm| (vm.id, vm.class))
                .collect(),
            busy_cores: engine.busy_cores(),
            max_wi: self.placement_wi(),
            est_cpu_load: 0.0,
        }
    }

    /// Remove a resident VM entirely (a routed `Departure`, or a matured
    /// migration pulling it off this source host): take it out of the
    /// engine and hand the daemon a [`SchedEvent::Departure`] so the
    /// long-lived placement state drops the member immediately instead
    /// of waiting for the next monitor diff.
    fn remove_resident(&mut self, id: VmId) -> Result<Option<Vm>> {
        let vm = self.engine_mut().remove_vm(id);
        if vm.is_some() {
            self.inject_event(SchedEvent::Departure(id))?;
        }
        Ok(vm)
    }

    /// Accept a VM migrating in: apply the stop-and-copy pause, insert
    /// it, and hand the daemon a [`SchedEvent::Arrival`] so the newcomer
    /// is adopted (pin carried) or placed (pin lost) through the same
    /// bookkeeping as any other arrival — the bus's "delayed `Arrival`
    /// on the destination".
    fn accept_migrant(&mut self, mut vm: Vm, pause_until: Option<f64>) -> Result<()> {
        if let Some(until) = pause_until {
            vm.paused_until = until;
        }
        let id = vm.id;
        self.inject_migrated(vm);
        self.inject_event(SchedEvent::Arrival(id))
    }

    /// Adjust the host's external network load (migration transfer
    /// windows open with a positive delta and close with its negative).
    fn add_external_net_load(&mut self, delta: f64) {
        self.engine_mut().external_net_load += delta;
    }
}

/// One cluster host, partitioned by steppability: `Native` hosts are
/// `Send` and can live on pool/scoped worker threads; `Pinned` hosts
/// (e.g. XLA-backed daemons holding PJRT handles) step on the caller
/// thread.
pub enum ClusterHost {
    Native(NativeHost),
    Pinned(Box<dyn HostHandle>),
}

impl ClusterHost {
    pub fn handle(&self) -> &dyn HostHandle {
        match self {
            ClusterHost::Native(h) => h,
            ClusterHost::Pinned(h) => h.as_ref(),
        }
    }

    pub fn handle_mut(&mut self) -> &mut dyn HostHandle {
        match self {
            ClusterHost::Native(h) => h,
            ClusterHost::Pinned(h) => h.as_mut(),
        }
    }
}

/// A simulated host: engine + optional VMCd daemon.
pub struct SimHost<S: ?Sized + Scheduler = dyn Scheduler> {
    pub engine: SimEngine,
    /// Per-host daemon; `None` means pinning is managed externally (the
    /// global-migration strategy pins round-robin in-host).
    pub daemon: Option<Daemon<S>>,
    /// Round-robin cursor for daemon-less in-host pinning.
    pub rr_core: usize,
    /// Completed live migrations onto this host.
    pub migrants_in: u64,
}

/// The shardable host: natively-scored scheduler, so the whole host is
/// `Send` and can be owned by a worker thread.
pub type NativeHost = SimHost<dyn Scheduler + Send>;

// Compile-time guarantee behind the pool/scoped stepping paths.
#[allow(dead_code)]
fn _assert_native_host_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<NativeHost>();
}

impl<S: ?Sized + Scheduler> SimHost<S> {
    pub fn new(engine: SimEngine, daemon: Option<Daemon<S>>) -> SimHost<S> {
        SimHost {
            engine,
            daemon,
            rr_core: 0,
            migrants_in: 0,
        }
    }

    /// Next core of the in-host round-robin (daemon-less pinning, also
    /// used for migrated-in VMs).
    pub fn next_rr_core(&mut self) -> usize {
        let cores = self.engine.cfg.host.cores;
        let core = self.rr_core % cores;
        self.rr_core += 1;
        core
    }
}

impl<S: ?Sized + Scheduler> HostHandle for SimHost<S> {
    fn now(&self) -> f64 {
        self.engine.t
    }

    fn step_host(&mut self) -> Result<()> {
        if let Some(daemon) = &mut self.daemon {
            daemon.step(&mut self.engine)?;
        }
        self.engine.step();
        Ok(())
    }

    fn inject_arrival(&mut self, vm: Vm) -> Result<()> {
        let id = vm.id;
        self.engine.insert_vm(vm);
        match &mut self.daemon {
            Some(daemon) => daemon.on_arrival(&mut self.engine, id),
            None => {
                let core = self.next_rr_core();
                self.engine.pin_vcpu(id, core)
            }
        }
    }

    fn inject_event(&mut self, ev: SchedEvent) -> Result<()> {
        match &mut self.daemon {
            Some(daemon) => daemon.handle_event(&mut self.engine, ev),
            None => Ok(()),
        }
    }

    fn inject_migrated(&mut self, mut vm: Vm) {
        if self.daemon.is_none() {
            let core = self.next_rr_core();
            vm.pinned = Some(core);
        }
        self.migrants_in += 1;
        self.engine.insert_vm(vm);
    }

    fn engine(&self) -> &SimEngine {
        &self.engine
    }

    fn engine_mut(&mut self) -> &mut SimEngine {
        &mut self.engine
    }

    fn metrics(&self) -> HostMetrics {
        HostMetrics {
            resident: self.engine.vms.len(),
            busy_cores: self.engine.busy_cores(),
            core_hours: self.engine.ledger.core_hours(),
            repins: self.engine.ledger.repin_count,
            cycles: self.daemon.as_ref().map_or(0, |d| d.cycles),
            pin_failures: self.daemon.as_ref().map_or(0, |d| d.pin_failures),
            actuation_in_flight: self.daemon.as_ref().map_or(0, |d| d.in_flight()),
            migrants_in: self.migrants_in,
        }
    }

    fn placement_wi(&self) -> f64 {
        self.daemon
            .as_ref()
            .map_or(0.0, |d| d.placement_state().max_core_wi())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::VmState;
    use crate::testkit;
    use crate::vmcd::scheduler::{self, Policy};
    use crate::workloads::WorkloadClass;

    fn native_host(policy: Policy) -> NativeHost {
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build_native(policy, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon))
    }

    #[test]
    fn inject_arrival_places_via_daemon() {
        let mut host = native_host(Policy::Ras);
        let mut vm = Vm::new(
            VmId(0),
            WorkloadClass::Blackscholes,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        host.inject_arrival(vm).unwrap();
        assert!(host.engine().vms[0].pinned.is_some());
        host.step_host().unwrap();
        let m = host.metrics();
        assert_eq!(m.resident, 1);
        assert!(m.busy_cores >= 1);
        assert!(m.cycles >= 1);
        // The bus-facing summary sees the same occupancy plus the
        // daemon's placement interference.
        let s = host.summary();
        assert_eq!(s.resident, 1);
        assert_eq!(s.running, vec![(VmId(0), WorkloadClass::Blackscholes)]);
        assert!(s.max_wi >= 0.5, "solo member has WI 0.5, got {}", s.max_wi);
    }

    #[test]
    fn daemonless_host_pins_round_robin() {
        let cfg = testkit::quiet_config();
        let mut host: NativeHost = SimHost::new(SimEngine::new(cfg, Vec::new()), None);
        for i in 0..3u32 {
            let mut vm = Vm::new(
                VmId(i),
                WorkloadClass::Hadoop,
                0.0,
                crate::hostsim::ActivityModel::AlwaysOn,
            );
            vm.state = VmState::Running;
            vm.started = Some(0.0);
            host.inject_arrival(vm).unwrap();
        }
        let pins: Vec<_> = host.engine().vms.iter().map(|v| v.pinned).collect();
        assert_eq!(pins, vec![Some(0), Some(1), Some(2)]);
        // Event injection is a tolerated no-op without a daemon.
        host.inject_event(SchedEvent::Tick).unwrap();
        assert_eq!(host.metrics().cycles, 0);
        assert_eq!(host.placement_wi(), 0.0);
        // A migrated-in VM gets the next round-robin core, not the pin it
        // carried from its source host.
        let mut vm = Vm::new(
            VmId(9),
            WorkloadClass::Hadoop,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.pinned = Some(11);
        host.inject_migrated(vm);
        assert_eq!(host.engine().vms[3].pinned, Some(3));
    }

    #[test]
    fn injected_tick_runs_a_cycle() {
        let mut host = native_host(Policy::Ias);
        host.inject_event(SchedEvent::Tick).unwrap();
        assert_eq!(host.metrics().cycles, 1);
    }

    #[test]
    fn remove_resident_updates_daemon_bookkeeping() {
        let mut host = native_host(Policy::Ias);
        let mut vm = Vm::new(
            VmId(4),
            WorkloadClass::Jacobi,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        host.inject_arrival(vm).unwrap();
        assert_eq!(
            host.daemon.as_ref().unwrap().placement_state().placed(),
            1
        );
        let vm = host.remove_resident(VmId(4)).unwrap();
        assert_eq!(vm.map(|v| v.id), Some(VmId(4)));
        assert_eq!(host.engine().vms.len(), 0);
        assert_eq!(
            host.daemon.as_ref().unwrap().placement_state().placed(),
            0
        );
        // Removing a ghost is a tolerated no-op.
        assert!(host.remove_resident(VmId(4)).unwrap().is_none());
    }

    #[test]
    fn accept_migrant_pauses_and_adopts() {
        let mut host = native_host(Policy::Ias);
        let mut vm = Vm::new(
            VmId(6),
            WorkloadClass::StreamLow,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm.pinned = Some(5);
        // A live migrant carries its monitoring window; warm it so the
        // adoption sees a running (non-idle) workload.
        for _ in 0..12 {
            vm.record_cpu(0.8, 10);
        }
        host.accept_migrant(vm, Some(42.0)).unwrap();
        assert_eq!(host.engine().vms[0].paused_until, 42.0);
        assert_eq!(host.metrics().migrants_in, 1);
        // Adoption keeps the carried pin and books the member into the
        // long-lived placement state right away.
        assert_eq!(host.engine().vms[0].pinned, Some(5));
        assert_eq!(
            host.daemon.as_ref().unwrap().placement_state().placed(),
            1
        );
    }

    #[test]
    fn boxed_host_handle_steps_on_caller_thread() {
        // The non-Send path: any SimHost works behind Box<dyn HostHandle>.
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        let mut host: Box<dyn HostHandle> =
            Box::new(SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon)));
        host.step_host().unwrap();
        assert!(host.now() > 0.0);
    }
}

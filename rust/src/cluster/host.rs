//! The host-stepping interface the cluster simulator drives.
//!
//! [`HostHandle`] decouples `ClusterSim::step` from the concrete
//! daemon/engine pairing: a host is anything that can advance one tick,
//! accept injected events (arrivals, forced scheduler ticks), and drain
//! metrics. [`SimHost`] is the standard implementation — a [`SimEngine`]
//! plus an optional per-host VMCd [`Daemon`].
//!
//! `SimHost` is generic over the daemon's scheduler so the *type system*
//! decides which hosts can shard: [`NativeHost`]
//! (`SimHost<dyn Scheduler + Send>`, natively-scored) moves across
//! `std::thread` scoped workers, while an XLA-backed
//! `SimHost<dyn Scheduler>` is not `Send` (PJRT handles) and must step on
//! the caller thread behind a `Box<dyn HostHandle>`.

use crate::hostsim::{Hypervisor, SimEngine, Vm};
use crate::vmcd::daemon::SchedEvent;
use crate::vmcd::scheduler::Scheduler;
use crate::vmcd::Daemon;
use anyhow::Result;

/// Per-host summary counters drained by cluster-level reporting.
#[derive(Debug, Clone, Default)]
pub struct HostMetrics {
    /// Resident VMs (all lifecycle states still tracked by the engine).
    pub resident: usize,
    /// Cores currently holding a running VM.
    pub busy_cores: usize,
    /// Busy-core hours accumulated so far.
    pub core_hours: f64,
    /// vCPU re-pin actuations applied.
    pub repins: u64,
    /// Scheduler cycles run (0 for daemon-less hosts).
    pub cycles: u64,
    /// Tolerated actuation failures (0 for daemon-less hosts).
    pub pin_failures: u64,
}

/// One steppable host, as the cluster simulator sees it.
pub trait HostHandle {
    /// Current host-local virtual time.
    fn now(&self) -> f64;

    /// Advance one tick: run the daemon's event step (poll, diff,
    /// lifecycle events, Tick when due), then the engine physics.
    fn step_host(&mut self) -> Result<()>;

    /// Inject an arriving VM (the dispatch decision is already made):
    /// insert it and give it an initial pinning via the daemon, or
    /// round-robin when the host has no daemon.
    fn inject_arrival(&mut self, vm: Vm) -> Result<()>;

    /// Inject a scheduler event directly (e.g. a forced
    /// [`SchedEvent::Tick`]). A no-op on daemon-less hosts.
    fn inject_event(&mut self, ev: SchedEvent) -> Result<()>;

    /// Accept a VM migrated in from another host. Daemon-less hosts
    /// assign a fresh round-robin core (the global strategy's in-host
    /// contract); daemon hosts keep the carried pinning and let their
    /// daemon adopt and re-pin it on the next poll.
    fn inject_migrated(&mut self, vm: Vm);

    /// The simulated engine — the metrics drain and the surgical surface
    /// the migration model needs (every host wraps a [`SimEngine`]; the
    /// trait abstracts the daemon/backend coupling, not the physics).
    fn engine(&self) -> &SimEngine;
    fn engine_mut(&mut self) -> &mut SimEngine;

    /// Summary counters for dashboards and reports.
    fn metrics(&self) -> HostMetrics;
}

/// A simulated host: engine + optional VMCd daemon.
pub struct SimHost<S: ?Sized + Scheduler = dyn Scheduler> {
    pub engine: SimEngine,
    /// Per-host daemon; `None` means pinning is managed externally (the
    /// global-migration strategy pins round-robin in-host).
    pub daemon: Option<Daemon<S>>,
    /// Round-robin cursor for daemon-less in-host pinning.
    pub rr_core: usize,
}

/// The shardable host: natively-scored scheduler, so the whole host is
/// `Send` and can step on a worker thread.
pub type NativeHost = SimHost<dyn Scheduler + Send>;

// Compile-time guarantee behind the sharded stepping path.
#[allow(dead_code)]
fn _assert_native_host_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<NativeHost>();
}

impl<S: ?Sized + Scheduler> SimHost<S> {
    pub fn new(engine: SimEngine, daemon: Option<Daemon<S>>) -> SimHost<S> {
        SimHost {
            engine,
            daemon,
            rr_core: 0,
        }
    }

    /// Next core of the in-host round-robin (daemon-less pinning, also
    /// used for migrated-in VMs).
    pub fn next_rr_core(&mut self) -> usize {
        let cores = self.engine.cfg.host.cores;
        let core = self.rr_core % cores;
        self.rr_core += 1;
        core
    }
}

impl<S: ?Sized + Scheduler> HostHandle for SimHost<S> {
    fn now(&self) -> f64 {
        self.engine.t
    }

    fn step_host(&mut self) -> Result<()> {
        if let Some(daemon) = &mut self.daemon {
            daemon.step(&mut self.engine)?;
        }
        self.engine.step();
        Ok(())
    }

    fn inject_arrival(&mut self, vm: Vm) -> Result<()> {
        let id = vm.id;
        self.engine.insert_vm(vm);
        match &mut self.daemon {
            Some(daemon) => daemon.on_arrival(&mut self.engine, id),
            None => {
                let core = self.next_rr_core();
                self.engine.pin_vcpu(id, core)
            }
        }
    }

    fn inject_event(&mut self, ev: SchedEvent) -> Result<()> {
        match &mut self.daemon {
            Some(daemon) => daemon.handle_event(&mut self.engine, ev),
            None => Ok(()),
        }
    }

    fn inject_migrated(&mut self, mut vm: Vm) {
        if self.daemon.is_none() {
            let core = self.next_rr_core();
            vm.pinned = Some(core);
        }
        self.engine.insert_vm(vm);
    }

    fn engine(&self) -> &SimEngine {
        &self.engine
    }

    fn engine_mut(&mut self) -> &mut SimEngine {
        &mut self.engine
    }

    fn metrics(&self) -> HostMetrics {
        HostMetrics {
            resident: self.engine.vms.len(),
            busy_cores: self.engine.busy_cores(),
            core_hours: self.engine.ledger.core_hours(),
            repins: self.engine.ledger.repin_count,
            cycles: self.daemon.as_ref().map_or(0, |d| d.cycles),
            pin_failures: self.daemon.as_ref().map_or(0, |d| d.pin_failures),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::{VmId, VmState};
    use crate::testkit;
    use crate::vmcd::scheduler::{self, Policy};
    use crate::workloads::WorkloadClass;

    fn native_host(policy: Policy) -> NativeHost {
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build_native(policy, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched);
        SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon))
    }

    #[test]
    fn inject_arrival_places_via_daemon() {
        let mut host = native_host(Policy::Ras);
        let mut vm = Vm::new(
            VmId(0),
            WorkloadClass::Blackscholes,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        host.inject_arrival(vm).unwrap();
        assert!(host.engine().vms[0].pinned.is_some());
        host.step_host().unwrap();
        let m = host.metrics();
        assert_eq!(m.resident, 1);
        assert!(m.busy_cores >= 1);
        assert!(m.cycles >= 1);
    }

    #[test]
    fn daemonless_host_pins_round_robin() {
        let cfg = testkit::quiet_config();
        let mut host: NativeHost = SimHost::new(SimEngine::new(cfg, Vec::new()), None);
        for i in 0..3u32 {
            let mut vm = Vm::new(
                VmId(i),
                WorkloadClass::Hadoop,
                0.0,
                crate::hostsim::ActivityModel::AlwaysOn,
            );
            vm.state = VmState::Running;
            vm.started = Some(0.0);
            host.inject_arrival(vm).unwrap();
        }
        let pins: Vec<_> = host.engine().vms.iter().map(|v| v.pinned).collect();
        assert_eq!(pins, vec![Some(0), Some(1), Some(2)]);
        // Event injection is a tolerated no-op without a daemon.
        host.inject_event(SchedEvent::Tick).unwrap();
        assert_eq!(host.metrics().cycles, 0);
        // A migrated-in VM gets the next round-robin core, not the pin it
        // carried from its source host.
        let mut vm = Vm::new(
            VmId(9),
            WorkloadClass::Hadoop,
            0.0,
            crate::hostsim::ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.pinned = Some(11);
        host.inject_migrated(vm);
        assert_eq!(host.engine().vms[3].pinned, Some(3));
    }

    #[test]
    fn injected_tick_runs_a_cycle() {
        let mut host = native_host(Policy::Ias);
        host.inject_event(SchedEvent::Tick).unwrap();
        assert_eq!(host.metrics().cycles, 1);
    }

    #[test]
    fn boxed_host_handle_steps_on_caller_thread() {
        // The non-Send path: any SimHost works behind Box<dyn HostHandle>.
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched);
        let mut host: Box<dyn HostHandle> =
            Box::new(SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon)));
        host.step_host().unwrap();
        assert!(host.now() > 0.0);
    }
}

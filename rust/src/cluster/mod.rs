//! Cluster layer — the paper's named future work (§VI: "further explore
//! local vs global consolidation approaches … pit our approach against
//! infrastructure-scale schedulers") and its §III argument that
//! migration-based global consolidation "fails when the infrastructure as
//! a whole is oversubscribed".
//!
//! The layer's public API is one event type and two engines:
//!
//! * [`bus`] — the **cluster-wide event bus**: every piece of placement
//!   churn (arrival, departure, live migration, raw scheduler event) is
//!   a [`ClusterEvent`](bus::ClusterEvent) routed into per-host inboxes;
//!   a migration expands to a departure on the source plus a delayed,
//!   downtime-paused arrival on the destination. Hosts publish
//!   [`HostSummary`](bus::HostSummary)s back each tick — the *only*
//!   cluster state arrival policies and the global strategy see.
//! * [`pool`] — the **persistent shard pool**: workers own their native
//!   (`Send`) hosts for the whole run, drain the routed inboxes, step,
//!   and report; XLA-backed hosts stay on the caller thread. All step
//!   modes are bit-identical.
//! * [`sim`] — the cluster simulator over both, with two strategies:
//!
//!   * **Local** ([`Strategy::LocalVmcd`]): an [`ArrivalPolicy`] assigns
//!     each arriving VM to a host; from then on every host's own VMCd
//!     daemon (any per-host policy) does all optimisation by re-pinning
//!     locally. No migrations, no global knowledge.
//!   * **Global** ([`Strategy::GlobalMigration`]): a centralized
//!     scheduler with full cluster knowledge periodically reshuffles VMs
//!     *across* hosts (live migration) to pack them onto the fewest
//!     hosts, at the cost the paper identifies: each migration stalls
//!     the VM for a downtime window and burns network on both ends.
//!     Within a host it pins round-robin (the centralized schedulers the
//!     paper contrasts with do not micro-manage pinning).
//!
//! Steady-state consolidation lives in [`migrator`] — the **continuous
//! migration manager**: a [`VmMigrator`](migrator::VmMigrator) that
//! watches the bus-published summaries each tick, classifies hosts as
//! overloaded (spread) or underloaded (evacuate and park), and
//! publishes live [`ClusterEvent::Migrate`](bus::ClusterEvent)s under a
//! concurrent-transfer budget with per-VM cooldowns (Jin et al.,
//! arXiv:1404.2842: joint energy/interference objective). Its effect is
//! measured by the cluster-scope [`ClusterLedger`](crate::metrics::ClusterLedger):
//! parked-aware energy (Wh), core-hours, and overload-time SLAV.
//!
//! On top of both sits [`trace`] — **trace-driven scale-out**: dataset
//! readers (CSV vm-instances/vm-types files, dslab-style) and a seeded
//! heavy-tailed [`SyntheticTraceGenerator`](trace::synth::SyntheticTraceGenerator)
//! stream time-ordered [`TraceEvent`](trace::TraceEvent)s into a replay
//! driver ([`trace::replay::replay`]) that publishes them through the
//! event bus, so any dispatcher can be measured against 100k+ VM events
//! across thousands of hosts (`vmcd cluster --trace`, the `trace_replay`
//! bench).

pub mod bus;
pub mod dispatch;
pub mod host;
pub mod migration;
pub mod migrator;
pub mod pool;
pub mod sim;
pub mod trace;

pub use bus::{BusStats, ClusterEvent, EventBus, HostEvent, HostSummary, SummaryMatrix, TickReport};
pub use dispatch::{ArrivalBatch, ArrivalPolicy, Dispatcher};
pub use host::{ClusterHost, HostHandle, HostMetrics, NativeHost, SimHost};
pub use migration::MigrationModel;
pub use migrator::{MigratorStats, PlannedMove, VmMigrator};
pub use pool::{ShardPool, StepMode};
pub use sim::{validate_shape, ClusterResult, ClusterSim, ClusterSpec, Strategy};
pub use trace::replay::{replay, ReplayResult};
pub use trace::{TraceEvent, TraceOp, TraceReader};

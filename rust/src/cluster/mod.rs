//! Cluster layer — the paper's named future work (§VI: "further explore
//! local vs global consolidation approaches … pit our approach against
//! infrastructure-scale schedulers") and its §III argument that
//! migration-based global consolidation "fails when the infrastructure as
//! a whole is oversubscribed".
//!
//! Two cluster-level strategies over N simulated hosts:
//!
//! * **Local** ([`Strategy::LocalVmcd`]): a thin dispatcher assigns each
//!   arriving VM to a host (least-resident-VMs); from then on every host's
//!   own VMCd daemon (any per-host policy) does all optimisation by
//!   re-pinning locally. No migrations, no global knowledge.
//! * **Global** ([`Strategy::GlobalMigration`]): a centralized scheduler
//!   with full cluster knowledge periodically reshuffles VMs *across*
//!   hosts (live migration) to pack them onto the fewest hosts, at the
//!   cost the paper identifies: each migration stalls the VM for a
//!   downtime window and burns network on both ends. Within a host it
//!   pins round-robin (the centralized schedulers the paper contrasts
//!   with do not micro-manage pinning).

//!
//! Hosts are driven through the [`host::HostHandle`] interface; native
//! (`Send`) hosts can shard across worker threads
//! ([`ClusterSpec::shard_threads`](sim::ClusterSpec::shard_threads)),
//! XLA-backed hosts stay on the caller thread.

pub mod dispatch;
pub mod host;
pub mod migration;
pub mod sim;

pub use dispatch::Dispatcher;
pub use host::{HostHandle, HostMetrics, NativeHost, SimHost};
pub use migration::MigrationModel;
pub use sim::{ClusterHost, ClusterResult, ClusterSim, ClusterSpec, Strategy};

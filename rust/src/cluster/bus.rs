//! The cluster-wide event bus: every piece of placement churn — arrivals,
//! departures, live migrations — expressed as one [`ClusterEvent`] type
//! and routed to per-host inboxes of [`HostEvent`] deliveries.
//!
//! This is the cluster-level mirror of the per-host `SchedEvent` design:
//! the paper's datacenter management system "assigns a set of VMs to a
//! server" (§IV-B) and from then on each host's VMCd optimises locally
//! (§III). The bus is that assignment surface made explicit. Instead of
//! the cluster simulator reaching into `SimHost` internals, *everything*
//! flows through routed events:
//!
//! * [`ClusterEvent::Arrival`] — a VM arriving cluster-wide; an
//!   [`ArrivalPolicy`] picks the host from the published
//!   [`HostSummary`]s (never from raw engine state);
//! * [`ClusterEvent::Departure`] — a resident VM leaves its host, which
//!   removes it and hands its daemon a `SchedEvent::Departure` so the
//!   long-lived placement state drops the member in O(members);
//! * [`ClusterEvent::Migrate`] — expands to a **departure on the source
//!   plus a delayed arrival on the destination** once the transfer
//!   window elapses, with the [`MigrationModel`]'s costs (transfer
//!   network load on both ends, stop-and-copy downtime, abort risk under
//!   a busy destination) applied as routed deliveries;
//! * [`ClusterEvent::Sched`] — a raw scheduler event for one host's
//!   daemon (e.g. a forced `Tick`).
//!
//! Routing is deterministic (FIFO queue order, per-host append order), so
//! stepping the inboxes on the persistent shard pool is bit-identical to
//! single-threaded execution — see [`super::pool`].

use super::dispatch::{ArrivalBatch, ArrivalPolicy};
use super::host::HostHandle;
use super::migration::{Migration, MigrationModel};
use crate::hostsim::{Vm, VmId, VmState};
use crate::profiling::ProfileBank;
use crate::util::rng::Rng;
use crate::vmcd::daemon::SchedEvent;
use crate::vmcd::scheduler::ScoreBuf;
use crate::workloads::{MetricVec, WorkloadClass, NUM_METRICS};
use anyhow::Result;
use std::collections::VecDeque;

/// One piece of cluster-wide placement churn. Published with
/// [`EventBus::publish`], routed with [`EventBus::route`].
//
// The arrival variant carries the whole `Vm` by value: events are
// moved, short-lived, and one-per-churn-item, so boxing would buy
// nothing but an extra allocation on the dispatch path.
#[allow(clippy::large_enum_variant)]
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// A VM arriving cluster-wide. `host: None` lets the bus's
    /// [`ArrivalPolicy`] pick the destination from the published
    /// summaries; `Some(h)` forces it (re-dispatch, replay, tests).
    Arrival { vm: Vm, host: Option<usize> },
    /// A resident VM leaves the cluster outright (teardown, eviction).
    Departure { host: usize, vm: VmId },
    /// Live-migrate a VM: after the transfer window, a departure on
    /// `src` and a delayed arrival on `dst` (paused for the
    /// stop-and-copy downtime). Both hosts carry the transfer's network
    /// load for the whole window; under a busy destination the transfer
    /// may abort (pre-copy never converges) and the VM stays on `src`.
    Migrate { vm: VmId, src: usize, dst: usize },
    /// Inject a raw scheduler event into one host's daemon (a forced
    /// `Tick`, or an externally observed `ActuationComplete` when a
    /// remote actuation layer reports back through the bus).
    Sched { host: usize, ev: SchedEvent },
}

/// One routed, host-local delivery. Hosts drain their inbox at the start
/// of the tick, before stepping — see [`apply_host_event`].
#[derive(Debug, Clone)]
pub enum HostEvent {
    /// An arriving VM, already routed to this host.
    Arrival(Vm),
    /// A VM migrating in; `pause_until` is the end of the stop-and-copy
    /// window (None when the VM was not running).
    MigrateIn { vm: Vm, pause_until: Option<f64> },
    /// Remove the VM from the host entirely.
    Depart(VmId),
    /// Raw daemon event.
    Sched(SchedEvent),
    /// Delta to the host's external network load (migration transfer
    /// windows open with a positive delta and close with its negative).
    NetLoad(f64),
}

/// Per-host state published on the bus after every tick — what arrival
/// policies and the global strategy see instead of raw engine state.
#[derive(Debug, Clone, Default)]
pub struct HostSummary {
    /// Resident VMs (every lifecycle state the engine still tracks).
    /// Kept live within a tick: routing an arrival bumps it so multiple
    /// same-tick dispatch decisions don't all pick the same host.
    pub resident: usize,
    /// Currently running VMs, in engine order.
    pub running: Vec<(VmId, WorkloadClass)>,
    /// Cores currently holding a running VM.
    pub busy_cores: usize,
    /// Worst per-core workload interference (Eq. 3/4) of the host
    /// daemon's placement state; 0 for daemon-less hosts.
    pub max_wi: f64,
    /// Profile-estimated CPU load of the running VMs (Σ U[class][cpu]);
    /// filled in by [`EventBus::refresh`] from the profile bank.
    pub est_cpu_load: f64,
}

/// [`SummaryMatrix`] lane indices into its backing [`ScoreBuf`].
const COL_RESIDENT: usize = 0;
const COL_BUSY_CORES: usize = 1;
const COL_EST_CPU: usize = 2;
const COL_MAX_WI: usize = 3;
const COL_LOAD0: usize = 4;
const MATRIX_LANES: usize = COL_LOAD0 + NUM_METRICS;

/// The flat SoA mirror of the published [`HostSummary`]s: one dense
/// f64 column per summary fact (residents, busy cores, estimated CPU
/// load, worst-core interference) plus one per-resource load column
/// per profiled metric, all over one contiguous [`ScoreBuf`]. This is
/// what [`crate::cluster::dispatch::ArrivalPolicy::rank`] scores a
/// whole arrival batch against — columnar reads over thousands of
/// hosts instead of striding through a `Vec<HostSummary>` of
/// pointer-carrying structs.
///
/// The bus keeps the matrix **live within a tick**: routing an arrival
/// bumps the destination's resident and load columns (see
/// [`Self::note_arrival`]) so later same-tick ranking sees the pick,
/// exactly like the scalar summaries. `busy_cores`/`max_wi` are
/// placement-state facts only the host daemons know; they refresh at
/// the next tick.
#[derive(Debug, Clone, Default)]
pub struct SummaryMatrix {
    buf: ScoreBuf,
    host_cores: usize,
    /// Per-host capacity vectors (heterogeneous clusters: trace
    /// host-classes, `ClusterSpec::host_caps`). Empty = the homogeneous
    /// default (`host_cores` CPU, 1.0 per fractional metric). Kept
    /// outside `buf` on purpose: [`Self::rebuild`] resets the lanes
    /// every tick, but capacities are configuration, not tick state.
    caps: Vec<MetricVec>,
}

impl SummaryMatrix {
    pub fn new(hosts: usize, host_cores: usize) -> SummaryMatrix {
        let mut m = SummaryMatrix {
            buf: ScoreBuf::default(),
            host_cores,
            caps: Vec::new(),
        };
        m.buf.reset(MATRIX_LANES, hosts);
        m
    }

    /// Build a bank-less matrix straight from summaries: the CPU load
    /// column is the published `est_cpu_load`, the other resource
    /// columns 0 (no bank to derive them from). The scalar
    /// `ArrivalPolicy::pick` shim uses this.
    pub fn from_summaries(summaries: &[HostSummary], host_cores: usize) -> SummaryMatrix {
        let mut m = SummaryMatrix::new(summaries.len(), host_cores);
        m.rebuild_basic(summaries);
        m
    }

    pub fn hosts(&self) -> usize {
        self.buf.width()
    }

    /// Physical cores per host — the CPU column's capacity.
    pub fn host_cores(&self) -> usize {
        self.host_cores
    }

    /// Capacity of `host` on one metric column. Defaults to
    /// `host_cores` for CPU (loads are in units of cores) and 1.0 for
    /// the fractional metrics; heterogeneous per-host vectors installed
    /// via [`Self::set_caps`] override both.
    pub fn cap(&self, host: usize, metric: usize) -> f64 {
        if let Some(caps) = self.caps.get(host) {
            caps[metric]
        } else if metric == 0 {
            self.host_cores as f64
        } else {
            1.0
        }
    }

    /// Install per-host capacity vectors (`[cpu_cores, diskio, netio,
    /// membw]`, same axes as the load columns). An empty vector
    /// restores the homogeneous default.
    pub fn set_caps(&mut self, caps: Vec<MetricVec>) {
        debug_assert!(caps.is_empty() || caps.len() == self.hosts());
        self.caps = caps;
    }

    /// Resident-VM counts, as a dense f64 column.
    pub fn resident(&self) -> &[f64] {
        self.buf.lane(COL_RESIDENT)
    }

    /// Cores currently holding a running VM.
    pub fn busy_cores(&self) -> &[f64] {
        self.buf.lane(COL_BUSY_CORES)
    }

    /// Profile-estimated CPU load (identical to the CPU load column
    /// after a bank-aware rebuild).
    pub fn est_cpu_load(&self) -> &[f64] {
        self.buf.lane(COL_EST_CPU)
    }

    /// Worst per-core workload interference (Eq. 3/4).
    pub fn max_wi(&self) -> &[f64] {
        self.buf.lane(COL_MAX_WI)
    }

    /// One per-resource load column (Σ `U[class][metric]` over the
    /// host's running VMs).
    pub fn load(&self, metric: usize) -> &[f64] {
        self.buf.lane(COL_LOAD0 + metric)
    }

    /// Free capacity of `host` on `metric`, clamped at 0.
    pub fn free(&self, host: usize, metric: usize) -> f64 {
        (self.cap(host, metric) - self.load(metric)[host]).max(0.0)
    }

    /// Rebuild every column from summaries, deriving the per-resource
    /// load columns from the running classes' profile rows.
    pub fn rebuild(&mut self, summaries: &[HostSummary], bank: &ProfileBank) {
        self.buf.reset(MATRIX_LANES, summaries.len());
        for (h, s) in summaries.iter().enumerate() {
            self.set_basic(h, s);
            for &(_, class) in &s.running {
                let u = bank.u[class.index()];
                for m in 0..NUM_METRICS {
                    self.buf.lane_mut(COL_LOAD0 + m)[h] += u[m];
                }
            }
        }
    }

    /// Bank-less rebuild: load columns carry only the published
    /// `est_cpu_load` on the CPU lane.
    pub fn rebuild_basic(&mut self, summaries: &[HostSummary]) {
        self.buf.reset(MATRIX_LANES, summaries.len());
        for (h, s) in summaries.iter().enumerate() {
            self.set_basic(h, s);
            self.buf.lane_mut(COL_LOAD0)[h] = s.est_cpu_load;
        }
    }

    fn set_basic(&mut self, h: usize, s: &HostSummary) {
        self.buf.lane_mut(COL_RESIDENT)[h] = s.resident as f64;
        self.buf.lane_mut(COL_BUSY_CORES)[h] = s.busy_cores as f64;
        self.buf.lane_mut(COL_EST_CPU)[h] = s.est_cpu_load;
        self.buf.lane_mut(COL_MAX_WI)[h] = s.max_wi;
    }

    /// Live within-tick update for a routed arrival: one more resident,
    /// its demand charged to the load (and estimated-CPU) columns.
    pub fn note_arrival(&mut self, host: usize, demand: &MetricVec) {
        self.buf.lane_mut(COL_RESIDENT)[host] += 1.0;
        self.buf.lane_mut(COL_EST_CPU)[host] += demand[0];
        for (m, &d) in demand.iter().enumerate() {
            self.buf.lane_mut(COL_LOAD0 + m)[host] += d;
        }
    }

    /// Live within-tick update for a departure: one fewer resident.
    /// The load columns catch up at the next bank-aware rebuild (the
    /// departing VM's class is not known here).
    pub fn note_departure(&mut self, host: usize) {
        let r = &mut self.buf.lane_mut(COL_RESIDENT)[host];
        *r = (*r - 1.0).max(0.0);
    }

    /// Live within-tick update for a migrated-in VM: one more resident
    /// (loads catch up at the next rebuild, mirroring the summaries).
    pub fn note_transfer_in(&mut self, host: usize) {
        self.buf.lane_mut(COL_RESIDENT)[host] += 1.0;
    }
}

/// What one host reports back after draining its inbox and stepping.
#[derive(Debug, Clone)]
pub struct TickReport {
    pub summary: HostSummary,
    /// ≥ 1 busy core at the last ledger sample (host-hours integral).
    pub busy_now: bool,
    /// All batch workloads on this host have finished.
    pub batch_done: bool,
}

/// Routing counters, drained by cluster-level reporting.
#[derive(Debug, Clone, Copy, Default)]
pub struct BusStats {
    /// Cluster events routed (each may expand to several deliveries).
    pub events_routed: u64,
    pub migrations_started: u64,
    pub migrations_completed: u64,
    /// Transfers that aborted (pre-copy never converged); the VM stayed
    /// on its source host.
    pub migrations_failed: u64,
}

/// The dispatcher: a FIFO of published [`ClusterEvent`]s, per-host
/// inboxes of routed [`HostEvent`]s, the in-flight migration transfers,
/// and the per-host [`HostSummary`]s published by the last tick.
pub struct EventBus {
    queue: VecDeque<ClusterEvent>,
    inboxes: Vec<Vec<HostEvent>>,
    inflight: Vec<Migration>,
    summaries: Vec<HostSummary>,
    /// Columnar mirror of `summaries`, kept in lockstep (rebuilt on
    /// refresh/prime, live-bumped as events route) — what batched
    /// ranking reads.
    matrix: SummaryMatrix,
    /// Reusable buffers for the batched ranking pass, so a steady-state
    /// route() allocates nothing.
    score_buf: ScoreBuf,
    batch: ArrivalBatch,
    picks: Vec<usize>,
    model: MigrationModel,
    /// Physical cores per host (destination-business normaliser for the
    /// migration abort draw).
    host_cores: usize,
    /// Placement log of this routing window: `(vm, host)` for every
    /// policy-ranked arrival, forced arrival, and completed migration —
    /// how external drivers (trace replay) learn where the bus put each
    /// VM without reaching into engine state. Drained by
    /// [`Self::take_moves`]; aborted migrations never log (the VM stayed
    /// on its source).
    moves: Vec<(VmId, usize)>,
    pub stats: BusStats,
}

impl EventBus {
    pub fn new(hosts: usize, model: MigrationModel, host_cores: usize) -> EventBus {
        EventBus {
            queue: VecDeque::new(),
            inboxes: (0..hosts).map(|_| Vec::new()).collect(),
            inflight: Vec::new(),
            summaries: vec![HostSummary::default(); hosts],
            matrix: SummaryMatrix::new(hosts, host_cores),
            score_buf: ScoreBuf::default(),
            batch: ArrivalBatch::default(),
            picks: Vec::new(),
            model,
            host_cores,
            moves: Vec::new(),
            stats: BusStats::default(),
        }
    }

    /// Drain the placement log: every `(vm, host)` the bus decided since
    /// the last drain. See the `moves` field.
    pub fn take_moves(&mut self) -> Vec<(VmId, usize)> {
        std::mem::take(&mut self.moves)
    }

    /// Install per-host capacity vectors on the ranking matrix (see
    /// [`SummaryMatrix::set_caps`]).
    pub fn set_host_caps(&mut self, caps: Vec<MetricVec>) {
        self.matrix.set_caps(caps);
    }

    pub fn hosts(&self) -> usize {
        self.inboxes.len()
    }

    /// The per-host summaries published by the last tick (plus any
    /// within-tick routing increments).
    pub fn summaries(&self) -> &[HostSummary] {
        &self.summaries
    }

    /// The columnar mirror of [`Self::summaries`] — the batched
    /// ranking surface, kept in lockstep with the scalar summaries.
    pub fn matrix(&self) -> &SummaryMatrix {
        &self.matrix
    }

    /// Seed the published summaries before the first tick (hosts built
    /// with pre-existing residents would otherwise all look empty to
    /// arrival policies until the first refresh). `est_cpu_load` stays
    /// whatever the caller captured — typically 0 until a bank-aware
    /// [`Self::refresh`] runs.
    pub fn prime(&mut self, summaries: Vec<HostSummary>) {
        debug_assert_eq!(summaries.len(), self.hosts());
        self.summaries = summaries;
        self.matrix.rebuild_basic(&self.summaries);
    }

    /// Migration transfers currently in flight.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// The VMs whose transfers are currently in flight — what the
    /// migration planner must never select again while they travel.
    pub fn in_flight_vms(&self) -> impl Iterator<Item = VmId> + '_ {
        self.inflight.iter().map(|m| m.vm)
    }

    /// Enqueue one cluster event for the next [`Self::route`] pass.
    pub fn publish(&mut self, ev: ClusterEvent) {
        self.queue.push_back(ev);
    }

    /// Route every queued event into the per-host inboxes, preserving
    /// publish-order semantics. Consecutive policy-routed arrivals
    /// accumulate into one [`ArrivalBatch`] and go through a single
    /// batched [`ArrivalPolicy::rank`] call over the live
    /// [`SummaryMatrix`]; any other event (or a forced-host arrival) is
    /// a barrier that flushes the pending batch first, so interleaved
    /// departures/migrations see exactly the state they would have
    /// under per-arrival dispatch. Migrations open their transfer
    /// window (network load on both ends now, the move itself once
    /// [`Self::advance`] matures the transfer).
    ///
    /// `bank` supplies each arrival's demand row: routing charges it to
    /// the live summary/matrix columns (`est_cpu_load` included), so a
    /// same-tick burst spreads by estimated load, not just residents.
    pub fn route(
        &mut self,
        policy: &mut dyn ArrivalPolicy,
        bank: &ProfileBank,
        rng: &mut Rng,
    ) -> Result<()> {
        let hosts = self.hosts();
        let mut pending: Vec<Vm> = Vec::new();
        while let Some(ev) = self.queue.pop_front() {
            self.stats.events_routed += 1;
            match ev {
                ClusterEvent::Arrival { vm, host: None } => pending.push(vm),
                ClusterEvent::Arrival { vm, host: Some(h) } => {
                    self.flush_batch(&mut pending, policy, bank, rng)?;
                    anyhow::ensure!(h < hosts, "arrival routed to host {h} of {hosts}");
                    self.note_arrival(h, vm.class, bank);
                    self.moves.push((vm.id, h));
                    self.inboxes[h].push(HostEvent::Arrival(vm));
                }
                ClusterEvent::Departure { host, vm } => {
                    self.flush_batch(&mut pending, policy, bank, rng)?;
                    anyhow::ensure!(host < hosts, "departure on host {host} of {hosts}");
                    let s = &mut self.summaries[host];
                    s.resident = s.resident.saturating_sub(1);
                    self.matrix.note_departure(host);
                    self.inboxes[host].push(HostEvent::Depart(vm));
                }
                ClusterEvent::Sched { host, ev } => {
                    self.flush_batch(&mut pending, policy, bank, rng)?;
                    anyhow::ensure!(host < hosts, "sched event on host {host} of {hosts}");
                    self.inboxes[host].push(HostEvent::Sched(ev));
                }
                ClusterEvent::Migrate { vm, src, dst } => {
                    self.flush_batch(&mut pending, policy, bank, rng)?;
                    anyhow::ensure!(src < hosts && dst < hosts, "migration {src}->{dst}");
                    anyhow::ensure!(src != dst, "migration to the same host {src}");
                    let dest_busy = self.summaries[dst].est_cpu_load / self.host_cores as f64;
                    let mig = self.model.start(vm, src, dst, dest_busy, rng);
                    self.inboxes[src].push(HostEvent::NetLoad(self.model.transfer_net));
                    self.inboxes[dst].push(HostEvent::NetLoad(self.model.transfer_net));
                    self.inflight.push(mig);
                    self.stats.migrations_started += 1;
                }
            }
        }
        self.flush_batch(&mut pending, policy, bank, rng)
    }

    /// Rank the pending arrival batch in one [`ArrivalPolicy::rank`]
    /// call and route each VM to its ranked host, charging the live
    /// summary and matrix columns per pick.
    fn flush_batch(
        &mut self,
        pending: &mut Vec<Vm>,
        policy: &mut dyn ArrivalPolicy,
        bank: &ProfileBank,
        rng: &mut Rng,
    ) -> Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let hosts = self.hosts();
        self.batch.clear();
        for vm in pending.iter() {
            self.batch.push_class(vm.class, bank);
        }
        policy.rank(&self.matrix, &self.batch, &mut self.score_buf, rng, &mut self.picks);
        anyhow::ensure!(
            self.picks.len() == pending.len(),
            "policy ranked {} of {} batched arrivals",
            self.picks.len(),
            pending.len()
        );
        for (i, vm) in pending.drain(..).enumerate() {
            let h = self.picks[i];
            anyhow::ensure!(h < hosts, "arrival routed to host {h} of {hosts}");
            self.note_arrival(h, vm.class, bank);
            self.moves.push((vm.id, h));
            self.inboxes[h].push(HostEvent::Arrival(vm));
        }
        Ok(())
    }

    /// Charge one routed arrival to the live views: the scalar summary
    /// (resident + profile-estimated CPU load) and every matrix column.
    fn note_arrival(&mut self, host: usize, class: WorkloadClass, bank: &ProfileBank) {
        let demand = bank.u[class.index()];
        let s = &mut self.summaries[host];
        s.resident += 1;
        s.est_cpu_load += demand[0];
        self.matrix.note_arrival(host, &demand);
    }

    /// Advance in-flight transfers by `dt`; matured ones are removed and
    /// returned (in start order) for [`Self::extraction_requests`] +
    /// [`Self::deliver`].
    pub fn advance(&mut self, dt: f64) -> Vec<Migration> {
        for m in &mut self.inflight {
            m.remaining -= dt;
        }
        let (matured, keep): (Vec<Migration>, Vec<Migration>) = self
            .inflight
            .drain(..)
            .partition(|m| m.remaining <= 0.0);
        self.inflight = keep;
        matured
    }

    /// Which VMs the matured transfers pull off their source hosts. Only
    /// successful transfers extract — a doomed (aborted) transfer leaves
    /// the VM where it was.
    pub fn extraction_requests(matured: &[Migration]) -> Vec<(usize, VmId)> {
        matured
            .iter()
            .filter(|m| !m.doomed)
            .map(|m| (m.from_host, m.vm))
            .collect()
    }

    /// Finish matured transfers: close the transfer window on both ends
    /// and route each extracted VM into its destination, paused for the
    /// stop-and-copy downtime. `extracted` is the result of
    /// [`super::pool::ShardPool::extract`] over
    /// [`Self::extraction_requests`], in the same order.
    pub fn deliver(&mut self, matured: Vec<Migration>, extracted: Vec<Option<Vm>>, now: f64) {
        let mut pulled = extracted.into_iter();
        for m in matured {
            self.inboxes[m.from_host].push(HostEvent::NetLoad(-self.model.transfer_net));
            self.inboxes[m.to_host].push(HostEvent::NetLoad(-self.model.transfer_net));
            if m.doomed {
                self.stats.migrations_failed += 1;
                continue;
            }
            let Some(vm) = pulled.next().flatten() else {
                // The VM vanished from the source mid-transfer (e.g. a
                // concurrent departure); nothing to move.
                continue;
            };
            // A Departure routed this same tick wins over the move: the
            // cluster was told to tear the VM down, so the extracted VM
            // is dropped instead of resurrected on the destination (the
            // inbox Depart becomes a no-op and already adjusted the
            // resident view).
            let departing = self.inboxes[m.from_host]
                .iter()
                .any(|ev| matches!(ev, HostEvent::Depart(id) if *id == vm.id));
            if departing {
                continue;
            }
            let pause = (vm.state == VmState::Running).then_some(now + self.model.downtime);
            self.summaries[m.from_host].resident =
                self.summaries[m.from_host].resident.saturating_sub(1);
            self.summaries[m.to_host].resident += 1;
            self.matrix.note_departure(m.from_host);
            self.matrix.note_transfer_in(m.to_host);
            self.moves.push((vm.id, m.to_host));
            self.inboxes[m.to_host].push(HostEvent::MigrateIn {
                vm,
                pause_until: pause,
            });
            self.stats.migrations_completed += 1;
        }
    }

    /// Take the routed inboxes for this tick (leaving them empty), one
    /// per host in host order — the shard pool's step input.
    pub fn take_inboxes(&mut self) -> Vec<Vec<HostEvent>> {
        self.inboxes.iter_mut().map(std::mem::take).collect()
    }

    /// Publish fresh per-host summaries from the tick reports, deriving
    /// the profile-estimated CPU load from `bank`, and rebuild the
    /// columnar [`SummaryMatrix`] (per-resource load columns included)
    /// in lockstep.
    pub fn refresh(&mut self, reports: &[TickReport], bank: &ProfileBank) {
        for (h, report) in reports.iter().enumerate() {
            let mut s = report.summary.clone();
            s.est_cpu_load = s
                .running
                .iter()
                .map(|&(_, class)| bank.u[class.index()][0])
                .sum();
            self.summaries[h] = s;
        }
        self.matrix.rebuild(&self.summaries, bank);
    }
}

/// Apply one routed delivery to a host through its [`HostHandle`]
/// surface — the only place bus deliveries touch host state, shared by
/// every step mode so pool workers and the caller thread behave
/// identically.
pub fn apply_host_event(host: &mut dyn HostHandle, ev: HostEvent) -> Result<()> {
    match ev {
        HostEvent::Arrival(vm) => host.inject_arrival(vm),
        HostEvent::MigrateIn { vm, pause_until } => host.accept_migrant(vm, pause_until),
        HostEvent::Depart(id) => host.remove_resident(id).map(|_| ()),
        HostEvent::Sched(ev) => host.inject_event(ev),
        HostEvent::NetLoad(delta) => {
            host.add_external_net_load(delta);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dispatch::Dispatcher;
    use crate::cluster::host::{NativeHost, SimHost};
    use crate::hostsim::{ActivityModel, SimEngine};
    use crate::testkit;
    use crate::vmcd::scheduler::{self, Policy};
    use crate::vmcd::Daemon;
    use crate::workloads::WorkloadClass;

    fn native_host(policy: Policy) -> NativeHost {
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build_native(policy, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon))
    }

    fn running_vm(id: u32, class: WorkloadClass) -> Vm {
        let mut vm = Vm::new(VmId(id), class, 0.0, ActivityModel::AlwaysOn);
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm
    }

    #[test]
    fn arrivals_route_to_the_policy_pick_and_bump_summaries() {
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(3, MigrationModel::default(), 12);
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        for i in 0..3 {
            bus.publish(ClusterEvent::Arrival {
                vm: running_vm(i, WorkloadClass::Hadoop),
                host: None,
            });
        }
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        // Same-tick arrivals spread out because routing bumps the live
        // resident view between picks.
        let counts: Vec<usize> = bus.summaries().iter().map(|s| s.resident).collect();
        assert_eq!(counts, vec![1, 1, 1]);
        let inboxes = bus.take_inboxes();
        assert!(inboxes.iter().all(|i| i.len() == 1));
        assert_eq!(bus.stats.events_routed, 3);
    }

    #[test]
    fn forced_host_and_bad_host_indices() {
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, MigrationModel::default(), 12);
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(1);
        bus.publish(ClusterEvent::Arrival {
            vm: running_vm(0, WorkloadClass::Jacobi),
            host: Some(1),
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        assert_eq!(bus.summaries()[1].resident, 1);
        bus.publish(ClusterEvent::Sched {
            host: 7,
            ev: SchedEvent::Tick,
        });
        assert!(bus.route(policy.as_mut(), bank, &mut rng).is_err());
    }

    #[test]
    fn migrate_expands_to_departure_plus_delayed_arrival() {
        // The tentpole semantics: Migrate {vm, src, dst} opens the
        // transfer window now (network load both ends), then after
        // `transfer_secs` the VM departs the source and arrives paused on
        // the destination.
        let model = MigrationModel {
            downtime: 3.0,
            transfer_secs: 2.0,
            transfer_net: 0.25,
            failure_prob: 0.0,
        };
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, model.clone(), 12);
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(9);

        let mut src = native_host(Policy::Ias);
        let mut dst = native_host(Policy::Ias);
        src.inject_arrival(running_vm(5, WorkloadClass::Blackscholes))
            .unwrap();
        // Warm the monitoring window so the migrant is adopted as a
        // running workload on the destination, not parked as idle.
        for _ in 0..12 {
            src.step_host().unwrap();
        }
        assert_eq!(src.daemon.as_ref().unwrap().placement_state().placed(), 1);

        bus.publish(ClusterEvent::Migrate {
            vm: VmId(5),
            src: 0,
            dst: 1,
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        assert_eq!(bus.in_flight(), 1);
        assert_eq!(bus.stats.migrations_started, 1);

        // Transfer window open: both ends carry the network load.
        let mut inboxes = bus.take_inboxes();
        for (host, inbox) in [(&mut src, inboxes.remove(0)), (&mut dst, inboxes.remove(0))] {
            for ev in inbox {
                apply_host_event(host, ev).unwrap();
            }
        }
        assert_eq!(src.engine().external_net_load, model.transfer_net);
        assert_eq!(dst.engine().external_net_load, model.transfer_net);

        // First second: still in flight.
        assert!(bus.advance(1.0).is_empty());
        // Second second: matured. Extract from src, deliver to dst.
        let matured = bus.advance(1.0);
        assert_eq!(matured.len(), 1);
        let reqs = EventBus::extraction_requests(&matured);
        assert_eq!(reqs, vec![(0, VmId(5))]);
        let vm = src.remove_resident(VmId(5)).unwrap();
        assert!(vm.is_some());
        // Departure bookkeeping: the source daemon's placement state
        // dropped the member immediately (no monitor-poll wait).
        assert_eq!(src.daemon.as_ref().unwrap().placement_state().placed(), 0);

        let now = 2.0;
        bus.deliver(matured, vec![vm], now);
        let mut inboxes = bus.take_inboxes();
        for ev in inboxes.remove(0) {
            apply_host_event(&mut src, ev).unwrap();
        }
        for ev in inboxes.remove(0) {
            apply_host_event(&mut dst, ev).unwrap();
        }
        // Window closed on both ends; VM resident on dst, paused for the
        // stop-and-copy downtime, and adopted by the destination daemon.
        assert_eq!(src.engine().external_net_load, 0.0);
        assert_eq!(dst.engine().external_net_load, 0.0);
        assert_eq!(dst.engine().vms.len(), 1);
        assert_eq!(dst.engine().vms[0].id, VmId(5));
        assert_eq!(dst.engine().vms[0].paused_until, now + model.downtime);
        assert_eq!(dst.daemon.as_ref().unwrap().placement_state().placed(), 1);
        assert_eq!(bus.stats.migrations_completed, 1);
        assert_eq!(bus.stats.migrations_failed, 0);
    }

    #[test]
    fn same_tick_departure_wins_over_a_maturing_migration() {
        // A VM torn down in the very tick its transfer matures must not
        // be resurrected on the destination.
        let model = MigrationModel {
            downtime: 3.0,
            transfer_secs: 1.0,
            transfer_net: 0.25,
            failure_prob: 0.0,
        };
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, model, 12);
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(3);
        bus.publish(ClusterEvent::Migrate {
            vm: VmId(1),
            src: 0,
            dst: 1,
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let _ = bus.take_inboxes();
        // Next tick: the teardown lands just as the transfer matures.
        bus.publish(ClusterEvent::Departure {
            host: 0,
            vm: VmId(1),
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let matured = bus.advance(1.0);
        assert_eq!(matured.len(), 1);
        let mut vm = running_vm(1, WorkloadClass::Hadoop);
        vm.pinned = Some(0);
        bus.deliver(matured, vec![Some(vm)], 1.0);
        let inboxes = bus.take_inboxes();
        // Destination sees only the transfer-window close, never the VM.
        assert!(inboxes[1]
            .iter()
            .all(|ev| matches!(ev, HostEvent::NetLoad(_))));
        assert_eq!(bus.stats.migrations_completed, 0);
    }

    #[test]
    fn doomed_transfer_leaves_the_vm_on_the_source() {
        let model = MigrationModel {
            downtime: 3.0,
            transfer_secs: 1.0,
            transfer_net: 0.25,
            failure_prob: 1.0,
        };
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, model, 12);
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(2);
        // A saturated destination guarantees the abort draw (p clamps to
        // 0.9), so try until one dooms — seed 2 dooms on the first draw
        // at full business, but don't depend on that.
        let mut doomed_seen = false;
        for _ in 0..64 {
            bus.summaries[1].est_cpu_load = 12.0; // fully busy destination
            bus.publish(ClusterEvent::Migrate {
                vm: VmId(0),
                src: 0,
                dst: 1,
            });
            bus.route(policy.as_mut(), bank, &mut rng).unwrap();
            let matured = bus.advance(1.0);
            assert_eq!(matured.len(), 1);
            let doomed = matured[0].doomed;
            assert!(EventBus::extraction_requests(&matured).is_empty() == doomed);
            bus.deliver(matured, Vec::new(), 1.0);
            let _ = bus.take_inboxes();
            if doomed {
                doomed_seen = true;
                break;
            }
        }
        assert!(doomed_seen, "0.9 abort probability never fired in 64 draws");
        assert_eq!(bus.stats.migrations_failed, 1);
    }

    #[test]
    fn forced_abort_keeps_placement_but_charges_the_transfer_window() {
        // An aborted live migration end to end: the VM never leaves the
        // source daemon's placement, the destination never counts a
        // migrant in — but both ends still paid the transfer-window
        // network load while the doomed copy ran.
        let model = MigrationModel {
            downtime: 3.0,
            transfer_secs: 1.0,
            transfer_net: 0.25,
            failure_prob: 1.0,
        };
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, model.clone(), 12);
        let mut policy = Dispatcher::RoundRobin.build();
        // Seed 2 dooms the very first abort draw against a saturated
        // destination — the same deterministic stream the
        // doomed-transfer test above documents.
        let mut rng = Rng::new(2);

        let mut src = native_host(Policy::Ias);
        let mut dst = native_host(Policy::Ias);
        src.inject_arrival(running_vm(5, WorkloadClass::Blackscholes)).unwrap();
        for _ in 0..12 {
            src.step_host().unwrap();
        }
        let placed_before = src.daemon.as_ref().unwrap().placement_state().placed();

        bus.summaries[1].est_cpu_load = 12.0; // saturated destination
        bus.publish(ClusterEvent::Migrate {
            vm: VmId(5),
            src: 0,
            dst: 1,
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let mut inboxes = bus.take_inboxes();
        for (host, inbox) in [(&mut src, inboxes.remove(0)), (&mut dst, inboxes.remove(0))] {
            for ev in inbox {
                apply_host_event(host, ev).unwrap();
            }
        }
        // Transfer window open: the copy's network load lands both ends.
        assert_eq!(src.engine().external_net_load, model.transfer_net);
        assert_eq!(dst.engine().external_net_load, model.transfer_net);

        let matured = bus.advance(1.0);
        assert_eq!(matured.len(), 1);
        assert!(matured[0].doomed, "seed 2 must doom the first draw at p=0.9");
        assert!(EventBus::extraction_requests(&matured).is_empty());
        bus.deliver(matured, Vec::new(), 1.0);
        let mut inboxes = bus.take_inboxes();
        for (host, inbox) in [(&mut src, inboxes.remove(0)), (&mut dst, inboxes.remove(0))] {
            for ev in inbox {
                apply_host_event(host, ev).unwrap();
            }
        }
        // Window released on the abort; placement exactly as before.
        assert_eq!(src.engine().external_net_load, 0.0);
        assert_eq!(dst.engine().external_net_load, 0.0);
        assert_eq!(src.engine().vms.len(), 1);
        assert_eq!(src.engine().vms[0].id, VmId(5));
        assert_eq!(
            src.daemon.as_ref().unwrap().placement_state().placed(),
            placed_before
        );
        assert_eq!(dst.engine().vms.len(), 0);
        assert_eq!(dst.metrics().migrants_in, 0, "aborts never land");
        assert_eq!(bus.stats.migrations_failed, 1);
        assert_eq!(bus.stats.migrations_completed, 0);
    }

    #[test]
    fn same_tick_burst_spreads_by_estimated_load_not_just_residents() {
        // Regression for the HostSummary same-tick staleness bug: routing
        // an arrival used to bump `resident` but not `est_cpu_load`, so a
        // burst under lowest-interference stacked onto a host that merely
        // *started* with fewer residents. Host 1 starts with 5 residents,
        // host 0 with none; with live est_cpu_load charging, the burst's
        // picks alternate on the load tie-break instead of all four
        // stacking host 0 via the resident tie-break.
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, MigrationModel::default(), 12);
        bus.prime(vec![
            HostSummary::default(),
            HostSummary {
                resident: 5,
                ..HostSummary::default()
            },
        ]);
        let mut policy = Dispatcher::LowestInterference.build();
        let mut rng = Rng::new(1);
        for i in 0..4 {
            bus.publish(ClusterEvent::Arrival {
                vm: running_vm(i, WorkloadClass::Hadoop),
                host: None,
            });
        }
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let counts: Vec<usize> = bus.summaries().iter().map(|s| s.resident).collect();
        assert_eq!(counts, vec![2, 7], "burst must spread by estimated load");
        let u_cpu = bank.u[WorkloadClass::Hadoop.index()][0];
        assert!((bus.summaries()[0].est_cpu_load - 2.0 * u_cpu).abs() < 1e-12);
        assert!((bus.matrix().est_cpu_load()[0] - 2.0 * u_cpu).abs() < 1e-12);
    }

    #[test]
    fn per_host_caps_override_the_homogeneous_default_and_survive_rebuild() {
        let mut m = SummaryMatrix::new(2, 12);
        assert_eq!(m.cap(0, 0), 12.0);
        assert_eq!(m.cap(1, 2), 1.0);
        m.set_caps(vec![[16.0, 2.0, 1.0, 4.0], [8.0, 1.0, 0.5, 2.0]]);
        assert_eq!(m.cap(0, 0), 16.0);
        assert_eq!(m.cap(1, 3), 2.0);
        // Rebuild resets the tick lanes but never the configuration.
        m.rebuild_basic(&[HostSummary::default(), HostSummary::default()]);
        assert_eq!(m.cap(1, 2), 0.5);
        assert_eq!(m.free(1, 2), 0.5);
        m.set_caps(Vec::new());
        assert_eq!(m.cap(0, 0), 12.0);
    }

    #[test]
    fn take_moves_logs_where_every_arrival_landed() {
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(3, MigrationModel::default(), 12);
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        for i in 0..3 {
            bus.publish(ClusterEvent::Arrival {
                vm: running_vm(i, WorkloadClass::Hadoop),
                host: None,
            });
        }
        bus.publish(ClusterEvent::Arrival {
            vm: running_vm(9, WorkloadClass::Jacobi),
            host: Some(2),
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let moves = bus.take_moves();
        assert_eq!(moves.len(), 4);
        assert_eq!(moves[3], (VmId(9), 2), "forced arrival logs its host");
        let mut ids: Vec<u32> = moves.iter().map(|&(VmId(id), _)| id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 9]);
        assert!(moves.iter().all(|&(_, h)| h < 3));
        assert!(bus.take_moves().is_empty(), "drain leaves the log empty");
    }

    #[test]
    fn matrix_mirrors_summaries_through_refresh_and_routing() {
        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, MigrationModel::default(), 12);
        // A refresh publishes summaries and rebuilds the matrix columns
        // (per-resource loads derived from the running classes).
        let reports: Vec<TickReport> = [
            vec![(VmId(0), WorkloadClass::Jacobi), (VmId(1), WorkloadClass::Hadoop)],
            vec![(VmId(2), WorkloadClass::StreamLow)],
        ]
        .into_iter()
        .map(|running| TickReport {
            summary: HostSummary {
                resident: running.len(),
                busy_cores: running.len(),
                max_wi: 0.25,
                running,
                ..HostSummary::default()
            },
            busy_now: true,
            batch_done: false,
        })
        .collect();
        bus.refresh(&reports, bank);

        let m = bus.matrix();
        assert_eq!(m.hosts(), 2);
        assert_eq!(m.resident(), vec![2.0, 1.0]);
        assert_eq!(m.busy_cores(), vec![2.0, 1.0]);
        for h in 0..2 {
            assert_eq!(m.max_wi()[h], 0.25);
            // The CPU load column equals the published est_cpu_load, and
            // every metric column is the Σ of the running classes' rows.
            assert!((m.load(0)[h] - bus.summaries()[h].est_cpu_load).abs() < 1e-12);
            for metric in 0..NUM_METRICS {
                let want: f64 = bus.summaries()[h]
                    .running
                    .iter()
                    .map(|&(_, class)| bank.u[class.index()][metric])
                    .sum();
                assert!((m.load(metric)[h] - want).abs() < 1e-12);
                assert!(m.free(h, metric) <= m.cap(h, metric));
            }
        }

        // Routing a policy-less (forced) arrival keeps the mirror live.
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(4);
        bus.publish(ClusterEvent::Arrival {
            vm: running_vm(9, WorkloadClass::Jacobi),
            host: Some(1),
        });
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        assert_eq!(bus.matrix().resident()[1], 2.0);
        let u = bank.u[WorkloadClass::Jacobi.index()];
        for metric in 0..NUM_METRICS {
            let base: f64 = reports[1]
                .summary
                .running
                .iter()
                .map(|&(_, class)| bank.u[class.index()][metric])
                .sum();
            assert!((bus.matrix().load(metric)[1] - (base + u[metric])).abs() < 1e-12);
        }
    }
}

//! Live-migration cost model.
//!
//! §III: migration-based global consolidation is "technically unreliable
//! and proportionately more expensive in terms of migration time and
//! resource usage" when the infrastructure is oversubscribed. The model
//! captures exactly those two costs:
//!
//! * **downtime** — the VM makes no progress for `downtime` seconds
//!   (stop-and-copy window);
//! * **transfer load** — for `transfer_secs` seconds both the source and
//!   destination hosts carry extra NetIO (`transfer_net` of host
//!   capacity), contending with resident workloads;
//! * **failure** — under a loaded destination the migration aborts with
//!   probability `failure_prob` (pre-copy never converges), wasting the
//!   transfer load without moving the VM.
//!
//! Since the cluster-event redesign the model never touches hosts
//! itself: a `ClusterEvent::Migrate` routed through the
//! [`super::bus::EventBus`] opens the transfer window (network load on
//! both ends), and the matured [`Migration`] expands into a departure
//! on the source plus a delayed, downtime-paused arrival on the
//! destination.

use crate::hostsim::VmId;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct MigrationModel {
    /// VM stall, seconds (stop-and-copy).
    pub downtime: f64,
    /// Duration of the pre-copy transfer, seconds.
    pub transfer_secs: f64,
    /// Extra NetIO on both hosts during transfer (fraction of capacity).
    pub transfer_net: f64,
    /// Probability a migration to a busy destination aborts.
    pub failure_prob: f64,
}

impl Default for MigrationModel {
    fn default() -> Self {
        MigrationModel {
            downtime: 3.0,
            transfer_secs: 20.0,
            transfer_net: 0.30,
            failure_prob: 0.15,
        }
    }
}

/// An in-flight migration transfer (owned by the event bus).
#[derive(Debug, Clone)]
pub struct Migration {
    pub vm: VmId,
    pub from_host: usize,
    pub to_host: usize,
    /// Remaining transfer seconds.
    pub remaining: f64,
    /// Whether this migration will abort at the end of transfer.
    pub doomed: bool,
}

impl MigrationModel {
    /// Deterministic *planning* estimate of one transfer's duration:
    /// the pre-copy window stretched by the extra network load the
    /// copy itself adds, weighted by the VM's share of its host
    /// (`vm_frac` = estimated VM demand / host CPU capacity). No RNG —
    /// the payback gate in [`super::migrator::planner`] must not
    /// perturb the simulation's random stream.
    pub fn est_transfer_secs(&self, vm_frac: f64) -> f64 {
        self.transfer_secs * (1.0 + self.transfer_net * vm_frac.clamp(0.0, 1.0))
    }

    /// Start a migration; destination business decides the failure draw.
    pub fn start(
        &self,
        vm: VmId,
        from_host: usize,
        to_host: usize,
        dest_busy_fraction: f64,
        rng: &mut Rng,
    ) -> Migration {
        // Failure risk scales with how busy the destination already is —
        // the paper's "unreliable when the infrastructure is
        // oversubscribed".
        let p = self.failure_prob * dest_busy_fraction.clamp(0.0, 1.0) * 2.0;
        Migration {
            vm,
            from_host,
            to_host,
            remaining: self.transfer_secs,
            doomed: rng.chance(p.min(0.9)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_destination_rarely_fails() {
        let m = MigrationModel::default();
        let mut rng = Rng::new(1);
        let doomed = (0..1000)
            .filter(|_| m.start(VmId(0), 0, 1, 0.0, &mut rng).doomed)
            .count();
        assert_eq!(doomed, 0, "zero-busy destination must never abort");
    }

    #[test]
    fn saturated_destination_fails_often() {
        let m = MigrationModel::default();
        let mut rng = Rng::new(2);
        let doomed = (0..1000)
            .filter(|_| m.start(VmId(0), 0, 1, 1.0, &mut rng).doomed)
            .count();
        // p = 0.30 at full business.
        assert!((200..400).contains(&doomed), "{doomed}");
    }

    #[test]
    fn transfer_estimate_scales_with_vm_share_and_clamps() {
        let m = MigrationModel::default(); // 20 s, 0.30 net
        assert_eq!(m.est_transfer_secs(0.0), 20.0);
        assert!((m.est_transfer_secs(0.5) - 23.0).abs() < 1e-12);
        assert_eq!(m.est_transfer_secs(1.0), 26.0);
        assert_eq!(m.est_transfer_secs(7.0), 26.0, "share clamps at 1");
        assert_eq!(m.est_transfer_secs(-3.0), 20.0, "share clamps at 0");
    }

    #[test]
    fn migration_carries_transfer_state() {
        let m = MigrationModel::default();
        let mut rng = Rng::new(3);
        let mig = m.start(VmId(7), 2, 5, 0.5, &mut rng);
        assert_eq!(mig.vm, VmId(7));
        assert_eq!((mig.from_host, mig.to_host), (2, 5));
        assert_eq!(mig.remaining, m.transfer_secs);
    }
}

//! Trace-driven cluster workloads: dataset readers, a seeded synthetic
//! generator, and the replay driver that feeds either through the
//! [`ClusterEvent`](super::bus::ClusterEvent) bus.
//!
//! Every scenario before this subsystem was synthetic and small; traces
//! are how the cluster layer gets exercised at the "hundreds of
//! thousands of VM events across thousands of hosts" scale the ROADMAP
//! asks for. The design mirrors dslab-iaas's dataset-reader extensions
//! (`DatasetReader` + `azure_dataset_reader`/`huawei_dataset_reader`):
//! a streaming [`TraceReader`] yields time-ordered [`TraceEvent`]s and
//! never materializes the whole trace, so a 100k-event replay holds
//! O(live VMs) state, not O(events).
//!
//! ## Trace file format (CSV)
//!
//! [`csv::CsvTraceReader`] reads the dslab *vm-instances* shape: one
//! header line, then one row per VM, **sorted by `start_time`** (the
//! reader rejects out-of-order rows with a line-numbered error, exactly
//! like a malformed field). Columns:
//!
//! | column       | type | units                | meaning                          |
//! |--------------|------|----------------------|----------------------------------|
//! | `vm_id`      | u32  | —                    | unique VM identifier             |
//! | `vm_type`    | str  | —                    | key into the vm-types file, or a |
//! |              |      |                      | workload-class name directly     |
//! | `start_time` | f64  | seconds (sim ticks)  | arrival instant, non-decreasing  |
//! | `end_time`   | f64  | seconds (sim ticks)  | departure instant; empty or < 0  |
//! |              |      |                      | means "never departs"            |
//!
//! A 5-row example (`vm_type` referencing classes directly, so no
//! vm-types file is needed):
//!
//! ```text
//! vm_id,vm_type,start_time,end_time
//! 0,hadoop,0,340
//! 1,stream-low,2,
//! 2,blackscholes,2,97
//! 3,lamp-heavy,5,610
//! 4,jacobi,9,444
//! ```
//!
//! The optional *vm-types* file maps opaque dataset type ids onto the
//! profile bank (the azure/huawei datasets key instances by a numeric
//! type id whose row carries normalized resource demands). Columns:
//! `type_id,class` (explicit mapping) **or**
//! `type_id,cpu,diskio,netio,membw` — a demand vector matched to the
//! nearest profile-bank `U` row by L2 distance, which is how foreign
//! dataset sizes land on the eight profiled workload classes. The SAP
//! Cloud Infrastructure dataset paper (arXiv:2510.23911) is the
//! motivation for replaying *real* arrival/lifetime marginals: schedulers
//! tuned on uniform synthetic arrivals misrank under production burst
//! and heavy-tail lifetime distributions.
//!
//! ## `synth:` spec grammar
//!
//! [`synth::SyntheticTraceGenerator`] is the seeded stand-in for a real
//! dataset, with the distribution shapes the SAP paper reports:
//! Poisson-burst arrivals (exponential inter-burst gaps, geometric burst
//! sizes), lognormal **or** Pareto lifetimes, and diurnal load
//! modulation. The CLI spec is `synth:key=value[,key=value...]` —
//! unknown keys or malformed values are errors, every key is optional:
//!
//! | key       | default | meaning                                          |
//! |-----------|---------|--------------------------------------------------|
//! | `vms`     | 1000    | total arrivals to emit                           |
//! | `rate`    | 32.0    | mean arrivals per tick (sets the inter-burst gap)|
//! | `burst`   | 4.0     | mean burst size (geometric)                      |
//! | `life`    | 120.0   | lifetime scale, ticks (lognormal median /        |
//! |           |         | Pareto minimum)                                  |
//! | `dist`    | lognormal | lifetime family: `lognormal` or `pareto`       |
//! | `sigma`   | 0.8     | lognormal shape σ                                |
//! | `alpha`   | 1.5     | Pareto tail index α                              |
//! | `lmax`    | 20×life | lifetime cap, ticks (bounds the heavy tail)      |
//! | `diurnal` | 0.5     | arrival modulation amplitude ∈ [0, 1)            |
//! | `period`  | 360.0   | diurnal period, ticks                            |
//! | `migrates`| 0       | extra Migrate events for random live VMs         |
//! | `seed`    | CLI `--seed` | generator seed                              |
//!
//! Example: `synth:vms=50000,rate=32,dist=pareto,alpha=1.6,seed=7`.
//!
//! ## Replay
//!
//! [`replay::replay`] drives a [`ClusterSim`](super::sim::ClusterSim)
//! from any reader: arrivals are published as policy-routed
//! `ClusterEvent::Arrival`s (the dispatcher under test picks the host),
//! departures as `ClusterEvent::Departure` on whichever host the bus
//! routed the VM to (tracked via
//! [`EventBus::take_moves`](super::bus::EventBus::take_moves)), and
//! departure *times* come from the trace — either explicit `Departure`
//! events or, for
//! readers that only stamp `Arrival { lifetime }`, a replay-side due
//! heap. Throughput is reported as sustained bus events/sec end-to-end
//! (routing + batched rank + shard-pool stepping), the headline metric
//! of `benches/trace_replay.rs`.

pub mod csv;
pub mod replay;
pub mod synth;

use crate::workloads::WorkloadClass;
use anyhow::Result;

/// What one trace record does to the cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceOp {
    /// A VM arrives. `lifetime` (ticks from arrival) lets the replay
    /// driver schedule the departure itself when the reader does not
    /// emit explicit [`TraceOp::Departure`] events; `None` means the VM
    /// never departs (or the reader will say so explicitly).
    Arrival {
        class: WorkloadClass,
        lifetime: Option<f64>,
    },
    /// The VM leaves the cluster (end of its traced lifetime).
    Departure,
    /// Live-migrate the VM off its current host; the replay driver
    /// picks the least-resident other host as the destination.
    Migrate,
}

/// One time-ordered trace record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event instant in simulated seconds (= ticks at `dt` = 1).
    pub at_tick: f64,
    /// Trace-scoped VM identifier (unique per arrival).
    pub vm: u32,
    pub op: TraceOp,
}

/// A streaming source of time-ordered [`TraceEvent`]s — the dslab
/// `DatasetReader` surface. Implementations must yield events with
/// non-decreasing `at_tick` (the replay driver rejects regressions) and
/// must not require materializing the full trace.
pub trait TraceReader {
    /// The next event, or `Ok(None)` at end of trace. Errors are
    /// malformed input (line-numbered for file readers).
    fn next_event(&mut self) -> Result<Option<TraceEvent>>;

    /// Whether this reader emits explicit [`TraceOp::Departure`] events
    /// for every finite-lifetime VM. When `false`, the replay driver
    /// schedules departures itself from `Arrival { lifetime }`.
    fn emits_departures(&self) -> bool {
        true
    }
}

/// A pre-built in-memory trace — programmatic traces and tests. Events
/// are yielded in the order given; [`SliceReader::emitting_departures`]
/// controls whether the replay driver trusts it for departures or
/// schedules them from arrival lifetimes.
pub struct SliceReader {
    events: std::vec::IntoIter<TraceEvent>,
    emits_departures: bool,
}

impl SliceReader {
    pub fn new(events: Vec<TraceEvent>) -> SliceReader {
        SliceReader {
            events: events.into_iter(),
            emits_departures: true,
        }
    }

    /// Same, with the explicit-departure contract flipped off: the
    /// replay driver schedules departures from `Arrival { lifetime }`.
    pub fn emitting_departures(mut self, yes: bool) -> SliceReader {
        self.emits_departures = yes;
        self
    }
}

impl TraceReader for SliceReader {
    fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        Ok(self.events.next())
    }

    fn emits_departures(&self) -> bool {
        self.emits_departures
    }
}

/// Build a reader from a CLI `--trace` argument: `synth:spec` builds a
/// [`synth::SyntheticTraceGenerator`] (`seed` is the default when the
/// spec has no `seed=`); anything else is a vm-instances CSV path, with
/// `types_path` the optional vm-types file.
pub fn open(
    arg: &str,
    types_path: Option<&str>,
    seed: u64,
    bank: &crate::profiling::ProfileBank,
) -> Result<Box<dyn TraceReader>> {
    if let Some(spec) = arg.strip_prefix("synth:") {
        anyhow::ensure!(
            types_path.is_none(),
            "--trace-types only applies to file traces, not synth: specs"
        );
        Ok(Box::new(synth::SyntheticTraceGenerator::parse(spec, seed)?))
    } else {
        Ok(Box::new(csv::CsvTraceReader::open(arg, types_path, bank)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_reader_yields_in_order_and_ends() {
        let ev = |at, vm| TraceEvent {
            at_tick: at,
            vm,
            op: TraceOp::Departure,
        };
        let mut r = SliceReader::new(vec![ev(1.0, 0), ev(2.0, 1)]);
        assert!(r.emits_departures());
        assert_eq!(r.next_event().unwrap().unwrap().vm, 0);
        assert_eq!(r.next_event().unwrap().unwrap().vm, 1);
        assert!(r.next_event().unwrap().is_none());
        let r = SliceReader::new(Vec::new()).emitting_departures(false);
        assert!(!r.emits_departures());
    }

    #[test]
    fn open_dispatches_synth_vs_file() {
        let bank = crate::testkit::shared_bank();
        let mut r = open("synth:vms=3,rate=1", None, 9, bank).unwrap();
        assert!(r.next_event().unwrap().is_some());
        assert!(open("synth:vms=bogus", None, 9, bank).is_err());
        assert!(
            open("synth:vms=3", Some("x.csv"), 9, bank).is_err(),
            "types file + synth spec must be rejected"
        );
        assert!(open("/nonexistent/trace.csv", None, 9, bank).is_err());
    }
}

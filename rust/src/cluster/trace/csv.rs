//! CSV dataset reader — the dslab `azure_dataset_reader` /
//! `huawei_dataset_reader` shape mapped onto the profile bank. See the
//! [module docs](super) for the file formats (vm-instances, vm-types,
//! host-classes).
//!
//! The reader streams: one row of lookahead plus a departure heap over
//! *live* VMs, never the whole file. Every rejection — malformed field,
//! unknown type, out-of-order `start_time`, duplicate `vm_id` — is a
//! line-numbered `anyhow` error naming the path.

use super::{TraceEvent, TraceOp, TraceReader};
use crate::profiling::ProfileBank;
use crate::workloads::{MetricVec, WorkloadClass, ALL_CLASSES, NUM_METRICS};
use anyhow::{bail, ensure, Context, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::fs::File;
use std::io::{BufRead, BufReader};

/// How a vm-types file row resolves `vm_type` strings to classes.
fn parse_types_file(path: &str, bank: &ProfileBank) -> Result<BTreeMap<String, WorkloadClass>> {
    let file = File::open(path).with_context(|| format!("opening vm-types file '{path}'"))?;
    let mut map = BTreeMap::new();
    for (idx, line) in BufReader::new(file).lines().enumerate() {
        let n = idx + 1;
        let line = line.with_context(|| format!("{path} line {n}: read failed"))?;
        let line = line.trim();
        if n == 1 || line.is_empty() {
            continue; // header / blank
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let class = match fields.len() {
            // type_id,class — explicit mapping.
            2 => WorkloadClass::from_name(fields[1]).with_context(|| {
                format!("{path} line {n}: unknown workload class '{}'", fields[1])
            })?,
            // type_id,cpu,diskio,netio,membw — nearest bank row by L2.
            len if len == 1 + NUM_METRICS => {
                let mut demand = [0.0f64; NUM_METRICS];
                for (m, d) in fields[1..].iter().zip(demand.iter_mut()) {
                    *d = m.parse().with_context(|| {
                        format!("{path} line {n}: demand '{m}' is not a number")
                    })?;
                }
                nearest_class(&demand, bank)
            }
            len => bail!(
                "{path} line {n}: expected 2 (type_id,class) or {} \
                 (type_id + demand vector) fields, got {len}",
                1 + NUM_METRICS
            ),
        };
        ensure!(
            map.insert(fields[0].to_string(), class).is_none(),
            "{path} line {n}: duplicate type_id '{}'",
            fields[0]
        );
    }
    Ok(map)
}

/// Nearest profile-bank class to a foreign demand vector (L2 over the
/// normalized metric axes; lowest class index wins ties).
fn nearest_class(demand: &MetricVec, bank: &ProfileBank) -> WorkloadClass {
    let mut best = (f64::INFINITY, 0usize);
    for (i, u) in bank.u.iter().enumerate() {
        let d2: f64 = demand.iter().zip(u).map(|(a, b)| (a - b) * (a - b)).sum();
        if d2 < best.0 {
            best = (d2, i);
        }
    }
    ALL_CLASSES[best.1]
}

/// A parsed vm-instances row, pre-split into its replay events.
struct Row {
    arrival: TraceEvent,
    /// `(end_time bits, vm)` when the row has a finite end_time.
    departure: Option<(u64, u32)>,
}

/// Streaming vm-instances reader. Construction validates the header is
/// present; rows are validated lazily as the replay pulls events.
pub struct CsvTraceReader {
    path: String,
    lines: std::io::Lines<BufReader<File>>,
    /// 1-based line number of the *next* line `lines` will yield.
    line_no: usize,
    types: BTreeMap<String, WorkloadClass>,
    /// One-row lookahead so departures can be merged in time order.
    pending: Option<Row>,
    /// Departure heap over rows already consumed: `(end bits, vm)`.
    departures: BinaryHeap<Reverse<(u64, u32)>>,
    seen: BTreeSet<u32>,
    last_start: f64,
    exhausted: bool,
}

impl CsvTraceReader {
    /// Open `path` (vm-instances CSV), optionally resolving `vm_type`
    /// through a vm-types file; types not found there (or with no types
    /// file at all) must be workload-class names.
    pub fn open(
        path: &str,
        types_path: Option<&str>,
        bank: &ProfileBank,
    ) -> Result<CsvTraceReader> {
        let types = match types_path {
            Some(tp) => parse_types_file(tp, bank)?,
            None => BTreeMap::new(),
        };
        let file = File::open(path).with_context(|| format!("opening trace file '{path}'"))?;
        let mut lines = BufReader::new(file).lines();
        // Consume the mandatory header line.
        lines
            .next()
            .transpose()
            .with_context(|| format!("{path} line 1: read failed"))?
            .with_context(|| format!("{path}: empty file (expected a header line)"))?;
        Ok(CsvTraceReader {
            path: path.to_string(),
            lines,
            line_no: 2,
            types,
            pending: None,
            departures: BinaryHeap::new(),
            seen: BTreeSet::new(),
            last_start: 0.0,
            exhausted: false,
        })
    }

    /// Parse rows until one yields events (blank lines skip), filling
    /// the lookahead. `Ok(false)` = file exhausted.
    fn fill_lookahead(&mut self) -> Result<bool> {
        if self.pending.is_some() {
            return Ok(true);
        }
        while !self.exhausted {
            let n = self.line_no;
            let Some(line) = self
                .lines
                .next()
                .transpose()
                .with_context(|| format!("{} line {n}: read failed", self.path))?
            else {
                self.exhausted = true;
                break;
            };
            self.line_no += 1;
            if line.trim().is_empty() {
                continue;
            }
            self.pending = Some(self.parse_row(line.trim(), n)?);
            return Ok(true);
        }
        Ok(false)
    }

    fn parse_row(&mut self, line: &str, n: usize) -> Result<Row> {
        let path = &self.path;
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        ensure!(
            fields.len() == 4,
            "{path} line {n}: expected 4 fields (vm_id,vm_type,start_time,end_time), got {}",
            fields.len()
        );
        let vm: u32 = fields[0]
            .parse()
            .with_context(|| format!("{path} line {n}: vm_id '{}' is not a u32", fields[0]))?;
        ensure!(self.seen.insert(vm), "{path} line {n}: duplicate vm_id {vm}");
        let class = match self.types.get(fields[1]) {
            Some(&c) => c,
            None => WorkloadClass::from_name(fields[1]).with_context(|| {
                format!(
                    "{path} line {n}: vm_type '{}' is neither a vm-types id nor a workload class",
                    fields[1]
                )
            })?,
        };
        let start: f64 = fields[2].parse().with_context(|| {
            format!("{path} line {n}: start_time '{}' is not a number", fields[2])
        })?;
        ensure!(
            start.is_finite() && start >= 0.0,
            "{path} line {n}: start_time {start} must be finite and ≥ 0"
        );
        ensure!(
            start >= self.last_start,
            "{path} line {n}: start_time {start} regresses below {} (rows must be sorted)",
            self.last_start
        );
        self.last_start = start;
        // Empty or negative end_time = never departs.
        let end: Option<f64> = match fields[3] {
            "" => None,
            s => {
                let e: f64 = s.parse().with_context(|| {
                    format!("{path} line {n}: end_time '{s}' is not a number")
                })?;
                if e < 0.0 {
                    None
                } else {
                    ensure!(
                        e.is_finite() && e >= start,
                        "{path} line {n}: end_time {e} precedes start_time {start}"
                    );
                    Some(e)
                }
            }
        };
        Ok(Row {
            arrival: TraceEvent {
                at_tick: start,
                vm,
                op: TraceOp::Arrival {
                    class,
                    lifetime: end.map(|e| e - start),
                },
            },
            departure: end.map(|e| (e.to_bits(), vm)),
        })
    }
}

impl TraceReader for CsvTraceReader {
    fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        let has_row = self.fill_lookahead()?;
        // Departures due before the next arrival go first (ties too, so
        // a slot freed at t is visible to an arrival at t).
        if let Some(&Reverse((bits, vm))) = self.departures.peek() {
            let due = f64::from_bits(bits);
            let next_arrival = self.pending.as_ref().map(|r| r.arrival.at_tick);
            if !has_row || next_arrival.map_or(true, |a| due <= a) {
                self.departures.pop();
                return Ok(Some(TraceEvent {
                    at_tick: due,
                    vm,
                    op: TraceOp::Departure,
                }));
            }
        }
        match self.pending.take() {
            Some(row) => {
                if let Some(dep) = row.departure {
                    self.departures.push(Reverse(dep));
                }
                Ok(Some(row.arrival))
            }
            None => Ok(None),
        }
    }
}

/// Read a host-classes file for `--trace-hosts`: header, then
/// `count,cpu_cores,diskio,netio,membw` rows expanded in order into one
/// per-host capacity vector each. Row counts must sum to exactly
/// `hosts` so a miscounted file fails loudly instead of silently
/// defaulting part of the fleet.
pub fn read_host_classes(path: &str, hosts: usize) -> Result<Vec<MetricVec>> {
    let file = File::open(path).with_context(|| format!("opening host-classes file '{path}'"))?;
    let mut caps: Vec<MetricVec> = Vec::with_capacity(hosts);
    for (idx, line) in BufReader::new(file).lines().enumerate() {
        let n = idx + 1;
        let line = line.with_context(|| format!("{path} line {n}: read failed"))?;
        let line = line.trim();
        if n == 1 || line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        ensure!(
            fields.len() == 1 + NUM_METRICS,
            "{path} line {n}: expected {} fields (count,cpu_cores,diskio,netio,membw), got {}",
            1 + NUM_METRICS,
            fields.len()
        );
        let count: usize = fields[0]
            .parse()
            .with_context(|| format!("{path} line {n}: count '{}' is not a usize", fields[0]))?;
        let mut cap = [0.0f64; NUM_METRICS];
        for (m, c) in fields[1..].iter().zip(cap.iter_mut()) {
            *c = m.parse().with_context(|| {
                format!("{path} line {n}: capacity '{m}' is not a number")
            })?;
            ensure!(
                c.is_finite() && *c > 0.0,
                "{path} line {n}: capacity {c} must be finite and > 0"
            );
        }
        ensure!(
            caps.len() + count <= hosts,
            "{path} line {n}: host-class counts exceed --hosts {hosts}"
        );
        caps.extend(std::iter::repeat(cap).take(count));
    }
    ensure!(
        caps.len() == hosts,
        "{path}: host-class counts sum to {}, expected --hosts {hosts}",
        caps.len()
    );
    Ok(caps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::shared_bank;
    use std::io::Write;

    fn tmp(name: &str, contents: &str) -> String {
        let path = std::env::temp_dir().join(format!("vmcd_trace_{name}_{}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        path.to_str().unwrap().to_string()
    }

    fn drain(mut r: CsvTraceReader) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        while let Some(ev) = r.next_event().unwrap() {
            out.push(ev);
        }
        out
    }

    #[test]
    fn roundtrips_the_doc_example_in_time_order() {
        let path = tmp(
            "doc",
            "vm_id,vm_type,start_time,end_time\n\
             0,hadoop,0,340\n\
             1,stream-low,2,\n\
             2,blackscholes,2,97\n\
             3,lamp-heavy,5,610\n\
             4,jacobi,9,444\n",
        );
        let events = drain(CsvTraceReader::open(&path, None, shared_bank()).unwrap());
        std::fs::remove_file(&path).unwrap();
        // 5 arrivals + 4 departures (vm 1 never departs), non-decreasing.
        assert_eq!(events.len(), 9);
        let mut last = 0.0;
        for ev in &events {
            assert!(ev.at_tick >= last);
            last = ev.at_tick;
        }
        let arrivals: Vec<u32> = events
            .iter()
            .filter(|e| matches!(e.op, TraceOp::Arrival { .. }))
            .map(|e| e.vm)
            .collect();
        assert_eq!(arrivals, vec![0, 1, 2, 3, 4]);
        let departures: Vec<u32> = events
            .iter()
            .filter(|e| e.op == TraceOp::Departure)
            .map(|e| e.vm)
            .collect();
        assert_eq!(departures, vec![2, 0, 4, 3], "sorted by end_time");
        match events[0].op {
            TraceOp::Arrival { class, lifetime } => {
                assert_eq!(class, WorkloadClass::Hadoop);
                assert_eq!(lifetime, Some(340.0));
            }
            ref other => panic!("first event {other:?}"),
        }
    }

    #[test]
    fn malformed_rows_get_line_numbered_errors() {
        for (name, body, needle) in [
            ("badstart", "h\n0,hadoop,zero,\n", "line 2"),
            ("badid", "h\n-1,hadoop,0,\n", "vm_id"),
            ("badclass", "h\n0,no-such-type,0,\n", "line 2"),
            ("fields", "h\n0,hadoop,0\n", "expected 4 fields"),
            ("order", "h\n0,hadoop,5,\n1,hadoop,3,\n", "line 3"),
            ("dup", "h\n0,hadoop,0,\n0,jacobi,1,\n", "duplicate vm_id 0"),
            ("endlt", "h\n0,hadoop,5,2\n", "precedes start_time"),
        ] {
            let path = tmp(name, body);
            let err = drain_err(&path);
            std::fs::remove_file(&path).unwrap();
            assert!(
                err.contains(needle),
                "'{name}' error should mention '{needle}', got: {err}"
            );
        }
    }

    fn drain_err(path: &str) -> String {
        let mut r = match CsvTraceReader::open(path, None, shared_bank()) {
            Ok(r) => r,
            Err(e) => return format!("{e:#}"),
        };
        loop {
            match r.next_event() {
                Ok(Some(_)) => {}
                Ok(None) => return "no error".into(),
                Err(e) => return format!("{e:#}"),
            }
        }
    }

    #[test]
    fn vm_types_map_by_name_and_by_nearest_demand() {
        let bank = shared_bank();
        // Type 7 maps explicitly; type 9's demand vector is exactly the
        // hadoop bank row, so nearest-L2 must resolve to hadoop.
        let h = bank.u[WorkloadClass::Hadoop.index()];
        let types = tmp(
            "types",
            &format!(
                "type_id,class\n7,jacobi\n9,{},{},{},{}\n",
                h[0], h[1], h[2], h[3]
            ),
        );
        let trace = tmp("typed", "h\n0,7,0,\n1,9,1,\n");
        let events = drain(CsvTraceReader::open(&trace, Some(&types), bank).unwrap());
        std::fs::remove_file(&types).unwrap();
        std::fs::remove_file(&trace).unwrap();
        let classes: Vec<WorkloadClass> = events
            .iter()
            .filter_map(|e| match e.op {
                TraceOp::Arrival { class, .. } => Some(class),
                _ => None,
            })
            .collect();
        assert_eq!(classes, vec![WorkloadClass::Jacobi, WorkloadClass::Hadoop]);
    }

    #[test]
    fn host_classes_expand_counts_and_validate_totals() {
        let path = tmp("hosts", "count,cpu,dio,nio,mbw\n2,16,1,1,4\n1,8,2,1,2\n");
        let caps = read_host_classes(&path, 3).unwrap();
        let want = vec![
            [16.0, 1.0, 1.0, 4.0],
            [16.0, 1.0, 1.0, 4.0],
            [8.0, 2.0, 1.0, 2.0],
        ];
        assert_eq!(caps, want);
        let err = format!("{:#}", read_host_classes(&path, 5).unwrap_err());
        assert!(err.contains("sum to 3"), "{err}");
        let err = format!("{:#}", read_host_classes(&path, 2).unwrap_err());
        assert!(err.contains("exceed"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}

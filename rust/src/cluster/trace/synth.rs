//! Seeded synthetic trace generation: Poisson-burst arrivals, heavy-tail
//! (lognormal / Pareto) lifetimes, diurnal load modulation — the
//! distribution shapes production IaaS traces exhibit (cf. the SAP
//! Cloud Infrastructure dataset, arXiv:2510.23911) that uniform
//! synthetic scenarios miss. See the [module docs](super) for the
//! `synth:` spec grammar.
//!
//! The generator is a streaming [`TraceReader`]: it holds only the
//! departure heap of *live* VMs (plus O(1) arrival state), never the
//! whole trace, so `vms=500000` costs memory proportional to peak
//! concurrency, not trace length. Two generators built from the same
//! spec + seed emit bit-identical streams (test-gated), which is what
//! makes trace-replay determinism checks across step modes possible.

use super::{TraceEvent, TraceOp, TraceReader};
use crate::util::rng::Rng;
use crate::workloads::{WorkloadClass, ALL_CLASSES};
use anyhow::{bail, ensure, Context, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// Lifetime distribution family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifetimeDist {
    /// `exp(N(ln life, sigma))` — median `life`, log-scale σ `sigma`.
    Lognormal,
    /// `life · U^(−1/alpha)` — minimum `life`, tail index `alpha`.
    Pareto,
}

/// Parsed `synth:` spec (defaults per the module-doc grammar table).
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub vms: u64,
    pub rate: f64,
    pub burst: f64,
    pub life: f64,
    pub dist: LifetimeDist,
    pub sigma: f64,
    pub alpha: f64,
    /// Lifetime cap (bounds the heavy tail, so a replay's drain phase is
    /// bounded too). `None` resolves to `20 × life`.
    pub lmax: Option<f64>,
    pub diurnal: f64,
    pub period: f64,
    pub migrates: u64,
    /// `seed=` in the spec; falls back to the caller's seed.
    pub seed: Option<u64>,
}

impl Default for SynthSpec {
    fn default() -> SynthSpec {
        SynthSpec {
            vms: 1000,
            rate: 32.0,
            burst: 4.0,
            life: 120.0,
            dist: LifetimeDist::Lognormal,
            sigma: 0.8,
            alpha: 1.5,
            lmax: None,
            diurnal: 0.5,
            period: 360.0,
            migrates: 0,
            seed: None,
        }
    }
}

impl SynthSpec {
    /// Parse `key=value[,key=value...]` (the part after `synth:`).
    /// Unknown keys, malformed values, and out-of-range parameters are
    /// all errors naming the offending token.
    pub fn parse(s: &str) -> Result<SynthSpec> {
        let mut spec = SynthSpec::default();
        for tok in s.split(',').filter(|t| !t.is_empty()) {
            let (key, val) = tok
                .split_once('=')
                .with_context(|| format!("synth spec token '{tok}' is not key=value"))?;
            let num = |what: &str| -> Result<f64> {
                val.parse().with_context(|| format!("synth {what} '{val}' is not a number"))
            };
            let int = |what: &str| -> Result<u64> {
                val.parse().with_context(|| format!("synth {what} '{val}' is not an integer"))
            };
            match key {
                "vms" => spec.vms = int("vms")?,
                "rate" => spec.rate = num("rate")?,
                "burst" => spec.burst = num("burst")?,
                "life" => spec.life = num("life")?,
                "dist" => {
                    spec.dist = match val {
                        "lognormal" | "ln" => LifetimeDist::Lognormal,
                        "pareto" => LifetimeDist::Pareto,
                        other => bail!("synth dist '{other}' (valid: lognormal, pareto)"),
                    }
                }
                "sigma" => spec.sigma = num("sigma")?,
                "alpha" => spec.alpha = num("alpha")?,
                "lmax" => spec.lmax = Some(num("lmax")?),
                "diurnal" => spec.diurnal = num("diurnal")?,
                "period" => spec.period = num("period")?,
                "migrates" => spec.migrates = int("migrates")?,
                "seed" => spec.seed = Some(int("seed")?),
                other => bail!("unknown synth key '{other}' (see the synth: grammar table)"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.vms >= 1, "synth vms must be ≥ 1, got {}", self.vms);
        ensure!(
            self.vms <= 50_000_000,
            "synth vms {} is absurd (max 50000000)",
            self.vms
        );
        ensure!(self.rate > 0.0, "synth rate must be > 0, got {}", self.rate);
        ensure!(self.burst >= 1.0, "synth burst must be ≥ 1, got {}", self.burst);
        ensure!(self.life > 0.0, "synth life must be > 0, got {}", self.life);
        ensure!(self.sigma > 0.0, "synth sigma must be > 0, got {}", self.sigma);
        ensure!(self.alpha > 0.0, "synth alpha must be > 0, got {}", self.alpha);
        if let Some(lmax) = self.lmax {
            ensure!(lmax >= self.life, "synth lmax {} < life {}", lmax, self.life);
        }
        ensure!(
            (0.0..1.0).contains(&self.diurnal),
            "synth diurnal must be in [0, 1), got {}",
            self.diurnal
        );
        ensure!(self.period > 0.0, "synth period must be > 0, got {}", self.period);
        Ok(())
    }

    /// Resolved lifetime cap.
    pub fn life_cap(&self) -> f64 {
        self.lmax.unwrap_or(20.0 * self.life)
    }
}

/// Lifetime-bits heap key: departure times are finite non-negative f64s,
/// whose IEEE-754 bit patterns order identically to the values.
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0 && t.is_finite());
    t.to_bits()
}

/// The seeded streaming generator. See the [module docs](self).
pub struct SyntheticTraceGenerator {
    spec: SynthSpec,
    rng: Rng,
    /// Instant of the burst currently being drained.
    burst_at: f64,
    /// Arrivals left in the current burst.
    burst_left: u64,
    /// Arrivals emitted so far (ids are 0..spec.vms in arrival order).
    emitted: u64,
    /// Departure heap over live VMs: `(time bits, vm)` min-first.
    departures: BinaryHeap<Reverse<(u64, u32)>>,
    /// Live VM ids (arrived, not yet departed), with positions for O(1)
    /// swap-removal — only consulted for `migrates` sampling and the
    /// liveness invariant.
    live: Vec<u32>,
    live_pos: BTreeMap<u32, usize>,
    migrates_left: u64,
    /// Instant of the next Migrate draw (spread over the arrival span).
    next_migrate_at: f64,
    migrate_gap: f64,
    /// High-water mark for emitted timestamps (monotonicity clamp).
    last_at: f64,
}

impl SyntheticTraceGenerator {
    pub fn new(spec: SynthSpec, default_seed: u64) -> SyntheticTraceGenerator {
        let seed = spec.seed.unwrap_or(default_seed);
        // Spread the optional Migrate draws across the expected arrival
        // span so they interleave with churn instead of front-loading.
        let span = spec.vms as f64 / spec.rate;
        let migrate_gap = if spec.migrates > 0 {
            span / spec.migrates as f64
        } else {
            0.0
        };
        let mut g = SyntheticTraceGenerator {
            spec,
            rng: Rng::new(seed ^ 0x7A_CE_5EED),
            burst_at: 0.0,
            burst_left: 0,
            emitted: 0,
            departures: BinaryHeap::new(),
            live: Vec::new(),
            live_pos: BTreeMap::new(),
            migrates_left: 0,
            next_migrate_at: 0.0,
            migrate_gap,
            last_at: 0.0,
        };
        g.migrates_left = g.spec.migrates;
        g.next_migrate_at = 0.5 * migrate_gap;
        // The first burst fires after one modulated gap from t = 0.
        g.draw_next_burst(0.0);
        g
    }

    /// Parse the spec and build — the `--trace synth:...` entry point.
    pub fn parse(spec: &str, default_seed: u64) -> Result<SyntheticTraceGenerator> {
        Ok(SyntheticTraceGenerator::new(SynthSpec::parse(spec)?, default_seed))
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Diurnal arrival-intensity multiplier at `t` (≥ `1 − diurnal` > 0).
    fn modulation(&self, t: f64) -> f64 {
        1.0 + self.spec.diurnal * (std::f64::consts::TAU * t / self.spec.period).sin()
    }

    /// Draw the next burst instant and size: exponential inter-burst gap
    /// with mean `burst / rate` (so arrivals average `rate` per tick),
    /// thinned/stretched by the diurnal modulation, then a geometric
    /// burst size with mean `burst` — the Poisson-burst arrival process.
    fn draw_next_burst(&mut self, from: f64) {
        let mean_gap = self.spec.burst / self.spec.rate;
        let gap = self.rng.exponential(mean_gap) / self.modulation(from);
        self.burst_at = from + gap;
        self.burst_left = if self.spec.burst <= 1.0 {
            1
        } else {
            // Geometric on {1, 2, ...} with success probability 1/burst.
            let p = 1.0 / self.spec.burst;
            let u = self.rng.uniform().max(1e-12);
            1 + (u.ln() / (1.0 - p).ln()).floor() as u64
        };
    }

    /// One heavy-tailed lifetime draw, capped at `lmax`.
    fn draw_lifetime(&mut self) -> f64 {
        let raw = match self.spec.dist {
            LifetimeDist::Lognormal => {
                self.rng.normal_with(self.spec.life.ln(), self.spec.sigma).exp()
            }
            LifetimeDist::Pareto => {
                let u = (1.0 - self.rng.uniform()).max(1e-12);
                self.spec.life * u.powf(-1.0 / self.spec.alpha)
            }
        };
        raw.clamp(1e-3, self.spec.life_cap())
    }

    fn emit_arrival(&mut self) -> TraceEvent {
        let at = self.burst_at.max(self.last_at);
        let id = self.emitted as u32;
        self.emitted += 1;
        self.burst_left -= 1;
        if self.burst_left == 0 && self.emitted < self.spec.vms {
            self.draw_next_burst(self.burst_at);
        }
        let class = *self.rng.pick(&ALL_CLASSES);
        let lifetime = self.draw_lifetime();
        self.departures.push(Reverse((time_key(at + lifetime), id)));
        self.live_pos.insert(id, self.live.len());
        self.live.push(id);
        self.last_at = at;
        TraceEvent {
            at_tick: at,
            vm: id,
            op: TraceOp::Arrival {
                class,
                lifetime: Some(lifetime),
            },
        }
    }

    fn emit_departure(&mut self) -> TraceEvent {
        // detlint: allow(panic): caller gates on `!departures.is_empty()` (next_event)
        let Reverse((bits, id)) = self.departures.pop().expect("departure heap underflow");
        let at = f64::from_bits(bits).max(self.last_at);
        // detlint: allow(panic): every heap entry was inserted into live_pos at arrival
        let pos = self.live_pos.remove(&id).expect("departing VM not live");
        self.live.swap_remove(pos);
        if let Some(&moved) = self.live.get(pos) {
            self.live_pos.insert(moved, pos);
        }
        self.last_at = at;
        TraceEvent {
            at_tick: at,
            vm: id,
            op: TraceOp::Departure,
        }
    }

    fn emit_migrate(&mut self) -> TraceEvent {
        let at = self.next_migrate_at.max(self.last_at);
        self.migrates_left -= 1;
        self.next_migrate_at += self.migrate_gap;
        let vm = self.live[self.rng.below(self.live.len())];
        self.last_at = at;
        TraceEvent {
            at_tick: at,
            vm,
            op: TraceOp::Migrate,
        }
    }
}

impl TraceReader for SyntheticTraceGenerator {
    fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        // Candidate instants; ties resolve departure → migrate → arrival
        // (a fixed priority keeps the stream deterministic).
        let dep_at = self.departures.peek().map(|Reverse((bits, _))| f64::from_bits(*bits));
        let arr_at = (self.emitted < self.spec.vms).then_some(self.burst_at);
        let mig_at = (self.migrates_left > 0 && !self.live.is_empty())
            .then_some(self.next_migrate_at.max(self.last_at));

        let Some(next) = [dep_at, mig_at, arr_at].into_iter().flatten().reduce(f64::min) else {
            return Ok(None);
        };
        if dep_at == Some(next) {
            return Ok(Some(self.emit_departure()));
        }
        if mig_at == Some(next) {
            return Ok(Some(self.emit_migrate()));
        }
        debug_assert_eq!(arr_at, Some(next));
        Ok(Some(self.emit_arrival()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(mut g: SyntheticTraceGenerator) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        while let Some(ev) = g.next_event().unwrap() {
            out.push(ev);
        }
        out
    }

    #[test]
    fn spec_grammar_parses_and_rejects() {
        let s = SynthSpec::parse("vms=50,rate=8,burst=2,dist=pareto,alpha=1.6,seed=9").unwrap();
        assert_eq!(s.vms, 50);
        assert_eq!(s.dist, LifetimeDist::Pareto);
        assert_eq!(s.seed, Some(9));
        assert_eq!(SynthSpec::parse("").unwrap().vms, SynthSpec::default().vms);

        for bad in [
            "vms=abc",
            "rate=-1",
            "rate=0",
            "burst=0.5",
            "vms=0",
            "diurnal=1.0",
            "dist=weibull",
            "frequency=3",
            "novalue",
            "lmax=1,life=120",
        ] {
            assert!(SynthSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        let spec = "vms=200,rate=16,migrates=10,seed=7";
        let a = drain(SyntheticTraceGenerator::parse(spec, 0).unwrap());
        let b = drain(SyntheticTraceGenerator::parse(spec, 99).unwrap());
        assert_eq!(a, b, "spec seed overrides the default seed");
        let c = drain(SyntheticTraceGenerator::parse("vms=200,rate=16,migrates=10", 8).unwrap());
        assert_ne!(a, c, "different seeds must diverge");
    }

    #[test]
    fn every_arrival_departs_and_timestamps_never_regress() {
        let g = SyntheticTraceGenerator::parse("vms=300,rate=24,migrates=20,seed=3", 0).unwrap();
        let cap = g.spec().life_cap();
        let events = drain(g);
        let mut live: std::collections::HashSet<u32> = Default::default();
        let mut last = 0.0;
        let (mut arrivals, mut departures, mut migrates) = (0u64, 0u64, 0u64);
        for ev in &events {
            assert!(ev.at_tick >= last, "timestamps regressed: {} < {last}", ev.at_tick);
            last = ev.at_tick;
            match ev.op {
                TraceOp::Arrival { lifetime, .. } => {
                    assert!(live.insert(ev.vm), "duplicate arrival id {}", ev.vm);
                    let l = lifetime.unwrap();
                    assert!(l > 0.0 && l <= cap, "lifetime {l} out of (0, {cap}]");
                    arrivals += 1;
                }
                TraceOp::Departure => {
                    assert!(live.remove(&ev.vm), "departure for dead VM {}", ev.vm);
                    departures += 1;
                }
                TraceOp::Migrate => {
                    assert!(live.contains(&ev.vm), "migrate for dead VM {}", ev.vm);
                    migrates += 1;
                }
            }
        }
        assert_eq!(arrivals, 300);
        assert_eq!(departures, 300, "every capped lifetime ends in a departure");
        assert!(live.is_empty());
        assert!(migrates > 0 && migrates <= 20);
    }

    #[test]
    fn diurnal_modulation_shifts_arrival_density() {
        // With a period longer than the trace and a positive-phase
        // start, high modulation front-loads arrivals relative to the
        // flat process at the same seed.
        let flat =
            drain(SyntheticTraceGenerator::parse("vms=400,rate=8,diurnal=0,seed=5", 0).unwrap());
        let peaky = drain(
            SyntheticTraceGenerator::parse(
                "vms=400,rate=8,diurnal=0.9,period=100000,seed=5",
                0,
            )
            .unwrap(),
        );
        let span = |evs: &[TraceEvent]| {
            evs.iter()
                .filter_map(|e| matches!(e.op, TraceOp::Arrival { .. }).then_some(e.at_tick))
                .fold(0.0f64, f64::max)
        };
        assert!(
            span(&peaky) < span(&flat),
            "sin > 0 early phase must compress arrivals: {} vs {}",
            span(&peaky),
            span(&flat)
        );
    }
}

//! The replay driver: feed a [`TraceReader`]'s event stream through a
//! [`ClusterSim`]'s event bus — trace arrivals become policy-routed
//! `ClusterEvent::Arrival`s (the dispatcher under test picks the host),
//! trace departures become `ClusterEvent::Departure`s on whichever host
//! the bus routed the VM to, and trace `Migrate` records evict the VM
//! to the least-resident other host. This is the 100k-events-across-
//! thousands-of-hosts hot path the `trace_replay` bench measures:
//! bus routing + batched `rank` + shard-pool stepping, end to end.
//!
//! The driver holds O(live VMs) state: a `vm → host` map fed by the
//! bus's placement log
//! ([`EventBus::take_moves`](crate::cluster::bus::EventBus::take_moves)),
//! the live-VM set, and —
//! only for readers that don't emit explicit departures — a due-heap
//! built from `Arrival { lifetime }`. Departure/Migrate events whose VM
//! arrived *this same tick* (host not yet routed) are deferred one tick
//! and retried, preserving per-VM event order.

use super::{TraceEvent, TraceOp, TraceReader};
use crate::cluster::bus::ClusterEvent;
use crate::cluster::sim::{ClusterSim, ClusterSpec};
use crate::hostsim::{ActivityModel, Vm, VmId, VmState};
use crate::profiling::ProfileBank;
use crate::scenarios::ScenarioSpec;
use anyhow::{bail, ensure, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::time::{Duration, Instant};

/// What a finished replay reports — counters for correctness checks,
/// wall time for the headline events/sec, and bit-stable outputs
/// (`core_hours`, `final_residents`) for determinism gates.
#[derive(Debug, Clone)]
pub struct ReplayResult {
    /// Trace events published, by kind.
    pub arrivals: u64,
    pub departures: u64,
    pub migrates: u64,
    /// Departure/Migrate events skipped because their VM was no longer
    /// live (or the cluster had nowhere to migrate to).
    pub dropped: u64,
    /// Cluster events the bus routed over the whole replay.
    pub events_routed: u64,
    /// Migrations the bus actually started (≤ `migrates`).
    pub migrations_started: u64,
    /// Most VMs live at once — the trace's working-set high-water mark.
    pub peak_live: usize,
    /// VMs still resident when the trace drained (never-departing rows).
    pub final_live: usize,
    /// The replay hit `sim.max_time` with trace events still pending.
    pub truncated: bool,
    /// Simulated seconds at the end of the replay.
    pub completion_time: f64,
    /// Cluster ticks stepped.
    pub ticks: u64,
    /// Σ per-host busy-core hours (bit-stable across step modes).
    pub core_hours: f64,
    /// Final resident count per host, in host order.
    pub final_residents: Vec<usize>,
    /// Migrations the bus completed / aborted over the replay.
    pub migrations_completed: u64,
    pub migrations_failed: u64,
    /// Moves the continuous migrator published (0 when disabled).
    pub migrator_moves: u64,
    /// Parked-aware cluster energy in Wh (empty hosts draw 0 W).
    pub energy_wh: f64,
    /// Always-plugged cluster energy in Wh (Σ per-host ledgers).
    pub plugged_energy_wh: f64,
    /// dslab-style SLATAH: overload host-time over powered host-time.
    pub slav: f64,
    pub overload_seconds: f64,
    /// Hours of powered (non-empty) host time.
    pub active_host_hours: f64,
    /// Ticks from the powered-host peak to half-drain (`None` when the
    /// fleet never drains that far) — time-to-converge after the spike.
    pub converge_ticks: Option<u64>,
    /// End-to-end wall time of the replay loop.
    pub wall: Duration,
}

impl ReplayResult {
    /// Trace events published per wall-clock second — the headline
    /// sustained-throughput metric of `BENCH_trace.json`.
    pub fn events_per_sec(&self) -> f64 {
        let events = self.arrivals + self.departures + self.migrates;
        events as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Hosts still holding residents when the replay stopped.
    pub fn final_active_hosts(&self) -> usize {
        self.final_residents.iter().filter(|&&r| r > 0).count()
    }

    /// Fold every simulation-determined field (declaration order) into
    /// one FNV-1a digest — the `--digest` output the two-process audit
    /// compares. `wall` is deliberately excluded: it is the only field
    /// the machine, not the seed, decides.
    pub fn bit_digest(&self) -> u64 {
        let mut h = crate::util::digest::Fnv64::new();
        h.write_u64(self.arrivals)
            .write_u64(self.departures)
            .write_u64(self.migrates)
            .write_u64(self.dropped)
            .write_u64(self.events_routed)
            .write_u64(self.migrations_started)
            .write_usize(self.peak_live)
            .write_usize(self.final_live)
            .write_bool(self.truncated)
            .write_f64(self.completion_time)
            .write_u64(self.ticks)
            .write_f64(self.core_hours);
        h.write_usize(self.final_residents.len());
        for &r in &self.final_residents {
            h.write_usize(r);
        }
        h.write_u64(self.migrations_completed)
            .write_u64(self.migrations_failed)
            .write_u64(self.migrator_moves)
            .write_f64(self.energy_wh)
            .write_f64(self.plugged_energy_wh)
            .write_f64(self.slav)
            .write_f64(self.overload_seconds)
            .write_f64(self.active_host_hours);
        h.write_bool(self.converge_ticks.is_some());
        h.write_u64(self.converge_ticks.unwrap_or(0));
        h.finish()
    }
}

/// Heap key for departure-due times (finite, non-negative f64s order
/// identically to their IEEE-754 bit patterns).
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0 && t.is_finite());
    t.to_bits()
}

struct Driver<'a> {
    reader: &'a mut dyn TraceReader,
    lookahead: Option<TraceEvent>,
    /// Monotonicity guard over the reader's stream.
    last_at: f64,
    /// Where the bus routed each live VM (filled from `take_moves`).
    vm_host: BTreeMap<u32, usize>,
    live: BTreeSet<u32>,
    /// Every arrival id ever seen (duplicate detection).
    seen: BTreeSet<u32>,
    /// Departures/Migrates whose VM is live but not yet routed (arrived
    /// this very tick); retried next tick, in order.
    deferred: Vec<TraceEvent>,
    /// Replay-scheduled departures (`(due bits, vm)`) for readers with
    /// `emits_departures() == false`.
    due: BinaryHeap<Reverse<(u64, u32)>>,
    schedule_departures: bool,
    arrivals: u64,
    departures: u64,
    migrates: u64,
    dropped: u64,
    peak_live: usize,
}

impl Driver<'_> {
    fn next_trace_event(&mut self) -> Result<Option<&TraceEvent>> {
        if self.lookahead.is_none() {
            if let Some(ev) = self.reader.next_event()? {
                ensure!(
                    ev.at_tick.is_finite() && ev.at_tick >= 0.0,
                    "trace event for vm {} at invalid time {}",
                    ev.vm,
                    ev.at_tick
                );
                ensure!(
                    ev.at_tick >= self.last_at,
                    "trace timestamps regress: vm {} at {} after {}",
                    ev.vm,
                    ev.at_tick,
                    self.last_at
                );
                self.last_at = ev.at_tick;
                self.lookahead = Some(ev);
            }
        }
        Ok(self.lookahead.as_ref())
    }

    /// Publish one trace event into the sim, defer it, or drop it.
    fn apply(&mut self, ev: TraceEvent, sim: &mut ClusterSim) -> Result<()> {
        match ev.op {
            TraceOp::Arrival { class, lifetime } => {
                ensure!(
                    self.seen.insert(ev.vm),
                    "duplicate arrival for vm {} in trace",
                    ev.vm
                );
                self.live.insert(ev.vm);
                self.peak_live = self.peak_live.max(self.live.len());
                if self.schedule_departures {
                    if let Some(l) = lifetime {
                        ensure!(l >= 0.0, "vm {} has negative lifetime {l}", ev.vm);
                        self.due.push(Reverse((time_key(ev.at_tick + l), ev.vm)));
                    }
                }
                let now = sim.now();
                let mut vm = Vm::new(VmId(ev.vm), class, now, ActivityModel::AlwaysOn);
                vm.state = VmState::Running;
                vm.started = Some(now);
                sim.publish(ClusterEvent::Arrival { vm, host: None });
                self.arrivals += 1;
            }
            TraceOp::Departure => {
                if !self.live.contains(&ev.vm) {
                    self.dropped += 1;
                    return Ok(());
                }
                match self.vm_host.get(&ev.vm).copied() {
                    Some(host) => {
                        self.live.remove(&ev.vm);
                        self.vm_host.remove(&ev.vm);
                        sim.publish(ClusterEvent::Departure {
                            host,
                            vm: VmId(ev.vm),
                        });
                        self.departures += 1;
                    }
                    // Arrived this very tick: the bus hasn't routed it
                    // yet, so its host is unknown. Retry next tick.
                    None => self.deferred.push(ev),
                }
            }
            TraceOp::Migrate => {
                if !self.live.contains(&ev.vm) {
                    self.dropped += 1;
                    return Ok(());
                }
                let Some(src) = self.vm_host.get(&ev.vm).copied() else {
                    self.deferred.push(ev);
                    return Ok(());
                };
                // Destination: the least-resident other host, lowest
                // index on ties — deterministic, summary-driven.
                let summaries = sim.summaries();
                let mut dst = None;
                for (h, s) in summaries.iter().enumerate() {
                    if h == src {
                        continue;
                    }
                    match dst {
                        Some((_, best)) if s.resident >= best => {}
                        _ => dst = Some((h, s.resident)),
                    }
                }
                let Some((dst, _)) = dst else {
                    self.dropped += 1; // single-host cluster
                    return Ok(());
                };
                sim.publish(ClusterEvent::Migrate {
                    vm: VmId(ev.vm),
                    src,
                    dst,
                });
                // The bus logs the landing host when (if) the transfer
                // completes; until then the VM stays addressed at src.
                self.migrates += 1;
            }
        }
        Ok(())
    }

    /// Publish every replay-scheduled departure due by `now`. Entries
    /// whose VM has no routed host yet stay queued for next tick.
    fn publish_due_departures(&mut self, now: f64, sim: &mut ClusterSim) -> Result<()> {
        while let Some(&Reverse((bits, vm))) = self.due.peek() {
            if f64::from_bits(bits) > now {
                break;
            }
            if self.live.contains(&vm) && !self.vm_host.contains_key(&vm) {
                // Routed host unknown (same-tick arrival): retry next
                // tick. The heap top blocks later entries, preserving
                // due order.
                break;
            }
            self.due.pop();
            self.apply(
                TraceEvent {
                    at_tick: f64::from_bits(bits),
                    vm,
                    op: TraceOp::Departure,
                },
                sim,
            )?;
        }
        Ok(())
    }
}

/// Replay `reader` through a fresh [`ClusterSim`] built from `spec`.
/// Every trace event is published as a [`ClusterEvent`] and routed by
/// the bus (the spec's dispatcher picks arrival hosts); the loop ticks
/// until the trace is drained — or `spec.cfg.sim.max_time` truncates a
/// runaway trace (`truncated` is set instead of ticking forever).
pub fn replay(
    spec: &ClusterSpec,
    reader: &mut dyn TraceReader,
    bank: &ProfileBank,
) -> Result<ReplayResult> {
    let empty = ScenarioSpec {
        name: "trace-replay".to_string(),
        sr: 0.0,
        vms: Vec::new(),
        min_duration: 0.0,
    };
    let mut sim = ClusterSim::new(spec.clone(), &empty, bank)?;
    let max_time = spec.cfg.sim.max_time;
    let schedule_departures = !reader.emits_departures();
    let mut d = Driver {
        reader,
        lookahead: None,
        last_at: 0.0,
        vm_host: BTreeMap::new(),
        live: BTreeSet::new(),
        seen: BTreeSet::new(),
        deferred: Vec::new(),
        due: BinaryHeap::new(),
        schedule_departures,
        arrivals: 0,
        departures: 0,
        migrates: 0,
        dropped: 0,
        peak_live: 0,
    };

    #[allow(clippy::disallowed_methods)]
    // detlint: allow(wall-clock): measures reporting-only wall time; never feeds results
    let started = Instant::now();
    let mut truncated = false;
    let mut ticks = 0u64;
    loop {
        let now = sim.now();
        if now >= max_time {
            // Anything still pending is lost to the time horizon.
            truncated = d.lookahead.is_some()
                || !d.deferred.is_empty()
                || !d.due.is_empty()
                || d.next_trace_event()?.is_some();
            break;
        }

        // Deferred events first (they predate anything still unread).
        for ev in std::mem::take(&mut d.deferred) {
            d.apply(ev, &mut sim)?;
        }
        // Then every trace event due by now, in stream order.
        loop {
            let due = matches!(d.next_trace_event()?, Some(ev) if ev.at_tick <= now);
            if !due {
                break;
            }
            // detlint: allow(panic): `matches!` on the same Option one line up proves Some
            let ev = d.lookahead.take().expect("lookahead populated");
            d.apply(ev, &mut sim)?;
        }
        // Then replay-scheduled departures (lifetime fallback).
        d.publish_due_departures(now, &mut sim)?;

        // Drained once nothing is pending anywhere; the tick below
        // routes this iteration's publishes before we stop.
        let drained = d.lookahead.is_none()
            && d.deferred.is_empty()
            && d.due.is_empty()
            && d.next_trace_event()?.is_none();

        sim.tick(bank)?;
        ticks += 1;
        for (VmId(id), host) in sim.take_moves() {
            if d.live.contains(&id) {
                d.vm_host.insert(id, host);
            }
        }
        if drained {
            break;
        }
    }

    // Migrator settle window: the trace is drained, but in-flight
    // transfers are still travelling and the planner may still be
    // consolidating the never-departing survivors — keep ticking until
    // a full planning interval passes with no transfers in flight and
    // no new moves, so converge time and parked energy are measurable.
    // Migrator-off replays skip this entirely and stay bit-identical to
    // the pre-migrator driver.
    if let Some(params) = &spec.migrator {
        let mut quiet = 0.0;
        while sim.now() < max_time {
            let before = sim.migrator_stats().map_or(0, |s| s.planned_moves);
            sim.tick(bank)?;
            ticks += 1;
            for (VmId(id), host) in sim.take_moves() {
                if d.live.contains(&id) {
                    d.vm_host.insert(id, host);
                }
            }
            let after = sim.migrator_stats().map_or(0, |s| s.planned_moves);
            if sim.bus().in_flight() == 0 && after == before {
                quiet += spec.cfg.sim.dt;
                if quiet > params.interval {
                    break;
                }
            } else {
                quiet = 0.0;
            }
        }
    }
    let wall = started.elapsed();

    if d.arrivals == 0 && !truncated {
        bail!("trace contained no arrivals");
    }

    let stats = sim.bus().stats;
    let final_residents: Vec<usize> = sim.summaries().iter().map(|s| s.resident).collect();
    let completion_time = sim.now();
    let migrator_moves = sim.migrator_stats().map_or(0, |s| s.planned_moves);
    let mut ledger = sim.ledger().clone();
    let dt = spec.cfg.sim.dt;
    let hosts = sim.finish()?;
    let mut core_hours = 0.0;
    for host in &hosts {
        ledger.absorb(&host.handle().engine().ledger);
        core_hours += host.handle().engine().ledger.core_hours();
    }
    let converge_ticks = ledger.converge_time().map(|t| (t / dt).round() as u64);

    Ok(ReplayResult {
        arrivals: d.arrivals,
        departures: d.departures,
        migrates: d.migrates,
        dropped: d.dropped,
        events_routed: stats.events_routed,
        migrations_started: stats.migrations_started,
        peak_live: d.peak_live,
        final_live: d.live.len(),
        truncated,
        completion_time,
        ticks,
        core_hours,
        final_residents,
        migrations_completed: stats.migrations_completed,
        migrations_failed: stats.migrations_failed,
        migrator_moves,
        energy_wh: ledger.energy_wh(),
        plugged_energy_wh: ledger.plugged_energy_wh(),
        slav: ledger.slav(),
        overload_seconds: ledger.overload_seconds,
        active_host_hours: ledger.active_host_hours(),
        converge_ticks,
        wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::dispatch::Dispatcher;
    use crate::cluster::pool::StepMode;
    use crate::cluster::sim::Strategy;
    use crate::cluster::trace::synth::SyntheticTraceGenerator;
    use crate::cluster::trace::SliceReader;
    use crate::testkit;
    use crate::vmcd::ActuationSpec;
    use crate::workloads::WorkloadClass;

    fn spec(hosts: usize) -> ClusterSpec {
        let mut spec = ClusterSpec::new(hosts, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        spec
    }

    fn synth(s: &str) -> SyntheticTraceGenerator {
        SyntheticTraceGenerator::parse(s, 0).unwrap()
    }

    const SYNTH_SMALL: &str = "vms=60,rate=4,life=30,migrates=4,seed=11";

    #[test]
    fn synth_replay_routes_every_event_and_drains() {
        let bank = testkit::shared_bank();
        let mut reader = synth(SYNTH_SMALL);
        let r = replay(&spec(4), &mut reader, bank).unwrap();
        assert_eq!(r.arrivals, 60);
        assert_eq!(r.departures, 60, "capped lifetimes all depart");
        assert!(!r.truncated);
        assert_eq!(r.final_live, 0);
        assert_eq!(r.final_residents.iter().sum::<usize>(), 0);
        assert!(r.peak_live > 0 && r.peak_live <= 60);
        assert!(
            r.events_routed >= r.arrivals + r.departures,
            "every published event must be routed: {} < {}",
            r.events_routed,
            r.arrivals + r.departures
        );
        assert!(r.core_hours > 0.0);
        assert!(r.events_per_sec() > 0.0);
    }

    #[test]
    fn replay_is_bit_identical_across_step_modes() {
        let bank = testkit::shared_bank();
        let run = |mode: StepMode| {
            let mut s = spec(4);
            s.step_mode = mode;
            s.dispatcher = Dispatcher::PerpDistance;
            let mut reader = synth(SYNTH_SMALL);
            replay(&s, &mut reader, bank).unwrap()
        };
        let single = run(StepMode::Single);
        for other in [run(StepMode::Scoped(3)), run(StepMode::Pool(3))] {
            assert_eq!(single.core_hours.to_bits(), other.core_hours.to_bits());
            assert_eq!(
                single.completion_time.to_bits(),
                other.completion_time.to_bits()
            );
            assert_eq!(single.final_residents, other.final_residents);
            assert_eq!(single.events_routed, other.events_routed);
            assert_eq!(single.ticks, other.ticks);
            assert_eq!(single.migrations_started, other.migrations_started);
        }
    }

    #[test]
    fn replay_is_bit_identical_across_inline_and_zero_lag_deferred() {
        let bank = testkit::shared_bank();
        let run = |actuation: ActuationSpec| {
            let mut s = spec(3);
            s.actuation = actuation;
            let mut reader = synth("vms=40,rate=4,life=25,seed=5");
            replay(&s, &mut reader, bank).unwrap()
        };
        let inline = run(ActuationSpec::Inline);
        let deferred = run(ActuationSpec::Deferred {
            latency_ticks: 0,
            budget_per_tick: 0,
        });
        assert_eq!(inline.core_hours.to_bits(), deferred.core_hours.to_bits());
        assert_eq!(
            inline.completion_time.to_bits(),
            deferred.completion_time.to_bits()
        );
        assert_eq!(inline.final_residents, deferred.final_residents);
        assert_eq!(inline.events_routed, deferred.events_routed);
    }

    fn arrival(at: f64, vm: u32, lifetime: Option<f64>) -> TraceEvent {
        TraceEvent {
            at_tick: at,
            vm,
            op: TraceOp::Arrival {
                class: WorkloadClass::Hadoop,
                lifetime,
            },
        }
    }

    fn classed_arrival(
        at: f64,
        vm: u32,
        class: WorkloadClass,
        lifetime: Option<f64>,
    ) -> TraceEvent {
        TraceEvent {
            at_tick: at,
            vm,
            op: TraceOp::Arrival { class, lifetime },
        }
    }

    /// A load spike that decays: 48 CPU-heavy VMs burst in over 8 ticks;
    /// 40 of them depart staggered (t≈60..255), 8 streaming survivors
    /// never depart. A far-out sentinel arrival pins both the migrator
    /// and baseline replays to the same ~600 s window so their energy
    /// integrals are comparable.
    fn spike_trace() -> Vec<TraceEvent> {
        let mut events = Vec::new();
        for i in 0..8u32 {
            events.push(classed_arrival(i as f64, i, WorkloadClass::StreamHigh, None));
        }
        for i in 8..48u32 {
            events.push(classed_arrival(
                (i % 8) as f64,
                i,
                WorkloadClass::Blackscholes,
                Some(60.0 + (i - 8) as f64 * 5.0),
            ));
        }
        events.sort_by(|a, b| a.at_tick.partial_cmp(&b.at_tick).unwrap().then(a.vm.cmp(&b.vm)));
        events.push(classed_arrival(600.0, 100, WorkloadClass::StreamLow, None));
        events
    }

    fn migrator_params(spec_str: &str) -> crate::config::MigratorParams {
        crate::config::MigratorParams::parse(spec_str).unwrap()
    }

    #[test]
    fn migrator_converges_the_spike_to_fewer_hosts_and_less_energy() {
        // The PR's acceptance gate: the same decaying load spike with
        // the continuous migrator converges to fewer active hosts and
        // lower parked-aware cluster energy than without it, at equal
        // or lower SLAV.
        let bank = testkit::shared_bank();
        let run = |migrator: Option<crate::config::MigratorParams>| {
            let mut s = spec(8);
            s.migration.failure_prob = 0.0; // deterministic outcome
            s.migrator = migrator;
            let mut reader = SliceReader::new(spike_trace()).emitting_departures(false);
            replay(&s, &mut reader, bank).unwrap()
        };
        let without = run(None);
        let with = run(Some(migrator_params("0.85:0.35:6:15")));

        assert_eq!(without.migrator_moves, 0);
        assert_eq!(without.migrations_completed, 0);
        assert!(with.migrations_completed > 0, "migrator must move VMs");
        assert!(
            with.final_active_hosts() < without.final_active_hosts(),
            "consolidation must drain hosts: {} vs {}",
            with.final_active_hosts(),
            without.final_active_hosts()
        );
        assert!(
            with.energy_wh < without.energy_wh * 0.95,
            "parking must save energy: {:.2} Wh vs {:.2} Wh",
            with.energy_wh,
            without.energy_wh
        );
        assert!(
            with.slav <= without.slav + 1e-9,
            "consolidation must not add overload: {} vs {}",
            with.slav,
            without.slav
        );
        assert!(
            with.converge_ticks.is_some(),
            "the powered-host series must show the fleet half-draining"
        );
        // Residents are conserved either way: 8 survivors + sentinel.
        assert_eq!(with.final_residents.iter().sum::<usize>(), 9);
        assert_eq!(without.final_residents.iter().sum::<usize>(), 9);
    }

    #[test]
    fn migrator_replay_is_bit_identical_across_step_modes() {
        let bank = testkit::shared_bank();
        let run = |mode: StepMode| {
            let mut s = spec(4);
            s.step_mode = mode;
            s.migrator = Some(migrator_params("0.85:0.35:4:10"));
            let mut reader = synth(SYNTH_SMALL);
            replay(&s, &mut reader, bank).unwrap()
        };
        let single = run(StepMode::Single);
        for other in [run(StepMode::Scoped(3)), run(StepMode::Pool(3))] {
            assert_eq!(single.core_hours.to_bits(), other.core_hours.to_bits());
            assert_eq!(
                single.completion_time.to_bits(),
                other.completion_time.to_bits()
            );
            assert_eq!(single.energy_wh.to_bits(), other.energy_wh.to_bits());
            assert_eq!(single.slav.to_bits(), other.slav.to_bits());
            assert_eq!(single.final_residents, other.final_residents);
            assert_eq!(single.events_routed, other.events_routed);
            assert_eq!(single.ticks, other.ticks);
            assert_eq!(single.migrator_moves, other.migrator_moves);
            assert_eq!(single.migrations_started, other.migrations_started);
            assert_eq!(single.migrations_completed, other.migrations_completed);
            assert_eq!(single.migrations_failed, other.migrations_failed);
        }
    }

    #[test]
    fn migrator_replay_is_bit_identical_across_inline_and_zero_lag_deferred() {
        let bank = testkit::shared_bank();
        let run = |actuation: ActuationSpec| {
            let mut s = spec(3);
            s.actuation = actuation;
            s.migrator = Some(migrator_params("0.85:0.35:4:10"));
            let mut reader = synth("vms=40,rate=4,life=25,seed=5");
            replay(&s, &mut reader, bank).unwrap()
        };
        let inline = run(ActuationSpec::Inline);
        let deferred = run(ActuationSpec::Deferred {
            latency_ticks: 0,
            budget_per_tick: 0,
        });
        assert_eq!(inline.core_hours.to_bits(), deferred.core_hours.to_bits());
        assert_eq!(
            inline.completion_time.to_bits(),
            deferred.completion_time.to_bits()
        );
        assert_eq!(inline.energy_wh.to_bits(), deferred.energy_wh.to_bits());
        assert_eq!(inline.final_residents, deferred.final_residents);
        assert_eq!(inline.events_routed, deferred.events_routed);
        assert_eq!(inline.migrator_moves, deferred.migrator_moves);
    }

    #[test]
    fn never_firing_migrator_only_adds_the_settle_window() {
        // A migrator whose thresholds can never trip publishes nothing
        // and draws no RNG, so everything the placement computed —
        // core-hours, residents, routing — is bit-identical to the
        // migrator-off (PR 7) driver; only the settle-window ticks (and
        // their idle-time accounting) are extra.
        let bank = testkit::shared_bank();
        let run = |migrator: Option<crate::config::MigratorParams>| {
            let mut s = spec(4);
            s.migrator = migrator;
            let mut reader = synth(SYNTH_SMALL);
            replay(&s, &mut reader, bank).unwrap()
        };
        let off = run(None);
        let inert = run(Some(crate::config::MigratorParams {
            over: 1.5,
            under: 0.0,
            wi_threshold: 1e9,
            ..Default::default()
        }));
        assert_eq!(inert.migrator_moves, 0);
        assert_eq!(off.core_hours.to_bits(), inert.core_hours.to_bits());
        assert_eq!(off.final_residents, inert.final_residents);
        assert_eq!(off.events_routed, inert.events_routed);
        assert_eq!(off.migrations_started, inert.migrations_started);
        assert_eq!(off.arrivals, inert.arrivals);
        assert_eq!(off.departures, inert.departures);
        assert!(inert.ticks > off.ticks, "settle window ticks are extra");
    }

    #[test]
    fn lifetime_fallback_schedules_departures_replay_side() {
        // A reader that only stamps lifetimes: the driver's due-heap
        // must retire every finite-lifetime VM; the None-lifetime VM
        // stays resident.
        let bank = testkit::shared_bank();
        let events = vec![
            arrival(0.0, 0, Some(5.0)),
            arrival(0.0, 1, None),
            arrival(2.0, 2, Some(0.5)), // departs the tick after arrival
        ];
        let mut reader = SliceReader::new(events).emitting_departures(false);
        let r = replay(&spec(2), &mut reader, bank).unwrap();
        assert_eq!(r.arrivals, 3);
        assert_eq!(r.departures, 2);
        assert_eq!(r.final_live, 1);
        assert_eq!(r.final_residents.iter().sum::<usize>(), 1);
        assert!(!r.truncated);
    }

    #[test]
    fn same_tick_departure_defers_until_the_host_is_known() {
        // Explicit departure in the same tick as the arrival: the driver
        // can't address it until the bus routes the arrival, so it defers
        // one tick and then lands on the routed host.
        let bank = testkit::shared_bank();
        let events = vec![
            arrival(0.0, 0, None),
            TraceEvent {
                at_tick: 0.0,
                vm: 0,
                op: TraceOp::Departure,
            },
        ];
        let mut reader = SliceReader::new(events);
        let r = replay(&spec(2), &mut reader, bank).unwrap();
        assert_eq!(r.arrivals, 1);
        assert_eq!(r.departures, 1);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.final_residents.iter().sum::<usize>(), 0);
    }

    #[test]
    fn migrate_events_move_vms_through_the_bus() {
        let bank = testkit::shared_bank();
        let events = vec![
            arrival(0.0, 0, None),
            TraceEvent {
                at_tick: 3.0,
                vm: 0,
                op: TraceOp::Migrate,
            },
        ];
        let mut s = spec(2);
        s.migration.failure_prob = 0.0;
        let mut reader = SliceReader::new(events);
        let r = replay(&s, &mut reader, bank).unwrap();
        assert_eq!(r.migrates, 1);
        assert_eq!(r.migrations_started, 1);
        assert_eq!(r.final_live, 1);
        // The replay loop stops once the trace drains; the transfer may
        // still be in flight, but it was started through the bus — which
        // is the contract (migration completion is the bus's job).
    }

    #[test]
    fn departures_and_migrates_for_dead_vms_are_counted_not_fatal() {
        let bank = testkit::shared_bank();
        let events = vec![
            arrival(0.0, 0, None),
            TraceEvent {
                at_tick: 1.0,
                vm: 0,
                op: TraceOp::Departure,
            },
            TraceEvent {
                at_tick: 2.0,
                vm: 0,
                op: TraceOp::Migrate,
            },
            TraceEvent {
                at_tick: 3.0,
                vm: 0,
                op: TraceOp::Departure,
            },
        ];
        let mut reader = SliceReader::new(events);
        let r = replay(&spec(2), &mut reader, bank).unwrap();
        assert_eq!(r.departures, 1);
        assert_eq!(r.dropped, 2);
    }

    #[test]
    fn malformed_streams_error_out() {
        let bank = testkit::shared_bank();
        // Duplicate arrival id.
        let mut dup = SliceReader::new(vec![arrival(0.0, 7, None), arrival(1.0, 7, None)]);
        let err = replay(&spec(2), &mut dup, bank).unwrap_err().to_string();
        assert!(err.contains("duplicate arrival"), "{err}");
        // Regressing timestamps.
        let mut back = SliceReader::new(vec![arrival(5.0, 0, None), arrival(1.0, 1, None)]);
        let err = replay(&spec(2), &mut back, bank).unwrap_err().to_string();
        assert!(err.contains("regress"), "{err}");
        // An empty trace is a configuration error, not a silent no-op.
        let mut empty = SliceReader::new(Vec::new());
        assert!(replay(&spec(2), &mut empty, bank).is_err());
    }

    #[test]
    fn events_beyond_max_time_truncate_instead_of_ticking_forever() {
        let bank = testkit::shared_bank();
        let mut s = spec(2);
        s.cfg.sim.max_time = 50.0;
        let events = vec![arrival(0.0, 0, None), arrival(1e9, 1, None)];
        let mut reader = SliceReader::new(events);
        let r = replay(&s, &mut reader, bank).unwrap();
        assert!(r.truncated);
        assert_eq!(r.arrivals, 1);
        assert!(r.completion_time <= 50.0 + s.cfg.sim.dt);
    }

    /// Drive a sawtooth load straight through a [`ClusterSim`] —
    /// arrivals and departures published by hand, `vm → host` tracked
    /// via the placement log — so the test can read the powered-host
    /// series, which [`ReplayResult`] deliberately does not carry.
    ///
    /// The shape: 8 streaming floor VMs (2 per host, never departing)
    /// plus 4 waves of 16 Jacobi VMs that arrive at `t = 30 + 90·w` and
    /// depart 60 s later. Every 30 s trough dips each floor host below
    /// the `under` line (0.6 of 12 cores at `under=0.25`); every wave
    /// lifts it back out (≥ 4.2 cores). The convex power table bills
    /// packed hosts steeply, so needless consolidation shows up in the
    /// energy integral, not just the migration counters.
    fn run_sawtooth(migrator: &str) -> (crate::cluster::BusStats, crate::metrics::ClusterLedger) {
        let bank = testkit::shared_bank();
        let mut s = spec(4);
        s.cfg.power =
            crate::config::PowerModel::parse("piecewise:0=10,0.5=40,1=1000").unwrap();
        s.migration.failure_prob = 0.0; // deterministic move outcomes
        s.migration.downtime = 0.0; // moved VMs keep their cores busy
        s.migrator = Some(migrator_params(migrator));
        let empty = ScenarioSpec {
            name: "sawtooth".to_string(),
            sr: 0.0,
            vms: Vec::new(),
            min_duration: 0.0,
        };
        let mut sim = ClusterSim::new(s, &empty, bank).unwrap();

        let mut arrivals: Vec<(f64, u32, WorkloadClass)> = (0..8)
            .map(|i| (0.0, i, WorkloadClass::StreamHigh))
            .collect();
        let mut departures: Vec<(f64, u32)> = Vec::new();
        let mut id = 8u32;
        for wave in 0..4 {
            let at = 30.0 + 90.0 * wave as f64;
            for _ in 0..16 {
                arrivals.push((at, id, WorkloadClass::Jacobi));
                departures.push((at + 60.0, id));
                id += 1;
            }
        }

        let mut vm_host: BTreeMap<u32, usize> = BTreeMap::new();
        let mut next_arrival = 0;
        let mut next_departure = 0;
        while sim.now() < 420.0 {
            let now = sim.now();
            while next_arrival < arrivals.len() && arrivals[next_arrival].0 <= now {
                let (_, vm, class) = arrivals[next_arrival];
                let mut vm = Vm::new(VmId(vm), class, now, ActivityModel::AlwaysOn);
                vm.state = VmState::Running;
                vm.started = Some(now);
                sim.publish(ClusterEvent::Arrival { vm, host: None });
                next_arrival += 1;
            }
            while next_departure < departures.len() && departures[next_departure].0 <= now {
                let (_, vm) = departures[next_departure];
                // Jacobi arrive tens of ticks before they depart, so the
                // placement log always knows their host by now.
                let host = vm_host[&vm];
                sim.publish(ClusterEvent::Departure { host, vm: VmId(vm) });
                next_departure += 1;
            }
            sim.tick(bank).unwrap();
            for (VmId(v), host) in sim.take_moves() {
                vm_host.insert(v, host);
            }
        }
        (sim.bus().stats, sim.ledger().clone())
    }

    /// Σ of positive deltas in the powered-host series after `after` —
    /// every unit is one host powering back up (an unpark). A planner
    /// that never parks scores 0; park/unpark thrash scores one rise
    /// per host per cycle.
    fn unpark_rises(ledger: &crate::metrics::ClusterLedger, after: f64) -> f64 {
        ledger
            .powered_series
            .points
            .windows(2)
            .filter(|w| w[1].0 > after && w[1].1 > w[0].1)
            .map(|w| w[1].1 - w[0].1)
            .sum()
    }

    #[test]
    fn forecaster_and_payback_suppress_sawtooth_park_unpark_thrash() {
        // ISSUE 10 thrash regression gate. The myopic PR 8 planner
        // consolidates the floor at the first trough and then re-parks
        // (full 8-VM evacuations) at every later trough, while each
        // wave powers the drained hosts straight back up — classic
        // park/unpark thrash. With forecast=on, the k=3 hysteresis
        // needs 45 s of consecutive under-predicted passes and every
        // trough only lasts 30 s, so the forecaster never parks at all:
        // strictly fewer cycles, ≥30% fewer migrations, and (under the
        // convex power table) far less energy at no worse SLAV.
        const MYOPIC: &str = "0.7:0.25:8:15,cooldown=30,wi=1000000";
        const FORECAST: &str = "0.7:0.25:8:15,cooldown=30,wi=1000000,\
                                forecast=on,alpha=0.3,beta=0.05,horizon=20,k=3,payback=600";
        let (my_stats, my_ledger) = run_sawtooth(MYOPIC);
        let (fc_stats, fc_ledger) = run_sawtooth(FORECAST);

        // Load-bearing floor: the myopic planner must reproduce the
        // thrash (initial 6-move consolidation + an 8-move blob hop per
        // trough), or this test is vacuous.
        assert!(
            my_stats.migrations_started >= 12,
            "myopic planner must thrash: only {} migrations",
            my_stats.migrations_started
        );
        let my_cycles = unpark_rises(&my_ledger, 50.0);
        let fc_cycles = unpark_rises(&fc_ledger, 50.0);
        assert!(
            my_cycles >= 3.0,
            "myopic parks must be undone by the waves: {my_cycles} rises"
        );
        assert!(
            fc_cycles < my_cycles,
            "forecaster must produce strictly fewer park/unpark cycles: {fc_cycles} vs {my_cycles}"
        );
        assert_eq!(
            fc_stats.migrations_started, 0,
            "every dip is shorter than k·interval — the forecaster must not park"
        );
        assert!(
            fc_stats.migrations_started * 10 <= my_stats.migrations_started * 7,
            "forecaster must cut migration events by ≥30%: {} vs {}",
            fc_stats.migrations_started,
            my_stats.migrations_started
        );
        assert!(
            fc_ledger.energy_wh() <= my_ledger.energy_wh(),
            "forecaster must not burn more energy: {:.2} Wh vs {:.2} Wh",
            fc_ledger.energy_wh(),
            my_ledger.energy_wh()
        );
        assert!(
            fc_ledger.slav() <= my_ledger.slav() + 1e-12,
            "forecaster must not add overload: {} vs {}",
            fc_ledger.slav(),
            my_ledger.slav()
        );
    }

    #[test]
    fn keyword_defaults_replay_bit_identical_to_pr8_grammar() {
        // ISSUE 10 digest gate: `forecast=off,payback=inf,power=linear`
        // spelled out must be bit-identical to the bare PR 8 grammar —
        // across Single/Scoped/Pool step modes and Inline vs zero-lag
        // Deferred actuation.
        let bank = testkit::shared_bank();
        let run = |mode: StepMode, actuation: ActuationSpec, migrator: &str, power: &str| {
            let mut s = spec(4);
            s.step_mode = mode;
            s.actuation = actuation;
            s.cfg.power = crate::config::PowerModel::parse(power).unwrap();
            s.migrator = Some(migrator_params(migrator));
            let mut reader = synth(SYNTH_SMALL);
            replay(&s, &mut reader, bank).unwrap()
        };
        const BARE: &str = "0.85:0.35:4:10";
        const SPELLED: &str = "0.85:0.35:4:10,forecast=off,payback=inf,k=2,cooldown=120";
        let baseline = run(StepMode::Single, ActuationSpec::Inline, BARE, "linear");
        for (mode, actuation) in [
            (StepMode::Single, ActuationSpec::Inline),
            (
                StepMode::Single,
                ActuationSpec::Deferred {
                    latency_ticks: 0,
                    budget_per_tick: 0,
                },
            ),
            (StepMode::Scoped(3), ActuationSpec::Inline),
            (StepMode::Pool(3), ActuationSpec::Inline),
        ] {
            let spelled = run(mode, actuation, SPELLED, "linear");
            assert_eq!(
                baseline.bit_digest(),
                spelled.bit_digest(),
                "keyword defaults diverged from the PR 8 planner ({mode:?})"
            );
        }
    }

    #[test]
    fn linear_and_one_segment_piecewise_agree_on_the_spike_scenario() {
        // ISSUE 10 power-model gate, cluster-scenario edition: a
        // one-segment piecewise table tracing the exact linear law
        // (idle 2×20 W, slope 15 W/core over 12 cores → 40 W at u=0,
        // 220 W at u=1) must integrate to the same energy as `Linear`
        // on the full spike scenario, within float rounding of the
        // interpolation arithmetic.
        let bank = testkit::shared_bank();
        let run = |power: &str| {
            let mut s = spec(8);
            s.cfg.power = crate::config::PowerModel::parse(power).unwrap();
            s.migration.failure_prob = 0.0;
            s.migrator = Some(migrator_params("0.85:0.35:6:15"));
            let mut reader = SliceReader::new(spike_trace()).emitting_departures(false);
            replay(&s, &mut reader, bank).unwrap()
        };
        let lin = run("linear");
        let pw = run("piecewise:0=40,1=220");
        // Placement decisions never see the power model, so everything
        // simulation-side is identical; only the energy integral may
        // differ by interpolation rounding.
        assert_eq!(lin.final_residents, pw.final_residents);
        assert_eq!(lin.migrations_started, pw.migrations_started);
        assert_eq!(lin.core_hours.to_bits(), pw.core_hours.to_bits());
        let tol = 1e-9 * lin.energy_wh.abs().max(1.0);
        assert!(
            (lin.energy_wh - pw.energy_wh).abs() <= tol,
            "one-segment piecewise must trace the linear law: {} vs {} Wh",
            lin.energy_wh,
            pw.energy_wh
        );
        assert!(lin.energy_wh > 0.0);
    }
}

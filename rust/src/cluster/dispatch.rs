//! Arrival dispatch: which host receives a new VM.
//!
//! The paper assumes "the datacenter management system assigns a set of
//! VMs to a server" (§IV-B); these are the standard assignment policies
//! such a system uses. Since the cluster-event redesign, policies are
//! [`ArrivalPolicy`] trait objects driven by the per-host
//! [`HostSummary`]s the event bus publishes each tick — never by raw
//! engine state — so any summary field (residents, profile-estimated
//! load, placement interference) can inform the pick.
//!
//! [`Dispatcher`] is the parseable configuration surface (symmetric
//! with `Policy::parse`): an enum naming the built-in policies, with
//! [`Dispatcher::build`] producing the routing-time object.

use super::bus::HostSummary;
use crate::util::rng::Rng;

/// Host-selection policy for cluster arrivals. `pick` sees the bus's
/// published summaries, which the bus keeps live within a tick (routing
/// an arrival bumps the destination's `resident`), so same-tick
/// arrivals spread out exactly as they would with live engine counts.
pub trait ArrivalPolicy {
    /// Pick the destination host index for one arriving VM.
    /// `summaries` is never empty.
    fn pick(&mut self, summaries: &[HostSummary], rng: &mut Rng) -> usize;

    fn name(&self) -> &'static str;
}

/// Cycle over hosts in index order.
pub struct RoundRobinPolicy {
    cursor: usize,
}

impl ArrivalPolicy for RoundRobinPolicy {
    fn pick(&mut self, summaries: &[HostSummary], _rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        let h = self.cursor % summaries.len();
        self.cursor += 1;
        h
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Host with the fewest resident VMs. Ties break **deterministically on
/// the lowest host index** — the strict `<` comparison keeps the first
/// host among equals, independent of any iterator-combinator tie rule —
/// so runs are reproducible across toolchains (regression-tested).
pub struct LeastLoadedPolicy;

impl ArrivalPolicy for LeastLoadedPolicy {
    fn pick(&mut self, summaries: &[HostSummary], _rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        let mut best = 0;
        for (h, s) in summaries.iter().enumerate().skip(1) {
            if s.resident < summaries[best].resident {
                best = h;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Pack by published interference: the host whose placement currently
/// shows the lowest worst-core workload interference (`max_wi`, Eq. 3/4
/// as published in [`HostSummary`]), tie-broken by the lowest
/// profile-estimated CPU load, then by the **live** resident count, then
/// by the lowest host index. Daemon-less hosts publish 0 interference,
/// so under the global strategy this degrades to a load-then-count pack.
///
/// The bus does not adjust `max_wi`/`est_cpu_load` within a tick (they
/// are placement-state facts only the host daemons know), but it does
/// bump `resident` as it routes — the resident tie-break is what spreads
/// a same-tick arrival burst across equally-quiet hosts instead of
/// stacking it on the first one; the interference facts catch up at the
/// next summary refresh.
pub struct LowestInterferencePolicy;

impl ArrivalPolicy for LowestInterferencePolicy {
    fn pick(&mut self, summaries: &[HostSummary], _rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        let mut best = 0;
        for (h, s) in summaries.iter().enumerate().skip(1) {
            let b = &summaries[best];
            // Strict `<` comparisons keep the first host among exact
            // ties, independent of any iterator-combinator tie rule —
            // the same reproducibility contract as least-loaded.
            let quieter = s.max_wi < b.max_wi
                || (s.max_wi == b.max_wi
                    && (s.est_cpu_load < b.est_cpu_load
                        || (s.est_cpu_load == b.est_cpu_load && s.resident < b.resident)));
            if quieter {
                best = h;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "lowest-interference"
    }
}

/// Uniformly random host.
pub struct RandomPolicy;

impl ArrivalPolicy for RandomPolicy {
    fn pick(&mut self, summaries: &[HostSummary], rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        rng.below(summaries.len())
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// The parseable dispatcher configuration (CLI `--dispatcher`, specs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatcher {
    RoundRobin,
    LeastLoaded,
    LowestInterference,
    Random,
}

impl Dispatcher {
    pub const ALL: [Dispatcher; 4] = [
        Dispatcher::RoundRobin,
        Dispatcher::LeastLoaded,
        Dispatcher::LowestInterference,
        Dispatcher::Random,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Dispatcher::RoundRobin => "round-robin",
            Dispatcher::LeastLoaded => "least-loaded",
            Dispatcher::LowestInterference => "lowest-interference",
            Dispatcher::Random => "random",
        }
    }

    pub fn from_name(name: &str) -> Option<Dispatcher> {
        match name.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Dispatcher::RoundRobin),
            "least-loaded" | "ll" => Some(Dispatcher::LeastLoaded),
            "lowest-interference" | "li" => Some(Dispatcher::LowestInterference),
            "random" => Some(Dispatcher::Random),
            _ => None,
        }
    }

    /// [`Self::from_name`] as a `Result`: case-insensitive, and the
    /// error lists the valid names (what the CLI surfaces on a typo) —
    /// symmetric with `Policy::parse`.
    pub fn parse(name: &str) -> anyhow::Result<Dispatcher> {
        Dispatcher::from_name(name).ok_or_else(|| {
            let valid: Vec<&str> = Dispatcher::ALL.iter().map(|d| d.name()).collect();
            anyhow::anyhow!("unknown dispatcher '{name}' (valid: {})", valid.join(", "))
        })
    }

    /// Build the routing-time policy object the bus drives.
    pub fn build(self) -> Box<dyn ArrivalPolicy> {
        match self {
            Dispatcher::RoundRobin => Box::new(RoundRobinPolicy { cursor: 0 }),
            Dispatcher::LeastLoaded => Box::new(LeastLoadedPolicy),
            Dispatcher::LowestInterference => Box::new(LowestInterferencePolicy),
            Dispatcher::Random => Box::new(RandomPolicy),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summaries(residents: &[usize]) -> Vec<HostSummary> {
        residents
            .iter()
            .map(|&resident| HostSummary {
                resident,
                ..HostSummary::default()
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(1);
        let s = summaries(&[0, 0, 0]);
        let picks: Vec<usize> = (0..5).map(|_| policy.pick(&s, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_prefers_empty_host() {
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[3, 0, 2]), &mut rng), 1);
    }

    #[test]
    fn least_loaded_ties_break_on_lowest_host_index() {
        // Regression: the tie-break is part of the policy's contract, not
        // an accident of iterator internals.
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[2, 1, 1, 1]), &mut rng), 1);
        assert_eq!(policy.pick(&summaries(&[0, 0, 0, 0]), &mut rng), 0);
        assert_eq!(policy.pick(&summaries(&[5, 4, 3, 3]), &mut rng), 2);
    }

    /// Summaries with explicit interference/load facts alongside the
    /// resident counts.
    fn wi_summaries(rows: &[(usize, f64, f64)]) -> Vec<HostSummary> {
        rows.iter()
            .map(|&(resident, max_wi, est_cpu_load)| HostSummary {
                resident,
                max_wi,
                est_cpu_load,
                ..HostSummary::default()
            })
            .collect()
    }

    #[test]
    fn lowest_interference_vs_least_loaded_head_to_head() {
        // Host 0: fewest residents but a high-interference placement.
        // Host 2: more residents, quiet placement. Least-loaded packs by
        // count and picks host 0; lowest-interference reads the bus's
        // max_wi and picks host 2 — the ROADMAP's WI-aware dispatch.
        let s = wi_summaries(&[(1, 2.4, 0.9), (3, 1.1, 2.0), (2, 0.6, 1.4)]);
        let mut rng = Rng::new(1);
        let mut ll = Dispatcher::LeastLoaded.build();
        let mut li = Dispatcher::LowestInterference.build();
        assert_eq!(ll.pick(&s, &mut rng), 0);
        assert_eq!(li.pick(&s, &mut rng), 2);
    }

    #[test]
    fn lowest_interference_tie_breaks_on_load_then_residents_then_index() {
        let mut policy = Dispatcher::LowestInterference.build();
        let mut rng = Rng::new(1);
        // Equal interference: the profile-estimated load decides.
        let s = wi_summaries(&[(1, 0.8, 2.0), (1, 0.8, 0.5), (1, 0.8, 1.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 1);
        // Equal interference and load: the live resident count decides —
        // this is what spreads a same-tick burst, because the bus bumps
        // `resident` as it routes while `max_wi` stays stale in-tick.
        let s = wi_summaries(&[(2, 0.8, 1.0), (0, 0.8, 1.0), (1, 0.8, 1.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 1);
        // Full tie: lowest host index (empty cluster start).
        let s = wi_summaries(&[(0, 0.0, 0.0), (0, 0.0, 0.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 0);
    }

    #[test]
    fn lowest_interference_spreads_a_same_tick_burst_via_live_residents() {
        // Route 4 arrivals into an empty 2-host cluster in one tick: the
        // bus's live resident bumps must alternate the picks instead of
        // stacking everything on host 0.
        use crate::cluster::bus::{ClusterEvent, EventBus};
        use crate::cluster::migration::MigrationModel;
        use crate::hostsim::{ActivityModel, Vm, VmId, VmState};

        let mut bus = EventBus::new(2, MigrationModel::default(), 12);
        let mut policy = Dispatcher::LowestInterference.build();
        let mut rng = Rng::new(1);
        for i in 0..4 {
            let mut vm = Vm::new(
                VmId(i),
                crate::workloads::WorkloadClass::Hadoop,
                0.0,
                ActivityModel::AlwaysOn,
            );
            vm.state = VmState::Running;
            bus.publish(ClusterEvent::Arrival { vm, host: None });
        }
        bus.route(policy.as_mut(), &mut rng).unwrap();
        let counts: Vec<usize> = bus.summaries().iter().map(|s| s.resident).collect();
        assert_eq!(counts, vec![2, 2], "burst must spread across hosts");
    }

    #[test]
    fn random_stays_in_range() {
        let mut policy = Dispatcher::Random.build();
        let mut rng = Rng::new(2);
        let s = summaries(&[1, 1, 1, 1]);
        for _ in 0..100 {
            assert!(policy.pick(&s, &mut rng) < 4);
        }
    }

    #[test]
    fn parse_lists_valid_names_on_error() {
        for d in Dispatcher::ALL {
            assert_eq!(Dispatcher::parse(d.name()).unwrap(), d);
            assert_eq!(
                Dispatcher::parse(&d.name().to_ascii_uppercase()).unwrap(),
                d
            );
        }
        assert_eq!(Dispatcher::parse("rr").unwrap(), Dispatcher::RoundRobin);
        assert_eq!(
            Dispatcher::parse("li").unwrap(),
            Dispatcher::LowestInterference
        );
        let err = Dispatcher::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("round-robin"), "{err}");
        assert!(err.contains("least-loaded"), "{err}");
        assert!(err.contains("lowest-interference"), "{err}");
        assert!(err.contains("random"), "{err}");
        assert_eq!(Dispatcher::ALL.map(|d| d.name()).len(), 4);
    }
}

//! Arrival dispatch: which host receives a new VM.
//!
//! The paper assumes "the datacenter management system assigns a set of
//! VMs to a server" (§IV-B); these are the standard assignment policies
//! such a system uses.

use crate::util::rng::Rng;

/// Host-selection policy for arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatcher {
    /// Cycle over hosts.
    RoundRobin,
    /// Host with the fewest resident VMs.
    LeastLoaded,
    /// Uniformly random host.
    Random,
}

impl Dispatcher {
    /// Pick a host given per-host resident-VM counts.
    pub fn pick(
        self,
        residents: &[usize],
        rr_state: &mut usize,
        rng: &mut Rng,
    ) -> usize {
        assert!(!residents.is_empty());
        match self {
            Dispatcher::RoundRobin => {
                let h = *rr_state % residents.len();
                *rr_state += 1;
                h
            }
            Dispatcher::LeastLoaded => residents
                .iter()
                .enumerate()
                .min_by_key(|(_, &n)| n)
                .map(|(h, _)| h)
                .unwrap(),
            Dispatcher::Random => rng.below(residents.len()),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dispatcher::RoundRobin => "round-robin",
            Dispatcher::LeastLoaded => "least-loaded",
            Dispatcher::Random => "random",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut rr = 0;
        let mut rng = Rng::new(1);
        let counts = vec![0, 0, 0];
        let picks: Vec<usize> = (0..5)
            .map(|_| Dispatcher::RoundRobin.pick(&counts, &mut rr, &mut rng))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_prefers_empty_host() {
        let mut rr = 0;
        let mut rng = Rng::new(1);
        let h = Dispatcher::LeastLoaded.pick(&[3, 0, 2], &mut rr, &mut rng);
        assert_eq!(h, 1);
    }

    #[test]
    fn random_stays_in_range() {
        let mut rr = 0;
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let h = Dispatcher::Random.pick(&[1, 1, 1, 1], &mut rr, &mut rng);
            assert!(h < 4);
        }
    }
}

//! Arrival dispatch: which host receives a new VM.
//!
//! The paper assumes "the datacenter management system assigns a set of
//! VMs to a server" (§IV-B); these are the standard assignment policies
//! such a system uses. Since the cluster-event redesign, policies are
//! [`ArrivalPolicy`] trait objects driven by the per-host
//! [`HostSummary`]s the event bus publishes each tick — never by raw
//! engine state — so any summary field (residents, profile-estimated
//! load, placement interference) can inform the pick.
//!
//! [`Dispatcher`] is the parseable configuration surface (symmetric
//! with `Policy::parse`): an enum naming the built-in policies, with
//! [`Dispatcher::build`] producing the routing-time object.

use super::bus::HostSummary;
use crate::util::rng::Rng;

/// Host-selection policy for cluster arrivals. `pick` sees the bus's
/// published summaries, which the bus keeps live within a tick (routing
/// an arrival bumps the destination's `resident`), so same-tick
/// arrivals spread out exactly as they would with live engine counts.
pub trait ArrivalPolicy {
    /// Pick the destination host index for one arriving VM.
    /// `summaries` is never empty.
    fn pick(&mut self, summaries: &[HostSummary], rng: &mut Rng) -> usize;

    fn name(&self) -> &'static str;
}

/// Cycle over hosts in index order.
pub struct RoundRobinPolicy {
    cursor: usize,
}

impl ArrivalPolicy for RoundRobinPolicy {
    fn pick(&mut self, summaries: &[HostSummary], _rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        let h = self.cursor % summaries.len();
        self.cursor += 1;
        h
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Host with the fewest resident VMs. Ties break **deterministically on
/// the lowest host index** — the strict `<` comparison keeps the first
/// host among equals, independent of any iterator-combinator tie rule —
/// so runs are reproducible across toolchains (regression-tested).
pub struct LeastLoadedPolicy;

impl ArrivalPolicy for LeastLoadedPolicy {
    fn pick(&mut self, summaries: &[HostSummary], _rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        let mut best = 0;
        for (h, s) in summaries.iter().enumerate().skip(1) {
            if s.resident < summaries[best].resident {
                best = h;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Uniformly random host.
pub struct RandomPolicy;

impl ArrivalPolicy for RandomPolicy {
    fn pick(&mut self, summaries: &[HostSummary], rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        rng.below(summaries.len())
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// The parseable dispatcher configuration (CLI `--dispatcher`, specs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatcher {
    RoundRobin,
    LeastLoaded,
    Random,
}

impl Dispatcher {
    pub const ALL: [Dispatcher; 3] = [
        Dispatcher::RoundRobin,
        Dispatcher::LeastLoaded,
        Dispatcher::Random,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Dispatcher::RoundRobin => "round-robin",
            Dispatcher::LeastLoaded => "least-loaded",
            Dispatcher::Random => "random",
        }
    }

    pub fn from_name(name: &str) -> Option<Dispatcher> {
        match name.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Dispatcher::RoundRobin),
            "least-loaded" | "ll" => Some(Dispatcher::LeastLoaded),
            "random" => Some(Dispatcher::Random),
            _ => None,
        }
    }

    /// [`Self::from_name`] as a `Result`: case-insensitive, and the
    /// error lists the valid names (what the CLI surfaces on a typo) —
    /// symmetric with `Policy::parse`.
    pub fn parse(name: &str) -> anyhow::Result<Dispatcher> {
        Dispatcher::from_name(name).ok_or_else(|| {
            let valid: Vec<&str> = Dispatcher::ALL.iter().map(|d| d.name()).collect();
            anyhow::anyhow!("unknown dispatcher '{name}' (valid: {})", valid.join(", "))
        })
    }

    /// Build the routing-time policy object the bus drives.
    pub fn build(self) -> Box<dyn ArrivalPolicy> {
        match self {
            Dispatcher::RoundRobin => Box::new(RoundRobinPolicy { cursor: 0 }),
            Dispatcher::LeastLoaded => Box::new(LeastLoadedPolicy),
            Dispatcher::Random => Box::new(RandomPolicy),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summaries(residents: &[usize]) -> Vec<HostSummary> {
        residents
            .iter()
            .map(|&resident| HostSummary {
                resident,
                ..HostSummary::default()
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(1);
        let s = summaries(&[0, 0, 0]);
        let picks: Vec<usize> = (0..5).map(|_| policy.pick(&s, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_prefers_empty_host() {
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[3, 0, 2]), &mut rng), 1);
    }

    #[test]
    fn least_loaded_ties_break_on_lowest_host_index() {
        // Regression: the tie-break is part of the policy's contract, not
        // an accident of iterator internals.
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[2, 1, 1, 1]), &mut rng), 1);
        assert_eq!(policy.pick(&summaries(&[0, 0, 0, 0]), &mut rng), 0);
        assert_eq!(policy.pick(&summaries(&[5, 4, 3, 3]), &mut rng), 2);
    }

    #[test]
    fn random_stays_in_range() {
        let mut policy = Dispatcher::Random.build();
        let mut rng = Rng::new(2);
        let s = summaries(&[1, 1, 1, 1]);
        for _ in 0..100 {
            assert!(policy.pick(&s, &mut rng) < 4);
        }
    }

    #[test]
    fn parse_lists_valid_names_on_error() {
        for d in Dispatcher::ALL {
            assert_eq!(Dispatcher::parse(d.name()).unwrap(), d);
            assert_eq!(
                Dispatcher::parse(&d.name().to_ascii_uppercase()).unwrap(),
                d
            );
        }
        assert_eq!(Dispatcher::parse("rr").unwrap(), Dispatcher::RoundRobin);
        let err = Dispatcher::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("round-robin"), "{err}");
        assert!(err.contains("least-loaded"), "{err}");
        assert!(err.contains("random"), "{err}");
        assert_eq!(Dispatcher::ALL.map(|d| d.name()).len(), 3);
    }
}

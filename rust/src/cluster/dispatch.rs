//! Arrival dispatch: which host receives a new VM.
//!
//! The paper assumes "the datacenter management system assigns a set of
//! VMs to a server" (§IV-B); these are the standard assignment policies
//! such a system uses. Since the cluster-event redesign, policies are
//! [`ArrivalPolicy`] trait objects driven by the state the event bus
//! publishes each tick — never by raw engine state. Since the
//! score-matrix redesign that state is the flat SoA
//! [`SummaryMatrix`]: one dense column per summary fact (residents,
//! busy cores, profile-estimated load, worst-core interference) plus
//! one per-resource load column per profiled metric, and policies rank
//! a whole same-tick [`ArrivalBatch`] against all hosts in one
//! [`ArrivalPolicy::rank`] call instead of one scalar pick per VM.
//!
//! ## Policy ↔ literature map
//!
//! The classic policies mirror dslab's `vm_placement_algorithms` (the
//! reference simulator the ROADMAP benchmarks against) and the paper's
//! equations:
//!
//! | policy                | dslab analogue     | paper hook              |
//! |-----------------------|--------------------|-------------------------|
//! | `round-robin`         | `RoundRobin`       | RRS baseline, cluster-scope |
//! | `least-loaded`        | `LeastLoadedHost`  | count-packed baseline   |
//! | `random`              | `RandomHost`       | arrival-order control   |
//! | `lowest-interference` | —                  | Eq. 3/4 WI + Eq. 5 pack |
//! | `dot-product`         | `DotProduct`       | Eq. 2 vector headroom   |
//! | `cosine`              | `CosineSimilarity` | Eq. 2, shape-matched    |
//! | `norm-greedy`         | `NormBasedGreedy`  | Eq. 2, L2 best-fit      |
//! | `perp-distance`       | `PerpendicularDistance` | Eq. 2, least stranded headroom |
//!
//! The vector family scores the arrival's profile-bank demand row
//! (`U[class]`, the Eq. 2 utilisation vector) against each host's
//! **free-capacity** columns `max(cap − load, 0)`: `dot-product` packs
//! onto the host with the most demand-aligned headroom,
//! `cosine` onto the host whose headroom *shape* best matches the
//! demand (scale-free), `norm-greedy` is the L2 best-fit — the host
//! whose headroom the demand most snugly consumes — and
//! `perp-distance` minimises the headroom component orthogonal to the
//! demand direction (absolute-units shape match: large-but-misshapen
//! headroom loses). All four break exact ties on the lowest host
//! index, the same reproducibility contract as the classic policies.
//!
//! [`Dispatcher`] is the parseable configuration surface (symmetric
//! with `Policy::parse`): an enum naming the built-in policies, with
//! [`Dispatcher::build`] producing the routing-time object.

use super::bus::{HostSummary, SummaryMatrix};
use crate::profiling::ProfileBank;
use crate::util::rng::Rng;
use crate::vmcd::scheduler::ScoreBuf;
use crate::workloads::{MetricVec, WorkloadClass, NUM_METRICS};

/// The same-tick arrivals a policy ranks in one pass: one profile-bank
/// demand row (`U[class]`, Eq. 2) per arriving VM, in publish order.
#[derive(Debug, Clone, Default)]
pub struct ArrivalBatch {
    demands: Vec<MetricVec>,
}

impl ArrivalBatch {
    pub fn clear(&mut self) {
        self.demands.clear();
    }

    /// Append one arrival with an explicit demand vector.
    pub fn push(&mut self, demand: MetricVec) {
        self.demands.push(demand);
    }

    /// Append one arrival, demand looked up from the profile bank.
    pub fn push_class(&mut self, class: WorkloadClass, bank: &ProfileBank) {
        self.demands.push(bank.u[class.index()]);
    }

    pub fn len(&self) -> usize {
        self.demands.len()
    }

    pub fn is_empty(&self) -> bool {
        self.demands.is_empty()
    }

    /// The demand rows, in arrival order.
    pub fn demands(&self) -> &[MetricVec] {
        &self.demands
    }
}

/// The single-arrival demand the scalar [`ArrivalPolicy::pick`] shim
/// ranks with: one CPU core, nothing else — the neutral stand-in when
/// the caller has no profile row for the arrival.
const UNIT_CPU: MetricVec = [1.0, 0.0, 0.0, 0.0];

/// Host-selection policy for cluster arrivals.
///
/// [`Self::rank`] is the primary entry point: one call scores every
/// candidate host × every same-tick arrival off the bus's published
/// [`SummaryMatrix`] columns. Implementations must mirror the bus's
/// live within-tick updates on their own working copies — after each
/// in-batch pick the destination's `resident` grows by one and its
/// load columns by the arrival's demand — so ranking a burst is
/// bit-identical to scalar-picking it one arrival at a time against a
/// live-updated bus (the parity property in `rust/tests/proptests.rs`).
pub trait ArrivalPolicy {
    /// Rank the whole arrival batch: append one destination host index
    /// per batch entry (in batch order) to `out`, which is cleared
    /// first. `scratch` is a caller-owned reusable buffer for working
    /// copies of matrix columns; `matrix` always has ≥ 1 host.
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        rng: &mut Rng,
        out: &mut Vec<usize>,
    );

    /// Scalar compatibility shim: pick the destination for one arriving
    /// VM straight from summaries. Builds a bank-less single-arrival
    /// matrix (CPU load column from `est_cpu_load`, [`UNIT_CPU`]
    /// demand) and delegates to [`Self::rank`] — identical to the
    /// pre-matrix scalar behavior for the classic policies.
    fn pick(&mut self, summaries: &[HostSummary], rng: &mut Rng) -> usize {
        let matrix = SummaryMatrix::from_summaries(summaries, 1);
        let mut batch = ArrivalBatch::default();
        batch.push(UNIT_CPU);
        let mut scratch = ScoreBuf::default();
        let mut out = Vec::with_capacity(1);
        self.rank(&matrix, &batch, &mut scratch, rng, &mut out);
        out[0]
    }

    fn name(&self) -> &'static str;
}

/// Cycle over hosts in index order.
pub struct RoundRobinPolicy {
    cursor: usize,
}

impl ArrivalPolicy for RoundRobinPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        _scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        for _ in 0..batch.len() {
            out.push(self.cursor % hosts);
            self.cursor += 1;
        }
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Host with the fewest resident VMs. Ties break **deterministically on
/// the lowest host index** — the strict `<` comparison keeps the first
/// host among equals, independent of any iterator-combinator tie rule —
/// so runs are reproducible across toolchains (regression-tested).
pub struct LeastLoadedPolicy;

impl ArrivalPolicy for LeastLoadedPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        scratch.reset(1, hosts);
        scratch.fill_lane(0, matrix.resident());
        for _ in 0..batch.len() {
            let resident = scratch.lane(0);
            let mut best = 0;
            for (h, &r) in resident.iter().enumerate().skip(1) {
                if r < resident[best] {
                    best = h;
                }
            }
            scratch.lane_mut(0)[best] += 1.0;
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Pack by published interference: the host whose placement currently
/// shows the lowest worst-core workload interference (`max_wi`, Eq. 3/4
/// as published on the bus), tie-broken by the lowest profile-estimated
/// CPU load, then by the **live** resident count, then by the lowest
/// host index. Daemon-less hosts publish 0 interference, so under the
/// global strategy this degrades to a load-then-count pack.
///
/// `max_wi` is a placement-state fact only the host daemons know and
/// stays stale within a tick, but the load and resident columns are
/// live — the bus (and this policy's in-batch working copies) bump them
/// per routed arrival, which is what spreads a same-tick burst across
/// equally-quiet hosts instead of stacking it on the first one.
pub struct LowestInterferencePolicy;

impl ArrivalPolicy for LowestInterferencePolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        scratch.reset(3, hosts);
        scratch.fill_lane(0, matrix.max_wi());
        scratch.fill_lane(1, matrix.est_cpu_load());
        scratch.fill_lane(2, matrix.resident());
        for demand in batch.demands() {
            let best = {
                let wi = scratch.lane(0);
                let est = scratch.lane(1);
                let res = scratch.lane(2);
                let mut best = 0;
                for h in 1..hosts {
                    // Strict `<` comparisons keep the first host among
                    // exact ties, independent of any iterator-combinator
                    // tie rule — the same reproducibility contract as
                    // least-loaded.
                    let quieter = wi[h] < wi[best]
                        || (wi[h] == wi[best]
                            && (est[h] < est[best]
                                || (est[h] == est[best] && res[h] < res[best])));
                    if quieter {
                        best = h;
                    }
                }
                best
            };
            scratch.lane_mut(1)[best] += demand[0];
            scratch.lane_mut(2)[best] += 1.0;
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "lowest-interference"
    }
}

/// Uniformly random host. Batched ranking draws once per arrival in
/// batch order — the same RNG stream the scalar path consumes.
pub struct RandomPolicy;

impl ArrivalPolicy for RandomPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        _scratch: &mut ScoreBuf,
        rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        for _ in 0..batch.len() {
            out.push(rng.below(hosts));
        }
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Copy the per-resource load columns into `scratch` (one lane per
/// metric) — the vector policies' live working state for a batch.
fn load_working_copy(matrix: &SummaryMatrix, scratch: &mut ScoreBuf) {
    scratch.reset(NUM_METRICS, matrix.hosts());
    for m in 0..NUM_METRICS {
        scratch.fill_lane(m, matrix.load(m));
    }
}

/// Free capacity of `host` on `metric` against the live working loads
/// (per-host capacity vectors respected — heterogeneous clusters).
fn free_at(matrix: &SummaryMatrix, scratch: &ScoreBuf, host: usize, metric: usize) -> f64 {
    (matrix.cap(host, metric) - scratch.lane(metric)[host]).max(0.0)
}

/// Charge a placed arrival's demand to the working loads.
fn charge(scratch: &mut ScoreBuf, host: usize, demand: &MetricVec) {
    for (m, &d) in demand.iter().enumerate() {
        scratch.lane_mut(m)[host] += d;
    }
}

/// dslab `DotProduct`: maximise `demand · free` — the host with the
/// most headroom *in the directions this arrival will use*.
pub struct DotProductPolicy;

impl ArrivalPolicy for DotProductPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        load_working_copy(matrix, scratch);
        for demand in batch.demands() {
            let mut best = 0;
            let mut best_score = f64::NEG_INFINITY;
            for h in 0..hosts {
                let mut dot = 0.0;
                for (m, &d) in demand.iter().enumerate() {
                    dot += d * free_at(matrix, scratch, h, m);
                }
                if dot > best_score {
                    best_score = dot;
                    best = h;
                }
            }
            charge(scratch, best, demand);
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "dot-product"
    }
}

/// dslab `CosineSimilarity`: maximise `cos(demand, free)` — the host
/// whose free-capacity *shape* best matches the demand, independent of
/// scale. A zero-norm side (saturated host or zero demand) scores 0.
pub struct CosineSimilarityPolicy;

impl ArrivalPolicy for CosineSimilarityPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        load_working_copy(matrix, scratch);
        for demand in batch.demands() {
            let dnorm = demand.iter().map(|d| d * d).sum::<f64>().sqrt();
            let mut best = 0;
            let mut best_score = f64::NEG_INFINITY;
            for h in 0..hosts {
                let mut dot = 0.0;
                let mut fsq = 0.0;
                for (m, &d) in demand.iter().enumerate() {
                    let f = free_at(matrix, scratch, h, m);
                    dot += d * f;
                    fsq += f * f;
                }
                let denom = dnorm * fsq.sqrt();
                let cos = if denom > 0.0 { dot / denom } else { 0.0 };
                if cos > best_score {
                    best_score = cos;
                    best = h;
                }
            }
            charge(scratch, best, demand);
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "cosine"
    }
}

/// dslab `NormBasedGreedy`: minimise `‖free − demand‖²` — the L2
/// best-fit host, whose remaining headroom the arrival most snugly
/// consumes (bin-packing flavour: keeps big holes intact).
pub struct NormBasedGreedyPolicy;

impl ArrivalPolicy for NormBasedGreedyPolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        load_working_copy(matrix, scratch);
        for demand in batch.demands() {
            let mut best = 0;
            let mut best_score = f64::INFINITY;
            for h in 0..hosts {
                let mut dist = 0.0;
                for (m, &d) in demand.iter().enumerate() {
                    let gap = free_at(matrix, scratch, h, m) - d;
                    dist += gap * gap;
                }
                if dist < best_score {
                    best_score = dist;
                    best = h;
                }
            }
            charge(scratch, best, demand);
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "norm-greedy"
    }
}

/// dslab `PerpendicularDistance`: minimise `‖f‖² − (f·d̂)²` — the squared
/// perpendicular distance from the host's free-capacity vector `f` to
/// the line spanned by the demand direction `d̂`. The winner is the host
/// whose headroom is most *parallel* to what this arrival consumes,
/// i.e. with the least headroom stranded orthogonal to the demand —
/// unlike `cosine` it penalises large but misshapen headroom in
/// absolute units rather than by angle alone.
///
/// Zero demand scores every host 0 (lowest index wins). Because
/// charging a demand moves `f` exactly along `d̂`, identical in-batch
/// arrivals score the charged host identically and stack (like
/// `norm-greedy`) until a metric clamps at 0.
pub struct PerpDistancePolicy;

impl ArrivalPolicy for PerpDistancePolicy {
    fn rank(
        &mut self,
        matrix: &SummaryMatrix,
        batch: &ArrivalBatch,
        scratch: &mut ScoreBuf,
        _rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        let hosts = matrix.hosts();
        assert!(hosts > 0);
        out.clear();
        load_working_copy(matrix, scratch);
        for demand in batch.demands() {
            let dsq: f64 = demand.iter().map(|d| d * d).sum();
            let mut best = 0;
            let mut best_score = f64::INFINITY;
            for h in 0..hosts {
                let mut dot = 0.0;
                let mut fsq = 0.0;
                for (m, &d) in demand.iter().enumerate() {
                    let f = free_at(matrix, scratch, h, m);
                    dot += d * f;
                    fsq += f * f;
                }
                let perp = if dsq > 0.0 { fsq - dot * dot / dsq } else { 0.0 };
                // Strict `<` keeps the lowest host index on exact ties.
                if perp < best_score {
                    best_score = perp;
                    best = h;
                }
            }
            charge(scratch, best, demand);
            out.push(best);
        }
    }

    fn name(&self) -> &'static str {
        "perp-distance"
    }
}

/// The frozen pre-matrix scalar pickers, verbatim. These are **not**
/// wired into the bus — they are the baseline the parity proptest
/// checks the batched [`ArrivalPolicy::rank`] path against bit-for-bit,
/// and the per-host scalar side of the `dispatch` bench.
pub mod scalar {
    use super::HostSummary;
    use crate::util::rng::Rng;

    /// Scalar round-robin: advance the cursor one host per arrival.
    pub fn round_robin(cursor: &mut usize, summaries: &[HostSummary]) -> usize {
        assert!(!summaries.is_empty());
        let h = *cursor % summaries.len();
        *cursor += 1;
        h
    }

    /// Scalar least-loaded: fewest residents, lowest index on ties.
    pub fn least_loaded(summaries: &[HostSummary]) -> usize {
        assert!(!summaries.is_empty());
        let mut best = 0;
        for (h, s) in summaries.iter().enumerate().skip(1) {
            if s.resident < summaries[best].resident {
                best = h;
            }
        }
        best
    }

    /// Scalar lowest-interference: min `max_wi`, tie-broken by load,
    /// then residents, then index.
    pub fn lowest_interference(summaries: &[HostSummary]) -> usize {
        assert!(!summaries.is_empty());
        let mut best = 0;
        for (h, s) in summaries.iter().enumerate().skip(1) {
            let b = &summaries[best];
            let quieter = s.max_wi < b.max_wi
                || (s.max_wi == b.max_wi
                    && (s.est_cpu_load < b.est_cpu_load
                        || (s.est_cpu_load == b.est_cpu_load && s.resident < b.resident)));
            if quieter {
                best = h;
            }
        }
        best
    }

    /// Scalar uniform random pick.
    pub fn random(summaries: &[HostSummary], rng: &mut Rng) -> usize {
        assert!(!summaries.is_empty());
        rng.below(summaries.len())
    }
}

/// The parseable dispatcher configuration (CLI `--dispatcher`, specs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatcher {
    RoundRobin,
    LeastLoaded,
    LowestInterference,
    Random,
    DotProduct,
    CosineSimilarity,
    NormBasedGreedy,
    PerpDistance,
}

impl Dispatcher {
    pub const ALL: [Dispatcher; 8] = [
        Dispatcher::RoundRobin,
        Dispatcher::LeastLoaded,
        Dispatcher::LowestInterference,
        Dispatcher::Random,
        Dispatcher::DotProduct,
        Dispatcher::CosineSimilarity,
        Dispatcher::NormBasedGreedy,
        Dispatcher::PerpDistance,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Dispatcher::RoundRobin => "round-robin",
            Dispatcher::LeastLoaded => "least-loaded",
            Dispatcher::LowestInterference => "lowest-interference",
            Dispatcher::Random => "random",
            Dispatcher::DotProduct => "dot-product",
            Dispatcher::CosineSimilarity => "cosine",
            Dispatcher::NormBasedGreedy => "norm-greedy",
            Dispatcher::PerpDistance => "perp-distance",
        }
    }

    pub fn from_name(name: &str) -> Option<Dispatcher> {
        match name.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Dispatcher::RoundRobin),
            "least-loaded" | "ll" => Some(Dispatcher::LeastLoaded),
            "lowest-interference" | "li" => Some(Dispatcher::LowestInterference),
            "random" => Some(Dispatcher::Random),
            "dot-product" | "dp" => Some(Dispatcher::DotProduct),
            "cosine" | "cos" => Some(Dispatcher::CosineSimilarity),
            "norm-greedy" | "ng" => Some(Dispatcher::NormBasedGreedy),
            "perp-distance" | "pd" => Some(Dispatcher::PerpDistance),
            _ => None,
        }
    }

    /// [`Self::from_name`] as a `Result`: case-insensitive, and the
    /// error lists the valid names (what the CLI surfaces on a typo) —
    /// symmetric with `Policy::parse`.
    pub fn parse(name: &str) -> anyhow::Result<Dispatcher> {
        Dispatcher::from_name(name).ok_or_else(|| {
            let valid: Vec<&str> = Dispatcher::ALL.iter().map(|d| d.name()).collect();
            anyhow::anyhow!("unknown dispatcher '{name}' (valid: {})", valid.join(", "))
        })
    }

    /// Build the routing-time policy object the bus drives.
    pub fn build(self) -> Box<dyn ArrivalPolicy> {
        match self {
            Dispatcher::RoundRobin => Box::new(RoundRobinPolicy { cursor: 0 }),
            Dispatcher::LeastLoaded => Box::new(LeastLoadedPolicy),
            Dispatcher::LowestInterference => Box::new(LowestInterferencePolicy),
            Dispatcher::Random => Box::new(RandomPolicy),
            Dispatcher::DotProduct => Box::new(DotProductPolicy),
            Dispatcher::CosineSimilarity => Box::new(CosineSimilarityPolicy),
            Dispatcher::NormBasedGreedy => Box::new(NormBasedGreedyPolicy),
            Dispatcher::PerpDistance => Box::new(PerpDistancePolicy),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summaries(residents: &[usize]) -> Vec<HostSummary> {
        residents
            .iter()
            .map(|&resident| HostSummary {
                resident,
                ..HostSummary::default()
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut policy = Dispatcher::RoundRobin.build();
        let mut rng = Rng::new(1);
        let s = summaries(&[0, 0, 0]);
        let picks: Vec<usize> = (0..5).map(|_| policy.pick(&s, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn least_loaded_prefers_empty_host() {
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[3, 0, 2]), &mut rng), 1);
    }

    #[test]
    fn least_loaded_ties_break_on_lowest_host_index() {
        // Regression: the tie-break is part of the policy's contract, not
        // an accident of iterator internals.
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        assert_eq!(policy.pick(&summaries(&[2, 1, 1, 1]), &mut rng), 1);
        assert_eq!(policy.pick(&summaries(&[0, 0, 0, 0]), &mut rng), 0);
        assert_eq!(policy.pick(&summaries(&[5, 4, 3, 3]), &mut rng), 2);
    }

    #[test]
    fn least_loaded_batched_spreads_within_the_batch() {
        // One rank call over a 4-arrival batch must spread exactly like
        // four scalar picks with live resident bumps in between.
        let mut policy = Dispatcher::LeastLoaded.build();
        let mut rng = Rng::new(1);
        let matrix = SummaryMatrix::from_summaries(&summaries(&[1, 0, 0]), 12);
        let mut batch = ArrivalBatch::default();
        for _ in 0..4 {
            batch.push([0.5, 0.0, 0.0, 0.0]);
        }
        let mut scratch = ScoreBuf::default();
        let mut out = Vec::new();
        policy.rank(&matrix, &batch, &mut scratch, &mut rng, &mut out);
        // [1,0,0] → host 1, [1,1,0] → host 2, [1,1,1] → host 0 (tie),
        // [2,1,1] → host 1.
        assert_eq!(out, vec![1, 2, 0, 1]);
    }

    /// Summaries with explicit interference/load facts alongside the
    /// resident counts.
    fn wi_summaries(rows: &[(usize, f64, f64)]) -> Vec<HostSummary> {
        rows.iter()
            .map(|&(resident, max_wi, est_cpu_load)| HostSummary {
                resident,
                max_wi,
                est_cpu_load,
                ..HostSummary::default()
            })
            .collect()
    }

    #[test]
    fn lowest_interference_vs_least_loaded_head_to_head() {
        // Host 0: fewest residents but a high-interference placement.
        // Host 2: more residents, quiet placement. Least-loaded packs by
        // count and picks host 0; lowest-interference reads the bus's
        // max_wi and picks host 2 — the ROADMAP's WI-aware dispatch.
        let s = wi_summaries(&[(1, 2.4, 0.9), (3, 1.1, 2.0), (2, 0.6, 1.4)]);
        let mut rng = Rng::new(1);
        let mut ll = Dispatcher::LeastLoaded.build();
        let mut li = Dispatcher::LowestInterference.build();
        assert_eq!(ll.pick(&s, &mut rng), 0);
        assert_eq!(li.pick(&s, &mut rng), 2);
    }

    #[test]
    fn lowest_interference_tie_breaks_on_load_then_residents_then_index() {
        let mut policy = Dispatcher::LowestInterference.build();
        let mut rng = Rng::new(1);
        // Equal interference: the profile-estimated load decides.
        let s = wi_summaries(&[(1, 0.8, 2.0), (1, 0.8, 0.5), (1, 0.8, 1.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 1);
        // Equal interference and load: the live resident count decides —
        // this is what spreads a same-tick burst, because the bus bumps
        // `resident` as it routes while `max_wi` stays stale in-tick.
        let s = wi_summaries(&[(2, 0.8, 1.0), (0, 0.8, 1.0), (1, 0.8, 1.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 1);
        // Full tie: lowest host index (empty cluster start).
        let s = wi_summaries(&[(0, 0.0, 0.0), (0, 0.0, 0.0)]);
        assert_eq!(policy.pick(&s, &mut rng), 0);
    }

    #[test]
    fn lowest_interference_spreads_a_same_tick_burst_via_live_residents() {
        // Route 4 arrivals into an empty 2-host cluster in one tick: the
        // bus's live resident bumps must alternate the picks instead of
        // stacking everything on host 0.
        use crate::cluster::bus::{ClusterEvent, EventBus};
        use crate::cluster::migration::MigrationModel;
        use crate::hostsim::{ActivityModel, Vm, VmId, VmState};
        use crate::testkit;

        let bank = testkit::shared_bank();
        let mut bus = EventBus::new(2, MigrationModel::default(), 12);
        let mut policy = Dispatcher::LowestInterference.build();
        let mut rng = Rng::new(1);
        for i in 0..4 {
            let mut vm = Vm::new(
                VmId(i),
                crate::workloads::WorkloadClass::Hadoop,
                0.0,
                ActivityModel::AlwaysOn,
            );
            vm.state = VmState::Running;
            bus.publish(ClusterEvent::Arrival { vm, host: None });
        }
        bus.route(policy.as_mut(), bank, &mut rng).unwrap();
        let counts: Vec<usize> = bus.summaries().iter().map(|s| s.resident).collect();
        assert_eq!(counts, vec![2, 2], "burst must spread across hosts");
    }

    #[test]
    fn random_stays_in_range() {
        let mut policy = Dispatcher::Random.build();
        let mut rng = Rng::new(2);
        let s = summaries(&[1, 1, 1, 1]);
        for _ in 0..100 {
            assert!(policy.pick(&s, &mut rng) < 4);
        }
    }

    /// A hand-built matrix: `host_cores` CPU capacity, per-host loads
    /// charged via the same live-update path the bus uses.
    fn matrix_with_loads(host_cores: usize, loads: &[MetricVec]) -> SummaryMatrix {
        let mut m = SummaryMatrix::new(loads.len(), host_cores);
        for (h, load) in loads.iter().enumerate() {
            m.note_arrival(h, load);
        }
        m
    }

    fn rank_one(policy: &mut dyn ArrivalPolicy, m: &SummaryMatrix, demand: MetricVec) -> usize {
        let mut batch = ArrivalBatch::default();
        batch.push(demand);
        let mut scratch = ScoreBuf::default();
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        policy.rank(m, &batch, &mut scratch, &mut rng, &mut out);
        out[0]
    }

    #[test]
    fn vector_policies_head_to_head_known_best_hosts() {
        // cap = [4, 1, 1, 1]. Host 0 is empty (free [4,1,1,1]); host 1's
        // free capacity [2, 0.5, 0, 0] is exactly proportional to the
        // demand [2, 0.5, 0, 0].
        let m = matrix_with_loads(4, &[[0.0; 4], [2.0, 0.5, 1.0, 1.0]]);
        let demand = [2.0, 0.5, 0.0, 0.0];
        // Dot-product wants raw aligned headroom: host 0 (8.5 vs 4.25).
        assert_eq!(rank_one(&mut DotProductPolicy, &m, demand), 0);
        // Cosine wants shape: host 1 is a perfect match (cos = 1).
        assert_eq!(rank_one(&mut CosineSimilarityPolicy, &m, demand), 1);
        // Norm-greedy wants the snuggest fit: host 1 (‖f−d‖² = 0).
        assert_eq!(rank_one(&mut NormBasedGreedyPolicy, &m, demand), 1);
    }

    #[test]
    fn norm_greedy_best_fits_where_dot_and_cosine_spread() {
        // CPU-only demand; host 1 has exactly one core free (snug),
        // host 0 is empty (roomy).
        let m = matrix_with_loads(4, &[[0.0; 4], [3.0, 0.0, 0.0, 0.0]]);
        let demand = [1.0, 0.0, 0.0, 0.0];
        assert_eq!(rank_one(&mut DotProductPolicy, &m, demand), 0);
        assert_eq!(rank_one(&mut CosineSimilarityPolicy, &m, demand), 0);
        assert_eq!(rank_one(&mut NormBasedGreedyPolicy, &m, demand), 1);
    }

    #[test]
    fn vector_policies_tie_break_on_lowest_host_index() {
        // Identical hosts: every vector policy must keep host 0.
        let m = matrix_with_loads(4, &[[1.0, 0.2, 0.1, 0.0]; 3]);
        let demand = [0.5, 0.1, 0.0, 0.0];
        assert_eq!(rank_one(&mut DotProductPolicy, &m, demand), 0);
        assert_eq!(rank_one(&mut CosineSimilarityPolicy, &m, demand), 0);
        assert_eq!(rank_one(&mut NormBasedGreedyPolicy, &m, demand), 0);
    }

    #[test]
    fn vector_policies_spread_within_a_batch_via_working_loads() {
        // Two identical hosts, two identical arrivals in one batch: the
        // first pick charges host 0's working loads, so the second must
        // land on host 1 (dot and cosine; norm-greedy *stacks* by design
        // — the charged host became the snugger fit).
        let m = matrix_with_loads(4, &[[0.0; 4]; 2]);
        let mut batch = ArrivalBatch::default();
        batch.push([1.0, 0.2, 0.0, 0.0]);
        batch.push([1.0, 0.2, 0.0, 0.0]);
        let mut scratch = ScoreBuf::default();
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        DotProductPolicy.rank(&m, &batch, &mut scratch, &mut rng, &mut out);
        assert_eq!(out, vec![0, 1]);
        CosineSimilarityPolicy.rank(&m, &batch, &mut scratch, &mut rng, &mut out);
        assert_eq!(out, vec![0, 1]);
        NormBasedGreedyPolicy.rank(&m, &batch, &mut scratch, &mut rng, &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn perp_distance_vs_cosine_head_to_head() {
        // cap = [4,1,1,1]. Host 0 free [1,1,0,0]; host 1 free [4,1,0,0].
        // Demand is pure CPU. Cosine rewards host 1's better *angle*
        // (4/√17 ≈ 0.97 vs 1/√2 ≈ 0.71), but both hosts strand exactly
        // one unit of non-CPU headroom (perp² = 1 each), so
        // perp-distance ties and keeps the lowest index — the
        // absolute-residue vs angle distinction in one matrix.
        let m = matrix_with_loads(4, &[[3.0, 0.0, 1.0, 1.0], [0.0, 0.0, 1.0, 1.0]]);
        let demand = [1.0, 0.0, 0.0, 0.0];
        assert_eq!(rank_one(&mut CosineSimilarityPolicy, &m, demand), 1);
        assert_eq!(rank_one(&mut PerpDistancePolicy, &m, demand), 0);
        // Give host 1 *less* stranded non-CPU headroom and it wins
        // outright (perp² = 0.25 vs host 0's 1.0).
        let m = matrix_with_loads(4, &[[3.0, 0.0, 1.0, 1.0], [0.0, 0.5, 1.0, 1.0]]);
        assert_eq!(rank_one(&mut PerpDistancePolicy, &m, demand), 1);
    }

    #[test]
    fn perp_distance_tie_breaks_on_lowest_host_index() {
        let m = matrix_with_loads(4, &[[1.0, 0.2, 0.1, 0.0]; 3]);
        assert_eq!(rank_one(&mut PerpDistancePolicy, &m, [0.5, 0.1, 0.0, 0.0]), 0);
        // Zero demand: every host scores a clean 0 — lowest index, no NaN.
        assert_eq!(rank_one(&mut PerpDistancePolicy, &m, [0.0; 4]), 0);
    }

    #[test]
    fn perp_distance_stacks_identical_arrivals_within_a_batch() {
        // Charging moves f exactly along d̂, which leaves the orthogonal
        // residue — the score — unchanged, so identical same-batch
        // arrivals stack on the tie-break host (norm-greedy flavour, by
        // design; documented on the policy).
        let m = matrix_with_loads(4, &[[0.0; 4]; 2]);
        let mut batch = ArrivalBatch::default();
        batch.push([1.0, 0.2, 0.0, 0.0]);
        batch.push([1.0, 0.2, 0.0, 0.0]);
        let mut scratch = ScoreBuf::default();
        let mut rng = Rng::new(7);
        let mut out = Vec::new();
        PerpDistancePolicy.rank(&m, &batch, &mut scratch, &mut rng, &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn vector_policies_respect_per_host_caps() {
        // Heterogeneous capacities (satellite: ClusterSpec/trace-fed
        // caps): host 1 is a bigger box, so with equal loads it has the
        // most demand-aligned headroom.
        let mut m = matrix_with_loads(4, &[[1.0, 0.0, 0.0, 0.0]; 2]);
        m.set_caps(vec![[4.0, 1.0, 1.0, 1.0], [16.0, 1.0, 1.0, 1.0]]);
        assert_eq!(rank_one(&mut DotProductPolicy, &m, [1.0, 0.0, 0.0, 0.0]), 1);
        // And the snug-fit family flips to the *smaller* box.
        assert_eq!(rank_one(&mut NormBasedGreedyPolicy, &m, [1.0, 0.0, 0.0, 0.0]), 0);
    }

    #[test]
    fn cosine_zero_norm_scores_zero_not_nan() {
        // Host 0 fully saturated (free = 0 in every metric): its score
        // must be a clean 0, never NaN, so the empty host wins.
        let m = matrix_with_loads(4, &[[4.0, 1.0, 1.0, 1.0], [0.0; 4]]);
        assert_eq!(
            rank_one(&mut CosineSimilarityPolicy, &m, [1.0, 0.0, 0.0, 0.0]),
            1
        );
        // Zero demand: every host scores 0 — lowest index wins.
        assert_eq!(rank_one(&mut CosineSimilarityPolicy, &m, [0.0; 4]), 0);
    }

    #[test]
    fn parse_lists_valid_names_on_error() {
        for d in Dispatcher::ALL {
            assert_eq!(Dispatcher::parse(d.name()).unwrap(), d);
            assert_eq!(
                Dispatcher::parse(&d.name().to_ascii_uppercase()).unwrap(),
                d
            );
        }
        assert_eq!(Dispatcher::parse("rr").unwrap(), Dispatcher::RoundRobin);
        assert_eq!(
            Dispatcher::parse("li").unwrap(),
            Dispatcher::LowestInterference
        );
        assert_eq!(Dispatcher::parse("dp").unwrap(), Dispatcher::DotProduct);
        assert_eq!(
            Dispatcher::parse("cos").unwrap(),
            Dispatcher::CosineSimilarity
        );
        assert_eq!(Dispatcher::parse("ng").unwrap(), Dispatcher::NormBasedGreedy);
        assert_eq!(Dispatcher::parse("pd").unwrap(), Dispatcher::PerpDistance);
        let err = Dispatcher::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("round-robin"), "{err}");
        assert!(err.contains("least-loaded"), "{err}");
        assert!(err.contains("lowest-interference"), "{err}");
        assert!(err.contains("random"), "{err}");
        assert!(err.contains("dot-product"), "{err}");
        assert!(err.contains("cosine"), "{err}");
        assert!(err.contains("norm-greedy"), "{err}");
        assert!(err.contains("perp-distance"), "{err}");
        assert_eq!(Dispatcher::ALL.map(|d| d.name()).len(), 8);
    }
}

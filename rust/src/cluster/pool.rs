//! Persistent shard-worker pool for cluster host stepping.
//!
//! PR 2 sharded native hosts across `std::thread::scope` workers spawned
//! *every tick*; for clusters of hundreds of hosts the per-tick spawn and
//! join dominates. [`ShardPool`] amortises it: workers are spawned once,
//! **own their native hosts for the whole run**, drain the per-tick
//! [`HostEvent`] inboxes the bus routed to them, step, and publish a
//! [`TickReport`] (metrics + the [`super::bus::HostSummary`] the bus
//! republishes) back to the coordinator over channels.
//!
//! Worker assignment is **size-aware**: hosts are weighted by resident
//! VM count and dealt, in global order, to the lightest worker, so a
//! cluster built with a few crowded and many idle hosts starts balanced
//! instead of handing one worker every crowded host in a contiguous
//! chunk. Determinism is untouched — per-host serial application plus
//! global-order reassembly make every assignment bit-identical.
//!
//! Three step modes share one code path (`step_one`): everything on
//! the caller thread ([`StepMode::Single`]), the PR 2 per-tick scoped
//! workers ([`StepMode::Scoped`], kept as the bench baseline), and the
//! persistent pool ([`StepMode::Pool`]). Hosts are independent within a
//! tick and every delivery/step mutates exactly one host, so all three
//! modes are **bit-identical** (test-gated in `sim.rs`). XLA-backed
//! hosts are not `Send` and always stay on the caller thread, whatever
//! the mode.
//!
//! This module is one of the two sanctioned thread/channel seams of the
//! determinism contract (see `DETERMINISM.md`, rule R4): `detlint`
//! confines `std::thread`/`mpsc` to here and `vmcd::actuator`, and the
//! ThreadSanitizer CI job audits both seams for races. The seam keeps
//! bit-identity because workers never share mutable state and replies
//! are reassembled in global host order, never arrival order.

use super::bus::{apply_host_event, HostEvent, TickReport};
use super::host::{ClusterHost, HostHandle, NativeHost};
use crate::hostsim::{Vm, VmId};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// How the cluster steps its hosts each tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// Everything on the caller thread.
    Single,
    /// Per-tick `std::thread::scope` workers (the pre-pool design, kept
    /// for comparison benches). Values < 2 behave like [`Self::Single`].
    Scoped(usize),
    /// Persistent worker pool: the given number of workers (≥ 1) own
    /// the native hosts for the whole run.
    Pool(usize),
}

impl StepMode {
    pub fn name(self) -> &'static str {
        match self {
            StepMode::Single => "single",
            StepMode::Scoped(_) => "scoped",
            StepMode::Pool(_) => "pool",
        }
    }
}

/// Drain one host's inbox, step it once, report. The single code path
/// every step mode funnels through.
fn step_one(host: &mut dyn HostHandle, inbox: Vec<HostEvent>) -> Result<TickReport> {
    for ev in inbox {
        apply_host_event(host, ev)?;
    }
    host.step_host()?;
    let engine = host.engine();
    Ok(TickReport {
        summary: host.summary(),
        busy_now: engine
            .ledger
            .busy_series
            .points
            .last()
            .map(|p| p.1 > 0.0)
            == Some(true),
        batch_done: engine.all_batch_done(),
    })
}

/// Work sent to a persistent worker.
enum Job {
    /// Remove the given VMs (worker-local host index) from their hosts;
    /// reply [`Reply::Extracted`] in request order.
    Extract(Vec<(usize, VmId)>),
    /// Apply one inbox per owned host (worker-local order) and step each
    /// host once; reply [`Reply::Stepped`] in the same order.
    Step(Vec<Vec<HostEvent>>),
}

enum Reply {
    Extracted(Result<Vec<Option<Vm>>>),
    Stepped(Result<Vec<TickReport>>),
}

fn worker_loop(
    mut hosts: Vec<NativeHost>,
    rx: Receiver<Job>,
    tx: Sender<Reply>,
) -> Vec<NativeHost> {
    // Channel closed (pool dropped or torn down) => return the hosts to
    // whoever joins us.
    while let Ok(job) = rx.recv() {
        let reply = match job {
            Job::Extract(reqs) => Reply::Extracted(
                reqs.into_iter()
                    .map(|(i, id)| hosts[i].remove_resident(id))
                    .collect(),
            ),
            Job::Step(inboxes) => Reply::Stepped(
                hosts
                    .iter_mut()
                    .zip(inboxes)
                    .map(|(host, inbox)| step_one(host, inbox))
                    .collect(),
            ),
        };
        if tx.send(reply).is_err() {
            break;
        }
    }
    hosts
}

struct Worker {
    tx: Sender<Job>,
    rx: Receiver<Reply>,
    handle: JoinHandle<Vec<NativeHost>>,
    /// Hosts this worker owns.
    count: usize,
}

/// Where one global host index lives.
#[derive(Debug, Clone, Copy)]
enum Slot {
    /// Caller-thread host (index into `ShardPool::local`).
    Local(usize),
    /// Pool-worker host (worker index, worker-local host index).
    Remote { worker: usize, idx: usize },
}

/// The host-stepping engine behind `ClusterSim`: owns every host (some
/// behind persistent workers), steps them against the bus's routed
/// inboxes, and reassembles per-host reports in global host order so
/// results never depend on worker scheduling.
pub struct ShardPool {
    slots: Vec<Slot>,
    local: Vec<ClusterHost>,
    workers: Vec<Worker>,
    /// > 1 => step local native hosts under a per-tick `thread::scope`.
    scoped_threads: usize,
}

impl ShardPool {
    /// Build the pool, spawning persistent workers for
    /// [`StepMode::Pool`]. Errors if the OS refuses a worker thread.
    pub fn new(hosts: Vec<ClusterHost>, mode: StepMode) -> Result<ShardPool> {
        let pool_workers = match mode {
            StepMode::Pool(n) => n.max(1),
            _ => 0,
        };
        let scoped_threads = match mode {
            StepMode::Scoped(n) => n,
            _ => 0,
        };

        let mut slots = Vec::with_capacity(hosts.len());
        let mut local = Vec::new();
        // (global index, host) pairs destined for pool workers.
        let mut native: Vec<(usize, NativeHost)> = Vec::new();
        for (g, host) in hosts.into_iter().enumerate() {
            match host {
                ClusterHost::Native(h) if pool_workers > 0 => {
                    slots.push(Slot::Remote { worker: 0, idx: 0 }); // patched below
                    native.push((g, h));
                }
                other => {
                    slots.push(Slot::Local(local.len()));
                    local.push(other);
                }
            }
        }

        let mut workers = Vec::new();
        if !native.is_empty() {
            let n_workers = pool_workers.min(native.len());
            // Size-aware assignment (ROADMAP): weight each host by its
            // resident VM count (+1 so empty hosts still cost their
            // share of fixed per-host stepping work) and hand hosts, in
            // global order, to the lightest worker so far — ties break
            // on the lowest worker index, so an all-empty cluster deals
            // evenly (same per-worker counts as the old contiguous
            // split, dealt round-robin). Reassembly is by global slot
            // order, so ANY assignment is result-identical (bit-identity
            // with single-thread stepping is test-gated); only
            // wall-clock balance changes when a few hosts are crowded
            // and many are idle.
            let mut owned: Vec<Vec<NativeHost>> =
                (0..n_workers).map(|_| Vec::new()).collect();
            let mut weights = vec![0usize; n_workers];
            for (g, h) in native {
                // Lightest worker so far, lowest index on ties. A plain
                // scan keeps this total: n_workers >= 1 here, so there
                // is always a minimum and nothing to unwrap.
                let mut w = 0;
                for cand in 1..n_workers {
                    if weights[cand] < weights[w] {
                        w = cand;
                    }
                }
                weights[w] += h.engine.vms.len() + 1;
                slots[g] = Slot::Remote {
                    worker: w,
                    idx: owned[w].len(),
                };
                owned[w].push(h);
            }
            for (w, hosts) in owned.into_iter().enumerate() {
                // Every worker owns >= 1 host: the +1 weight floor means
                // the first n_workers hosts land on distinct workers.
                let count = hosts.len();
                let (tx_job, rx_job) = channel::<Job>();
                let (tx_reply, rx_reply) = channel::<Reply>();
                let handle = std::thread::Builder::new()
                    .name(format!("shard-worker-{w}"))
                    .spawn(move || worker_loop(hosts, rx_job, tx_reply))
                    .map_err(|e| anyhow!("spawning shard worker {w}: {e}"))?;
                workers.push(Worker {
                    tx: tx_job,
                    rx: rx_reply,
                    handle,
                    count,
                });
            }
        }

        Ok(ShardPool {
            slots,
            local,
            workers,
            scoped_threads,
        })
    }

    /// Total hosts (local + worker-owned).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Worker threads currently running.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Hosts owned per worker (the size-aware assignment's shape, for
    /// tests and diagnostics).
    pub fn worker_counts(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.count).collect()
    }

    /// Remove VMs from their hosts (global host index), e.g. matured
    /// migration transfers pulling VMs off their sources. Results are in
    /// request order; `None` means the VM was not resident.
    pub fn extract(&mut self, requests: &[(usize, VmId)]) -> Result<Vec<Option<Vm>>> {
        // Partition per destination, remembering where each answer lands.
        enum Origin {
            Local(usize),
            Worker(usize, usize),
        }
        let mut origins = Vec::with_capacity(requests.len());
        let mut local_reqs: Vec<(usize, VmId)> = Vec::new();
        let mut worker_reqs: Vec<Vec<(usize, VmId)>> =
            (0..self.workers.len()).map(|_| Vec::new()).collect();
        for &(g, id) in requests {
            anyhow::ensure!(g < self.slots.len(), "extract from host {g} of {}", self.slots.len());
            match self.slots[g] {
                Slot::Local(i) => {
                    origins.push(Origin::Local(local_reqs.len()));
                    local_reqs.push((i, id));
                }
                Slot::Remote { worker, idx } => {
                    origins.push(Origin::Worker(worker, worker_reqs[worker].len()));
                    // Workers address hosts by their local index.
                    worker_reqs[worker].push((idx, id));
                }
            }
        }

        let mut asked = vec![false; self.workers.len()];
        for (w, reqs) in worker_reqs.iter_mut().enumerate() {
            if !reqs.is_empty() {
                self.workers[w]
                    .tx
                    .send(Job::Extract(std::mem::take(reqs)))
                    .map_err(|_| anyhow!("shard worker {w} hung up"))?;
                asked[w] = true;
            }
        }

        let mut local_out: Vec<Option<Vm>> = Vec::with_capacity(local_reqs.len());
        for (i, id) in local_reqs {
            local_out.push(self.local[i].handle_mut().remove_resident(id)?);
        }

        // Every asked worker is drained before any error propagates, so
        // the request/reply channels stay in lockstep for later calls.
        let mut worker_out: Vec<Vec<Option<Vm>>> =
            (0..self.workers.len()).map(|_| Vec::new()).collect();
        let mut first_err = None;
        for (w, worker) in self.workers.iter().enumerate() {
            if asked[w] {
                let outcome = match worker.rx.recv() {
                    Ok(Reply::Extracted(Ok(r))) => Ok(r),
                    Ok(Reply::Extracted(Err(e))) => Err(e),
                    Ok(_) => Err(anyhow!("shard worker {w} answered out of protocol")),
                    Err(_) => Err(anyhow!("shard worker {w} died")),
                };
                match outcome {
                    Ok(r) => worker_out[w] = r,
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        // Each position is consumed exactly once, so take() is safe.
        Ok(origins
            .into_iter()
            .map(|o| match o {
                Origin::Local(i) => local_out[i].take(),
                Origin::Worker(w, i) => worker_out[w][i].take(),
            })
            .collect())
    }

    /// Apply one routed inbox per host (global host order — the bus's
    /// [`super::bus::EventBus::take_inboxes`] output) and step every
    /// host one tick. Reports come back in global host order.
    pub fn step(&mut self, mut inboxes: Vec<Vec<HostEvent>>) -> Result<Vec<TickReport>> {
        anyhow::ensure!(
            inboxes.len() == self.slots.len(),
            "{} inboxes for {} hosts",
            inboxes.len(),
            self.slots.len()
        );
        // Partition the inboxes by destination.
        let mut local_in: Vec<Vec<HostEvent>> = (0..self.local.len()).map(|_| Vec::new()).collect();
        let mut worker_in: Vec<Vec<Vec<HostEvent>>> = self
            .workers
            .iter()
            .map(|w| (0..w.count).map(|_| Vec::new()).collect())
            .collect();
        for (g, inbox) in inboxes.drain(..).enumerate() {
            match self.slots[g] {
                Slot::Local(i) => local_in[i] = inbox,
                Slot::Remote { worker, idx } => worker_in[worker][idx] = inbox,
            }
        }

        // Kick the workers first so they overlap with the local stepping.
        for (w, job) in worker_in.into_iter().enumerate() {
            self.workers[w]
                .tx
                .send(Job::Step(job))
                .map_err(|_| anyhow!("shard worker {w} hung up"))?;
        }
        let local_result = self.step_local(local_in);
        // Drain every worker before propagating any error (local or
        // remote), so the request/reply channels stay in lockstep.
        let mut worker_reports: Vec<Vec<Option<TickReport>>> =
            Vec::with_capacity(self.workers.len());
        let mut first_err = None;
        for (w, worker) in self.workers.iter().enumerate() {
            let outcome = match worker.rx.recv() {
                Ok(Reply::Stepped(Ok(r))) => Ok(r),
                Ok(Reply::Stepped(Err(e))) => Err(e),
                Ok(_) => Err(anyhow!("shard worker {w} answered out of protocol")),
                Err(_) => Err(anyhow!("shard worker {w} died")),
            };
            match outcome {
                Ok(r) => worker_reports.push(r.into_iter().map(Some).collect()),
                Err(e) => {
                    worker_reports.push(Vec::new());
                    first_err = first_err.or(Some(e));
                }
            }
        }
        let mut local_reports = local_result?;
        if let Some(e) = first_err {
            return Err(e);
        }

        // Reassemble in global host order.
        Ok(self
            .slots
            .iter()
            .map(|slot| match *slot {
                // Invariant: every slot maps to exactly one report and
                // each is consumed exactly once (reports were built from
                // these same slots above, and errors returned already).
                // detlint: allow(panic): documented invariant, checked by every pool test
                Slot::Local(i) => local_reports[i].take().expect("local report missing"),
                Slot::Remote { worker, idx } => {
                    // detlint: allow(panic): documented invariant, checked by every pool test
                    worker_reports[worker][idx].take().expect("worker report missing")
                }
            })
            .collect())
    }

    /// Step the caller-thread hosts: natives optionally under a per-tick
    /// scope ([`StepMode::Scoped`]), pinned hosts always inline.
    fn step_local(&mut self, mut inboxes: Vec<Vec<HostEvent>>) -> Result<Vec<Option<TickReport>>> {
        let mut results: Vec<Option<TickReport>> = (0..self.local.len()).map(|_| None).collect();
        let threads = self.scoped_threads;
        let mut native: Vec<(usize, &mut NativeHost)> = Vec::new();
        let mut pinned: Vec<(usize, &mut Box<dyn HostHandle>)> = Vec::new();
        for (i, host) in self.local.iter_mut().enumerate() {
            match host {
                ClusterHost::Native(h) => native.push((i, h)),
                ClusterHost::Pinned(h) => pinned.push((i, h)),
            }
        }
        if threads > 1 && native.len() > 1 {
            #[allow(unknown_lints, clippy::manual_div_ceil)]
            let chunk = (native.len() + threads - 1) / threads;
            let shard_results: Vec<Result<Vec<(usize, TickReport)>>> =
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for shard in native.chunks_mut(chunk) {
                        // Each worker takes its hosts' inboxes with it.
                        let jobs: Vec<Vec<HostEvent>> = shard
                            .iter()
                            .map(|(i, _)| std::mem::take(&mut inboxes[*i]))
                            .collect();
                        handles.push(s.spawn(move || -> Result<Vec<(usize, TickReport)>> {
                            let mut out = Vec::with_capacity(shard.len());
                            for ((i, host), inbox) in shard.iter_mut().zip(jobs) {
                                out.push((*i, step_one(&mut **host, inbox)?));
                            }
                            Ok(out)
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| match h.join() {
                            Ok(reports) => reports,
                            Err(_) => Err(anyhow!("scoped shard worker panicked")),
                        })
                        .collect()
                });
            for shard in shard_results {
                for (i, report) in shard? {
                    results[i] = Some(report);
                }
            }
        } else {
            for (i, host) in native {
                results[i] = Some(step_one(host, std::mem::take(&mut inboxes[i]))?);
            }
        }
        for (i, host) in pinned {
            results[i] = Some(step_one(host.as_mut(), std::mem::take(&mut inboxes[i]))?);
        }
        Ok(results)
    }

    /// Tear the pool down, returning every host in the original global
    /// order (workers exit when their job channel closes).
    pub fn into_hosts(self) -> Result<Vec<ClusterHost>> {
        let ShardPool {
            slots,
            local,
            workers,
            ..
        } = self;
        let mut handles = Vec::with_capacity(workers.len());
        for worker in workers {
            let Worker { tx, handle, .. } = worker;
            drop(tx); // closes the job channel; the worker returns its hosts
            handles.push(handle);
        }
        let mut worker_hosts: Vec<Vec<Option<NativeHost>>> = Vec::with_capacity(handles.len());
        for handle in handles {
            let hosts = handle
                .join()
                .map_err(|_| anyhow!("shard worker panicked during teardown"))?;
            worker_hosts.push(hosts.into_iter().map(Some).collect());
        }
        let mut local: Vec<Option<ClusterHost>> = local.into_iter().map(Some).collect();
        Ok(slots
            .into_iter()
            .map(|slot| match slot {
                // Invariant: teardown consumes each host exactly once —
                // the slots were built from these exact hosts in new().
                // detlint: allow(panic): documented invariant, checked by every pool test
                Slot::Local(i) => local[i].take().expect("local host missing"),
                Slot::Remote { worker, idx } => ClusterHost::Native(
                    // detlint: allow(panic): documented invariant, checked by every pool test
                    worker_hosts[worker][idx].take().expect("worker host missing"),
                ),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::host::SimHost;
    use crate::hostsim::{ActivityModel, SimEngine, VmState};
    use crate::testkit;
    use crate::vmcd::scheduler::{self, Policy};
    use crate::vmcd::Daemon;
    use crate::workloads::WorkloadClass;

    fn native_host() -> NativeHost {
        let cfg = testkit::quiet_config();
        let bank = testkit::shared_bank();
        let sched = scheduler::build_native(Policy::Ias, bank, cfg.sched.ras_threshold, None);
        let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
        SimHost::new(SimEngine::new(cfg, Vec::new()), Some(daemon))
    }

    fn running_vm(id: u32) -> Vm {
        let mut vm = Vm::new(
            VmId(id),
            WorkloadClass::Hadoop,
            0.0,
            ActivityModel::AlwaysOn,
        );
        vm.state = VmState::Running;
        vm.started = Some(0.0);
        vm
    }

    fn empty_inboxes(n: usize) -> Vec<Vec<HostEvent>> {
        (0..n).map(|_| Vec::new()).collect()
    }

    #[test]
    fn pool_steps_and_returns_hosts_in_global_order() {
        let hosts: Vec<ClusterHost> =
            (0..5).map(|_| ClusterHost::Native(native_host())).collect();
        let mut pool = ShardPool::new(hosts, StepMode::Pool(2)).unwrap();
        assert_eq!(pool.len(), 5);
        assert_eq!(pool.workers(), 2);

        // Deliver one arrival to host 3 via its inbox, then step twice.
        let mut inboxes = empty_inboxes(5);
        inboxes[3].push(HostEvent::Arrival(running_vm(7)));
        let reports = pool.step(inboxes).unwrap();
        assert_eq!(reports.len(), 5);
        assert_eq!(reports[3].summary.resident, 1);
        assert!(reports.iter().enumerate().all(|(h, r)| h == 3 || r.summary.resident == 0));

        let reports = pool.step(empty_inboxes(5)).unwrap();
        assert!(reports[3].summary.busy_cores >= 1);

        let hosts = pool.into_hosts().unwrap();
        assert_eq!(hosts.len(), 5);
        let residents: Vec<usize> = hosts
            .iter()
            .map(|h| h.handle().engine().vms.len())
            .collect();
        assert_eq!(residents, vec![0, 0, 0, 1, 0]);
    }

    #[test]
    fn extract_pulls_the_vm_from_a_worker_owned_host() {
        let hosts: Vec<ClusterHost> =
            (0..4).map(|_| ClusterHost::Native(native_host())).collect();
        let mut pool = ShardPool::new(hosts, StepMode::Pool(4)).unwrap();
        let mut inboxes = empty_inboxes(4);
        inboxes[2].push(HostEvent::Arrival(running_vm(9)));
        pool.step(inboxes).unwrap();

        let out = pool.extract(&[(2, VmId(9)), (1, VmId(9))]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_ref().map(|vm| vm.id), Some(VmId(9)));
        assert!(out[1].is_none(), "host 1 never held the VM");

        let hosts = pool.into_hosts().unwrap();
        assert_eq!(hosts[2].handle().engine().vms.len(), 0);
    }

    /// A native host pre-populated with `n` running residents.
    fn populated_host(first_id: u32, n: u32) -> NativeHost {
        let mut host = native_host();
        for i in 0..n {
            host.inject_arrival(running_vm(first_id + i)).unwrap();
        }
        host
    }

    #[test]
    fn size_aware_assignment_balances_crowded_hosts() {
        // Host 0 carries 5 residents, the rest are empty. The old
        // contiguous split would give worker 0 hosts {0, 1} (6+1 weight)
        // and worker 1 hosts {2, 3}; the size-aware deal gives worker 0
        // only the crowded host and worker 1 the three empty ones.
        let hosts: Vec<ClusterHost> = vec![
            ClusterHost::Native(populated_host(0, 5)),
            ClusterHost::Native(native_host()),
            ClusterHost::Native(native_host()),
            ClusterHost::Native(native_host()),
        ];
        let pool = ShardPool::new(hosts, StepMode::Pool(2)).unwrap();
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.worker_counts(), vec![1, 3]);
        // Teardown preserves global order whatever the assignment.
        let hosts = pool.into_hosts().unwrap();
        let residents: Vec<usize> = hosts
            .iter()
            .map(|h| h.handle().engine().vms.len())
            .collect();
        assert_eq!(residents, vec![5, 0, 0, 0]);
    }

    #[test]
    fn empty_hosts_deal_round_robin_like_the_old_contiguous_split() {
        let hosts: Vec<ClusterHost> =
            (0..6).map(|_| ClusterHost::Native(native_host())).collect();
        let pool = ShardPool::new(hosts, StepMode::Pool(3)).unwrap();
        assert_eq!(pool.worker_counts(), vec![2, 2, 2]);
        pool.into_hosts().unwrap();
    }

    #[test]
    fn size_aware_chunking_is_bit_identical_to_single_thread() {
        // The satellite acceptance: the weighted assignment must not
        // change any report bit vs caller-thread stepping, even when
        // the weights actually skew the assignment.
        let run = |mode: StepMode| {
            let hosts: Vec<ClusterHost> = vec![
                ClusterHost::Native(populated_host(0, 5)),
                ClusterHost::Native(native_host()),
                ClusterHost::Native(populated_host(10, 2)),
                ClusterHost::Native(native_host()),
            ];
            let mut pool = ShardPool::new(hosts, mode).unwrap();
            let mut inboxes = empty_inboxes(4);
            inboxes[1].push(HostEvent::Arrival(running_vm(30)));
            pool.step(inboxes).unwrap();
            let reports = pool.step(empty_inboxes(4)).unwrap();
            reports
                .iter()
                .map(|r| {
                    (
                        r.summary.resident,
                        r.summary.busy_cores,
                        r.summary.max_wi.to_bits(),
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(StepMode::Single), run(StepMode::Pool(2)));
        assert_eq!(run(StepMode::Single), run(StepMode::Pool(3)));
    }

    #[test]
    fn single_and_pool_modes_report_identically() {
        let run = |mode: StepMode| {
            let hosts: Vec<ClusterHost> =
                (0..3).map(|_| ClusterHost::Native(native_host())).collect();
            let mut pool = ShardPool::new(hosts, mode).unwrap();
            let mut inboxes = empty_inboxes(3);
            inboxes[0].push(HostEvent::Arrival(running_vm(1)));
            inboxes[2].push(HostEvent::Arrival(running_vm(2)));
            pool.step(inboxes).unwrap();
            let reports = pool.step(empty_inboxes(3)).unwrap();
            reports
                .iter()
                .map(|r| (r.summary.resident, r.summary.busy_cores, r.summary.max_wi.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(StepMode::Single), run(StepMode::Pool(3)));
        assert_eq!(run(StepMode::Single), run(StepMode::Scoped(2)));
    }
}

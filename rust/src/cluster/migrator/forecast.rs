//! Per-host load forecasting for the migration planner.
//!
//! The PR 8 migrator planned against the *current* tick's
//! [`HostSummary`]s — exactly the myopia that park/unpark-thrashes when
//! load oscillates across the `under` line (SAP production traces,
//! arXiv:2510.23911, punish this hard). The forecaster keeps one
//! Holt-linear (double-exponential) track per host, fed from the same
//! summary stream the planner already reads, and extrapolates
//! `horizon` seconds ahead so classification sees where the host is
//! *going*, not where it happens to be this instant.
//!
//! * **est-CPU load** — Holt level + trend (`alpha` smooths the level,
//!   `beta` the per-second trend), so a ramp is anticipated, not chased.
//! * **`max_wi`** — plain EWMA (`alpha`); interference readings are too
//!   noisy for a trend term to help.
//!
//! Everything here is O(1) per host per tick and a pure fold over
//! simulation-published values in host order — no wall-clock, no RNG,
//! no hashing — so forecast state is bit-deterministic across runs and
//! step modes (DETERMINISM.md: forecast state is simulation-determined).

use super::super::bus::HostSummary;

/// One host's smoothing state. `level`/`trend` follow the estimated
/// CPU load (cores); `wi` follows `max_wi`.
#[derive(Debug, Clone, Copy, Default)]
struct HostTrack {
    level: f64,
    /// Per-second slope of the level.
    trend: f64,
    wi: f64,
    /// First observation seeds the track instead of smoothing toward it
    /// from zero (which would fake a cold-start ramp).
    seeded: bool,
}

/// Per-host EWMA/Holt-linear predictor over the published summary
/// stream. Owned by [`super::VmMigrator`] when `forecast=on`; fed once
/// per tick from [`crate::cluster::ClusterSim::tick`] after the bus
/// refresh.
#[derive(Debug, Clone)]
pub struct LoadForecaster {
    alpha: f64,
    beta: f64,
    hosts: Vec<HostTrack>,
}

impl LoadForecaster {
    pub fn new(alpha: f64, beta: f64) -> LoadForecaster {
        LoadForecaster {
            alpha,
            beta,
            hosts: Vec::new(),
        }
    }

    /// Fold one tick of summaries into the tracks. `dt` converts the
    /// level delta into a per-second trend; non-positive `dt` is a
    /// no-op (there is no interval to attribute the delta to).
    pub fn observe(&mut self, summaries: &[HostSummary], dt: f64) {
        if dt <= 0.0 {
            return;
        }
        self.hosts.resize(summaries.len(), HostTrack::default());
        for (track, s) in self.hosts.iter_mut().zip(summaries) {
            if !track.seeded {
                track.level = s.est_cpu_load;
                track.trend = 0.0;
                track.wi = s.max_wi;
                track.seeded = true;
                continue;
            }
            let prev = track.level;
            track.level = self.alpha * s.est_cpu_load
                + (1.0 - self.alpha) * (track.level + track.trend * dt);
            track.trend =
                self.beta * ((track.level - prev) / dt) + (1.0 - self.beta) * track.trend;
            track.wi = self.alpha * s.max_wi + (1.0 - self.alpha) * track.wi;
        }
    }

    /// Predicted est-CPU load per host, `horizon` seconds out, clamped
    /// at zero (a downward trend never predicts negative work). Hosts
    /// the forecaster has not observed yet fall back to the current
    /// summary value — identical to what the myopic planner would use.
    pub fn predict_load(&self, summaries: &[HostSummary], horizon: f64) -> Vec<f64> {
        summaries
            .iter()
            .enumerate()
            .map(|(h, s)| match self.hosts.get(h) {
                Some(t) if t.seeded => (t.level + t.trend * horizon).max(0.0),
                _ => s.est_cpu_load,
            })
            .collect()
    }

    /// Smoothed `max_wi` per host (EWMA holds no trend, so the horizon
    /// does not enter). Unobserved hosts fall back to the summary.
    pub fn predict_wi(&self, summaries: &[HostSummary]) -> Vec<f64> {
        summaries
            .iter()
            .enumerate()
            .map(|(h, s)| match self.hosts.get(h) {
                Some(t) if t.seeded => t.wi,
                _ => s.max_wi,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::WorkloadClass;

    fn summary(est: f64, wi: f64) -> HostSummary {
        HostSummary {
            resident: 1,
            running: vec![(crate::hostsim::VmId(0), WorkloadClass::Blackscholes)],
            busy_cores: 1,
            max_wi: wi,
            est_cpu_load: est,
        }
    }

    #[test]
    fn first_observation_seeds_without_a_cold_start_ramp() {
        let mut f = LoadForecaster::new(0.3, 0.1);
        f.observe(&[summary(8.0, 1.2)], 5.0);
        let pred = f.predict_load(&[summary(8.0, 1.2)], 100.0);
        assert_eq!(pred, vec![8.0], "seed takes the value verbatim, zero trend");
        assert_eq!(f.predict_wi(&[summary(8.0, 1.2)]), vec![1.2]);
    }

    #[test]
    fn steady_ramp_is_extrapolated_ahead() {
        let mut f = LoadForecaster::new(0.5, 0.5);
        // Load climbs 1 core per 5 s tick; the trend should pick up a
        // positive slope and predict beyond the last observation.
        let mut last = 0.0;
        for i in 0..40 {
            last = i as f64;
            f.observe(&[summary(last, 1.0)], 5.0);
        }
        let now = f.predict_load(&[summary(last, 1.0)], 0.0)[0];
        let ahead = f.predict_load(&[summary(last, 1.0)], 60.0)[0];
        assert!(ahead > now, "horizon must extrapolate the ramp: {ahead} vs {now}");
        // 1 core / 5 s → 0.2 cores/s → +12 cores over 60 s, roughly.
        assert!((ahead - now - 12.0).abs() < 3.0, "slope off: {}", ahead - now);
    }

    #[test]
    fn downward_trend_clamps_at_zero() {
        let mut f = LoadForecaster::new(0.5, 0.5);
        for i in (0..10).rev() {
            f.observe(&[summary(i as f64, 1.0)], 5.0);
        }
        let pred = f.predict_load(&[summary(0.0, 1.0)], 600.0)[0];
        assert_eq!(pred, 0.0, "negative work is not a prediction");
    }

    #[test]
    fn unobserved_and_grown_fleets_fall_back_to_the_summary() {
        let f = LoadForecaster::new(0.3, 0.1);
        let s = [summary(4.0, 1.1), summary(6.0, 0.9)];
        assert_eq!(f.predict_load(&s, 90.0), vec![4.0, 6.0]);
        assert_eq!(f.predict_wi(&s), vec![1.1, 0.9]);
    }

    #[test]
    fn zero_dt_observation_is_a_no_op() {
        let mut f = LoadForecaster::new(0.3, 0.1);
        f.observe(&[summary(8.0, 1.0)], 5.0);
        f.observe(&[summary(100.0, 9.0)], 0.0);
        assert_eq!(f.predict_load(&[summary(100.0, 9.0)], 0.0), vec![8.0]);
    }

    #[test]
    fn ewma_smooths_wi_spikes() {
        let mut f = LoadForecaster::new(0.2, 0.1);
        f.observe(&[summary(4.0, 1.0)], 5.0);
        f.observe(&[summary(4.0, 5.0)], 5.0); // one-tick spike
        let wi = f.predict_wi(&[summary(4.0, 5.0)])[0];
        assert!((wi - 1.8).abs() < 1e-12, "0.2·5 + 0.8·1 = 1.8, got {wi}");
    }
}

//! Continuous migration manager: the steady-state watcher that turns
//! bus-published [`HostSummary`]s into live
//! [`ClusterEvent::Migrate`](super::bus::ClusterEvent) traffic (dslab's
//! `vm_migrator` shape).
//!
//! The paper's consolidation claim (§III, Figures 4–6: up to ~50% CPU
//! time saved while workload performance holds) needs something to
//! *generate* migrations in steady state — arrivals alone only ever
//! grow placements. Following Jin et al. (arXiv:1404.2842), cost and
//! interference are optimized jointly rather than in sequence:
//!
//! | Condition (per host)                      | Classification | Action |
//! |-------------------------------------------|----------------|--------|
//! | est-CPU fraction > `over` or `max_wi` > `wi_threshold` | Overloaded | **Spread**: shed largest VMs to the least-interfering destination that stays under `over` |
//! | est-CPU fraction < `under`, non-empty     | Underloaded    | **Park**: evacuate *fully* onto packed destinations with WI headroom; emptied hosts draw 0 W |
//! | otherwise                                 | Normal         | candidate destination |
//!
//! Spreading runs first — §III's performance floor beats the energy
//! objective when they conflict; parking only consumes whatever budget
//! overload relief left over. The planner itself is pure and
//! deterministic (see [`planner`]): all state that varies tick-to-tick
//! (in-flight transfers, per-VM cooldowns) is resolved *before*
//! planning, and a disabled migrator publishes nothing and draws no
//! RNG, so migrator-off runs are bit-identical to a build without the
//! subsystem.
//!
//! ## CLI grammar (`vmcd cluster --migrator <spec>`)
//!
//! `over:under:budget[:interval][,key=value...]` — positional fields
//! first (empty fields keep defaults), then optional keyword fields:
//!
//! | Field      | Meaning                                    | Default |
//! |------------|--------------------------------------------|---------|
//! | `over`     | overload threshold, est-CPU / CPU capacity | 0.85    |
//! | `under`    | underload (parking) threshold, same units  | 0.35    |
//! | `budget`   | max concurrent transfers (incl. in-flight) | 4       |
//! | `interval` | seconds between planning passes            | 30      |
//!
//! | Key        | Meaning                                             | Default |
//! |------------|-----------------------------------------------------|---------|
//! | `forecast` | `on`/`off`: plan on the Holt-linear [`forecast`]    | `off`   |
//! | `alpha`    | level/EWMA smoothing factor, (0, 1]                 | 0.3     |
//! | `beta`     | trend smoothing factor, [0, 1]                      | 0.1     |
//! | `horizon`  | prediction horizon, seconds ahead                   | 90      |
//! | `k`        | hysteresis: consecutive under-predicted passes      | 2       |
//! | `payback`  | payback horizon, seconds (or `inf`: gate off)       | `inf`   |
//! | `cooldown` | per-VM replan cooldown, seconds                     | 120     |
//! | `wi`       | interference threshold (`wi_threshold`)             | 1.5     |
//!
//! e.g. `--migrator 0.85:0.35:4:30,forecast=on,horizon=120,payback=600`.
//! All keys also ride along via config JSON (`"migrator": {...}`,
//! [`crate::config::MigratorParams`]).
//!
//! With `forecast=on` the planner classifies hosts on the predicted
//! load/WI at `horizon` seconds out ([`forecast::LoadForecaster`], fed
//! each tick from the published summaries — simulation-determined
//! state, no wall-clock), and a host must be *predicted* under the
//! `under` line for `k` consecutive planning passes before the park
//! pass may evacuate it. With a finite `payback`, each candidate
//! consolidation is weighed by its copy cost — estimated transfer
//! seconds ([`MigrationModel::est_transfer_secs`](super::migration::MigrationModel::est_transfer_secs),
//! VM size × network load) at source+destination power draw
//! ([`crate::config::PowerModel`]) — and skipped when the parked
//! host's idle draw over the payback horizon cannot repay it. The
//! defaults (`forecast=off`, `payback=inf`) are bit-identical to the
//! myopic PR 8 planner — digest-gated by the planner tests.
//!
//! Respecting [`MigrationModel`](super::migration::MigrationModel)
//! outcomes: the budget counts the bus's in-flight transfers, aborted
//! transfers leave the VM on its source (where the next pass may pick
//! it again once its cooldown lapses), and completed transfers move the
//! summary load so the next pass plans from the post-move fleet.

pub mod forecast;
pub mod planner;

use crate::config::{HostSpec, MigratorParams, PowerModel};
use crate::hostsim::VmId;
use crate::profiling::ProfileBank;
use std::collections::{BTreeMap, BTreeSet};

use super::bus::{EventBus, HostSummary};
use super::migration::MigrationModel;
use forecast::LoadForecaster;
pub use planner::{classify, plan, HostClass, PlannedMove};

/// Lifetime counters of one migrator instance.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigratorStats {
    /// Planning passes that actually ran (interval-due ticks).
    pub plans: u64,
    /// Moves published across all passes.
    pub planned_moves: u64,
    /// Host-passes observed overloaded at planning time.
    pub overloaded_seen: u64,
    /// Full evacuations committed (hosts sent toward parking).
    pub parked_hosts_planned: u64,
}

/// What the payback gate knows about the cluster it plans for: the
/// migration cost model, the power model the ledger bills with, and
/// the (homogeneous) host spec. Cloned in at construction so
/// [`VmMigrator::maybe_plan`]'s signature stays put.
#[derive(Debug, Clone)]
pub struct PlanEnv {
    pub migration: MigrationModel,
    pub power: PowerModel,
    pub host: HostSpec,
}

impl Default for PlanEnv {
    fn default() -> Self {
        PlanEnv {
            migration: MigrationModel::default(),
            power: PowerModel::Linear,
            host: HostSpec::default(),
        }
    }
}

/// The continuous migration manager. Owned by
/// [`ClusterSim`](super::ClusterSim) when
/// [`ClusterSpec::migrator`](super::ClusterSpec) is set; consulted once
/// per tick before routing.
#[derive(Debug, Clone)]
pub struct VmMigrator {
    params: MigratorParams,
    env: PlanEnv,
    /// Virtual time of the last planning pass.
    last_plan: f64,
    /// vm → virtual time it was last planned (cooldown bookkeeping).
    /// Ordered so every traversal (retain, key collection) is
    /// deterministic — a `HashMap` here made plans depend on the
    /// process's hash seed (see DETERMINISM.md R1).
    cooldowns: BTreeMap<VmId, f64>,
    /// Holt-linear predictor over the summary stream; built only when
    /// `params.forecast` is set, so forecast-off runs hold no forecast
    /// state and execute no forecast arithmetic (bit-identity).
    forecast: Option<LoadForecaster>,
    /// Hysteresis: per-host count of consecutive planning passes the
    /// host was predicted under the `under` line.
    under_streak: Vec<usize>,
    pub stats: MigratorStats,
}

impl VmMigrator {
    pub fn new(params: MigratorParams) -> VmMigrator {
        VmMigrator::with_env(params, PlanEnv::default())
    }

    /// Build with the cluster's actual migration/power/host models so
    /// the payback gate prices copies the way the ledger will bill
    /// them. [`Self::new`] uses defaults (fine while `payback` is
    /// infinite — the gate never runs).
    pub fn with_env(params: MigratorParams, env: PlanEnv) -> VmMigrator {
        let forecast = params
            .forecast
            .then(|| LoadForecaster::new(params.alpha, params.beta));
        VmMigrator {
            params,
            env,
            last_plan: f64::NEG_INFINITY,
            cooldowns: BTreeMap::new(),
            forecast,
            under_streak: Vec::new(),
            stats: MigratorStats::default(),
        }
    }

    pub fn params(&self) -> &MigratorParams {
        &self.params
    }

    /// Feed one tick of published summaries into the forecaster.
    /// No-op (no state, no arithmetic) when `forecast=off`.
    pub fn observe(&mut self, summaries: &[HostSummary], dt: f64) {
        if let Some(f) = self.forecast.as_mut() {
            f.observe(summaries, dt);
        }
    }

    /// Run a planning pass if the interval is due; returns the moves to
    /// publish (empty off-interval or when the budget is exhausted).
    pub fn maybe_plan(
        &mut self,
        now: f64,
        bus: &EventBus,
        bank: &ProfileBank,
    ) -> Vec<PlannedMove> {
        if now - self.last_plan < self.params.interval {
            return Vec::new();
        }
        self.last_plan = now;
        self.stats.plans += 1;
        self.cooldowns
            .retain(|_, &mut at| now - at < self.params.cooldown);
        let budget_left = self.params.budget.saturating_sub(bus.in_flight());
        if budget_left == 0 {
            return Vec::new();
        }
        let mut blocked: BTreeSet<VmId> = self.cooldowns.keys().copied().collect();
        blocked.extend(bus.in_flight_vms());
        let summaries = bus.summaries();
        let matrix = bus.matrix();
        // Forecast inputs (None when forecast=off → myopic planning).
        let predicted = self
            .forecast
            .as_ref()
            .map(|f| f.predict_load(summaries, self.params.horizon));
        let predicted_wi = self.forecast.as_ref().map(|f| f.predict_wi(summaries));
        // Hysteresis streaks advance once per planning pass: a host is
        // park-eligible only after K consecutive passes predicted below
        // the `under` fraction.
        let park_eligible: Option<Vec<bool>> = if let Some(pred) = predicted.as_deref() {
            self.under_streak.resize(summaries.len(), 0);
            let mut eligible = Vec::with_capacity(summaries.len());
            for (h, s) in summaries.iter().enumerate() {
                let cap = matrix.cap(h, 0);
                let under_now = cap > 0.0 && pred[h] / cap < self.params.under && s.resident > 0;
                self.under_streak[h] = if under_now {
                    self.under_streak[h] + 1
                } else {
                    0
                };
                eligible.push(self.under_streak[h] >= self.params.hysteresis);
            }
            Some(eligible)
        } else {
            None
        };
        let ctx = planner::PlanContext {
            predicted: predicted.as_deref(),
            predicted_wi: predicted_wi.as_deref(),
            park_eligible: park_eligible.as_deref(),
            // Built only for finite payback: the default (∞) planner
            // must execute zero cost arithmetic (bit-identity).
            cost: self.params.payback.is_finite().then(|| planner::CostContext {
                migration: &self.env.migration,
                power: &self.env.power,
                host: &self.env.host,
                payback: self.params.payback,
            }),
        };
        self.stats.overloaded_seen += planner::classify_with(
            &self.params,
            summaries,
            matrix,
            ctx.predicted,
            ctx.predicted_wi,
        )
        .iter()
        .filter(|&&c| c == HostClass::Overloaded)
        .count() as u64;
        let moves =
            planner::plan_with(&self.params, summaries, matrix, bank, &blocked, budget_left, &ctx);
        let mut parked: BTreeSet<usize> = BTreeSet::new();
        for m in &moves {
            self.cooldowns.insert(m.vm, now);
            if summaries[m.src].est_cpu_load < self.params.under * matrix.cap(m.src, 0) {
                parked.insert(m.src);
            }
        }
        self.stats.planned_moves += moves.len() as u64;
        self.stats.parked_hosts_planned += parked.len() as u64;
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::super::bus::SummaryMatrix;
    use super::*;
    use crate::testkit;
    use crate::workloads::WorkloadClass;

    fn summary(running: Vec<(VmId, WorkloadClass)>, est: f64, wi: f64) -> HostSummary {
        HostSummary {
            resident: running.len(),
            busy_cores: running.len(),
            running,
            max_wi: wi,
            est_cpu_load: est,
        }
    }

    fn vmid(n: u32) -> VmId {
        VmId(n)
    }

    fn fleet(summaries: &[HostSummary]) -> SummaryMatrix {
        SummaryMatrix::from_summaries(summaries, 12)
    }

    #[test]
    fn classify_maps_thresholds() {
        let p = MigratorParams::default(); // over 0.85, under 0.35, wi 1.5
        let cls = WorkloadClass::Blackscholes;
        let summaries = vec![
            summary(vec![(vmid(0), cls)], 11.0, 1.0), // 11/12 > 0.85
            summary(vec![(vmid(1), cls)], 6.0, 2.0),  // wi-hot
            summary(vec![(vmid(2), cls)], 2.0, 1.0),  // 2/12 < 0.35
            summary(vec![], 0.0, 0.0),                // empty: normal, not parkable
            summary(vec![(vmid(3), cls)], 6.0, 1.0),  // mid
        ];
        let m = fleet(&summaries);
        let got = classify(&p, &summaries, &m);
        assert_eq!(
            got,
            vec![
                HostClass::Overloaded,
                HostClass::Overloaded,
                HostClass::Underloaded,
                HostClass::Normal,
                HostClass::Normal,
            ]
        );
    }

    #[test]
    fn spread_moves_biggest_vm_off_the_hottest_host() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        // CpuBound demand dwarfs Idle demand in the profile bank.
        let big = WorkloadClass::Blackscholes;
        let small = WorkloadClass::StreamLow;
        let summaries = vec![
            summary(vec![(vmid(0), big), (vmid(1), small)], 11.5, 1.0),
            summary(vec![(vmid(2), small)], 5.0, 1.0),
            summary(vec![(vmid(3), small)], 6.0, 1.2),
        ];
        let m = fleet(&summaries);
        let moves = plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 4);
        assert!(!moves.is_empty());
        let first = moves[0];
        assert_eq!(first.src, 0);
        assert_eq!(first.vm, vmid(0), "largest VM moves first");
        assert_eq!(first.dst, 1, "least-loaded of the WI-equal destinations");
    }

    #[test]
    fn wi_hot_host_sheds_exactly_one_vm() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        let cls = WorkloadClass::Blackscholes;
        let summaries = vec![
            summary(
                vec![(vmid(0), cls), (vmid(1), cls), (vmid(2), cls)],
                6.0, // load fine — interference is the problem
                2.5,
            ),
            summary(vec![], 0.0, 0.0),
            summary(vec![], 0.0, 0.0),
        ];
        let m = fleet(&summaries);
        let moves = plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 8);
        assert_eq!(moves.len(), 1, "stale WI reading sheds one VM per pass");
        assert_eq!(moves[0].src, 0);
    }

    #[test]
    fn park_evacuates_fully_or_not_at_all() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        let small = WorkloadClass::StreamLow;
        let summaries = vec![
            summary(vec![(vmid(0), small), (vmid(1), small)], 1.0, 1.0),
            summary(vec![(vmid(2), small)], 6.0, 1.0),
        ];
        let m = fleet(&summaries);
        // Budget 2 covers the full evacuation of host 0 → both VMs move.
        let moves = plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 2);
        assert_eq!(moves.len(), 2);
        assert!(moves.iter().all(|mv| mv.src == 0 && mv.dst == 1));
        // Budget 1 cannot: no partial evacuation.
        let moves = plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 1);
        assert!(moves.is_empty(), "partial evacuation wastes the budget");
    }

    #[test]
    fn park_merges_underloaded_hosts_without_cycles() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        let small = WorkloadClass::StreamLow;
        // Two parkable hosts; the emptier one must evacuate onto the
        // other, and the receiver must then NOT park itself.
        let summaries = vec![
            summary(vec![(vmid(0), small)], 0.5, 1.0),
            summary(vec![(vmid(1), small), (vmid(2), small)], 1.0, 1.0),
            summary(vec![], 0.0, 0.0),
        ];
        let m = fleet(&summaries);
        let moves = plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 8);
        let sources: BTreeSet<usize> = moves.iter().map(|mv| mv.src).collect();
        let dests: BTreeSet<usize> = moves.iter().map(|mv| mv.dst).collect();
        assert!(!moves.is_empty());
        assert!(
            sources.is_disjoint(&dests),
            "an evacuation target must not itself evacuate: {moves:?}"
        );
    }

    #[test]
    fn blocked_vms_and_budget_are_respected() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        let cls = WorkloadClass::Blackscholes;
        let summaries = vec![
            summary(
                (0..6).map(|i| (vmid(i), cls)).collect(),
                12.0,
                1.0,
            ),
            summary(vec![], 0.0, 0.0),
            summary(vec![], 0.0, 0.0),
        ];
        let m = fleet(&summaries);
        let blocked: BTreeSet<VmId> = [vmid(0), vmid(1)].into_iter().collect();
        let moves = plan(&p, &summaries, &m, &bank, &blocked, 2);
        assert!(moves.len() <= 2);
        assert!(moves.iter().all(|mv| !blocked.contains(&mv.vm)));
    }

    #[test]
    fn empty_and_single_host_fleets_plan_nothing() {
        let p = MigratorParams::default();
        let bank = testkit::shared_bank();
        let summaries = vec![summary(
            vec![(vmid(0), WorkloadClass::Blackscholes)],
            12.0,
            3.0,
        )];
        let m = fleet(&summaries);
        assert!(plan(&p, &summaries, &m, &bank, &BTreeSet::new(), 4).is_empty());
        assert!(plan(&p, &[], &SummaryMatrix::from_summaries(&[], 12), &bank, &BTreeSet::new(), 4)
            .is_empty());
    }

    #[test]
    fn cooldown_blocks_replanning_the_same_vm() {
        let params = MigratorParams {
            interval: 1.0,
            cooldown: 100.0,
            ..MigratorParams::default()
        };
        let mut mig = VmMigrator::new(params);
        mig.cooldowns.insert(vmid(7), 0.0);
        // At t=50 the cooldown (100 s) still holds; at t=150 it lapsed.
        mig.cooldowns.retain(|_, &mut at| 50.0 - at < 100.0);
        assert!(mig.cooldowns.contains_key(&vmid(7)));
        mig.cooldowns.retain(|_, &mut at| 150.0 - at < 100.0);
        assert!(!mig.cooldowns.contains_key(&vmid(7)));
    }
}

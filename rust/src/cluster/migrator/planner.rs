//! The pure planning pass: classify hosts from the published summaries
//! and produce a bounded list of moves. No RNG, no simulation state —
//! given the same summaries, matrix, blocked set, and budget the plan is
//! byte-identical, which is what keeps migrator-enabled replays
//! bit-identical across `Single`/`Scoped`/`Pool` step modes.
//!
//! Two passes, in priority order (Jin et al., arXiv:1404.2842: optimize
//! energy and interference *jointly* — spread when interference or
//! overload demands it, consolidate and park when headroom allows):
//!
//! 1. **Spread** — hosts whose estimated CPU fraction exceeds `over` or
//!    whose `max_wi` exceeds `wi_threshold` shed their largest movable
//!    VMs onto the least-interfering destination that stays under the
//!    `over` line (working loads are tracked so one pass never stacks a
//!    destination past the threshold it is relieving).
//! 2. **Park** — hosts under the `under` fraction are evacuated *fully*
//!    (emptied hosts draw 0 W in the cluster ledger) onto the
//!    most-loaded destinations whose WI headroom absorbs the load;
//!    a host that cannot be fully emptied within the remaining budget
//!    is left untouched (a half-evacuation spends migrations without
//!    saving a host).

use crate::config::{HostSpec, MigratorParams, PowerModel};
use crate::hostsim::VmId;
use crate::profiling::ProfileBank;
use std::collections::BTreeSet;

use super::super::bus::{HostSummary, SummaryMatrix};
use super::super::migration::MigrationModel;

/// One planned live migration, ready to publish as
/// [`crate::cluster::ClusterEvent::Migrate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedMove {
    pub vm: VmId,
    pub src: usize,
    pub dst: usize,
}

/// Migrator's view of one host, derived from the published summaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostClass {
    /// Estimated CPU fraction above `over`, or `max_wi` above
    /// `wi_threshold`: shed load.
    Overloaded,
    /// Non-empty but below the `under` fraction: evacuate and park.
    Underloaded,
    Normal,
}

/// CPU-load fraction of one host: estimated CPU load over the host's
/// CPU capacity column (cap metric 0 — cores for homogeneous fleets,
/// the host-class capacity otherwise).
fn frac(load: f64, matrix: &SummaryMatrix, host: usize) -> f64 {
    let cap = matrix.cap(host, 0);
    if cap <= 0.0 {
        f64::INFINITY
    } else {
        load / cap
    }
}

/// Migration-cost accounting for the park pass: skip consolidations
/// whose energy saving over `payback` seconds never repays the copy.
/// Only built when `payback` is finite, so the default (`payback=∞`)
/// planner never touches these folds and stays bit-identical.
#[derive(Debug, Clone, Copy)]
pub struct CostContext<'a> {
    pub migration: &'a MigrationModel,
    pub power: &'a PowerModel,
    pub host: &'a HostSpec,
    /// Payback horizon, seconds (finite by construction).
    pub payback: f64,
}

/// Optional forecast/hysteresis/cost inputs to [`plan_with`]. The
/// all-`None` default reproduces the myopic PR 8 planner exactly —
/// [`plan`] is that default, and the digest-identity tests gate it.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanContext<'a> {
    /// Predicted est-CPU load per host (forecast horizon); `None`
    /// plans on the current summaries.
    pub predicted: Option<&'a [f64]>,
    /// Predicted (smoothed) `max_wi` per host.
    pub predicted_wi: Option<&'a [f64]>,
    /// Hysteresis gate: a host may only be evacuated for parking when
    /// its flag is set (predicted under `under` for K consecutive
    /// planning passes). `None` = every underloaded host is eligible.
    pub park_eligible: Option<&'a [bool]>,
    pub cost: Option<CostContext<'a>>,
}

/// Planning estimate of the energy (J) one migration burns: the
/// model's transfer window, stretched by the VM's share of the network
/// load, at the *current* source + destination power draw. Public so
/// the payback proptest recomputes the same figure the gate used.
pub fn move_cost_joules(
    cost: &CostContext,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
    mv: &PlannedMove,
    vm_load: f64,
) -> f64 {
    let src_cap = matrix.cap(mv.src, 0);
    let vm_frac = if src_cap > 0.0 { vm_load / src_cap } else { 1.0 };
    let secs = cost.migration.est_transfer_secs(vm_frac);
    let w_src = cost
        .power
        .watts(summaries[mv.src].busy_cores, matrix.cap(mv.src, 0), cost.host);
    let w_dst = cost
        .power
        .watts(summaries[mv.dst].busy_cores, matrix.cap(mv.dst, 0), cost.host);
    secs * (w_src + w_dst)
}

/// Classify every host against the thresholds, on predicted values
/// when a forecast is supplied (else the current summaries).
pub fn classify_with(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
    predicted: Option<&[f64]>,
    predicted_wi: Option<&[f64]>,
) -> Vec<HostClass> {
    summaries
        .iter()
        .enumerate()
        .map(|(h, s)| {
            let load = predicted.map_or(s.est_cpu_load, |p| p[h]);
            let wi = predicted_wi.map_or(s.max_wi, |p| p[h]);
            let f = frac(load, matrix, h);
            if f > params.over || wi > params.wi_threshold {
                HostClass::Overloaded
            } else if f < params.under && s.resident > 0 {
                HostClass::Underloaded
            } else {
                HostClass::Normal
            }
        })
        .collect()
}

/// Classify every host against the thresholds (current summaries).
pub fn classify(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
) -> Vec<HostClass> {
    classify_with(params, summaries, matrix, None, None)
}

/// The myopic planner: [`plan_with`] under the default (empty)
/// [`PlanContext`] — current-tick loads, no hysteresis, no payback
/// gate. This is the PR 8 behavior and must stay bit-identical to it.
pub fn plan(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
    bank: &ProfileBank,
    blocked: &BTreeSet<VmId>,
    budget_left: usize,
) -> Vec<PlannedMove> {
    plan_with(
        params,
        summaries,
        matrix,
        bank,
        blocked,
        budget_left,
        &PlanContext::default(),
    )
}

/// Plan at most `budget_left` moves. `blocked` holds VMs that must not
/// be selected (in-flight transfers and cooling-down recent movers);
/// `ctx` carries the optional forecast/hysteresis/cost inputs.
pub fn plan_with(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
    bank: &ProfileBank,
    blocked: &BTreeSet<VmId>,
    mut budget_left: usize,
    ctx: &PlanContext,
) -> Vec<PlannedMove> {
    let n = summaries.len();
    let mut moves = Vec::new();
    if n < 2 || budget_left == 0 {
        return moves;
    }
    let classes = classify_with(params, summaries, matrix, ctx.predicted, ctx.predicted_wi);
    // Interference reading per host: the smoothed forecast when one is
    // supplied, the raw summary otherwise (identical values then).
    let wi = |h: usize| ctx.predicted_wi.map_or(summaries[h].max_wi, |p| p[h]);
    // Working copies the passes mutate as they commit moves, so one plan
    // never stacks a destination past the line it is policing. With a
    // forecast these start from the predicted loads — the plan is
    // feasible where the fleet is *going*.
    let mut loads: Vec<f64> = match ctx.predicted {
        Some(p) => p.to_vec(),
        None => summaries.iter().map(|s| s.est_cpu_load).collect(),
    };
    let mut taken: BTreeSet<VmId> = BTreeSet::new();
    let demand = |class: crate::workloads::WorkloadClass| bank.u[class.index()][0];
    let movable = |vm: VmId, taken: &BTreeSet<VmId>| !blocked.contains(&vm) && !taken.contains(&vm);

    // --- Pass 1: spread off overloaded hosts ---------------------------
    let mut over_hosts: Vec<usize> = (0..n)
        .filter(|&h| classes[h] == HostClass::Overloaded)
        .collect();
    over_hosts.sort_by(|&a, &b| {
        frac(loads[b], matrix, b)
            .total_cmp(&frac(loads[a], matrix, a))
            .then(a.cmp(&b))
    });
    let mut received: BTreeSet<usize> = BTreeSet::new();
    for src in over_hosts {
        // An interference-driven (not load-driven) overload sheds one VM
        // per pass: WI is recomputed by the daemons next tick, so
        // draining further on a stale reading would overshoot.
        let wi_hot = wi(src) > params.wi_threshold;
        let mut shed = 0usize;
        // Largest movable VMs first: fewest migrations per shed core.
        let mut vms: Vec<(VmId, f64)> = summaries[src]
            .running
            .iter()
            .map(|&(id, class)| (id, demand(class)))
            .collect();
        vms.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        for (vm, load) in vms {
            if budget_left == 0 {
                return moves;
            }
            let load_hot = frac(loads[src], matrix, src) > params.over;
            if !load_hot && (!wi_hot || shed > 0) {
                break; // relieved
            }
            if !movable(vm, &taken) {
                continue;
            }
            // Destination: lowest interference, then most headroom,
            // then lowest index — and never another hot host.
            let dst = (0..n)
                .filter(|&h| h != src && classes[h] != HostClass::Overloaded)
                .filter(|&h| frac(loads[h] + load, matrix, h) <= params.over)
                .filter(|&h| wi(h) <= params.wi_threshold)
                .min_by(|&a, &b| {
                    wi(a)
                        .total_cmp(&wi(b))
                        .then(frac(loads[a], matrix, a).total_cmp(&frac(loads[b], matrix, b)))
                        .then(a.cmp(&b))
                });
            // No room for this VM anywhere — a smaller one may still fit.
            let Some(dst) = dst else { continue };
            loads[src] -= load;
            loads[dst] += load;
            taken.insert(vm);
            received.insert(dst);
            moves.push(PlannedMove { vm, src, dst });
            budget_left -= 1;
            shed += 1;
        }
    }

    // --- Pass 2: evacuate and park underloaded hosts -------------------
    let mut park_hosts: Vec<usize> = (0..n)
        .filter(|&h| classes[h] == HostClass::Underloaded)
        .collect();
    // Emptiest first: cheapest full evacuations save hosts soonest.
    park_hosts.sort_by(|&a, &b| loads[a].total_cmp(&loads[b]).then(a.cmp(&b)));
    let mut parking: BTreeSet<usize> = BTreeSet::new();
    for src in park_hosts {
        // A host the spread pass (or an earlier evacuation) already
        // routed VMs onto is staying powered — parking it would strand
        // the incoming transfers on a host this plan meant to empty.
        if received.contains(&src) {
            continue;
        }
        // Hysteresis: under a forecast, a host must have been predicted
        // below `under` for K consecutive planning passes before it is
        // evacuated — one dip across the line is not a parking case.
        if ctx.park_eligible.is_some_and(|pe| !pe[src]) {
            continue;
        }
        let mut vms: Vec<(VmId, f64)> = summaries[src]
            .running
            .iter()
            .map(|&(id, class)| (id, demand(class)))
            .collect();
        // Parking is all-or-nothing: every resident must be movable and
        // within budget, or the host stays up and the budget is saved.
        if vms.is_empty()
            || vms.len() != summaries[src].resident
            || vms.len() > budget_left
            || vms.iter().any(|&(vm, _)| !movable(vm, &taken))
        {
            continue;
        }
        vms.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut tentative: Vec<PlannedMove> = Vec::with_capacity(vms.len());
        let mut tentative_loads = loads.clone();
        let feasible = vms.iter().all(|&(vm, load)| {
            // Pack: the most-loaded destination that stays under `over`
            // with WI headroom — merging underloaded hosts is allowed,
            // but never onto a host this plan is itself evacuating.
            let dst = (0..n)
                .filter(|&h| {
                    h != src && classes[h] != HostClass::Overloaded && !parking.contains(&h)
                })
                .filter(|&h| frac(tentative_loads[h] + load, matrix, h) <= params.over)
                .filter(|&h| wi(h) <= params.wi_threshold)
                .max_by(|&a, &b| {
                    frac(tentative_loads[a], matrix, a)
                        .total_cmp(&frac(tentative_loads[b], matrix, b))
                        .then(b.cmp(&a)) // ties: lowest index
                });
            match dst {
                Some(dst) => {
                    tentative_loads[dst] += load;
                    tentative.push(PlannedMove { vm, src, dst });
                    true
                }
                None => false,
            }
        });
        if !feasible {
            continue;
        }
        // Payback gate: parking `src` saves its idle floor draw; the
        // evacuation burns transfer-seconds of source+destination power.
        // If the copy cannot repay itself within the payback horizon,
        // the consolidation is net-negative — keep the host up.
        if let Some(cost) = &ctx.cost {
            let copy_j: f64 = vms
                .iter()
                .zip(&tentative)
                .map(|(&(_, load), mv)| move_cost_joules(cost, summaries, matrix, mv, load))
                .sum();
            let idle_w = cost.power.watts(0, matrix.cap(src, 0), cost.host);
            if copy_j > idle_w * cost.payback {
                continue;
            }
        }
        budget_left -= tentative.len();
        loads = tentative_loads;
        loads[src] = 0.0;
        parking.insert(src);
        for m in &tentative {
            taken.insert(m.vm);
            received.insert(m.dst);
        }
        moves.extend(tentative);
        if budget_left == 0 {
            break;
        }
    }
    moves
}

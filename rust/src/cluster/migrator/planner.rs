//! The pure planning pass: classify hosts from the published summaries
//! and produce a bounded list of moves. No RNG, no simulation state —
//! given the same summaries, matrix, blocked set, and budget the plan is
//! byte-identical, which is what keeps migrator-enabled replays
//! bit-identical across `Single`/`Scoped`/`Pool` step modes.
//!
//! Two passes, in priority order (Jin et al., arXiv:1404.2842: optimize
//! energy and interference *jointly* — spread when interference or
//! overload demands it, consolidate and park when headroom allows):
//!
//! 1. **Spread** — hosts whose estimated CPU fraction exceeds `over` or
//!    whose `max_wi` exceeds `wi_threshold` shed their largest movable
//!    VMs onto the least-interfering destination that stays under the
//!    `over` line (working loads are tracked so one pass never stacks a
//!    destination past the threshold it is relieving).
//! 2. **Park** — hosts under the `under` fraction are evacuated *fully*
//!    (emptied hosts draw 0 W in the cluster ledger) onto the
//!    most-loaded destinations whose WI headroom absorbs the load;
//!    a host that cannot be fully emptied within the remaining budget
//!    is left untouched (a half-evacuation spends migrations without
//!    saving a host).

use crate::config::MigratorParams;
use crate::hostsim::VmId;
use crate::profiling::ProfileBank;
use std::collections::BTreeSet;

use super::super::bus::{HostSummary, SummaryMatrix};

/// One planned live migration, ready to publish as
/// [`crate::cluster::ClusterEvent::Migrate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedMove {
    pub vm: VmId,
    pub src: usize,
    pub dst: usize,
}

/// Migrator's view of one host, derived from the published summaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostClass {
    /// Estimated CPU fraction above `over`, or `max_wi` above
    /// `wi_threshold`: shed load.
    Overloaded,
    /// Non-empty but below the `under` fraction: evacuate and park.
    Underloaded,
    Normal,
}

/// CPU-load fraction of one host: estimated CPU load over the host's
/// CPU capacity column (cap metric 0 — cores for homogeneous fleets,
/// the host-class capacity otherwise).
fn frac(load: f64, matrix: &SummaryMatrix, host: usize) -> f64 {
    let cap = matrix.cap(host, 0);
    if cap <= 0.0 {
        f64::INFINITY
    } else {
        load / cap
    }
}

/// Classify every host against the thresholds.
pub fn classify(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
) -> Vec<HostClass> {
    summaries
        .iter()
        .enumerate()
        .map(|(h, s)| {
            let f = frac(s.est_cpu_load, matrix, h);
            if f > params.over || s.max_wi > params.wi_threshold {
                HostClass::Overloaded
            } else if f < params.under && s.resident > 0 {
                HostClass::Underloaded
            } else {
                HostClass::Normal
            }
        })
        .collect()
}

/// Plan at most `budget_left` moves. `blocked` holds VMs that must not
/// be selected (in-flight transfers and cooling-down recent movers).
pub fn plan(
    params: &MigratorParams,
    summaries: &[HostSummary],
    matrix: &SummaryMatrix,
    bank: &ProfileBank,
    blocked: &BTreeSet<VmId>,
    mut budget_left: usize,
) -> Vec<PlannedMove> {
    let n = summaries.len();
    let mut moves = Vec::new();
    if n < 2 || budget_left == 0 {
        return moves;
    }
    let classes = classify(params, summaries, matrix);
    // Working copies the passes mutate as they commit moves, so one plan
    // never stacks a destination past the line it is policing.
    let mut loads: Vec<f64> = summaries.iter().map(|s| s.est_cpu_load).collect();
    let mut taken: BTreeSet<VmId> = BTreeSet::new();
    let demand = |class: crate::workloads::WorkloadClass| bank.u[class.index()][0];
    let movable = |vm: VmId, taken: &BTreeSet<VmId>| !blocked.contains(&vm) && !taken.contains(&vm);

    // --- Pass 1: spread off overloaded hosts ---------------------------
    let mut over_hosts: Vec<usize> = (0..n)
        .filter(|&h| classes[h] == HostClass::Overloaded)
        .collect();
    over_hosts.sort_by(|&a, &b| {
        frac(loads[b], matrix, b)
            .total_cmp(&frac(loads[a], matrix, a))
            .then(a.cmp(&b))
    });
    let mut received: BTreeSet<usize> = BTreeSet::new();
    for src in over_hosts {
        // An interference-driven (not load-driven) overload sheds one VM
        // per pass: WI is recomputed by the daemons next tick, so
        // draining further on a stale reading would overshoot.
        let wi_hot = summaries[src].max_wi > params.wi_threshold;
        let mut shed = 0usize;
        // Largest movable VMs first: fewest migrations per shed core.
        let mut vms: Vec<(VmId, f64)> = summaries[src]
            .running
            .iter()
            .map(|&(id, class)| (id, demand(class)))
            .collect();
        vms.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        for (vm, load) in vms {
            if budget_left == 0 {
                return moves;
            }
            let load_hot = frac(loads[src], matrix, src) > params.over;
            if !load_hot && (!wi_hot || shed > 0) {
                break; // relieved
            }
            if !movable(vm, &taken) {
                continue;
            }
            // Destination: lowest interference, then most headroom,
            // then lowest index — and never another hot host.
            let dst = (0..n)
                .filter(|&h| h != src && classes[h] != HostClass::Overloaded)
                .filter(|&h| frac(loads[h] + load, matrix, h) <= params.over)
                .filter(|&h| summaries[h].max_wi <= params.wi_threshold)
                .min_by(|&a, &b| {
                    summaries[a]
                        .max_wi
                        .total_cmp(&summaries[b].max_wi)
                        .then(frac(loads[a], matrix, a).total_cmp(&frac(loads[b], matrix, b)))
                        .then(a.cmp(&b))
                });
            // No room for this VM anywhere — a smaller one may still fit.
            let Some(dst) = dst else { continue };
            loads[src] -= load;
            loads[dst] += load;
            taken.insert(vm);
            received.insert(dst);
            moves.push(PlannedMove { vm, src, dst });
            budget_left -= 1;
            shed += 1;
        }
    }

    // --- Pass 2: evacuate and park underloaded hosts -------------------
    let mut park_hosts: Vec<usize> = (0..n)
        .filter(|&h| classes[h] == HostClass::Underloaded)
        .collect();
    // Emptiest first: cheapest full evacuations save hosts soonest.
    park_hosts.sort_by(|&a, &b| loads[a].total_cmp(&loads[b]).then(a.cmp(&b)));
    let mut parking: BTreeSet<usize> = BTreeSet::new();
    for src in park_hosts {
        // A host the spread pass (or an earlier evacuation) already
        // routed VMs onto is staying powered — parking it would strand
        // the incoming transfers on a host this plan meant to empty.
        if received.contains(&src) {
            continue;
        }
        let mut vms: Vec<(VmId, f64)> = summaries[src]
            .running
            .iter()
            .map(|&(id, class)| (id, demand(class)))
            .collect();
        // Parking is all-or-nothing: every resident must be movable and
        // within budget, or the host stays up and the budget is saved.
        if vms.is_empty()
            || vms.len() != summaries[src].resident
            || vms.len() > budget_left
            || vms.iter().any(|&(vm, _)| !movable(vm, &taken))
        {
            continue;
        }
        vms.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut tentative: Vec<PlannedMove> = Vec::with_capacity(vms.len());
        let mut tentative_loads = loads.clone();
        let feasible = vms.iter().all(|&(vm, load)| {
            // Pack: the most-loaded destination that stays under `over`
            // with WI headroom — merging underloaded hosts is allowed,
            // but never onto a host this plan is itself evacuating.
            let dst = (0..n)
                .filter(|&h| {
                    h != src && classes[h] != HostClass::Overloaded && !parking.contains(&h)
                })
                .filter(|&h| frac(tentative_loads[h] + load, matrix, h) <= params.over)
                .filter(|&h| summaries[h].max_wi <= params.wi_threshold)
                .max_by(|&a, &b| {
                    frac(tentative_loads[a], matrix, a)
                        .total_cmp(&frac(tentative_loads[b], matrix, b))
                        .then(b.cmp(&a)) // ties: lowest index
                });
            match dst {
                Some(dst) => {
                    tentative_loads[dst] += load;
                    tentative.push(PlannedMove { vm, src, dst });
                    true
                }
                None => false,
            }
        });
        if !feasible {
            continue;
        }
        budget_left -= tentative.len();
        loads = tentative_loads;
        loads[src] = 0.0;
        parking.insert(src);
        for m in &tentative {
            taken.insert(m.vm);
            received.insert(m.dst);
        }
        moves.extend(tentative);
        if budget_left == 0 {
            break;
        }
    }
    moves
}

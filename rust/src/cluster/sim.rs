//! The cluster simulator: N hosts stepped through the uniform
//! [`HostHandle`] interface, one dispatcher, and either per-host VMCd
//! daemons (local strategy) or a centralized migration-based consolidator
//! (global strategy).
//!
//! Hosts are independent within one tick (dispatch, reshuffle and
//! migration bookkeeping all happen on the coordinator thread between
//! ticks), so native-backend hosts can shard across `std::thread` scoped
//! workers — see [`ClusterSpec::shard_threads`] — with results
//! bit-identical to single-threaded stepping. XLA-backed hosts are not
//! `Send` and always step on the caller thread
//! ([`ClusterHost::Pinned`]).

use super::dispatch::Dispatcher;
use super::host::{HostHandle, NativeHost, SimHost};
use super::migration::{Migration, MigrationModel};
use crate::config::Config;
use crate::hostsim::{Vm, VmId, VmState};
use crate::profiling::ProfileBank;
use crate::scenarios::ScenarioSpec;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::vmcd::scheduler::{self, Policy};
use crate::vmcd::Daemon;
use crate::workloads::catalog::spec_of;
use crate::workloads::WorkloadKind;
use anyhow::Result;

/// Cluster-level consolidation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Dispatch at arrival; each host's own VMCd daemon optimises locally
    /// by re-pinning. No migrations (the paper's approach). Each daemon
    /// mutates one long-lived placement state via event deltas, so a
    /// cluster tick costs O(resident VMs) per host.
    LocalVmcd,
    /// Centralized scheduler with global knowledge: periodic reshuffle
    /// packs VMs onto the fewest hosts via live migration; hosts pin
    /// round-robin internally (the §III strawman the paper argues against
    /// under oversubscription).
    GlobalMigration,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::LocalVmcd => "local-vmcd",
            Strategy::GlobalMigration => "global-migration",
        }
    }
}

/// Cluster experiment description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub hosts: usize,
    pub cfg: Config,
    pub strategy: Strategy,
    pub dispatcher: Dispatcher,
    /// Per-host daemon policy for [`Strategy::LocalVmcd`].
    pub local_policy: Policy,
    pub migration: MigrationModel,
    /// Global reshuffle period, seconds.
    pub global_interval: f64,
    /// Max concurrent migrations per reshuffle.
    pub max_migrations: usize,
    /// Worker threads for stepping native hosts; 0 or 1 = step on the
    /// caller thread. Results are bit-identical either way.
    pub shard_threads: usize,
}

impl ClusterSpec {
    pub fn new(hosts: usize, strategy: Strategy) -> ClusterSpec {
        ClusterSpec {
            hosts,
            cfg: Config::default(),
            strategy,
            dispatcher: Dispatcher::LeastLoaded,
            local_policy: Policy::Ias,
            migration: MigrationModel::default(),
            global_interval: 120.0,
            max_migrations: 4,
            shard_threads: 0,
        }
    }
}

/// Cluster run summary.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub strategy: Strategy,
    pub avg_perf: f64,
    /// Σ per-host busy-core hours.
    pub core_hours: f64,
    /// Σ hours each host spent powered (≥ 1 busy core) — what global
    /// consolidation optimises by draining hosts.
    pub host_hours: f64,
    pub migrations_started: u64,
    pub migrations_failed: u64,
    pub completion_time: f64,
}

/// One cluster host, partitioned by steppability: `Native` hosts are
/// `Send` and shard across worker threads; `Pinned` hosts (e.g. XLA-
/// backed daemons holding PJRT handles) step on the caller thread.
pub enum ClusterHost {
    Native(NativeHost),
    Pinned(Box<dyn HostHandle>),
}

impl ClusterHost {
    pub fn handle(&self) -> &dyn HostHandle {
        match self {
            ClusterHost::Native(h) => h,
            ClusterHost::Pinned(h) => h.as_ref(),
        }
    }

    pub fn handle_mut(&mut self) -> &mut dyn HostHandle {
        match self {
            ClusterHost::Native(h) => h,
            ClusterHost::Pinned(h) => h.as_mut(),
        }
    }
}

struct HostSlot {
    host: ClusterHost,
    /// Host-powered integral (seconds).
    powered_seconds: f64,
}

/// One pending (not yet arrived) VM.
struct Pending {
    vm: Vm,
}

pub struct ClusterSim {
    spec: ClusterSpec,
    hosts: Vec<HostSlot>,
    pending: Vec<Pending>,
    migrations: Vec<Migration>,
    rng: Rng,
    rr_dispatch: usize,
    last_reshuffle: f64,
    t: f64,
    migrations_started: u64,
    migrations_failed: u64,
}

impl ClusterSim {
    /// Build from a scenario spec: `scenario.vms` arrive cluster-wide and
    /// are dispatched to hosts on arrival. Hosts are native (shardable);
    /// use [`Self::from_hosts`] to mix in caller-thread-pinned hosts.
    pub fn new(spec: ClusterSpec, scenario: &ScenarioSpec, bank: &ProfileBank) -> ClusterSim {
        let mut hosts = Vec::with_capacity(spec.hosts);
        for _ in 0..spec.hosts {
            let engine = crate::hostsim::SimEngine::new(spec.cfg.clone(), Vec::new());
            let daemon = match spec.strategy {
                Strategy::LocalVmcd => {
                    let sched = scheduler::build_native(
                        spec.local_policy,
                        bank,
                        spec.cfg.sched.ras_threshold,
                        spec.cfg.sched.ias_threshold,
                    );
                    Some(Daemon::new(spec.cfg.sched.clone(), sched))
                }
                Strategy::GlobalMigration => None,
            };
            hosts.push(ClusterHost::Native(SimHost::new(engine, daemon)));
        }
        ClusterSim::from_hosts(spec, scenario, hosts)
    }

    /// Build over explicit hosts (native and/or pinned). `spec.hosts` is
    /// overridden by `hosts.len()`.
    pub fn from_hosts(
        mut spec: ClusterSpec,
        scenario: &ScenarioSpec,
        hosts: Vec<ClusterHost>,
    ) -> ClusterSim {
        spec.hosts = hosts.len();
        let hosts = hosts
            .into_iter()
            .map(|host| HostSlot {
                host,
                powered_seconds: 0.0,
            })
            .collect();
        let pending = scenario
            .vms
            .iter()
            .enumerate()
            .map(|(i, t)| Pending {
                vm: Vm::new(VmId(i as u32), t.class, t.arrival, t.activity.clone()),
            })
            .collect();
        let rng = Rng::new(spec.cfg.sim.seed ^ 0xC1_05_7E_12);
        ClusterSim {
            spec,
            hosts,
            pending,
            migrations: Vec::new(),
            rng,
            rr_dispatch: 0,
            last_reshuffle: 0.0,
            t: 0.0,
            migrations_started: 0,
            migrations_failed: 0,
        }
    }

    fn dispatch_arrivals(&mut self) -> Result<()> {
        let due: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, p)| p.vm.arrival <= self.t)
            .map(|(i, _)| i)
            .collect();
        for &i in due.iter().rev() {
            let mut p = self.pending.remove(i);
            let residents: Vec<usize> = self
                .hosts
                .iter()
                .map(|h| h.host.handle().engine().vms.len())
                .collect();
            let host = self
                .spec
                .dispatcher
                .pick(&residents, &mut self.rr_dispatch, &mut self.rng);
            p.vm.state = VmState::Running;
            p.vm.started = Some(self.t);
            self.hosts[host].host.handle_mut().inject_arrival(p.vm)?;
        }
        Ok(())
    }

    /// The centralized consolidator: estimate each host's CPU load from
    /// profiles, drain the least-loaded non-empty host into the others if
    /// they have headroom.
    fn global_reshuffle(&mut self, bank: &ProfileBank) {
        let cores = self.spec.cfg.host.cores as f64;
        let cap = cores * self.spec.cfg.sched.ras_threshold;
        let load = |slot: &HostSlot| -> f64 {
            slot.host
                .handle()
                .engine()
                .vms
                .iter()
                .filter(|vm| vm.state == VmState::Running)
                .map(|vm| bank.u[vm.class.index()][0])
                .sum()
        };
        let loads: Vec<f64> = self.hosts.iter().map(load).collect();
        let counts: Vec<usize> = self
            .hosts
            .iter()
            .map(|h| {
                h.host
                    .handle()
                    .engine()
                    .vms
                    .iter()
                    .filter(|vm| vm.state == VmState::Running)
                    .count()
            })
            .collect();

        // Drain candidate: the least-loaded host with any residents.
        let Some(src) = (0..self.hosts.len())
            .filter(|&h| counts[h] > 0)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
        else {
            return;
        };
        // Only drain if the rest of the cluster can absorb it.
        let spare: f64 = (0..self.hosts.len())
            .filter(|&h| h != src)
            .map(|h| (cap - loads[h]).max(0.0))
            .sum();
        if spare < loads[src] || counts[src] == 0 {
            return;
        }

        let vm_ids: Vec<VmId> = self.hosts[src]
            .host
            .handle()
            .engine()
            .vms
            .iter()
            .filter(|vm| vm.state == VmState::Running)
            .map(|vm| vm.id)
            .take(self.spec.max_migrations)
            .collect();
        for id in vm_ids {
            if self.migrations.len() >= self.spec.max_migrations {
                break;
            }
            // Destination: most-loaded host that still fits the VM (pack).
            let vm_load = {
                let vm = self.hosts[src]
                    .host
                    .handle()
                    .engine()
                    .vms
                    .iter()
                    .find(|vm| vm.id == id)
                    .unwrap();
                bank.u[vm.class.index()][0]
            };
            let Some(dst) = (0..self.hosts.len())
                .filter(|&h| h != src)
                .filter(|&h| load(&self.hosts[h]) + vm_load <= cap)
                .max_by(|&a, &b| {
                    load(&self.hosts[a])
                        .partial_cmp(&load(&self.hosts[b]))
                        .unwrap()
                })
            else {
                continue;
            };
            let dest_busy = load(&self.hosts[dst]) / cores;
            let mig = self.spec.migration.start(
                id.0 as usize,
                src,
                dst,
                dest_busy,
                &mut self.rng,
            );
            // Transfer load on both ends for the whole window.
            self.hosts[src].host.handle_mut().engine_mut().external_net_load +=
                self.spec.migration.transfer_net;
            self.hosts[dst].host.handle_mut().engine_mut().external_net_load +=
                self.spec.migration.transfer_net;
            self.migrations.push(mig);
            self.migrations_started += 1;
        }
    }

    fn advance_migrations(&mut self, dt: f64) {
        let mut finished = Vec::new();
        for (i, m) in self.migrations.iter_mut().enumerate() {
            m.remaining -= dt;
            if m.remaining <= 0.0 {
                finished.push(i);
            }
        }
        for &i in finished.iter().rev() {
            let m = self.migrations.remove(i);
            self.hosts[m.from_host]
                .host
                .handle_mut()
                .engine_mut()
                .external_net_load -= self.spec.migration.transfer_net;
            self.hosts[m.to_host]
                .host
                .handle_mut()
                .engine_mut()
                .external_net_load -= self.spec.migration.transfer_net;
            let id = VmId(m.vm_index as u32);
            if m.doomed {
                self.migrations_failed += 1;
                continue; // pre-copy never converged; VM stays.
            }
            // Stop-and-copy: move the VM, pause it for the downtime.
            let moved = self.hosts[m.from_host]
                .host
                .handle_mut()
                .engine_mut()
                .remove_vm(id);
            if let Some(mut vm) = moved {
                if vm.state == VmState::Running {
                    vm.paused_until = self.t + self.spec.migration.downtime;
                }
                self.hosts[m.to_host].host.handle_mut().inject_migrated(vm);
            }
        }
    }

    /// Advance every host one tick. Native hosts shard across scoped
    /// worker threads when `shard_threads > 1`; pinned hosts always step
    /// on the caller thread. Hosts are independent within a tick, so the
    /// schedule of workers cannot change results.
    fn step_hosts(&mut self) -> Result<()> {
        let threads = self.spec.shard_threads;
        let mut native: Vec<&mut NativeHost> = Vec::new();
        let mut pinned: Vec<&mut Box<dyn HostHandle>> = Vec::new();
        for slot in &mut self.hosts {
            match &mut slot.host {
                ClusterHost::Native(h) => native.push(h),
                ClusterHost::Pinned(h) => pinned.push(h),
            }
        }
        if threads > 1 && native.len() > 1 {
            // Manual ceil-div: usize::div_ceil needs rustc 1.73, above
            // this crate's declared MSRV. unknown_lints keeps older
            // clippy (which predates manual_div_ceil) happy too.
            #[allow(unknown_lints, clippy::manual_div_ceil)]
            let chunk = (native.len() + threads - 1) / threads;
            let results: Vec<Result<()>> = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for shard in native.chunks_mut(chunk) {
                    handles.push(s.spawn(move || -> Result<()> {
                        for host in shard.iter_mut() {
                            host.step_host()?;
                        }
                        Ok(())
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard worker panicked"))
                    .collect()
            });
            for r in results {
                r?;
            }
        } else {
            for host in native {
                host.step_host()?;
            }
        }
        for host in pinned {
            host.step_host()?;
        }
        Ok(())
    }

    /// Run to completion; returns the cluster summary.
    pub fn run(mut self, bank: &ProfileBank, min_duration: f64) -> Result<ClusterResult> {
        let dt = self.spec.cfg.sim.dt;
        let max_time = self.spec.cfg.sim.max_time;
        loop {
            self.dispatch_arrivals()?;

            if self.spec.strategy == Strategy::GlobalMigration
                && self.t - self.last_reshuffle >= self.spec.global_interval
            {
                self.last_reshuffle = self.t;
                self.global_reshuffle(bank);
            }
            self.advance_migrations(dt);

            self.step_hosts()?;
            for slot in &mut self.hosts {
                let busy_now = slot
                    .host
                    .handle()
                    .engine()
                    .ledger
                    .busy_series
                    .points
                    .last()
                    .map(|p| p.1 > 0.0);
                if busy_now == Some(true) {
                    slot.powered_seconds += dt;
                }
            }
            self.t += dt;

            let batch_done = self
                .hosts
                .iter()
                .all(|slot| slot.host.handle().engine().all_batch_done())
                && self.pending.is_empty();
            if (batch_done && self.t >= min_duration) || self.t >= max_time {
                break;
            }
        }

        let mut perfs = Vec::new();
        let mut core_hours = 0.0;
        let mut host_hours = 0.0;
        for slot in &self.hosts {
            let engine = slot.host.handle().engine();
            core_hours += engine.ledger.core_hours();
            host_hours += slot.powered_seconds / 3600.0;
            for vm in &engine.vms {
                if vm.state == VmState::NotArrived {
                    continue;
                }
                if let Some(p) = vm.normalized_perf() {
                    perfs.push(p);
                } else if vm.spec.perf.kind == WorkloadKind::Batch {
                    if let Some(start) = vm.work_started {
                        let elapsed = self.t - start;
                        if elapsed > 0.0 {
                            perfs.push((vm.work_done / elapsed).clamp(0.0, 1.0));
                        }
                    }
                }
            }
        }
        // Sanity: every spec'd class is consistent (defensive, cheap).
        debug_assert!(self.hosts.iter().all(|slot| {
            slot.host
                .handle()
                .engine()
                .vms
                .iter()
                .all(|vm| spec_of(vm.class).class == vm.class)
        }));
        Ok(ClusterResult {
            strategy: self.spec.strategy,
            avg_perf: mean(&perfs),
            core_hours,
            host_hours,
            migrations_started: self.migrations_started,
            migrations_failed: self.migrations_failed,
            completion_time: self.t,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::SimEngine;
    use crate::scenarios::random;
    use crate::testkit;

    fn cluster_scenario(hosts: usize, sr: f64, seed: u64) -> ScenarioSpec {
        // SR is per-host: hosts × cores × sr VMs cluster-wide.
        random::build(hosts * 12, sr, seed).unwrap()
    }

    #[test]
    fn local_strategy_runs_and_consolidates() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank);
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert_eq!(r.migrations_started, 0, "local strategy never migrates");
        assert!(r.avg_perf > 0.6, "perf {}", r.avg_perf);
        assert!(r.core_hours > 0.0);
        assert!(r.host_hours > 0.0);
    }

    #[test]
    fn global_strategy_migrates_and_pays_for_it() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::GlobalMigration);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank);
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert!(r.migrations_started > 0, "global strategy must migrate");
    }

    #[test]
    fn local_beats_global_when_cluster_is_oversubscribed() {
        // The paper's §III argument: with the whole infrastructure
        // oversubscribed, migrations are unreliable and expensive, so the
        // local approach preserves performance better.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 1.8, 42);

        let mut lspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        lspec.cfg = testkit::quiet_config();
        let local = ClusterSim::new(lspec, &scen, bank)
            .run(bank, scen.min_duration)
            .unwrap();

        let mut gspec = ClusterSpec::new(3, Strategy::GlobalMigration);
        gspec.cfg = testkit::quiet_config();
        let global = ClusterSim::new(gspec, &scen, bank)
            .run(bank, scen.min_duration)
            .unwrap();

        assert!(
            local.avg_perf >= global.avg_perf - 0.02,
            "local {:.3} must not lose to global {:.3} under oversubscription",
            local.avg_perf,
            global.avg_perf
        );
    }

    #[test]
    fn dispatcher_balances_residents() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(4, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(4, 0.5, 7);
        let mut sim = ClusterSim::new(spec, &scen, bank);
        // Step past all arrivals (engines only: isolate the dispatcher).
        for _ in 0..(30 * scen.vms.len() + 10) {
            sim.dispatch_arrivals().unwrap();
            for slot in &mut sim.hosts {
                slot.host.handle_mut().engine_mut().step();
            }
            sim.t += 1.0;
        }
        let counts: Vec<usize> = sim
            .hosts
            .iter()
            .map(|h| h.host.handle().engine().vms.len())
            .collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "least-loaded must balance: {counts:?}");
    }

    #[test]
    fn sharded_stepping_is_bit_identical_to_single_thread() {
        // The acceptance property: hosts are independent within a tick,
        // so the worker-thread split cannot change any result bit.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(4, 1.0, 11);
        let run = |threads: usize| {
            let mut spec = ClusterSpec::new(4, Strategy::LocalVmcd);
            spec.cfg = testkit::quiet_config();
            spec.shard_threads = threads;
            ClusterSim::new(spec, &scen, bank)
                .run(bank, scen.min_duration)
                .unwrap()
        };
        let single = run(0);
        let sharded = run(3);
        assert_eq!(single.avg_perf.to_bits(), sharded.avg_perf.to_bits());
        assert_eq!(single.core_hours.to_bits(), sharded.core_hours.to_bits());
        assert_eq!(single.host_hours.to_bits(), sharded.host_hours.to_bits());
        assert_eq!(
            single.completion_time.to_bits(),
            sharded.completion_time.to_bits()
        );
        assert_eq!(single.migrations_started, sharded.migrations_started);
    }

    #[test]
    fn pinned_hosts_mix_with_sharded_native_hosts() {
        // A caller-thread host (the XLA stand-in: Box<dyn HostHandle>)
        // alongside sharded native hosts must reproduce the all-native
        // results exactly — same policy, same backend math.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 0.75, 42);
        let cfg = testkit::quiet_config();

        let mut nspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        nspec.cfg = cfg.clone();
        let all_native = ClusterSim::new(nspec, &scen, bank)
            .run(bank, scen.min_duration)
            .unwrap();

        let mut mspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        mspec.cfg = cfg.clone();
        mspec.shard_threads = 2;
        let mut hosts = Vec::new();
        for i in 0..3 {
            let engine = SimEngine::new(cfg.clone(), Vec::new());
            if i == 2 {
                let sched =
                    scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
                let daemon = Daemon::new(cfg.sched.clone(), sched);
                hosts.push(ClusterHost::Pinned(Box::new(SimHost::new(
                    engine,
                    Some(daemon),
                ))));
            } else {
                let sched = scheduler::build_native(
                    Policy::Ias,
                    bank,
                    cfg.sched.ras_threshold,
                    None,
                );
                let daemon = Daemon::new(cfg.sched.clone(), sched);
                hosts.push(ClusterHost::Native(SimHost::new(engine, Some(daemon))));
            }
        }
        let mixed = ClusterSim::from_hosts(mspec, &scen, hosts)
            .run(bank, scen.min_duration)
            .unwrap();
        assert_eq!(all_native.avg_perf.to_bits(), mixed.avg_perf.to_bits());
        assert_eq!(all_native.core_hours.to_bits(), mixed.core_hours.to_bits());
    }
}

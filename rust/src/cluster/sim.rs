//! The cluster simulator: N hosts behind a [`ShardPool`], one
//! [`EventBus`], and either per-host VMCd daemons (local strategy) or a
//! centralized migration-based consolidator (global strategy).
//!
//! Since the cluster-event redesign, `ClusterSim` never mutates host
//! placement state directly. Every tick it publishes cluster arrivals
//! (and, for the global strategy, planned migrations) as
//! [`ClusterEvent`]s, lets the bus route them into per-host inboxes,
//! and steps all hosts through the pool — which drains each inbox
//! through the shared [`super::bus::apply_host_event`] path and
//! publishes fresh [`HostSummary`]s back. The global strategy plans
//! *from those summaries*, so the coordinator's view of the cluster is
//! exactly what the bus publishes.
//!
//! Hosts are independent within one tick, so every [`StepMode`] —
//! caller thread, per-tick scoped workers, persistent pool — produces
//! bit-identical results (test-gated below).

use super::bus::{ClusterEvent, EventBus, HostSummary};
use super::dispatch::{ArrivalPolicy, Dispatcher};
use super::host::{ClusterHost, HostHandle, SimHost};
use super::migration::MigrationModel;
use super::migrator::{MigratorStats, VmMigrator};
use super::pool::{ShardPool, StepMode};
use crate::config::{Config, MigratorParams};
use crate::metrics::ClusterLedger;
use crate::hostsim::{Vm, VmId, VmState};
use crate::profiling::ProfileBank;
use crate::scenarios::ScenarioSpec;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::vmcd::scheduler::{self, Policy};
use crate::vmcd::{ActuationSpec, Daemon};
use crate::workloads::catalog::spec_of;
use crate::workloads::WorkloadKind;
use anyhow::Result;

/// Cluster-level consolidation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Dispatch at arrival; each host's own VMCd daemon optimises locally
    /// by re-pinning. No migrations (the paper's approach). Each daemon
    /// mutates one long-lived placement state via event deltas, so a
    /// cluster tick costs O(resident VMs) per host.
    LocalVmcd,
    /// Centralized scheduler with global knowledge: periodic reshuffle
    /// packs VMs onto the fewest hosts via live migration; hosts pin
    /// round-robin internally (the §III strawman the paper argues against
    /// under oversubscription).
    GlobalMigration,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::LocalVmcd => "local-vmcd",
            Strategy::GlobalMigration => "global-migration",
        }
    }
}

/// Cluster experiment description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub hosts: usize,
    pub cfg: Config,
    pub strategy: Strategy,
    pub dispatcher: Dispatcher,
    /// Per-host daemon policy for [`Strategy::LocalVmcd`].
    pub local_policy: Policy,
    pub migration: MigrationModel,
    /// Global reshuffle period, seconds.
    pub global_interval: f64,
    /// Max concurrent migrations per reshuffle.
    pub max_migrations: usize,
    /// How hosts step each tick. Results are bit-identical across
    /// modes; only wall time differs.
    pub step_mode: StepMode,
    /// Actuation backend of each host daemon ([`Strategy::LocalVmcd`]):
    /// `Inline` enforces pins within the deciding pass, `Deferred`
    /// models real enforcement latency (pins land N ticks late, within
    /// a per-tick budget).
    pub actuation: ActuationSpec,
    /// Per-host capacity vectors for the dispatch matrix
    /// (`[cpu_cores, diskio, netio, membw]`, e.g. from a trace
    /// host-classes file). `None` = every host advertises
    /// [`crate::config::HostSpec::metric_caps`]; shorter vectors are
    /// padded with that default. Dispatch-side only: the engine physics
    /// keep the homogeneous `HostSpec`, so this models what the
    /// *scheduler* believes about a heterogeneous fleet.
    pub host_caps: Option<Vec<crate::workloads::MetricVec>>,
    /// Continuous migration manager ([`super::migrator`]): `None`
    /// disables it — the sim then publishes nothing extra and draws no
    /// extra RNG, so runs are bit-identical to a build without it.
    pub migrator: Option<MigratorParams>,
}

impl ClusterSpec {
    pub fn new(hosts: usize, strategy: Strategy) -> ClusterSpec {
        ClusterSpec {
            hosts,
            cfg: Config::default(),
            strategy,
            dispatcher: Dispatcher::LeastLoaded,
            local_policy: Policy::Ias,
            migration: MigrationModel::default(),
            global_interval: 120.0,
            max_migrations: 4,
            step_mode: StepMode::Single,
            actuation: ActuationSpec::Inline,
            host_caps: None,
            migrator: None,
        }
    }
}

/// Reject zero/absurd cluster shapes before they build a silent empty
/// run (0 hosts) or an OOM-sized fleet — the `vmcd cluster` argument
/// validation satellite.
pub fn validate_shape(hosts: usize, vms: usize) -> Result<()> {
    anyhow::ensure!(hosts >= 1, "--hosts must be ≥ 1, got {hosts}");
    anyhow::ensure!(
        hosts <= 1 << 20,
        "--hosts {hosts} is absurd (max {})",
        1usize << 20
    );
    anyhow::ensure!(vms >= 1, "--vms must be ≥ 1, got {vms}");
    anyhow::ensure!(
        vms <= 10_000_000,
        "--vms {vms} is absurd (max 10000000)"
    );
    Ok(())
}

/// Cluster run summary.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub strategy: Strategy,
    pub avg_perf: f64,
    /// Σ per-host busy-core hours.
    pub core_hours: f64,
    /// Σ hours each host spent powered (≥ 1 busy core) — what global
    /// consolidation optimises by draining hosts.
    pub host_hours: f64,
    pub migrations_started: u64,
    pub migrations_completed: u64,
    pub migrations_failed: u64,
    /// Cluster events routed through the bus over the whole run.
    pub events_routed: u64,
    pub completion_time: f64,
    /// Parked-aware cluster energy in Wh (empty hosts draw 0 W).
    pub energy_wh: f64,
    /// Always-plugged cluster energy in Wh (Σ per-host ledgers) — the
    /// gap to `energy_wh` is what parking saved.
    pub plugged_energy_wh: f64,
    /// dslab-style SLATAH: overload host-time over powered host-time.
    pub slav: f64,
    pub overload_seconds: f64,
    /// Hours of powered (non-empty) host time.
    pub active_host_hours: f64,
    /// Moves the continuous migrator published (0 when disabled).
    pub migrator_moves: u64,
}

impl ClusterResult {
    /// Fold every field (declaration order) into one FNV-1a digest —
    /// what `vmcd cluster … --digest` prints so two processes with the
    /// same seed can be compared for bit-identity (see DETERMINISM.md).
    pub fn bit_digest(&self) -> u64 {
        let mut h = crate::util::digest::Fnv64::new();
        h.write_bytes(self.strategy.name().as_bytes())
            .write_f64(self.avg_perf)
            .write_f64(self.core_hours)
            .write_f64(self.host_hours)
            .write_u64(self.migrations_started)
            .write_u64(self.migrations_completed)
            .write_u64(self.migrations_failed)
            .write_u64(self.events_routed)
            .write_f64(self.completion_time)
            .write_f64(self.energy_wh)
            .write_f64(self.plugged_energy_wh)
            .write_f64(self.slav)
            .write_f64(self.overload_seconds)
            .write_f64(self.active_host_hours)
            .write_u64(self.migrator_moves);
        h.finish()
    }
}

/// One pending (not yet arrived) VM.
struct Pending {
    vm: Vm,
}

pub struct ClusterSim {
    spec: ClusterSpec,
    pool: ShardPool,
    bus: EventBus,
    policy: Box<dyn ArrivalPolicy>,
    pending: Vec<Pending>,
    rng: Rng,
    last_reshuffle: f64,
    t: f64,
    /// Per-host powered integral (seconds).
    powered_seconds: Vec<f64>,
    /// All batch work finished as of the last tick.
    batch_done: bool,
    /// Continuous migration manager (None = disabled).
    migrator: Option<VmMigrator>,
    /// Cluster-scope accounting, fed once per tick from the reports.
    ledger: ClusterLedger,
}

impl ClusterSim {
    /// Build from a scenario spec: `scenario.vms` arrive cluster-wide and
    /// are dispatched to hosts on arrival. Hosts are native (shardable);
    /// use [`Self::from_hosts`] to mix in caller-thread-pinned hosts.
    /// Errors if the shard pool cannot spawn its workers.
    pub fn new(
        spec: ClusterSpec,
        scenario: &ScenarioSpec,
        bank: &ProfileBank,
    ) -> Result<ClusterSim> {
        let mut hosts = Vec::with_capacity(spec.hosts);
        for _ in 0..spec.hosts {
            let engine = crate::hostsim::SimEngine::new(spec.cfg.clone(), Vec::new());
            let daemon = match spec.strategy {
                Strategy::LocalVmcd => {
                    let sched = scheduler::build_native(
                        spec.local_policy,
                        bank,
                        spec.cfg.sched.ras_threshold,
                        spec.cfg.sched.ias_threshold,
                    );
                    Some(Daemon::with_actuation(
                        spec.cfg.sched.clone(),
                        sched,
                        spec.cfg.host.cores,
                        spec.actuation.build(),
                    ))
                }
                Strategy::GlobalMigration => None,
            };
            hosts.push(ClusterHost::Native(SimHost::new(engine, daemon)));
        }
        ClusterSim::from_hosts(spec, scenario, hosts)
    }

    /// Build over explicit hosts (native and/or pinned). `spec.hosts` is
    /// overridden by `hosts.len()`. Errors if the shard pool cannot
    /// spawn its workers.
    pub fn from_hosts(
        mut spec: ClusterSpec,
        scenario: &ScenarioSpec,
        hosts: Vec<ClusterHost>,
    ) -> Result<ClusterSim> {
        spec.hosts = hosts.len();
        let n = hosts.len();
        // Capture each host's starting occupancy before the pool takes
        // ownership, so arrival policies see pre-existing residents even
        // on the first tick (the load estimate fills in at first refresh).
        let initial: Vec<HostSummary> = hosts.iter().map(|h| h.handle().summary()).collect();
        let pool = ShardPool::new(hosts, spec.step_mode)?;
        let mut bus = EventBus::new(n, spec.migration.clone(), spec.cfg.host.cores);
        bus.prime(initial);
        // Scheduler-side CPU capacities double as the power model's
        // utilization denominators (empty = homogeneous fleet, core
        // count per host).
        let mut cpu_caps = Vec::new();
        if let Some(mut caps) = spec.host_caps.clone() {
            caps.resize(n, spec.cfg.host.metric_caps());
            cpu_caps = caps.iter().map(|c| c[0]).collect();
            bus.set_host_caps(caps);
        }
        let policy = spec.dispatcher.build();
        let pending = scenario
            .vms
            .iter()
            .enumerate()
            .map(|(i, t)| Pending {
                vm: Vm::new(VmId(i as u32), t.class, t.arrival, t.activity.clone()),
            })
            .collect();
        let rng = Rng::new(spec.cfg.sim.seed ^ 0xC1_05_7E_12);
        let migrator = spec.migrator.clone().map(|p| {
            VmMigrator::with_env(
                p,
                super::migrator::PlanEnv {
                    migration: spec.migration.clone(),
                    power: spec.cfg.power.clone(),
                    host: spec.cfg.host.clone(),
                },
            )
        });
        let ledger = ClusterLedger::with_power(spec.cfg.power.clone(), cpu_caps);
        Ok(ClusterSim {
            spec,
            pool,
            bus,
            policy,
            pending,
            rng,
            last_reshuffle: 0.0,
            t: 0.0,
            powered_seconds: vec![0.0; n],
            batch_done: false,
            migrator,
            ledger,
        })
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// The bus (summaries, routing stats) — the only cluster-state view
    /// embedders get, same as the strategies themselves.
    pub fn bus(&self) -> &EventBus {
        &self.bus
    }

    /// Publish an external cluster event (evictions, forced scheduler
    /// ticks, replayed traces); it is routed on the next [`Self::tick`].
    pub fn publish(&mut self, ev: ClusterEvent) {
        self.bus.publish(ev);
    }

    /// Drain the bus's placement log: where every arrival (and completed
    /// migration) since the last drain landed. Trace replay reads this
    /// to address later `Departure`/`Migrate` events at the right host.
    pub fn take_moves(&mut self) -> Vec<(VmId, usize)> {
        self.bus.take_moves()
    }

    /// Queue every due scenario arrival as a routed cluster event.
    fn publish_arrivals(&mut self) {
        let due: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, p)| p.vm.arrival <= self.t)
            .map(|(i, _)| i)
            .collect();
        for &i in due.iter().rev() {
            let mut p = self.pending.remove(i);
            p.vm.state = VmState::Running;
            p.vm.started = Some(self.t);
            self.bus.publish(ClusterEvent::Arrival {
                vm: p.vm,
                host: None,
            });
        }
    }

    /// The centralized consolidator, planning **from the bus-published
    /// summaries**: estimate each host's CPU load from profiles, drain
    /// the least-loaded non-empty host into the others if they have
    /// headroom — each move published as a `ClusterEvent::Migrate`.
    fn plan_reshuffle(&mut self, bank: &ProfileBank) {
        let cores = self.spec.cfg.host.cores as f64;
        let cap = cores * self.spec.cfg.sched.ras_threshold;
        let summaries = self.bus.summaries();
        let n = summaries.len();
        let loads: Vec<f64> = summaries.iter().map(|s| s.est_cpu_load).collect();
        let counts: Vec<usize> = summaries.iter().map(|s| s.running.len()).collect();

        // Drain candidate: the least-loaded host with any running VMs.
        let Some(src) = (0..n)
            .filter(|&h| counts[h] > 0)
            .min_by(|&a, &b| loads[a].total_cmp(&loads[b]))
        else {
            return;
        };
        // Only drain if the rest of the cluster can absorb it.
        let spare: f64 = (0..n)
            .filter(|&h| h != src)
            .map(|h| (cap - loads[h]).max(0.0))
            .sum();
        if spare < loads[src] {
            return;
        }

        let candidates: Vec<(VmId, f64)> = summaries[src]
            .running
            .iter()
            .take(self.spec.max_migrations)
            .map(|&(id, class)| (id, bank.u[class.index()][0]))
            .collect();
        let in_flight = self.bus.in_flight();
        let mut started = 0;
        for (id, vm_load) in candidates {
            if in_flight + started >= self.spec.max_migrations {
                break;
            }
            // Destination: most-loaded host that still fits the VM (pack).
            let Some(dst) = (0..n)
                .filter(|&h| h != src)
                .filter(|&h| loads[h] + vm_load <= cap)
                .max_by(|&a, &b| loads[a].total_cmp(&loads[b]))
            else {
                continue;
            };
            self.bus.publish(ClusterEvent::Migrate { vm: id, src, dst });
            started += 1;
        }
    }

    /// One cluster tick: publish due arrivals (and reshuffle moves),
    /// route everything through the bus, finish matured transfers, and
    /// step every host against its inbox.
    pub fn tick(&mut self, bank: &ProfileBank) -> Result<()> {
        let dt = self.spec.cfg.sim.dt;
        self.publish_arrivals();

        if self.spec.strategy == Strategy::GlobalMigration
            && self.t - self.last_reshuffle >= self.spec.global_interval
        {
            self.last_reshuffle = self.t;
            self.plan_reshuffle(bank);
        }

        // The continuous migrator plans from the same refreshed
        // summaries the arrival policies read, before routing — its
        // moves enter this tick's routing window like any other event.
        if let Some(mig) = self.migrator.as_mut() {
            for m in mig.maybe_plan(self.t, &self.bus, bank) {
                self.bus.publish(ClusterEvent::Migrate {
                    vm: m.vm,
                    src: m.src,
                    dst: m.dst,
                });
            }
        }

        self.bus.route(self.policy.as_mut(), bank, &mut self.rng)?;

        let matured = self.bus.advance(dt);
        if !matured.is_empty() {
            let requests = EventBus::extraction_requests(&matured);
            let extracted = self.pool.extract(&requests)?;
            self.bus.deliver(matured, extracted, self.t);
        }

        let inboxes = self.bus.take_inboxes();
        let reports = self.pool.step(inboxes)?;
        let mut powered = 0usize;
        for (h, report) in reports.iter().enumerate() {
            if report.busy_now {
                self.powered_seconds[h] += dt;
            }
            let s = &report.summary;
            if s.resident > 0 || s.busy_cores > 0 {
                powered += 1;
            }
            self.ledger
                .record_host_tick(h, s.busy_cores, s.resident, dt, &self.spec.cfg.host);
        }
        self.ledger.note_tick(self.t, powered);
        self.batch_done =
            reports.iter().all(|r| r.batch_done) && self.pending.is_empty();
        self.bus.refresh(&reports, bank);
        // Feed the freshly refreshed summaries into the migrator's
        // forecaster (no-op with forecast=off), so the next planning
        // pass extrapolates from the very view it will plan over.
        if let Some(mig) = self.migrator.as_mut() {
            let summaries = self.bus.summaries();
            mig.observe(summaries, dt);
        }
        self.t += dt;
        Ok(())
    }

    /// Cluster-scope accounting as of now (energy, overload time,
    /// powered-host series). Per-host ledgers are folded in by
    /// [`Self::run`] / replay at the end of the run.
    pub fn ledger(&self) -> &ClusterLedger {
        &self.ledger
    }

    /// Continuous-migrator counters, when one is enabled.
    pub fn migrator_stats(&self) -> Option<MigratorStats> {
        self.migrator.as_ref().map(|m| m.stats)
    }

    /// Tear down the pool and hand back every host (tests, inspection).
    pub fn finish(self) -> Result<Vec<ClusterHost>> {
        self.pool.into_hosts()
    }

    /// Run to completion; returns the cluster summary.
    pub fn run(mut self, bank: &ProfileBank, min_duration: f64) -> Result<ClusterResult> {
        let max_time = self.spec.cfg.sim.max_time;
        loop {
            self.tick(bank)?;
            if (self.batch_done && self.t >= min_duration) || self.t >= max_time {
                break;
            }
        }

        let ClusterSim {
            spec,
            pool,
            bus,
            powered_seconds,
            t,
            migrator,
            mut ledger,
            ..
        } = self;
        let hosts = pool.into_hosts()?;

        let mut perfs = Vec::new();
        let mut core_hours = 0.0;
        let mut host_hours = 0.0;
        for (h, host) in hosts.iter().enumerate() {
            let engine = host.handle().engine();
            ledger.absorb(&engine.ledger);
            core_hours += engine.ledger.core_hours();
            host_hours += powered_seconds[h] / 3600.0;
            for vm in &engine.vms {
                if vm.state == VmState::NotArrived {
                    continue;
                }
                if let Some(p) = vm.normalized_perf() {
                    perfs.push(p);
                } else if vm.spec.perf.kind == WorkloadKind::Batch {
                    if let Some(start) = vm.work_started {
                        let elapsed = t - start;
                        if elapsed > 0.0 {
                            perfs.push((vm.work_done / elapsed).clamp(0.0, 1.0));
                        }
                    }
                }
            }
        }
        // Sanity: every spec'd class is consistent (defensive, cheap).
        debug_assert!(hosts.iter().all(|host| {
            host.handle()
                .engine()
                .vms
                .iter()
                .all(|vm| spec_of(vm.class).class == vm.class)
        }));
        Ok(ClusterResult {
            strategy: spec.strategy,
            avg_perf: mean(&perfs),
            core_hours,
            host_hours,
            migrations_started: bus.stats.migrations_started,
            migrations_completed: bus.stats.migrations_completed,
            migrations_failed: bus.stats.migrations_failed,
            events_routed: bus.stats.events_routed,
            completion_time: t,
            energy_wh: ledger.energy_wh(),
            plugged_energy_wh: ledger.plugged_energy_wh(),
            slav: ledger.slav(),
            overload_seconds: ledger.overload_seconds,
            active_host_hours: ledger.active_host_hours(),
            migrator_moves: migrator.map(|m| m.stats.planned_moves).unwrap_or(0),
        })
    }
}

/// Convenience: current per-host summaries (after at least one tick).
impl ClusterSim {
    pub fn summaries(&self) -> &[HostSummary] {
        self.bus.summaries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostsim::SimEngine;
    use crate::scenarios::random;
    use crate::testkit;
    use crate::vmcd::daemon::SchedEvent;

    fn cluster_scenario(hosts: usize, sr: f64, seed: u64) -> ScenarioSpec {
        // SR is per-host: hosts × cores × sr VMs cluster-wide.
        random::build(hosts * 12, sr, seed).unwrap()
    }

    #[test]
    fn validate_shape_rejects_zero_and_absurd_sizes() {
        assert!(validate_shape(4, 100).is_ok());
        assert!(validate_shape(1, 1).is_ok());
        for (hosts, vms, needle) in [
            (0, 10, "--hosts must be ≥ 1"),
            (usize::MAX, 10, "absurd"),
            (4, 0, "--vms must be ≥ 1"),
            (4, usize::MAX, "absurd"),
        ] {
            let err = validate_shape(hosts, vms).unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }

    #[test]
    fn spec_host_caps_reach_the_dispatch_matrix_padded_to_the_fleet() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        // One explicit big box; the other two pad to the HostSpec default.
        spec.host_caps = Some(vec![[32.0, 2.0, 2.0, 8.0]]);
        let default_caps = spec.cfg.host.metric_caps();
        let mut scen = cluster_scenario(3, 0.5, 1);
        scen.vms.clear();
        let sim = ClusterSim::new(spec, &scen, bank).unwrap();
        let m = sim.bus().matrix();
        assert_eq!(m.cap(0, 0), 32.0);
        assert_eq!(m.cap(0, 3), 8.0);
        for h in 1..3 {
            for metric in 0..crate::workloads::NUM_METRICS {
                assert_eq!(m.cap(h, metric), default_caps[metric]);
            }
        }
    }

    #[test]
    fn local_strategy_runs_and_consolidates() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank).unwrap();
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert_eq!(r.migrations_started, 0, "local strategy never migrates");
        assert!(r.avg_perf > 0.6, "perf {}", r.avg_perf);
        assert!(r.core_hours > 0.0);
        assert!(r.host_hours > 0.0);
        assert!(
            r.events_routed >= scen.vms.len() as u64,
            "every arrival must be routed: {} < {}",
            r.events_routed,
            scen.vms.len()
        );
    }

    #[test]
    fn global_strategy_migrates_and_pays_for_it() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::GlobalMigration);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank).unwrap();
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert!(r.migrations_started > 0, "global strategy must migrate");
    }

    #[test]
    fn local_beats_global_when_cluster_is_oversubscribed() {
        // The paper's §III argument: with the whole infrastructure
        // oversubscribed, migrations are unreliable and expensive, so the
        // local approach preserves performance better.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 1.8, 42);

        let mut lspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        lspec.cfg = testkit::quiet_config();
        let local = ClusterSim::new(lspec, &scen, bank)
            .unwrap()
            .run(bank, scen.min_duration)
            .unwrap();

        let mut gspec = ClusterSpec::new(3, Strategy::GlobalMigration);
        gspec.cfg = testkit::quiet_config();
        let global = ClusterSim::new(gspec, &scen, bank)
            .unwrap()
            .run(bank, scen.min_duration)
            .unwrap();

        assert!(
            local.avg_perf >= global.avg_perf - 0.02,
            "local {:.3} must not lose to global {:.3} under oversubscription",
            local.avg_perf,
            global.avg_perf
        );
    }

    #[test]
    fn dispatcher_balances_residents() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(4, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(4, 0.5, 7);
        let total = scen.vms.len();
        let mut sim = ClusterSim::new(spec, &scen, bank).unwrap();
        // Tick past all arrivals; the bus's published summaries are the
        // dispatcher's own view, so assert balance on exactly those.
        for _ in 0..(30 * total + 10) {
            sim.tick(bank).unwrap();
        }
        let counts: Vec<usize> = sim.summaries().iter().map(|s| s.resident).collect();
        assert_eq!(counts.iter().sum::<usize>(), total, "all VMs dispatched");
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "least-loaded must balance: {counts:?}");
    }

    #[test]
    fn all_step_modes_are_bit_identical() {
        // The acceptance property: hosts are independent within a tick
        // and every delivery mutates exactly one host, so neither the
        // per-tick scoped split nor the persistent pool can change any
        // result bit.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(4, 1.0, 11);
        let run = |mode: StepMode| {
            let mut spec = ClusterSpec::new(4, Strategy::LocalVmcd);
            spec.cfg = testkit::quiet_config();
            spec.step_mode = mode;
            ClusterSim::new(spec, &scen, bank)
                .unwrap()
                .run(bank, scen.min_duration)
                .unwrap()
        };
        let single = run(StepMode::Single);
        for other in [run(StepMode::Scoped(3)), run(StepMode::Pool(3))] {
            assert_eq!(single.avg_perf.to_bits(), other.avg_perf.to_bits());
            assert_eq!(single.core_hours.to_bits(), other.core_hours.to_bits());
            assert_eq!(single.host_hours.to_bits(), other.host_hours.to_bits());
            assert_eq!(
                single.completion_time.to_bits(),
                other.completion_time.to_bits()
            );
            assert_eq!(single.migrations_started, other.migrations_started);
            assert_eq!(single.events_routed, other.events_routed);
        }
    }

    #[test]
    fn inline_and_zero_lag_deferred_are_bit_identical_cluster_wide() {
        // The tentpole acceptance at cluster scale: a Deferred backend
        // with zero latency and no budget enforces every command before
        // the engine physics of the same tick, so whole-run results
        // cannot differ from Inline by a single bit.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 1.0, 42);
        let run = |actuation: ActuationSpec| {
            let mut spec = ClusterSpec::new(3, Strategy::LocalVmcd);
            spec.cfg = testkit::quiet_config();
            spec.actuation = actuation;
            ClusterSim::new(spec, &scen, bank)
                .unwrap()
                .run(bank, scen.min_duration)
                .unwrap()
        };
        let inline = run(ActuationSpec::Inline);
        let deferred = run(ActuationSpec::Deferred {
            latency_ticks: 0,
            budget_per_tick: 0,
        });
        assert_eq!(inline.avg_perf.to_bits(), deferred.avg_perf.to_bits());
        assert_eq!(inline.core_hours.to_bits(), deferred.core_hours.to_bits());
        assert_eq!(
            inline.completion_time.to_bits(),
            deferred.completion_time.to_bits()
        );
        assert_eq!(inline.events_routed, deferred.events_routed);
    }

    #[test]
    fn deferred_actuation_with_lag_still_completes_the_scenario() {
        // Actuation-lag sensitivity end-to-end: pins landing 4 ticks
        // late (and budgeted) slow workloads down but the cluster still
        // converges and finishes.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(2, 0.75, 7);
        let mut spec = ClusterSpec::new(2, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        spec.actuation = ActuationSpec::Deferred {
            latency_ticks: 4,
            budget_per_tick: 8,
        };
        let r = ClusterSim::new(spec, &scen, bank)
            .unwrap()
            .run(bank, scen.min_duration)
            .unwrap();
        assert!(r.avg_perf > 0.3, "perf {}", r.avg_perf);
        assert!(r.core_hours > 0.0);
    }

    #[test]
    fn global_strategy_is_bit_identical_across_step_modes() {
        // Migration traffic exercises extract + deliver across worker
        // boundaries; it too must not depend on the step mode.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 0.75, 42);
        let run = |mode: StepMode| {
            let mut spec = ClusterSpec::new(3, Strategy::GlobalMigration);
            spec.cfg = testkit::quiet_config();
            spec.step_mode = mode;
            ClusterSim::new(spec, &scen, bank)
                .unwrap()
                .run(bank, scen.min_duration)
                .unwrap()
        };
        let single = run(StepMode::Single);
        let pooled = run(StepMode::Pool(3));
        assert_eq!(single.avg_perf.to_bits(), pooled.avg_perf.to_bits());
        assert_eq!(single.migrations_started, pooled.migrations_started);
        assert_eq!(single.migrations_failed, pooled.migrations_failed);
    }

    #[test]
    fn pinned_hosts_mix_with_pooled_native_hosts() {
        // A caller-thread host (the XLA stand-in: Box<dyn HostHandle>)
        // alongside pool-owned native hosts must reproduce the all-native
        // results exactly — same policy, same backend math.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 0.75, 42);
        let cfg = testkit::quiet_config();

        let mut nspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        nspec.cfg = cfg.clone();
        let all_native = ClusterSim::new(nspec, &scen, bank)
            .unwrap()
            .run(bank, scen.min_duration)
            .unwrap();

        let mut mspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        mspec.cfg = cfg.clone();
        mspec.step_mode = StepMode::Pool(2);
        let mut hosts = Vec::new();
        for i in 0..3 {
            let engine = SimEngine::new(cfg.clone(), Vec::new());
            if i == 2 {
                let sched =
                    scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
                let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
                hosts.push(ClusterHost::Pinned(Box::new(SimHost::new(
                    engine,
                    Some(daemon),
                ))));
            } else {
                let sched = scheduler::build_native(
                    Policy::Ias,
                    bank,
                    cfg.sched.ras_threshold,
                    None,
                );
                let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
                hosts.push(ClusterHost::Native(SimHost::new(engine, Some(daemon))));
            }
        }
        let mixed = ClusterSim::from_hosts(mspec, &scen, hosts)
            .unwrap()
            .run(bank, scen.min_duration)
            .unwrap();
        assert_eq!(all_native.avg_perf.to_bits(), mixed.avg_perf.to_bits());
        assert_eq!(all_native.core_hours.to_bits(), mixed.core_hours.to_bits());
    }

    #[test]
    fn published_migrate_event_moves_bookkeeping_not_just_the_vm() {
        // The satellite acceptance: Departure + delayed Arrival through
        // the bus must leave both daemons' long-lived placement states
        // exactly as the old in-place move left the engines — source
        // empty, destination holding the member — with the stop-and-copy
        // pause applied.
        let bank = testkit::shared_bank();
        let cfg = testkit::quiet_config();
        let mut spec = ClusterSpec::new(2, Strategy::LocalVmcd);
        spec.cfg = cfg.clone();
        spec.migration.failure_prob = 0.0; // deterministic success
        let transfer = spec.migration.transfer_secs;
        let downtime = spec.migration.downtime;

        // One always-on VM arriving at t=0 on host 0 (least-loaded
        // tie-break); a known CPU-heavy class so it never parks as idle.
        let mut scen = cluster_scenario(2, 0.75, 42);
        scen.vms.truncate(1);
        scen.vms[0].arrival = 0.0;
        scen.vms[0].class = crate::workloads::WorkloadClass::Blackscholes;
        scen.vms[0].activity = crate::hostsim::ActivityModel::AlwaysOn;
        let mut sim = ClusterSim::new(spec, &scen, bank).unwrap();
        let dt = cfg.sim.dt;
        // Let it settle so the monitor window warms.
        for _ in 0..15 {
            sim.tick(bank).unwrap();
        }
        assert_eq!(sim.summaries()[0].resident, 1);

        sim.publish(ClusterEvent::Migrate {
            vm: VmId(0),
            src: 0,
            dst: 1,
        });
        let move_published_at = sim.now();
        // Route + transfer window + the completing tick.
        let ticks = (transfer / dt).ceil() as usize + 1;
        for _ in 0..ticks {
            sim.tick(bank).unwrap();
        }
        let completed_at = move_published_at + ticks as f64 * dt;
        assert_eq!(sim.bus().stats.migrations_started, 1);
        assert_eq!(sim.summaries()[0].resident, 0);
        assert_eq!(sim.summaries()[1].resident, 1);

        let hosts = sim.finish().unwrap();
        let daemon_state = |h: &ClusterHost| match h {
            ClusterHost::Native(host) => host
                .daemon
                .as_ref()
                .unwrap()
                .placement_state()
                .placed(),
            ClusterHost::Pinned(_) => unreachable!(),
        };
        assert_eq!(daemon_state(&hosts[0]), 0, "source daemon kept a ghost");
        assert_eq!(daemon_state(&hosts[1]), 1, "destination daemon missed the arrival");
        assert_eq!(hosts[0].handle().engine().vms.len(), 0);
        let dst_engine = hosts[1].handle().engine();
        assert_eq!(dst_engine.vms.len(), 1);
        assert_eq!(dst_engine.vms[0].id, VmId(0));
        // The move completed on the tick the transfer matured, pausing
        // the VM for the stop-and-copy downtime from that instant.
        assert!(
            (dst_engine.vms[0].paused_until - (completed_at - dt + downtime)).abs() <= dt + 1e-9,
            "pause {} vs completion {}",
            dst_engine.vms[0].paused_until,
            completed_at
        );
    }

    #[test]
    fn external_sched_events_route_to_one_host() {
        let bank = testkit::shared_bank();
        let cfg = testkit::quiet_config();
        let mut spec = ClusterSpec::new(2, Strategy::LocalVmcd);
        spec.cfg = cfg;
        let mut scen = cluster_scenario(2, 0.5, 3);
        scen.vms.clear();
        let mut sim = ClusterSim::new(spec, &scen, bank).unwrap();
        // First tick: both daemons run their own due cycle.
        sim.tick(bank).unwrap();
        // An injected Tick gives host 1 one extra cycle (and resets its
        // interval clock); host 0 stays on its own schedule.
        sim.publish(ClusterEvent::Sched {
            host: 1,
            ev: SchedEvent::Tick,
        });
        sim.tick(bank).unwrap();
        let hosts = sim.finish().unwrap();
        let cycles = |h: &ClusterHost| h.handle().metrics().cycles;
        assert!(
            cycles(&hosts[1]) > cycles(&hosts[0]),
            "injected tick must add a cycle: {} vs {}",
            cycles(&hosts[1]),
            cycles(&hosts[0])
        );
    }
}

//! The cluster simulator: N hosts in lockstep, one dispatcher, and either
//! per-host VMCd daemons (local strategy) or a centralized
//! migration-based consolidator (global strategy).

use super::dispatch::Dispatcher;
use super::migration::{Migration, MigrationModel};
use crate::config::Config;
use crate::hostsim::{SimEngine, Vm, VmId, VmState};
use crate::profiling::ProfileBank;
use crate::scenarios::ScenarioSpec;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::vmcd::scheduler::{self, Policy};
use crate::vmcd::Daemon;
use crate::workloads::catalog::spec_of;
use crate::workloads::WorkloadKind;
use anyhow::Result;

/// Cluster-level consolidation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Dispatch at arrival; each host's own VMCd daemon optimises locally
    /// by re-pinning. No migrations (the paper's approach). Each daemon's
    /// scheduler scores on the incremental placement cache, so a lockstep
    /// cluster step costs O(resident VMs) per host rather than
    /// O(cores × members²).
    LocalVmcd,
    /// Centralized scheduler with global knowledge: periodic reshuffle
    /// packs VMs onto the fewest hosts via live migration; hosts pin
    /// round-robin internally (the §III strawman the paper argues against
    /// under oversubscription).
    GlobalMigration,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::LocalVmcd => "local-vmcd",
            Strategy::GlobalMigration => "global-migration",
        }
    }
}

/// Cluster experiment description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub hosts: usize,
    pub cfg: Config,
    pub strategy: Strategy,
    pub dispatcher: Dispatcher,
    /// Per-host daemon policy for [`Strategy::LocalVmcd`].
    pub local_policy: Policy,
    pub migration: MigrationModel,
    /// Global reshuffle period, seconds.
    pub global_interval: f64,
    /// Max concurrent migrations per reshuffle.
    pub max_migrations: usize,
}

impl ClusterSpec {
    pub fn new(hosts: usize, strategy: Strategy) -> ClusterSpec {
        ClusterSpec {
            hosts,
            cfg: Config::default(),
            strategy,
            dispatcher: Dispatcher::LeastLoaded,
            local_policy: Policy::Ias,
            migration: MigrationModel::default(),
            global_interval: 120.0,
            max_migrations: 4,
        }
    }
}

/// Cluster run summary.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    pub strategy: Strategy,
    pub avg_perf: f64,
    /// Σ per-host busy-core hours.
    pub core_hours: f64,
    /// Σ hours each host spent powered (≥ 1 busy core) — what global
    /// consolidation optimises by draining hosts.
    pub host_hours: f64,
    pub migrations_started: u64,
    pub migrations_failed: u64,
    pub completion_time: f64,
}

struct HostSlot {
    engine: SimEngine,
    daemon: Option<Daemon>,
    /// Round-robin core cursor for the global strategy's in-host pinning.
    rr_core: usize,
    /// Host-powered integral (seconds).
    powered_seconds: f64,
}

/// One pending (not yet arrived) VM.
struct Pending {
    vm: Vm,
}

pub struct ClusterSim {
    spec: ClusterSpec,
    hosts: Vec<HostSlot>,
    pending: Vec<Pending>,
    migrations: Vec<Migration>,
    rng: Rng,
    rr_dispatch: usize,
    last_reshuffle: f64,
    t: f64,
    migrations_started: u64,
    migrations_failed: u64,
}

impl ClusterSim {
    /// Build from a scenario spec: `scenario.vms` arrive cluster-wide and
    /// are dispatched to hosts on arrival.
    pub fn new(spec: ClusterSpec, scenario: &ScenarioSpec, bank: &ProfileBank) -> ClusterSim {
        let mut hosts = Vec::with_capacity(spec.hosts);
        for _ in 0..spec.hosts {
            let engine = SimEngine::new(spec.cfg.clone(), Vec::new());
            let daemon = match spec.strategy {
                Strategy::LocalVmcd => {
                    let sched = scheduler::build(
                        spec.local_policy,
                        bank,
                        spec.cfg.sched.ras_threshold,
                        spec.cfg.sched.ias_threshold,
                    );
                    Some(Daemon::new(spec.cfg.sched.clone(), sched))
                }
                Strategy::GlobalMigration => None,
            };
            hosts.push(HostSlot {
                engine,
                daemon,
                rr_core: 0,
                powered_seconds: 0.0,
            });
        }
        let pending = scenario
            .vms
            .iter()
            .enumerate()
            .map(|(i, t)| Pending {
                vm: Vm::new(VmId(i as u32), t.class, t.arrival, t.activity.clone()),
            })
            .collect();
        let rng = Rng::new(spec.cfg.sim.seed ^ 0xC1_05_7E_12);
        ClusterSim {
            spec,
            hosts,
            pending,
            migrations: Vec::new(),
            rng,
            rr_dispatch: 0,
            last_reshuffle: 0.0,
            t: 0.0,
            migrations_started: 0,
            migrations_failed: 0,
        }
    }

    fn dispatch_arrivals(&mut self) -> Result<()> {
        let due: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, p)| p.vm.arrival <= self.t)
            .map(|(i, _)| i)
            .collect();
        for &i in due.iter().rev() {
            let mut p = self.pending.remove(i);
            let residents: Vec<usize> =
                self.hosts.iter().map(|h| h.engine.vms.len()).collect();
            let host = self
                .spec
                .dispatcher
                .pick(&residents, &mut self.rr_dispatch, &mut self.rng);
            p.vm.state = VmState::Running;
            p.vm.started = Some(self.t);
            let id = p.vm.id;
            let slot = &mut self.hosts[host];
            slot.engine.insert_vm(p.vm);
            match &mut slot.daemon {
                Some(daemon) => daemon.on_arrival(&mut slot.engine, id)?,
                None => {
                    let core = slot.rr_core % self.spec.cfg.host.cores;
                    slot.rr_core += 1;
                    use crate::hostsim::Hypervisor;
                    slot.engine.pin_vcpu(id, core)?;
                }
            }
        }
        Ok(())
    }

    /// The centralized consolidator: estimate each host's CPU load from
    /// profiles, drain the least-loaded non-empty host into the others if
    /// they have headroom.
    fn global_reshuffle(&mut self, bank: &ProfileBank) {
        let cores = self.spec.cfg.host.cores as f64;
        let cap = cores * self.spec.cfg.sched.ras_threshold;
        let load = |slot: &HostSlot| -> f64 {
            slot.engine
                .vms
                .iter()
                .filter(|vm| vm.state == VmState::Running)
                .map(|vm| bank.u[vm.class.index()][0])
                .sum()
        };
        let loads: Vec<f64> = self.hosts.iter().map(load).collect();
        let counts: Vec<usize> = self
            .hosts
            .iter()
            .map(|h| {
                h.engine
                    .vms
                    .iter()
                    .filter(|vm| vm.state == VmState::Running)
                    .count()
            })
            .collect();

        // Drain candidate: the least-loaded host with any residents.
        let Some(src) = (0..self.hosts.len())
            .filter(|&h| counts[h] > 0)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
        else {
            return;
        };
        // Only drain if the rest of the cluster can absorb it.
        let spare: f64 = (0..self.hosts.len())
            .filter(|&h| h != src)
            .map(|h| (cap - loads[h]).max(0.0))
            .sum();
        if spare < loads[src] || counts[src] == 0 {
            return;
        }

        let vm_ids: Vec<VmId> = self.hosts[src]
            .engine
            .vms
            .iter()
            .filter(|vm| vm.state == VmState::Running)
            .map(|vm| vm.id)
            .take(self.spec.max_migrations)
            .collect();
        for id in vm_ids {
            if self.migrations.len() >= self.spec.max_migrations {
                break;
            }
            // Destination: most-loaded host that still fits the VM (pack).
            let vm_load = {
                let vm = self.hosts[src]
                    .engine
                    .vms
                    .iter()
                    .find(|vm| vm.id == id)
                    .unwrap();
                bank.u[vm.class.index()][0]
            };
            let Some(dst) = (0..self.hosts.len())
                .filter(|&h| h != src)
                .filter(|&h| load(&self.hosts[h]) + vm_load <= cap)
                .max_by(|&a, &b| {
                    load(&self.hosts[a])
                        .partial_cmp(&load(&self.hosts[b]))
                        .unwrap()
                })
            else {
                continue;
            };
            let dest_busy = load(&self.hosts[dst]) / cores;
            let mig = self.spec.migration.start(
                id.0 as usize,
                src,
                dst,
                dest_busy,
                &mut self.rng,
            );
            // Transfer load on both ends for the whole window.
            self.hosts[src].engine.external_net_load += self.spec.migration.transfer_net;
            self.hosts[dst].engine.external_net_load += self.spec.migration.transfer_net;
            self.migrations.push(mig);
            self.migrations_started += 1;
        }
    }

    fn advance_migrations(&mut self, dt: f64) {
        let mut finished = Vec::new();
        for (i, m) in self.migrations.iter_mut().enumerate() {
            m.remaining -= dt;
            if m.remaining <= 0.0 {
                finished.push(i);
            }
        }
        for &i in finished.iter().rev() {
            let m = self.migrations.remove(i);
            self.hosts[m.from_host].engine.external_net_load -=
                self.spec.migration.transfer_net;
            self.hosts[m.to_host].engine.external_net_load -=
                self.spec.migration.transfer_net;
            let id = VmId(m.vm_index as u32);
            if m.doomed {
                self.migrations_failed += 1;
                continue; // pre-copy never converged; VM stays.
            }
            // Stop-and-copy: move the VM, pause it for the downtime.
            if let Some(mut vm) = self.hosts[m.from_host].engine.remove_vm(id) {
                if vm.state == VmState::Running {
                    vm.paused_until = self.t + self.spec.migration.downtime;
                }
                let dst = &mut self.hosts[m.to_host];
                let core = dst.rr_core % self.spec.cfg.host.cores;
                dst.rr_core += 1;
                vm.pinned = Some(core);
                dst.engine.insert_vm(vm);
            }
        }
    }

    /// Run to completion; returns the cluster summary.
    pub fn run(mut self, bank: &ProfileBank, min_duration: f64) -> Result<ClusterResult> {
        let dt = self.spec.cfg.sim.dt;
        let max_time = self.spec.cfg.sim.max_time;
        loop {
            self.dispatch_arrivals()?;

            if self.spec.strategy == Strategy::GlobalMigration
                && self.t - self.last_reshuffle >= self.spec.global_interval
            {
                self.last_reshuffle = self.t;
                self.global_reshuffle(bank);
            }
            self.advance_migrations(dt);

            for slot in &mut self.hosts {
                if let Some(daemon) = &mut slot.daemon {
                    daemon.maybe_cycle(&mut slot.engine)?;
                }
                slot.engine.step();
                if slot.engine.ledger.busy_series.points.last().map(|p| p.1 > 0.0)
                    == Some(true)
                {
                    slot.powered_seconds += dt;
                }
            }
            self.t += dt;

            let batch_done = self.hosts.iter().all(|slot| slot.engine.all_batch_done())
                && self.pending.is_empty();
            if (batch_done && self.t >= min_duration) || self.t >= max_time {
                break;
            }
        }

        let mut perfs = Vec::new();
        let mut core_hours = 0.0;
        let mut host_hours = 0.0;
        for slot in &self.hosts {
            core_hours += slot.engine.ledger.core_hours();
            host_hours += slot.powered_seconds / 3600.0;
            for vm in &slot.engine.vms {
                if vm.state == VmState::NotArrived {
                    continue;
                }
                if let Some(p) = vm.normalized_perf() {
                    perfs.push(p);
                } else if vm.spec.perf.kind == WorkloadKind::Batch {
                    if let Some(start) = vm.work_started {
                        let elapsed = self.t - start;
                        if elapsed > 0.0 {
                            perfs.push((vm.work_done / elapsed).clamp(0.0, 1.0));
                        }
                    }
                }
            }
        }
        // Sanity: every spec'd class is consistent (defensive, cheap).
        debug_assert!(self.hosts.iter().all(|slot| {
            slot.engine
                .vms
                .iter()
                .all(|vm| spec_of(vm.class).class == vm.class)
        }));
        Ok(ClusterResult {
            strategy: self.spec.strategy,
            avg_perf: mean(&perfs),
            core_hours,
            host_hours,
            migrations_started: self.migrations_started,
            migrations_failed: self.migrations_failed,
            completion_time: self.t,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::random;
    use crate::testkit;

    fn cluster_scenario(hosts: usize, sr: f64, seed: u64) -> ScenarioSpec {
        // SR is per-host: hosts × cores × sr VMs cluster-wide.
        random::build(hosts * 12, sr, seed).unwrap()
    }

    #[test]
    fn local_strategy_runs_and_consolidates() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank);
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert_eq!(r.migrations_started, 0, "local strategy never migrates");
        assert!(r.avg_perf > 0.6, "perf {}", r.avg_perf);
        assert!(r.core_hours > 0.0);
        assert!(r.host_hours > 0.0);
    }

    #[test]
    fn global_strategy_migrates_and_pays_for_it() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(3, Strategy::GlobalMigration);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(3, 0.75, 42);
        let sim = ClusterSim::new(spec, &scen, bank);
        let r = sim.run(bank, scen.min_duration).unwrap();
        assert!(r.migrations_started > 0, "global strategy must migrate");
    }

    #[test]
    fn local_beats_global_when_cluster_is_oversubscribed() {
        // The paper's §III argument: with the whole infrastructure
        // oversubscribed, migrations are unreliable and expensive, so the
        // local approach preserves performance better.
        let bank = testkit::shared_bank();
        let scen = cluster_scenario(3, 1.8, 42);

        let mut lspec = ClusterSpec::new(3, Strategy::LocalVmcd);
        lspec.cfg = testkit::quiet_config();
        let local = ClusterSim::new(lspec, &scen, bank)
            .run(bank, scen.min_duration)
            .unwrap();

        let mut gspec = ClusterSpec::new(3, Strategy::GlobalMigration);
        gspec.cfg = testkit::quiet_config();
        let global = ClusterSim::new(gspec, &scen, bank)
            .run(bank, scen.min_duration)
            .unwrap();

        assert!(
            local.avg_perf >= global.avg_perf - 0.02,
            "local {:.3} must not lose to global {:.3} under oversubscription",
            local.avg_perf,
            global.avg_perf
        );
    }

    #[test]
    fn dispatcher_balances_residents() {
        let bank = testkit::shared_bank();
        let mut spec = ClusterSpec::new(4, Strategy::LocalVmcd);
        spec.cfg = testkit::quiet_config();
        let scen = cluster_scenario(4, 0.5, 7);
        let mut sim = ClusterSim::new(spec, &scen, bank);
        // Step past all arrivals.
        for _ in 0..(30 * scen.vms.len() + 10) {
            sim.dispatch_arrivals().unwrap();
            for slot in &mut sim.hosts {
                slot.engine.step();
            }
            sim.t += 1.0;
        }
        let counts: Vec<usize> = sim.hosts.iter().map(|h| h.engine.vms.len()).collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "least-loaded must balance: {counts:?}");
    }
}

//! The offline profiling phase (paper §IV-A).
//!
//! Each workload class is run (a) isolated — yielding its utilisation row
//! in matrix **U** — and (b) co-pinned on the same core with every other
//! class — yielding the pairwise slowdown matrix **S** (Eq. 1:
//! `S_ij = P(ψ_i, ψ_j) / P(ψ_i)` with P the class's own performance
//! metric). The schedulers receive only these profiles; they never see the
//! simulator's internal interference constants, mirroring how the paper's
//! scheduler only sees measured profiles of the real hardware.

use crate::config::Config;
use crate::hostsim::{ActivityModel, SimEngine, Vm, VmId, VmState};
use crate::util::json::Json;
use crate::workloads::{WorkloadClass, ALL_CLASSES, NUM_METRICS};
use anyhow::{Context, Result};

/// How long each profiling co-run executes (virtual seconds). Long enough
/// to wash out monitoring-window transients.
const PROFILE_DURATION: f64 = 240.0;

/// The S and U matrices plus bookkeeping.
#[derive(Debug, Clone)]
pub struct ProfileBank {
    /// Class order for the matrix indices.
    pub classes: Vec<WorkloadClass>,
    /// `s[i][j]` — slowdown of class i when co-pinned with class j (≥ ~1).
    pub s: Vec<Vec<f64>>,
    /// `u[i]` — utilisation vector of class i in isolation.
    pub u: Vec<[f64; NUM_METRICS]>,
}

impl ProfileBank {
    /// Run the full profiling phase under the given config.
    pub fn generate(cfg: &Config) -> ProfileBank {
        let n = ALL_CLASSES.len();
        let mut s = vec![vec![1.0; n]; n];
        let mut u = vec![[0.0; NUM_METRICS]; n];
        let mut iso_perf = vec![1.0; n];

        // Isolated runs: utilisation row + isolated performance baseline.
        for (i, &class) in ALL_CLASSES.iter().enumerate() {
            let (perf, util) = run_isolated(cfg, class);
            iso_perf[i] = perf;
            u[i] = util;
        }

        // Pairwise co-pinned runs.
        for (i, &a) in ALL_CLASSES.iter().enumerate() {
            for (j, &b) in ALL_CLASSES.iter().enumerate() {
                let perf_a = run_copinned(cfg, a, b);
                // Eq. 1: slowdown of i with j, relative to isolated.
                s[i][j] = (iso_perf[i] / perf_a.max(1e-6)).max(1.0);
            }
        }

        ProfileBank {
            classes: ALL_CLASSES.to_vec(),
            s,
            u,
        }
    }

    pub fn n(&self) -> usize {
        self.classes.len()
    }

    /// Slowdown of `a` when co-pinned with `b`.
    pub fn slowdown(&self, a: WorkloadClass, b: WorkloadClass) -> f64 {
        self.s[a.index()][b.index()]
    }

    /// Isolated utilisation vector of `a`.
    pub fn utilization(&self, a: WorkloadClass) -> [f64; NUM_METRICS] {
        self.u[a.index()]
    }

    /// Eq. 5 — mean of S, the derived IAS threshold.
    pub fn mean_slowdown(&self) -> f64 {
        crate::interference::ias_threshold(&self.s)
    }

    // ---- persistence ----

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| Json::Str(c.name().to_string()))
                        .collect(),
                ),
            ),
            (
                "s",
                Json::Arr(self.s.iter().map(|row| Json::num_array(row)).collect()),
            ),
            (
                "u",
                Json::Arr(self.u.iter().map(|row| Json::num_array(row)).collect()),
            ),
        ])
    }

    pub fn from_json(json: &Json) -> Result<ProfileBank> {
        let classes: Vec<WorkloadClass> = json
            .field("classes")?
            .as_arr()
            .context("classes must be an array")?
            .iter()
            .map(|v| {
                let name = v.as_str().context("class name must be a string")?;
                WorkloadClass::from_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown class '{name}'"))
            })
            .collect::<Result<_>>()?;
        let s: Vec<Vec<f64>> = json
            .field("s")?
            .as_arr()
            .context("s must be an array")?
            .iter()
            .map(|row| row.to_f64_vec())
            .collect::<Result<_>>()?;
        let u_rows: Vec<Vec<f64>> = json
            .field("u")?
            .as_arr()
            .context("u must be an array")?
            .iter()
            .map(|row| row.to_f64_vec())
            .collect::<Result<_>>()?;
        anyhow::ensure!(s.len() == classes.len(), "S shape mismatch");
        anyhow::ensure!(u_rows.len() == classes.len(), "U shape mismatch");
        let mut u = Vec::with_capacity(u_rows.len());
        for row in u_rows {
            anyhow::ensure!(row.len() == NUM_METRICS, "U row width");
            u.push([row[0], row[1], row[2], row[3]]);
        }
        Ok(ProfileBank { classes, s, u })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing profile bank {path}"))
    }

    pub fn load(path: &str) -> Result<ProfileBank> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile bank {path}"))?;
        let json = Json::parse(&text).context("parsing profile bank")?;
        ProfileBank::from_json(&json)
    }

    /// Load from disk if present, else generate (and cache when a path is
    /// given).
    pub fn load_or_generate(cfg: &Config, cache: Option<&str>) -> ProfileBank {
        if let Some(path) = cache {
            if let Ok(bank) = ProfileBank::load(path) {
                return bank;
            }
        }
        let bank = ProfileBank::generate(cfg);
        if let Some(path) = cache {
            let _ = bank.save(path);
        }
        bank
    }
}

/// Profiling-mode config: deterministic (no demand noise) and quiet.
fn profiling_cfg(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.sim.demand_noise = 0.0;
    c
}

fn fresh_vm(id: u32, class: WorkloadClass, core: usize) -> Vm {
    let mut vm = Vm::new(VmId(id), class, 0.0, ActivityModel::AlwaysOn);
    vm.state = VmState::Running;
    vm.started = Some(0.0);
    vm.pinned = Some(core);
    vm
}

/// Run one class isolated; return (normalized perf, measured utilisation).
fn run_isolated(cfg: &Config, class: WorkloadClass) -> (f64, [f64; NUM_METRICS]) {
    let cfg = profiling_cfg(cfg);
    let vm = fresh_vm(0, class, 0);
    let mut eng = SimEngine::new(cfg, vec![vm]);
    let mut util_acc = [0.0f64; NUM_METRICS];
    let mut ticks = 0usize;
    while eng.t < PROFILE_DURATION {
        eng.step();
        if eng.vms[0].state != VmState::Running {
            break;
        }
        for r in 0..NUM_METRICS {
            util_acc[r] += eng.vms[0].last_util[r];
        }
        ticks += 1;
    }
    let mut util = [0.0; NUM_METRICS];
    if ticks > 0 {
        for r in 0..NUM_METRICS {
            util[r] = util_acc[r] / ticks as f64;
        }
    }
    (measured_perf(&eng, 0), util)
}

/// Run class `a` co-pinned with class `b` on the same core; return a's
/// normalized performance.
fn run_copinned(cfg: &Config, a: WorkloadClass, b: WorkloadClass) -> f64 {
    let cfg = profiling_cfg(cfg);
    let va = fresh_vm(0, a, 0);
    let vb = fresh_vm(1, b, 0);
    let mut eng = SimEngine::new(cfg, vec![va, vb]);
    while eng.t < PROFILE_DURATION && eng.vms[0].state == VmState::Running {
        eng.step();
    }
    measured_perf(&eng, 0)
}

/// Performance of vm `idx`: completed batch → run-time ratio; otherwise
/// average per-tick normalized performance; still-running batch → average
/// progress rate.
fn measured_perf(eng: &SimEngine, idx: usize) -> f64 {
    let vm = &eng.vms[idx];
    if let Some(p) = vm.normalized_perf() {
        return p;
    }
    // Batch that did not finish inside the profiling window: use the
    // average progress rate so far.
    let elapsed = eng.t - vm.started.unwrap_or(0.0);
    if elapsed > 0.0 {
        (vm.work_done / elapsed).clamp(1e-6, 1.0)
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.sim.demand_noise = 0.0;
        cfg
    }

    #[test]
    fn bank_shapes_and_bounds() {
        let bank = ProfileBank::generate(&small_cfg());
        let n = ALL_CLASSES.len();
        assert_eq!(bank.s.len(), n);
        assert_eq!(bank.u.len(), n);
        for row in &bank.s {
            assert_eq!(row.len(), n);
            for &x in row {
                assert!((1.0..6.0).contains(&x), "slowdown {x}");
            }
        }
    }

    #[test]
    fn cpu_hogs_slow_each_other_down_on_copin() {
        // Two 0.95-CPU VMs on one 2-way-SMT core: ~1.9/1.25 ≈ 1.55×
        // slowdown each (the SMT yield soaks part of the 2× a non-SMT
        // core would show).
        let bank = ProfileBank::generate(&small_cfg());
        let s = bank.slowdown(WorkloadClass::Blackscholes, WorkloadClass::Blackscholes);
        assert!((1.4..1.8).contains(&s), "BS|BS slowdown {s}");
    }

    #[test]
    fn light_pairs_barely_interfere() {
        let bank = ProfileBank::generate(&small_cfg());
        let s = bank.slowdown(WorkloadClass::LampLight, WorkloadClass::StreamLow);
        assert!(s < 1.2, "light pair slowdown {s}");
    }

    #[test]
    fn jacobi_jacobi_worse_than_blackscholes_jacobi_for_jacobi() {
        let bank = ProfileBank::generate(&small_cfg());
        let jj = bank.slowdown(WorkloadClass::Jacobi, WorkloadClass::Jacobi);
        let jb = bank.slowdown(WorkloadClass::Jacobi, WorkloadClass::Blackscholes);
        assert!(
            jj > jb,
            "membw interference must add on top of CPU sharing: jj={jj} jb={jb}"
        );
    }

    #[test]
    fn mean_slowdown_threshold_separates_light_from_heavy() {
        // Eq. 5: the threshold is the mean slowdown of a pair of random
        // co-scheduled workloads — distinct residents, so the diagonal
        // self-slowdowns are excluded. The paper lands at 1.5 on its
        // testbed; our calibrated catalog has more near-1.0 service
        // pairs, so the mean sits lower — what matters for IAS behaviour
        // is that it separates light pairs (below) from heavy ones (above).
        let bank = ProfileBank::generate(&small_cfg());
        let m = bank.mean_slowdown();
        assert!((1.0..1.6).contains(&m), "mean slowdown {m}");
        let light = bank.slowdown(WorkloadClass::LampLight, WorkloadClass::StreamLow);
        let heavy = bank.slowdown(WorkloadClass::Jacobi, WorkloadClass::Jacobi);
        assert!(light < m, "light pair {light} must sit below the mean {m}");
        assert!(heavy > m, "heavy pair {heavy} must sit above the mean {m}");
    }

    #[test]
    fn utilization_rows_match_catalog_demands() {
        let bank = ProfileBank::generate(&small_cfg());
        for &class in &ALL_CLASSES {
            let u = bank.utilization(class);
            let d = crate::workloads::catalog::spec_of(class).demand;
            // CPU and IO utilisation in isolation ≈ demand (no contention).
            assert!((u[0] - d[0]).abs() < 0.05, "{class:?} cpu {u:?} vs {d:?}");
            assert!((u[2] - d[2]).abs() < 0.05, "{class:?} net");
        }
    }

    #[test]
    fn json_roundtrip() {
        let bank = ProfileBank::generate(&small_cfg());
        let json = bank.to_json();
        let back = ProfileBank::from_json(&json).unwrap();
        assert_eq!(back.classes, bank.classes);
        for i in 0..bank.n() {
            for j in 0..bank.n() {
                assert!((back.s[i][j] - bank.s[i][j]).abs() < 1e-9);
            }
        }
    }
}

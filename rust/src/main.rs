//! `vmcd` — CLI for the VM-coordinator reproduction.
//!
//! Subcommands:
//! * `profile [--out FILE]` — run the offline profiling phase (§IV-A),
//!   print the S/U matrices, optionally cache them as JSON.
//! * `run --scenario NAME --policy P [--sr X] [--seed N] [--xla]
//!   [--actuation inline|deferred:N]` — run one scenario under one policy
//!   (optionally with lagged pin actuation) and print the summary.
//! * `report fig2|fig3|fig4|fig5|fig6|table1|all [--seeds N] [--out DIR]` —
//!   regenerate the paper's figures (ASCII + CSV).
//! * `validate` — assert the native and XLA scoring backends agree on a
//!   randomized placement battery.
//! * `daemon [--policy P] [--ticks N] [--ms-per-tick M]` — run the daemon
//!   loop against a simulated host in paced wall-clock time, printing
//!   monitor snapshots (a demo of the Alg. 1 loop).
//! * `cluster [--hosts N] [--vms N] [--strategy S] [--dispatcher D]
//!   [--step-mode M] [--workers W] [--actuation A]
//!   [--migrator [over:under:budget[:interval]]]` — run a cluster-wide
//!   scenario through the event bus and shard pool (local-vmcd vs
//!   global-migration), optionally with the continuous migration
//!   manager consolidating the fleet; summaries include the
//!   cluster-scope energy/SLAV ledger.
//! * `cluster --trace <path|synth:spec> [--trace-types FILE]
//!   [--trace-hosts FILE]` — replay a recorded or synthetic VM trace
//!   through the same bus instead of a generated scenario (see
//!   `vmcd::cluster::trace` for file formats and the `synth:` grammar).

use anyhow::{Context, Result};
use vmcd::config::Config;
use vmcd::hostsim::Hypervisor;
use vmcd::profiling::ProfileBank;
use vmcd::report;
use vmcd::scenarios::{self, ScenarioKind};
use vmcd::util::cli::Args;
use vmcd::util::logger;
use vmcd::vmcd::scheduler::Policy;

fn main() {
    logger::init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    if let Some(seed) = args.opt("seed") {
        cfg.sim.seed = seed.parse().context("--seed expects an integer")?;
    }
    if let Some(thr) = args.opt("ras-threshold") {
        cfg.sched.ras_threshold = thr.parse().context("--ras-threshold")?;
    }
    if let Some(thr) = args.opt("ias-threshold") {
        cfg.sched.ias_threshold = Some(thr.parse().context("--ias-threshold")?);
    }
    Ok(cfg)
}

fn bank_for(cfg: &Config, args: &Args) -> ProfileBank {
    ProfileBank::load_or_generate(cfg, args.opt("profiles"))
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "profile" => cmd_profile(args),
        "run" => cmd_run(args),
        "report" => cmd_report(args),
        "validate" => cmd_validate(args),
        "daemon" => cmd_daemon(args),
        "cluster" => cmd_cluster(args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `vmcd help`)"),
    }
}

const HELP: &str = "\
vmcd — resource- and interference-aware VM scheduling (Angelou et al. 2016)

USAGE:
  vmcd profile   [--out FILE] [--config FILE]
  vmcd run       --scenario random|latency|dynamic6|dynamic12 --policy rrs|cas|ras|ias
                 [--sr X] [--seed N] [--xla] [--profiles FILE]
                 [--actuation inline|deferred:N|deferred:N:B]
  vmcd report    fig2|fig3|fig4|fig5|fig6|table1|all [--seeds N] [--out DIR]
  vmcd validate  [--cases N]
  vmcd daemon    [--policy P] [--ticks N] [--ms-per-tick M]
  vmcd cluster   [--hosts N] [--vms N] [--strategy local-vmcd|global-migration]
                 [--dispatcher round-robin|least-loaded|lowest-interference|random
                               |dot-product|cosine|norm-greedy|perp-distance]
                 [--policy P] [--sr X] [--seed N]
                 [--step-mode single|scoped|pool] [--workers W]
                 [--actuation inline|deferred:N|deferred:N:B]
                 [--trace PATH|synth:k=v,...] [--trace-types FILE]
                 [--trace-hosts FILE]
                 [--migrator [over:under:budget[:interval][,key=value...]]]
                 [--power linear|piecewise:u=w,...] [--digest]

  --migrator enables the continuous migration manager; bare --migrator
  uses the config-file thresholds (or the defaults 0.85:0.35:4:30).
  Keyword fields ride behind the positional ones: forecast=on|off,
  alpha=, beta=, horizon=, k= (hysteresis), payback=<secs|inf>,
  cooldown=, wi= — e.g. 0.85:0.35:4:30,forecast=on,payback=600.
  --power selects the cluster ledger's utilization→watts curve:
  linear (default) or a piecewise breakpoint table such as
  piecewise:0=80,0.5=240,1=400 (SPECpower-style).
  --digest prints a 64-bit FNV-1a fingerprint of the run result —
  identical seeds must print identical digests (see DETERMINISM.md).
";

fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    log::info!("running offline profiling phase (isolated + pairwise co-runs)");
    let bank = ProfileBank::generate(&cfg);
    let names: Vec<&str> = bank.classes.iter().map(|c| c.name()).collect();

    let mut rows = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for j in 0..bank.n() {
            row.push(format!("{:.2}", bank.s[i][j]));
        }
        rows.push(row);
    }
    let mut headers: Vec<&str> = vec!["S (row=wl, col=co-runner)"];
    headers.extend(names.iter());
    println!("{}", report::render_table(&headers, &rows));

    let mut urows = Vec::new();
    for (i, name) in names.iter().enumerate() {
        urows.push(vec![
            name.to_string(),
            format!("{:.3}", bank.u[i][0]),
            format!("{:.3}", bank.u[i][1]),
            format!("{:.3}", bank.u[i][2]),
            format!("{:.3}", bank.u[i][3]),
        ]);
    }
    println!(
        "{}",
        report::render_table(&["U", "cpu", "diskio", "netio", "membw"], &urows)
    );
    println!(
        "mean pairwise slowdown (Eq. 5 threshold): {:.3}",
        bank.mean_slowdown()
    );

    if let Some(path) = args.opt("out") {
        bank.save(path)?;
        println!("profile bank written to {path}");
    }
    Ok(())
}

fn build_spec(
    cfg: &Config,
    kind: ScenarioKind,
    sr: f64,
    seed: u64,
) -> Result<scenarios::ScenarioSpec> {
    match kind {
        ScenarioKind::Random => scenarios::random::build(cfg.host.cores, sr, seed),
        ScenarioKind::LatencyHeavy => scenarios::latency::build(cfg.host.cores, sr, seed),
        ScenarioKind::Dynamic6 => scenarios::dynamic::build(6, seed),
        ScenarioKind::Dynamic12 => scenarios::dynamic::build(12, seed),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    use vmcd::vmcd::ActuationSpec;

    let cfg = load_config(args)?;
    let kind = ScenarioKind::from_name(&args.opt_or("scenario", "random"))
        .context("unknown --scenario")?;
    let policy = Policy::parse(&args.opt_or("policy", "ias"))?;
    let sr = args.opt_f64("sr", 1.0)?;
    let seed = args.opt_u64("seed", cfg.sim.seed)?;
    let actuation = ActuationSpec::parse(&args.opt_or("actuation", "inline"))?;
    let bank = bank_for(&cfg, args);
    let spec = build_spec(&cfg, kind, sr, seed)?;

    log::info!(
        "scenario {} ({} VMs) under {} ({} actuation)",
        spec.name,
        spec.vms.len(),
        policy.name(),
        actuation.name()
    );
    let result = if args.flag("xla") {
        anyhow::ensure!(
            actuation == ActuationSpec::Inline,
            "--actuation is only supported with the native scoring backend"
        );
        let rt = vmcd::runtime::Runtime::new()?;
        let backend = Box::new(vmcd::runtime::XlaScoring::new(rt)?);
        scenarios::runner::run_scenario_with_backend(&cfg, &spec, policy, &bank, backend)?
    } else {
        scenarios::run_scenario_with_actuation(&cfg, &spec, policy, &bank, actuation)?
    };

    println!("scenario        : {}", result.scenario);
    println!("policy          : {}", result.policy.name());
    println!("actuation       : {}", actuation.name());
    println!("VMs             : {}", spec.vms.len());
    println!("avg performance : {:.3} (1.0 = isolated)", result.avg_perf);
    println!("core-hours      : {:.3}", result.core_hours);
    println!("energy          : {:.1} Wh", result.energy_wh);
    println!("completed at    : {:.0} s", result.completion_time);
    println!("re-pins         : {}", result.repin_count);
    println!("sched cycles    : {}", result.sched_cycles);
    println!("per-class performance:");
    for (class, perf) in &result.per_class_perf {
        println!("  {:<14} {:.3}", class.name(), perf);
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let nseeds = args.opt_usize("seeds", 3)?;
    let seeds: Vec<u64> = (0..nseeds as u64).map(|i| cfg.sim.seed + i).collect();
    let out_dir = args.opt_or("out", "results");
    let out = std::path::Path::new(&out_dir);
    let bank = bank_for(&cfg, args);

    let mut figures = Vec::new();
    match which {
        "fig2" => figures.push(report::fig2(&cfg, &bank, &seeds)?),
        "fig3" => figures.push(report::fig3(&cfg, &bank, &seeds)?),
        "fig4" => figures.push(report::fig45(&cfg, &bank, 6, seeds[0])?),
        "fig5" => figures.push(report::fig45(&cfg, &bank, 12, seeds[0])?),
        "fig6" => figures.push(report::fig6(&cfg, &bank, &seeds)?),
        "table1" => {
            println!("{}", report::table1(&cfg)?);
            return Ok(());
        }
        "all" => {
            figures.push(report::fig2(&cfg, &bank, &seeds)?);
            figures.push(report::fig3(&cfg, &bank, &seeds)?);
            figures.push(report::fig45(&cfg, &bank, 6, seeds[0])?);
            figures.push(report::fig45(&cfg, &bank, 12, seeds[0])?);
            figures.push(report::fig6(&cfg, &bank, &seeds)?);
            println!("{}", report::table1(&cfg)?);
        }
        other => anyhow::bail!("unknown report '{other}'"),
    }
    for fig in &figures {
        println!("{}", fig.render());
        fig.write_csv(out)?;
    }
    println!("CSV mirrors under {out_dir}/");
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use vmcd::util::rng::Rng;
    use vmcd::vmcd::scheduler::{NativeScoring, PlacementState, ScoringBackend};
    use vmcd::workloads::ALL_CLASSES;

    let cfg = load_config(args)?;
    let cases = args.opt_usize("cases", 50)?;
    let bank = bank_for(&cfg, args);
    let rt = vmcd::runtime::Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());
    let mut xla = vmcd::runtime::XlaScoring::new(rt)?;
    let mut native = NativeScoring::new();
    let mut rng = Rng::new(cfg.sim.seed);

    let mut max_err = 0.0f64;
    for case in 0..cases {
        // Cached state: the native side runs the incremental engine, so
        // this battery validates XLA against the production hot path.
        let mut state = PlacementState::with_bank(cfg.host.cores, rng.chance(0.3), &bank);
        let nvms = rng.below(20);
        for _ in 0..nvms {
            let core = rng.below(cfg.host.cores);
            state.place(core, *rng.pick(&ALL_CLASSES));
        }
        let cand = *rng.pick(&ALL_CLASSES);
        let cpu_only = rng.chance(0.5);
        let a = xla.score(&state, cand, &bank, cfg.sched.ras_threshold, cpu_only);
        let b = native.score(&state, cand, &bank, cfg.sched.ras_threshold, cpu_only);
        for core in 0..cfg.host.cores {
            for (x, y, what) in [
                (a.ol_before()[core], b.ol_before()[core], "ol_before"),
                (a.ol_after()[core], b.ol_after()[core], "ol_after"),
                (a.ic_before()[core], b.ic_before()[core], "ic_before"),
                (a.ic_after()[core], b.ic_after()[core], "ic_after"),
            ] {
                let err = (x - y).abs();
                max_err = max_err.max(err);
                anyhow::ensure!(
                    err < 1e-3,
                    "case {case}: {what}[{core}] xla={x} native={y}"
                );
            }
        }
    }
    println!(
        "validate OK: {cases} randomized placements, max |xla - native| = {max_err:.2e}"
    );
    Ok(())
}

/// Minimal HTTP status endpoint (std TcpListener; tokio is not in the
/// offline crate set): GET anything -> JSON snapshot of the daemon state.
fn spawn_status_server(
    addr: &str,
    status: std::sync::Arc<std::sync::Mutex<String>>,
) -> Result<()> {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding status server on {addr}"))?;
    log::info!("status server listening on http://{addr}/status");
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { continue };
            let mut buf = [0u8; 1024];
            let _ = stream.read(&mut buf); // drain the request line
            let body = status.lock().map(|s| s.clone()).unwrap_or_default();
            let resp = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                body.len(),
                body
            );
            let _ = stream.write_all(resp.as_bytes());
        }
    });
    Ok(())
}

fn cmd_daemon(args: &Args) -> Result<()> {
    use vmcd::vmcd::Daemon;

    let cfg = load_config(args)?;
    let policy = Policy::parse(&args.opt_or("policy", "ras"))?;
    let ticks = args.opt_usize("ticks", 300)?;
    let ms = args.opt_u64("ms-per-tick", 5)?;
    let bank = bank_for(&cfg, args);
    let spec = scenarios::random::build(cfg.host.cores, 1.5, cfg.sim.seed)?;

    let vms: Vec<vmcd::hostsim::Vm> = spec
        .vms
        .iter()
        .enumerate()
        .map(|(i, t)| {
            vmcd::hostsim::Vm::new(
                vmcd::hostsim::VmId(i as u32),
                t.class,
                t.arrival,
                t.activity.clone(),
            )
        })
        .collect();
    let sched = vmcd::vmcd::scheduler::build(
        policy,
        &bank,
        cfg.sched.ras_threshold,
        cfg.sched.ias_threshold,
    );
    let mut engine = vmcd::hostsim::SimEngine::new(cfg.clone(), vms);
    let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);

    // Optional HTTP status endpoint: `--listen 127.0.0.1:7070`.
    let status = std::sync::Arc::new(std::sync::Mutex::new(String::from("{}")));
    if let Some(addr) = args.opt("listen") {
        spawn_status_server(addr, status.clone())?;
    }

    log::info!(
        "daemon demo: {} policy, {} VMs, {} ticks at {} ms/tick",
        policy.name(),
        spec.vms.len(),
        ticks,
        ms
    );
    for tick in 0..ticks {
        for id in engine.process_arrivals() {
            daemon.on_arrival(&mut engine, id)?;
            log::info!("t={:>5.0}s arrival {:?}", engine.t, id);
        }
        if daemon.step(&mut engine)? {
            let busy = engine.busy_cores();
            log::info!(
                "t={:>5.0}s cycle {}: {} resident, {} busy cores, {} re-pins so far",
                engine.t,
                daemon.cycles,
                engine.list_domains().len(),
                busy,
                engine.ledger.repin_count
            );
            let snapshot = vmcd::util::json::Json::from_pairs(vec![
                ("t", vmcd::util::json::Json::Num(engine.t)),
                ("policy", vmcd::util::json::Json::Str(policy.name().into())),
                (
                    "resident",
                    vmcd::util::json::Json::Num(engine.list_domains().len() as f64),
                ),
                ("busy_cores", vmcd::util::json::Json::Num(busy as f64)),
                (
                    "repins",
                    vmcd::util::json::Json::Num(engine.ledger.repin_count as f64),
                ),
                ("cycles", vmcd::util::json::Json::Num(daemon.cycles as f64)),
                (
                    "pin_failures",
                    vmcd::util::json::Json::Num(daemon.pin_failures as f64),
                ),
                (
                    "core_hours",
                    vmcd::util::json::Json::Num(engine.ledger.core_hours()),
                ),
            ]);
            if let Ok(mut s) = status.lock() {
                *s = snapshot.dump();
            }
        }
        engine.step();
        if ms > 0 && tick % 10 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms * 10));
        }
    }
    println!(
        "daemon demo done: {:.3} core-hours, {} re-pins, {} cycles",
        engine.ledger.core_hours(),
        engine.ledger.repin_count,
        daemon.cycles
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use vmcd::cluster::{ClusterSpec, Dispatcher, StepMode, Strategy};
    use vmcd::vmcd::ActuationSpec;

    let mut cfg = load_config(args)?;
    // `--power linear|piecewise:u=w,...` overrides the config file's
    // `power` section (the cluster ledger's utilization→watts curve).
    if let Some(spec) = args.opt("power") {
        cfg.power = vmcd::config::PowerModel::parse(spec).context("--power")?;
    }
    let hosts = args.opt_usize("hosts", 4)?;
    let strategy = match args.opt_or("strategy", "local-vmcd").as_str() {
        "local-vmcd" | "local" => Strategy::LocalVmcd,
        "global-migration" | "global" => Strategy::GlobalMigration,
        other => anyhow::bail!(
            "unknown strategy '{other}' (valid: local-vmcd, global-migration)"
        ),
    };
    let dispatcher = Dispatcher::parse(&args.opt_or("dispatcher", "least-loaded"))?;
    let policy = Policy::parse(&args.opt_or("policy", "ias"))?;
    let sr = args.opt_f64("sr", 1.0)?;
    let seed = args.opt_u64("seed", cfg.sim.seed)?;
    let workers = args.opt_usize("workers", 4)?;
    let step_mode = match args.opt_or("step-mode", "pool").as_str() {
        "single" => StepMode::Single,
        "scoped" => StepMode::Scoped(workers),
        "pool" => StepMode::Pool(workers),
        other => anyhow::bail!("unknown step mode '{other}' (valid: single, scoped, pool)"),
    };
    let actuation = ActuationSpec::parse(&args.opt_or("actuation", "inline"))?;
    // `--migrator over:under:budget[:interval]` overrides the config
    // file's `migrator` section; bare `--migrator` enables it with the
    // config (or default) thresholds; absent, the config file decides.
    let migrator = match args.opt("migrator") {
        Some(grammar) => Some(
            vmcd::config::MigratorParams::parse(grammar).context("--migrator")?,
        ),
        None if args.flag("migrator") => Some(cfg.migrator.clone().unwrap_or_default()),
        None => cfg.migrator.clone(),
    };
    let bank = bank_for(&cfg, args);

    let mut spec = ClusterSpec::new(hosts, strategy);
    spec.cfg = cfg.clone();
    spec.dispatcher = dispatcher;
    spec.local_policy = policy;
    spec.step_mode = step_mode;
    spec.actuation = actuation;
    spec.migrator = migrator.clone();
    if let Some(path) = args.opt("trace-hosts") {
        spec.host_caps = Some(vmcd::cluster::trace::csv::read_host_classes(path, hosts)?);
    }

    if let Some(trace_arg) = args.opt("trace") {
        // Trace replay: the trace supplies the VM population, so only
        // the fleet shape needs validating here.
        vmcd::cluster::validate_shape(hosts, 1)?;
        let mut reader =
            vmcd::cluster::trace::open(trace_arg, args.opt("trace-types"), seed, &bank)?;
        log::info!(
            "cluster trace replay: {} hosts, {} dispatch, {} stepping, trace {}",
            hosts,
            dispatcher.name(),
            step_mode.name(),
            trace_arg
        );
        let r = scenarios::run_trace(&spec, reader.as_mut(), &bank)?;
        println!("trace           : {trace_arg}");
        println!("hosts           : {hosts}");
        println!("dispatcher      : {}", dispatcher.name());
        if let Some(m) = &migrator {
            println!(
                "migrator        : over {:.2} / under {:.2}, budget {}, every {:.0} s",
                m.over, m.under, m.budget, m.interval
            );
            if m.forecast {
                println!(
                    "forecast        : on (alpha {:.2}, beta {:.2}, horizon {:.0} s, k {})",
                    m.alpha, m.beta, m.horizon, m.hysteresis
                );
            }
            if m.payback.is_finite() {
                println!("payback horizon : {:.0} s", m.payback);
            }
        }
        println!("power model     : {}", cfg.power.name());
        println!("arrivals        : {}", r.arrivals);
        println!("departures      : {}", r.departures);
        println!("migrates        : {}", r.migrates);
        println!("dropped         : {}", r.dropped);
        println!("peak live VMs   : {}", r.peak_live);
        println!("final live VMs  : {}", r.final_live);
        println!("active hosts    : {}", r.final_active_hosts());
        println!("events routed   : {}", r.events_routed);
        println!(
            "migrations      : {} started, {} completed, {} aborted",
            r.migrations_started, r.migrations_completed, r.migrations_failed
        );
        println!("migrator moves  : {}", r.migrator_moves);
        println!("core-hours      : {:.3}", r.core_hours);
        println!(
            "energy          : {:.1} Wh parked-aware ({:.1} Wh always-plugged)",
            r.energy_wh, r.plugged_energy_wh
        );
        println!(
            "SLAV            : {:.4} ({:.0} s overloaded over {:.2} active host-hours)",
            r.slav, r.overload_seconds, r.active_host_hours
        );
        if let Some(ticks) = r.converge_ticks {
            println!("converge        : {ticks} ticks from powered peak to half-drain");
        }
        println!("sim time        : {:.0} s over {} ticks", r.completion_time, r.ticks);
        if r.truncated {
            println!("truncated       : yes (trace ran past sim.max_time)");
        }
        println!("wall time       : {} ms", r.wall.as_millis());
        println!("events/sec      : {:.0}", r.events_per_sec());
        if args.flag("digest") {
            // Bit-identity fingerprint (FNV-1a over every simulation
            // field, wall time excluded) — the two-process audit in
            // rust/tests/detlint.rs greps this line from two same-seed
            // runs and asserts equality.
            println!("digest          : {:016x}", r.bit_digest());
        }
        return Ok(());
    }

    // Cluster-wide population: hosts × cores × sr by default.
    let vms = args.opt_usize("vms", hosts * cfg.host.cores)?;
    vmcd::cluster::validate_shape(hosts, vms)?;
    let scen = scenarios::random::build(vms, sr, seed)?;

    log::info!(
        "cluster: {} hosts, {} strategy, {} dispatch, {} VMs, {} stepping",
        hosts,
        strategy.name(),
        dispatcher.name(),
        scen.vms.len(),
        step_mode.name()
    );
    #[allow(clippy::disallowed_methods)] // process edge: CLI reports wall time
    let wall = std::time::Instant::now();
    let r = scenarios::run_cluster(&spec, &scen, &bank)?;
    println!("strategy        : {}", r.strategy.name());
    println!("hosts           : {hosts}");
    println!("dispatcher      : {}", dispatcher.name());
    println!("actuation       : {}", actuation.name());
    println!("power model     : {}", cfg.power.name());
    println!("VMs             : {}", scen.vms.len());
    println!("avg performance : {:.3} (1.0 = isolated)", r.avg_perf);
    println!("core-hours      : {:.3}", r.core_hours);
    println!("host-hours      : {:.3}", r.host_hours);
    println!(
        "migrations      : {} started, {} completed, {} failed",
        r.migrations_started, r.migrations_completed, r.migrations_failed
    );
    println!("migrator moves  : {}", r.migrator_moves);
    println!("events routed   : {}", r.events_routed);
    println!(
        "energy          : {:.1} Wh parked-aware ({:.1} Wh always-plugged)",
        r.energy_wh, r.plugged_energy_wh
    );
    println!(
        "SLAV            : {:.4} ({:.0} s overloaded over {:.2} active host-hours)",
        r.slav, r.overload_seconds, r.active_host_hours
    );
    println!("completed at    : {:.0} s", r.completion_time);
    println!("wall time       : {} ms", wall.elapsed().as_millis());
    if args.flag("digest") {
        println!("digest          : {:016x}", r.bit_digest());
    }
    Ok(())
}

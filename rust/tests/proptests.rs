//! Property-based tests (testkit mini-framework; proptest is not in the
//! offline crate set). Each property runs over dozens of seeded random
//! cases; failures report the case seed for replay.

use vmcd::interference::{core_interference, core_overload, workload_interference};
use vmcd::profiling::ProfileBank;
use vmcd::scenarios::{random, run_scenario};
use vmcd::testkit::{self, check, default_cases};
use vmcd::util::{close, rng::Rng};
use vmcd::vmcd::scheduler::scoring::{self, WiMode};
use vmcd::vmcd::scheduler::{self, NativeScoring, PlacementState, Policy, ScoringBackend};
use vmcd::workloads::{WorkloadClass, ALL_CLASSES};

fn random_state(rng: &mut Rng, cores: usize, max_vms: usize) -> PlacementState {
    let mut state = PlacementState::new(cores, rng.chance(0.3));
    for _ in 0..rng.below(max_vms + 1) {
        let core = rng.below(cores);
        state.place(core, *rng.pick(&ALL_CLASSES));
    }
    state
}

/// A cached and an uncached state built from the same placement sequence.
fn random_state_pair(
    rng: &mut Rng,
    bank: &ProfileBank,
    cores: usize,
    max_vms: usize,
) -> (PlacementState, PlacementState) {
    let reserve = rng.chance(0.3);
    let mut cached = PlacementState::with_bank(cores, reserve, bank);
    let mut plain = PlacementState::new(cores, reserve);
    for _ in 0..rng.below(max_vms + 1) {
        let core = rng.below(cores);
        let class = *rng.pick(&ALL_CLASSES);
        cached.place(core, class);
        plain.place(core, class);
    }
    (cached, plain)
}

#[test]
fn prop_selected_core_is_always_allowed() {
    let bank = testkit::shared_bank();
    check("selected-core-allowed", default_cases(), |rng| {
        let state = random_state(rng, 12, 30);
        let cand = *rng.pick(&ALL_CLASSES);
        for policy in [Policy::Cas, Policy::Ras, Policy::Ias] {
            let mut sched = scheduler::build(policy, bank, 1.2, None);
            let core = sched.select_pinning(&state, cand);
            assert!(
                state.allowed.contains(&core),
                "{policy:?} picked disallowed core {core}"
            );
        }
    });
}

#[test]
fn prop_ras_prefers_zero_overload_cores() {
    // Alg. 2: if any allowed core keeps OL = 0 with the candidate, the
    // chosen core must keep OL = 0 too.
    let bank = testkit::shared_bank();
    check("ras-zero-overload-first", default_cases(), |rng| {
        let state = random_state(rng, 12, 30);
        let cand = *rng.pick(&ALL_CLASSES);
        let mut backend = NativeScoring::new();
        let scores = backend.score(&state, cand, bank, 1.2, false);
        let mut sched = scheduler::build(Policy::Ras, bank, 1.2, None);
        let core = sched.select_pinning(&state, cand);
        let exists_zero = state
            .allowed
            .iter()
            .any(|&c| scores.ol_after()[c] <= 1e-12);
        if exists_zero {
            assert!(
                scores.ol_after()[core] <= 1e-12,
                "a zero-overload core existed but RAS picked OL={}",
                scores.ol_after()[core]
            );
        }
    });
}

#[test]
fn prop_ias_respects_threshold_when_possible() {
    // Alg. 3: if any allowed core stays under the threshold, the chosen
    // core must be under it; otherwise the choice minimises interference.
    let bank = testkit::shared_bank();
    let threshold = bank.mean_slowdown();
    check("ias-threshold", default_cases(), |rng| {
        let state = random_state(rng, 12, 30);
        let cand = *rng.pick(&ALL_CLASSES);
        let mut backend = NativeScoring::new();
        let scores = backend.score(&state, cand, bank, 1.2, false);
        let mut sched = scheduler::build(Policy::Ias, bank, 1.2, None);
        let core = sched.select_pinning(&state, cand);
        let exists_under = state
            .allowed
            .iter()
            .any(|&c| scores.ic_after()[c] < threshold);
        if exists_under {
            assert!(
                scores.ic_after()[core] < threshold,
                "an under-threshold core existed but IAS picked I={}",
                scores.ic_after()[core]
            );
        } else {
            let min = state
                .allowed
                .iter()
                .map(|&c| scores.ic_after()[c])
                .fold(f64::INFINITY, f64::min);
            assert!(
                scores.ic_after()[core] <= min + 1e-9,
                "IAS must minimise: picked {} vs min {min}",
                scores.ic_after()[core]
            );
        }
    });
}

#[test]
fn prop_overload_monotone_in_members() {
    // Adding a workload never decreases a core's overload.
    check("overload-monotone", default_cases(), |rng| {
        let n = 1 + rng.below(6);
        let mut loads: Vec<[f64; 4]> = Vec::new();
        for _ in 0..n {
            loads.push([
                rng.range(0.0, 1.0),
                rng.range(0.0, 1.0),
                rng.range(0.0, 1.0),
                rng.range(0.0, 1.0),
            ]);
        }
        let thr = rng.range(0.5, 2.0);
        let before = core_overload(&loads[..n - 1], thr);
        let after = core_overload(&loads, thr);
        assert!(after >= before - 1e-12, "overload shrank: {before} -> {after}");
    });
}

#[test]
fn prop_wi_at_least_half_and_monotone_in_slowdowns() {
    check("wi-bounds", default_cases(), |rng| {
        let n = rng.below(6);
        let mut slows: Vec<f64> = (0..n).map(|_| rng.range(1.0, 3.0)).collect();
        let wi = workload_interference(&slows);
        assert!(wi >= 0.5 - 1e-12, "WI {wi} below the alone-value 0.5");
        if !slows.is_empty() {
            // Raising any slowdown raises WI (S >= 1 everywhere).
            let k = rng.below(slows.len());
            let before = wi;
            slows[k] += 0.5;
            assert!(workload_interference(&slows) > before);
        }
    });
}

#[test]
fn prop_core_interference_is_max() {
    check("core-interference-max", default_cases(), |rng| {
        let n = rng.below(8);
        let wis: Vec<f64> = (0..n).map(|_| rng.range(0.0, 5.0)).collect();
        let ic = core_interference(&wis);
        for &w in &wis {
            assert!(ic >= w);
        }
        if !wis.is_empty() {
            assert!(wis.contains(&ic));
        }
    });
}

#[test]
fn prop_scenarios_conserve_physics() {
    // Whole-run invariants under arbitrary seeds and SRs: perf in (0, 1],
    // busy cores ≤ physical cores, CPU hours positive, energy consistent.
    let bank = testkit::shared_bank();
    let cfg = testkit::quiet_config();
    check("scenario-physics", 10, |rng| {
        let sr = rng.range(0.3, 2.2);
        let seed = rng.next_u64();
        let spec = random::build(cfg.host.cores, sr, seed).unwrap();
        let policy = *rng.pick(&Policy::ALL);
        let r = run_scenario(&cfg, &spec, policy, bank).unwrap();
        assert!(r.avg_perf > 0.0 && r.avg_perf <= 1.0 + 1e-9, "{policy:?} perf");
        assert!(r.busy_series.max() <= cfg.host.cores as f64 + 1e-9);
        assert!(r.core_hours > 0.0);
        assert!(r.energy_wh > 0.0);
        // Energy must be at least the idle floor over the run.
        let idle_wh = cfg.host.sockets as f64 * cfg.host.watts_socket_idle
            * r.completion_time
            / 3600.0;
        assert!(r.energy_wh >= idle_wh - 1e-6);
        for (_, perf) in &r.per_class_perf {
            assert!(*perf > 0.0 && *perf <= 1.0 + 1e-9);
        }
    });
}

#[test]
fn prop_incremental_scores_match_reference() {
    // The tentpole invariant: the cached aggregates must reproduce the
    // from-scratch Eq. 2–4 reference exactly, across random placement
    // states, thresholds, CPU masking, and every WI formula.
    let bank = testkit::shared_bank();
    check("incremental-vs-reference", default_cases(), |rng| {
        let cores = 1 + rng.below(16);
        let (cached, _) = random_state_pair(rng, bank, cores, 48);
        let cand = *rng.pick(&ALL_CLASSES);
        let cpu_only = rng.chance(0.5);
        let thr = rng.range(0.6, 2.0);
        for mode in [WiMode::MeanSumProd, WiMode::SumOnly, WiMode::ProdOnly] {
            let mut native = NativeScoring::with_wi_mode(mode);
            let fast = native.score(&cached, cand, bank, thr, cpu_only);
            let slow = scoring::reference_scores_with(mode, &cached, cand, bank, thr, cpu_only);
            for c in 0..cores {
                for (a, b, what) in [
                    (fast.ol_before()[c], slow.ol_before()[c], "ol_before"),
                    (fast.ol_after()[c], slow.ol_after()[c], "ol_after"),
                    (fast.ic_before()[c], slow.ic_before()[c], "ic_before"),
                    (fast.ic_after()[c], slow.ic_after()[c], "ic_after"),
                ] {
                    assert!(
                        (a - b).abs() < 1e-9,
                        "{mode:?} {what}[{c}]: incremental {a} vs reference {b}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_place_remove_interleavings_match_reference() {
    // The removal-delta invariant: ANY interleaving of place/remove must
    // leave the cached aggregates — and therefore the scores — equal to
    // the from-scratch Eq. 2–4 reference over the surviving membership.
    let bank = testkit::shared_bank();
    check("place-remove-roundtrip", default_cases(), |rng| {
        let cores = 1 + rng.below(12);
        let mut state = PlacementState::with_bank(cores, rng.chance(0.3), bank);
        let mut residents: Vec<(usize, WorkloadClass)> = Vec::new();
        for _ in 0..rng.below(80) {
            if !residents.is_empty() && rng.chance(0.4) {
                let k = rng.below(residents.len());
                let (core, class) = residents.swap_remove(k);
                assert!(state.remove(core, class), "remove must find {class:?} on {core}");
            } else {
                let core = rng.below(cores);
                let class = *rng.pick(&ALL_CLASSES);
                state.place(core, class);
                residents.push((core, class));
            }
        }
        assert_eq!(state.placed(), residents.len());
        assert!(state.cache_matches_rebuild(1e-9), "aggregates drifted");

        let cand = *rng.pick(&ALL_CLASSES);
        let cpu_only = rng.chance(0.5);
        let thr = rng.range(0.6, 2.0);
        let mut native = NativeScoring::new();
        let fast = native.score(&state, cand, bank, thr, cpu_only);
        let slow = scoring::reference_scores(&state, cand, bank, thr, cpu_only);
        for c in 0..cores {
            for (a, b, what) in [
                (fast.ol_before()[c], slow.ol_before()[c], "ol_before"),
                (fast.ol_after()[c], slow.ol_after()[c], "ol_after"),
                (fast.ic_before()[c], slow.ic_before()[c], "ic_before"),
                (fast.ic_after()[c], slow.ic_after()[c], "ic_after"),
            ] {
                // 1e-9 absolute-or-relative (util::close — the same rule
                // cache_matches_rebuild uses): the IC scores carry the
                // WI Π term, which grows like S^members on crowded cores,
                // where remove()'s divisions reorder ULPs and only a
                // relative comparison is meaningful.
                assert!(
                    close(a, b, 1e-9),
                    "{what}[{c}] after churn: delta {a} vs reference {b}"
                );
            }
        }
    });
}

#[test]
fn prop_drain_to_empty_restores_pristine_aggregates() {
    // Placing K workloads and removing all K must return every cached
    // aggregate to (numerically) zero-load / empty-partials.
    let bank = testkit::shared_bank();
    check("place-remove-drain", default_cases(), |rng| {
        let cores = 1 + rng.below(8);
        let mut state = PlacementState::with_bank(cores, false, bank);
        let mut residents: Vec<(usize, WorkloadClass)> = Vec::new();
        for _ in 0..1 + rng.below(40) {
            let core = rng.below(cores);
            let class = *rng.pick(&ALL_CLASSES);
            state.place(core, class);
            residents.push((core, class));
        }
        while !residents.is_empty() {
            let k = rng.below(residents.len());
            let (core, class) = residents.swap_remove(k);
            assert!(state.remove(core, class));
        }
        assert_eq!(state.placed(), 0);
        let cache = state.cache().unwrap();
        for core in 0..cores {
            assert!(cache.wi_parts(core).is_empty());
            for &l in cache.load(core).iter() {
                assert!(l.abs() < 1e-9, "residual load {l}");
            }
        }
    });
}

#[test]
fn prop_cached_and_uncached_states_agree_on_decisions() {
    // The same placement sequence, scored incrementally (cached state) and
    // from scratch (plain state), must yield identical pinning decisions
    // for every scoring policy.
    let bank = testkit::shared_bank();
    check("cached-vs-uncached-decisions", default_cases(), |rng| {
        let cores = 1 + rng.below(16);
        let (cached, plain) = random_state_pair(rng, bank, cores, 40);
        let cand = *rng.pick(&ALL_CLASSES);
        for policy in [Policy::Cas, Policy::Ras, Policy::Ias] {
            let mut sched = scheduler::build(policy, bank, 1.2, None);
            let a = sched.select_pinning(&cached, cand);
            let b = sched.select_pinning(&plain, cand);
            assert_eq!(a, b, "{policy:?} diverged: cached {a} vs uncached {b}");
        }
    });
}

#[test]
fn prop_single_core_states_always_offer_core0() {
    // Regression companion to the 1-core daemon fix: whatever the
    // reservation flag, a 1-core state must keep core 0 legal and every
    // policy must pick it.
    let bank = testkit::shared_bank();
    check("single-core-fallback", default_cases(), |rng| {
        let reserve = rng.chance(0.5);
        let state = PlacementState::new(1, reserve);
        assert_eq!(state.allowed, vec![0]);
        let cand = *rng.pick(&ALL_CLASSES);
        for policy in Policy::ALL {
            let mut sched = scheduler::build(policy, bank, 1.2, None);
            assert_eq!(sched.select_pinning(&state, cand), 0, "{policy:?}");
        }
    });
}

#[test]
fn prop_bus_routing_matches_direct_host_calls() {
    // The cluster-event invariant: routing an arbitrary interleaving of
    // ClusterEvents (arrivals, departures, raw scheduler ticks) through
    // the EventBus + ShardPool must leave every host's engine and
    // long-lived placement state bit-identical to driving the exact same
    // sequence via direct HostHandle calls.
    use vmcd::cluster::{
        ClusterEvent, ClusterHost, Dispatcher, EventBus, HostHandle, MigrationModel,
        NativeHost, ShardPool, SimHost, StepMode,
    };
    use vmcd::hostsim::{ActivityModel, SimEngine, Vm, VmId, VmState};
    use vmcd::vmcd::daemon::SchedEvent;
    use vmcd::vmcd::Daemon;

    #[allow(clippy::large_enum_variant)]
    #[derive(Clone)]
    enum Act {
        Arrive(usize, Vm),
        Depart(usize, VmId),
        Tick(usize),
    }

    let bank = testkit::shared_bank();
    let cfg = testkit::quiet_config();
    let hosts_n = 3;

    let make_hosts = |cfg: &vmcd::config::Config| -> Vec<NativeHost> {
        (0..hosts_n)
            .map(|_| {
                let sched =
                    scheduler::build_native(Policy::Ias, bank, cfg.sched.ras_threshold, None);
                let daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
                SimHost::new(SimEngine::new(cfg.clone(), Vec::new()), Some(daemon))
            })
            .collect()
    };

    // Everything that must agree, down to the bit: engine occupancy and
    // pinning, and the daemon's placement state with its cached loads.
    type Snapshot = (
        Vec<(VmId, Option<usize>)>,
        Vec<Vec<usize>>,
        Vec<usize>,
        Vec<Vec<u64>>,
    );
    let snapshot = |host: &NativeHost| -> Snapshot {
        let pins = host
            .engine
            .vms
            .iter()
            .map(|v| (v.id, v.pinned))
            .collect();
        let s = host.daemon.as_ref().unwrap().placement_state();
        let loads: Vec<Vec<u64>> = (0..s.cores.len())
            .map(|c| {
                s.cache()
                    .map(|k| k.load(c).iter().map(|x| x.to_bits()).collect())
                    .unwrap_or_default()
            })
            .collect();
        (pins, s.cores.clone(), s.allowed.clone(), loads)
    };

    check("bus-vs-direct", 12, |rng| {
        // Script the interleaving first so both drives replay it exactly.
        let ticks = 8 + rng.below(8);
        let mut next_id = 0u32;
        let mut live: Vec<Vec<VmId>> = vec![Vec::new(); hosts_n];
        let mut script: Vec<Vec<Act>> = Vec::new();
        for tick in 0..ticks {
            let mut acts = Vec::new();
            for _ in 0..rng.below(3) {
                let h = rng.below(hosts_n);
                let resident = !live[h].is_empty();
                if resident && rng.chance(0.3) {
                    let k = rng.below(live[h].len());
                    let id = live[h].swap_remove(k);
                    acts.push(Act::Depart(h, id));
                } else if rng.chance(0.2) {
                    acts.push(Act::Tick(h));
                } else {
                    let mut vm = Vm::new(
                        VmId(next_id),
                        *rng.pick(&ALL_CLASSES),
                        0.0,
                        ActivityModel::AlwaysOn,
                    );
                    vm.state = VmState::Running;
                    vm.started = Some(tick as f64);
                    live[h].push(vm.id);
                    next_id += 1;
                    acts.push(Act::Arrive(h, vm));
                }
            }
            script.push(acts);
        }

        // Drive A: through the bus + pool.
        let mut pool = ShardPool::new(
            make_hosts(&cfg).into_iter().map(ClusterHost::Native).collect(),
            StepMode::Single,
        )
        .unwrap();
        let mut bus = EventBus::new(hosts_n, MigrationModel::default(), cfg.host.cores);
        let mut policy = Dispatcher::RoundRobin.build();
        let mut route_rng = vmcd::util::rng::Rng::new(7);
        for acts in &script {
            for act in acts {
                bus.publish(match act {
                    Act::Arrive(h, vm) => ClusterEvent::Arrival {
                        vm: vm.clone(),
                        host: Some(*h),
                    },
                    Act::Depart(h, id) => ClusterEvent::Departure { host: *h, vm: *id },
                    Act::Tick(h) => ClusterEvent::Sched {
                        host: *h,
                        ev: SchedEvent::Tick,
                    },
                });
            }
            bus.route(policy.as_mut(), bank, &mut route_rng).unwrap();
            pool.step(bus.take_inboxes()).unwrap();
        }
        let routed = pool.into_hosts().unwrap();

        // Drive B: the same sequence via direct HostHandle calls.
        let mut direct = make_hosts(&cfg);
        for acts in &script {
            for act in acts {
                match act {
                    Act::Arrive(h, vm) => direct[*h].inject_arrival(vm.clone()).unwrap(),
                    Act::Depart(h, id) => {
                        direct[*h].remove_resident(*id).unwrap();
                    }
                    Act::Tick(h) => direct[*h].inject_event(SchedEvent::Tick).unwrap(),
                }
            }
            for host in &mut direct {
                host.step_host().unwrap();
            }
        }

        for (h, (a, b)) in routed.iter().zip(direct.iter()).enumerate() {
            let ClusterHost::Native(a) = a else {
                panic!("pool returned a pinned host")
            };
            assert_eq!(
                snapshot(a),
                snapshot(b),
                "host {h} diverged between bus routing and direct calls"
            );
        }
    });
}

#[test]
fn prop_inline_and_zero_lag_deferred_are_bit_identical() {
    // The actuation-API acceptance: a Deferred backend with zero latency
    // and unlimited budget enforces every command inside the same daemon
    // step as Inline does, so twin hosts driven through well over 100
    // mixed events (arrivals, departures, idle/wake churn, Ticks) must
    // never diverge by a single pin.
    use vmcd::hostsim::{ActivityModel, SimEngine, Vm, VmId};
    use vmcd::vmcd::{ActuationSpec, Daemon};

    let bank = testkit::shared_bank();
    let cfg = testkit::quiet_config();

    check("inline-vs-deferred0", 3, |rng| {
        let mut vms = Vec::new();
        for i in 0..12u32 {
            // The on/off third is pinned to a service class (it never
            // finishes), so the idle/wake churn keeps flowing for the
            // whole window and the 100-event floor below always holds.
            let (activity, class) = match i % 3 {
                0 => (ActivityModel::AlwaysOn, *rng.pick(&ALL_CLASSES)),
                1 => (
                    ActivityModel::OnOff {
                        period: 40.0 + rng.range(0.0, 40.0),
                        duty: 0.5,
                        phase: rng.range(0.0, 40.0),
                    },
                    WorkloadClass::LampHeavy,
                ),
                _ => (
                    ActivityModel::Windows(vec![(0.0, 120.0 + rng.range(0.0, 200.0))]),
                    *rng.pick(&ALL_CLASSES),
                ),
            };
            vms.push(Vm::new(VmId(i), class, rng.range(0.0, 120.0), activity));
        }
        let build = |actuation: ActuationSpec| {
            let sched = scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
            let daemon = Daemon::with_actuation(cfg.sched.clone(), sched, cfg.host.cores, actuation.build());
            (SimEngine::new(cfg.clone(), vms.clone()), daemon)
        };
        let (mut eng_a, mut inline) = build(ActuationSpec::Inline);
        let (mut eng_b, mut deferred) = build(ActuationSpec::Deferred {
            latency_ticks: 0,
            budget_per_tick: 0,
        });
        for _ in 0..1200 {
            for id in eng_a.process_arrivals() {
                inline.on_arrival(&mut eng_a, id).unwrap();
            }
            for id in eng_b.process_arrivals() {
                deferred.on_arrival(&mut eng_b, id).unwrap();
            }
            inline.step(&mut eng_a).unwrap();
            deferred.step(&mut eng_b).unwrap();
            eng_a.step();
            eng_b.step();
            let pins_a: Vec<_> = eng_a.vms.iter().map(|v| (v.id, v.pinned)).collect();
            let pins_b: Vec<_> = eng_b.vms.iter().map(|v| (v.id, v.pinned)).collect();
            assert_eq!(pins_a, pins_b, "pinning diverged at t={}", eng_a.t);
            assert_eq!(deferred.in_flight(), 0, "zero-lag must drain every step");
        }
        assert!(
            inline.events_handled >= 100,
            "churn too quiet to prove the actuation API: {} events",
            inline.events_handled
        );
        assert_eq!(inline.events_handled, deferred.events_handled);
        let (a, b) = (inline.placement_state(), deferred.placement_state());
        assert_eq!(a.cores, b.cores);
        assert_eq!(a.allowed, b.allowed);
    });
}

#[test]
fn prop_deferred_lag_reconciles_to_intent_once_drained() {
    // The convergence half of the actuation satellite: with lag > 0 the
    // enacted pinning trails the daemon's intent, but commands are FIFO,
    // so the moment the backend drains, every running resident sits on
    // exactly its intended core and the observed map agrees.
    use vmcd::hostsim::{ActivityModel, SimEngine, Vm, VmId, VmState};
    use vmcd::vmcd::actuator::Deferred;
    use vmcd::vmcd::Daemon;

    let bank = testkit::shared_bank();
    let cfg = testkit::quiet_config();

    check("deferred-lag-convergence", 6, |rng| {
        let lag = 1 + rng.below(5) as u64;
        let budget = [0usize, 2, 8][rng.below(3)];
        let mut vms = Vec::new();
        for i in 0..(6 + rng.below(6) as u32) {
            vms.push(Vm::new(
                VmId(i),
                *rng.pick(&ALL_CLASSES),
                rng.range(0.0, 60.0),
                ActivityModel::AlwaysOn,
            ));
        }
        let sched = scheduler::build(Policy::Ras, bank, cfg.sched.ras_threshold, None);
        let mut daemon = Daemon::with_actuation(
            cfg.sched.clone(),
            sched,
            cfg.host.cores,
            Box::new(Deferred::new(lag, budget)),
        );
        let mut eng = SimEngine::new(cfg.clone(), vms);
        let mut drained_after_churn = false;
        for step in 0..600 {
            for id in eng.process_arrivals() {
                daemon.on_arrival(&mut eng, id).unwrap();
            }
            daemon.step(&mut eng).unwrap();
            eng.step();
            // Let the arrival window pass before looking for a drained
            // instant (lag guarantees in-flight commands early on).
            if step > 80 && daemon.in_flight() == 0 {
                drained_after_churn = true;
                break;
            }
        }
        assert!(drained_after_churn, "deferred backend never drained");
        for vm in &eng.vms {
            if vm.state != VmState::Running {
                continue;
            }
            let intent = daemon.intended_pinning(vm.id);
            assert!(intent.is_some(), "running {:?} untracked", vm.id);
            assert_eq!(
                vm.pinned, intent,
                "enacted pin must reconcile to intent for {:?} (lag {lag}, budget {budget})",
                vm.id
            );
            assert_eq!(daemon.observed_pinning(vm.id), intent);
        }
    });
}

#[test]
fn prop_placement_state_accounting() {
    let bank = testkit::shared_bank();
    check("placement-accounting", default_cases(), |rng| {
        let cores = 2 + rng.below(31);
        let cached = rng.chance(0.5);
        let mut state = if cached {
            PlacementState::with_bank(cores, rng.chance(0.5), bank)
        } else {
            PlacementState::new(cores, rng.chance(0.5))
        };
        let mut placed = 0;
        for _ in 0..rng.below(40) {
            state.place(rng.below(cores), WorkloadClass::Hadoop);
            placed += 1;
        }
        assert_eq!(state.placed(), placed);
        if let Some(cache) = state.cache() {
            // Cached load vectors must equal a brute-force re-sum.
            let hadoop = WorkloadClass::Hadoop.index();
            for (core, members) in state.cores.iter().enumerate() {
                let load = cache.load(core);
                for (j, &l) in load.iter().enumerate() {
                    let want = bank.u[hadoop][j] * members.len() as f64;
                    assert!((l - want).abs() < 1e-9, "core {core} metric {j}");
                }
            }
        }
    });
}

#[test]
fn prop_batched_rank_matches_scalar_picks() {
    // The score-matrix parity contract: for the four classic policies,
    // one batched `rank` call over an N-arrival burst must produce
    // exactly the pick sequence of N scalar picks against summaries
    // live-updated between picks the way `EventBus::route` updates them
    // (`resident += 1`, `est_cpu_load += demand[cpu]`).
    use vmcd::cluster::dispatch::{scalar, ArrivalBatch, Dispatcher};
    use vmcd::cluster::{HostSummary, SummaryMatrix};
    use vmcd::vmcd::scheduler::ScoreBuf;

    let bank = testkit::shared_bank();
    check("batched-rank-parity", default_cases(), |rng| {
        let hosts = 1 + rng.below(12);
        let host_cores = 4 + rng.below(13);
        let burst = 1 + rng.below(16);

        // Random published summaries (whatever the last refresh left),
        // with deliberate exact ties so the tie-break order is exercised.
        let summaries: Vec<HostSummary> = (0..hosts)
            .map(|_| HostSummary {
                resident: rng.below(4),
                busy_cores: rng.below(host_cores + 1),
                max_wi: if rng.chance(0.4) {
                    0.0
                } else {
                    rng.range(0.0, 3.0)
                },
                est_cpu_load: if rng.chance(0.4) {
                    0.0
                } else {
                    rng.range(0.0, host_cores as f64)
                },
                ..HostSummary::default()
            })
            .collect();
        let classes: Vec<WorkloadClass> =
            (0..burst).map(|_| *rng.pick(&ALL_CLASSES)).collect();

        // Matrix and batch exactly as `EventBus::flush_batch` builds them.
        let matrix = SummaryMatrix::from_summaries(&summaries, host_cores);
        let mut batch = ArrivalBatch::default();
        for &class in &classes {
            batch.push_class(class, bank);
        }

        for d in [
            Dispatcher::RoundRobin,
            Dispatcher::LeastLoaded,
            Dispatcher::LowestInterference,
            Dispatcher::Random,
        ] {
            // Identical RNG streams on both sides (only Random draws).
            let mut rng_batched = Rng::new(rng.next_u64());
            let mut rng_scalar = rng_batched.clone();

            let mut policy = d.build();
            let mut scratch = ScoreBuf::default();
            let mut batched = Vec::new();
            policy.rank(&matrix, &batch, &mut scratch, &mut rng_batched, &mut batched);
            assert_eq!(batched.len(), burst, "{} rank pick count", d.name());

            // Scalar drive: frozen pre-matrix pickers over a summary copy
            // that replays the bus's per-arrival live updates.
            let mut live = summaries.clone();
            let mut cursor = 0usize;
            let mut picks = Vec::with_capacity(burst);
            for &class in &classes {
                let h = match d {
                    Dispatcher::RoundRobin => scalar::round_robin(&mut cursor, &live),
                    Dispatcher::LeastLoaded => scalar::least_loaded(&live),
                    Dispatcher::LowestInterference => scalar::lowest_interference(&live),
                    Dispatcher::Random => scalar::random(&live, &mut rng_scalar),
                    _ => unreachable!(),
                };
                live[h].resident += 1;
                live[h].est_cpu_load += bank.u[class.index()][0];
                picks.push(h);
            }

            assert_eq!(
                batched, picks,
                "{} batched rank diverged from scalar picks \
                 (hosts {hosts}, burst {burst})",
                d.name()
            );
        }
    });
}

#[test]
fn prop_migrator_plan_respects_budget_blocked_set_and_topology() {
    // The continuous-migrator planning contract, over random fleets: a
    // plan never exceeds the remaining budget, never selects a blocked
    // (in-flight or cooling-down) VM, never names a VM twice, only
    // moves VMs that are actually running on their source, never
    // targets the source itself, an out-of-range host, or an
    // overloaded destination — and only overloaded or underloaded
    // hosts ever shed VMs.
    use std::collections::{BTreeSet, HashSet};
    use vmcd::cluster::migrator::{classify, plan, HostClass};
    use vmcd::cluster::{HostSummary, SummaryMatrix};
    use vmcd::config::MigratorParams;
    use vmcd::hostsim::VmId;

    let bank = testkit::shared_bank();
    check("migrator-plan-invariants", default_cases(), |rng| {
        let hosts = 1 + rng.below(10);
        let host_cores = 4 + rng.below(13);
        let mut next_id = 0u32;
        let summaries: Vec<HostSummary> = (0..hosts)
            .map(|_| {
                let mut running = Vec::new();
                let mut est = 0.0;
                for _ in 0..rng.below(6) {
                    let class = *rng.pick(&ALL_CLASSES);
                    running.push((VmId(next_id), class));
                    est += bank.u[class.index()][0];
                    next_id += 1;
                }
                // Sometimes resident > running (idle VMs — exercises the
                // all-or-nothing park guard) and sometimes the estimated
                // load is inflated past what the running set explains.
                let resident = running.len() + if rng.chance(0.3) { rng.below(3) } else { 0 };
                if rng.chance(0.3) {
                    est += rng.range(0.0, host_cores as f64);
                }
                HostSummary {
                    resident,
                    running,
                    busy_cores: rng.below(host_cores + 1),
                    max_wi: rng.range(0.0, 3.0),
                    est_cpu_load: est,
                    ..HostSummary::default()
                }
            })
            .collect();
        let matrix = SummaryMatrix::from_summaries(&summaries, host_cores);
        let over = rng.range(0.3, 1.5);
        let params = MigratorParams {
            over,
            under: rng.range(0.0, over),
            wi_threshold: rng.range(0.5, 2.5),
            budget: 1 + rng.below(8),
            ..MigratorParams::default()
        };
        let budget_left = rng.below(9);
        // Block a random subset of the fleet's VMs.
        let blocked: BTreeSet<VmId> = (0..next_id)
            .filter(|_| rng.chance(0.25))
            .map(VmId)
            .collect();

        let classes = classify(&params, &summaries, &matrix);
        let moves = plan(&params, &summaries, &matrix, bank, &blocked, budget_left);

        assert!(
            moves.len() <= budget_left,
            "planned {} moves with budget {budget_left}",
            moves.len()
        );
        let mut seen: HashSet<VmId> = HashSet::new();
        for m in &moves {
            assert!(m.src < hosts && m.dst < hosts, "out of range: {m:?}");
            assert_ne!(m.src, m.dst, "self-migration: {m:?}");
            assert!(!blocked.contains(&m.vm), "blocked VM selected: {m:?}");
            assert!(seen.insert(m.vm), "VM planned twice: {m:?}");
            assert!(
                summaries[m.src].running.iter().any(|&(id, _)| id == m.vm),
                "VM not running on its source: {m:?}"
            );
            assert_ne!(
                classes[m.src],
                HostClass::Normal,
                "a normal host shed a VM: {m:?}"
            );
            assert_ne!(
                classes[m.dst],
                HostClass::Overloaded,
                "an overloaded destination: {m:?}"
            );
        }
    });
}

#[test]
fn prop_cost_aware_plan_keeps_invariants_and_respects_payback() {
    // The forecast/payback planning contract over random fleets and
    // random `PlanContext`s: every PR 8 invariant still holds under
    // predicted loads, hysteresis-ineligible hosts are never evacuated,
    // the empty context reproduces `plan` exactly, and — recomputing
    // with the same public `move_cost_joules` fold the gate used —
    // every parked host's copy energy fits inside the idle-power
    // payback window.
    use std::collections::{BTreeSet, HashSet};
    use vmcd::cluster::migrator::planner::{
        classify_with, move_cost_joules, plan, plan_with, CostContext, PlanContext,
    };
    use vmcd::cluster::migrator::HostClass;
    use vmcd::cluster::{HostSummary, MigrationModel, SummaryMatrix};
    use vmcd::config::{HostSpec, MigratorParams, PowerModel};
    use vmcd::hostsim::VmId;

    let bank = testkit::shared_bank();
    check("migrator-cost-aware-invariants", default_cases(), |rng| {
        let hosts = 1 + rng.below(10);
        let host_cores = 4 + rng.below(13);
        let mut next_id = 0u32;
        let summaries: Vec<HostSummary> = (0..hosts)
            .map(|_| {
                let mut running = Vec::new();
                let mut est = 0.0;
                for _ in 0..rng.below(6) {
                    let class = *rng.pick(&ALL_CLASSES);
                    running.push((VmId(next_id), class));
                    est += bank.u[class.index()][0];
                    next_id += 1;
                }
                let resident = running.len() + if rng.chance(0.3) { rng.below(3) } else { 0 };
                if rng.chance(0.3) {
                    est += rng.range(0.0, host_cores as f64);
                }
                HostSummary {
                    resident,
                    running,
                    busy_cores: rng.below(host_cores + 1),
                    max_wi: rng.range(0.0, 3.0),
                    est_cpu_load: est,
                    ..HostSummary::default()
                }
            })
            .collect();
        let matrix = SummaryMatrix::from_summaries(&summaries, host_cores);
        let over = rng.range(0.3, 1.5);
        let params = MigratorParams {
            over,
            under: rng.range(0.0, over),
            wi_threshold: rng.range(0.5, 2.5),
            budget: 1 + rng.below(8),
            ..MigratorParams::default()
        };
        let budget_left = rng.below(9);
        let blocked: BTreeSet<VmId> = (0..next_id)
            .filter(|_| rng.chance(0.25))
            .map(VmId)
            .collect();

        // Random forecast/hysteresis/cost inputs, each independently
        // present — all-absent must collapse to the myopic planner.
        let predicted: Option<Vec<f64>> = rng.chance(0.5).then(|| {
            (0..hosts)
                .map(|_| rng.range(0.0, host_cores as f64 * 1.5))
                .collect()
        });
        let predicted_wi: Option<Vec<f64>> =
            rng.chance(0.5).then(|| (0..hosts).map(|_| rng.range(0.0, 3.0)).collect());
        let park_eligible: Option<Vec<bool>> =
            rng.chance(0.5).then(|| (0..hosts).map(|_| rng.chance(0.5)).collect());
        let migration = MigrationModel {
            transfer_secs: rng.range(5.0, 40.0),
            transfer_net: rng.range(0.0, 1.0),
            ..MigrationModel::default()
        };
        let power = if rng.chance(0.5) {
            PowerModel::Linear
        } else {
            let w0 = rng.range(5.0, 100.0);
            let w1 = w0 + rng.range(1.0, 400.0);
            PowerModel::parse(&format!("piecewise:0={w0},1={w1}")).unwrap()
        };
        let host = HostSpec::default();
        let payback = rng.range(10.0, 2000.0);
        let cost = rng.chance(0.5).then(|| CostContext {
            migration: &migration,
            power: &power,
            host: &host,
            payback,
        });
        let ctx = PlanContext {
            predicted: predicted.as_deref(),
            predicted_wi: predicted_wi.as_deref(),
            park_eligible: park_eligible.as_deref(),
            cost,
        };

        let classes =
            classify_with(&params, &summaries, &matrix, ctx.predicted, ctx.predicted_wi);
        let moves = plan_with(&params, &summaries, &matrix, bank, &blocked, budget_left, &ctx);

        // The empty context IS the myopic planner.
        let myopic = plan(&params, &summaries, &matrix, bank, &blocked, budget_left);
        let empty = plan_with(
            &params,
            &summaries,
            &matrix,
            bank,
            &blocked,
            budget_left,
            &PlanContext::default(),
        );
        assert_eq!(myopic, empty, "default PlanContext diverged from plan()");

        assert!(
            moves.len() <= budget_left,
            "planned {} moves with budget {budget_left}",
            moves.len()
        );
        let mut seen: HashSet<VmId> = HashSet::new();
        for m in &moves {
            assert!(m.src < hosts && m.dst < hosts, "out of range: {m:?}");
            assert_ne!(m.src, m.dst, "self-migration: {m:?}");
            assert!(!blocked.contains(&m.vm), "blocked VM selected: {m:?}");
            assert!(seen.insert(m.vm), "VM planned twice: {m:?}");
            assert!(
                summaries[m.src].running.iter().any(|&(id, _)| id == m.vm),
                "VM not running on its source: {m:?}"
            );
            assert_ne!(
                classes[m.src],
                HostClass::Normal,
                "a normal host shed a VM: {m:?}"
            );
            assert_ne!(
                classes[m.dst],
                HostClass::Overloaded,
                "an overloaded destination: {m:?}"
            );
            if classes[m.src] == HostClass::Underloaded {
                if let Some(pe) = &park_eligible {
                    assert!(pe[m.src], "hysteresis-ineligible host evacuated: {m:?}");
                }
            }
        }

        // Payback audit: group the emitted park moves by source (park
        // sources are exactly the Underloaded ones) and recompute the
        // copy-energy fold in emission order — the identical f64 sum
        // the gate compared — then check it fits the idle-power window.
        if let Some(cost) = &ctx.cost {
            let demand = |vm: VmId, src: usize| {
                summaries[src]
                    .running
                    .iter()
                    .find(|&&(id, _)| id == vm)
                    .map(|&(_, class)| bank.u[class.index()][0])
                    .expect("planned VM runs on its source")
            };
            for src in 0..hosts {
                if classes[src] != HostClass::Underloaded {
                    continue;
                }
                let copy_j: f64 = moves
                    .iter()
                    .filter(|m| m.src == src)
                    .map(|m| move_cost_joules(cost, &summaries, &matrix, m, demand(m.vm, src)))
                    .sum();
                if copy_j == 0.0 {
                    continue; // host was not parked this plan
                }
                let idle_w = cost.power.watts(0, matrix.cap(src, 0), cost.host);
                assert!(
                    copy_j <= idle_w * cost.payback,
                    "parked host {src} cannot repay its copy: {copy_j} J > {idle_w} W × {} s",
                    cost.payback
                );
            }
        }
    });
}

#[test]
fn prop_synthetic_traces_are_well_formed() {
    // The trace-generator contract, over randomized `synth:` specs: the
    // stream is non-decreasing in time, arrival ids are unique, every
    // departure and migrate names a currently-live VM, and exactly
    // `vms` arrivals (each with a positive finite lifetime) are emitted.
    use std::collections::HashSet;
    use vmcd::cluster::trace::synth::SyntheticTraceGenerator;
    use vmcd::cluster::{TraceOp, TraceReader};

    check("synthetic-trace-well-formed", 12, |rng| {
        let vms = 20 + rng.below(180);
        let spec = format!(
            "vms={vms},rate={:.3},burst={:.3},life={:.3},dist={},sigma={:.3},alpha={:.3},\
             diurnal={:.3},period={:.1},migrates={}",
            rng.range(0.5, 40.0),
            rng.range(1.0, 6.0),
            rng.range(5.0, 200.0),
            if rng.chance(0.5) { "lognormal" } else { "pareto" },
            rng.range(0.2, 1.5),
            rng.range(0.8, 3.0),
            rng.range(0.0, 0.9),
            rng.range(60.0, 2000.0),
            rng.below(10),
        );
        let mut reader = SyntheticTraceGenerator::parse(&spec, rng.next_u64()).unwrap();

        let mut last_at = 0.0f64;
        let mut live: HashSet<u32> = HashSet::new();
        let mut seen: HashSet<u32> = HashSet::new();
        let (mut arrivals, mut departures, mut migrates) = (0usize, 0usize, 0usize);
        while let Some(ev) = reader.next_event().unwrap() {
            assert!(
                ev.at_tick.is_finite() && ev.at_tick >= last_at,
                "timestamps regressed: {} after {last_at} ({spec})",
                ev.at_tick
            );
            last_at = ev.at_tick;
            match ev.op {
                TraceOp::Arrival { lifetime, .. } => {
                    assert!(seen.insert(ev.vm), "duplicate arrival id {} ({spec})", ev.vm);
                    let l = lifetime.expect("synth arrivals carry lifetimes");
                    assert!(l.is_finite() && l > 0.0, "lifetime {l} ({spec})");
                    live.insert(ev.vm);
                    arrivals += 1;
                }
                TraceOp::Departure => {
                    assert!(live.remove(&ev.vm), "departure for dead vm {} ({spec})", ev.vm);
                    departures += 1;
                }
                TraceOp::Migrate => {
                    assert!(live.contains(&ev.vm), "migrate for dead vm {} ({spec})", ev.vm);
                    migrates += 1;
                }
            }
        }
        assert_eq!(arrivals, vms, "{spec}");
        assert_eq!(departures, vms, "every capped lifetime departs ({spec})");
        assert!(live.is_empty());
        assert!(migrates <= 10);
    });
}

//! Integration: the PJRT runtime layer — artifact loading, XLA-vs-native
//! scheduler agreement, and an XLA-backed scenario run.
//!
//! These tests skip (with a note) when `artifacts/` has not been built;
//! `make artifacts` first for full coverage.

use vmcd::profiling::ProfileBank;
use vmcd::runtime::{Runtime, XlaScoring};
use vmcd::scenarios::{random, run_scenario, runner::run_scenario_with_backend};
use vmcd::testkit;
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::{self, NativeScoring, PlacementState, Policy, ScoringBackend};
use vmcd::workloads::ALL_CLASSES;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn manifest_covers_all_three_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["score", "blackscholes", "jacobi"] {
        assert!(rt.manifest().entry(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn xla_and_native_backends_agree_on_random_states() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut xla = XlaScoring::new(rt).unwrap();
    let mut native = NativeScoring::new();
    let bank = testkit::shared_bank();
    let mut rng = Rng::new(0xDEC1DE);

    for case in 0..40 {
        // Cached state: native takes the incremental path, so this checks
        // the fused kernel against the production scoring engine.
        let mut state = PlacementState::with_bank(12, rng.chance(0.3), bank);
        for _ in 0..rng.below(24) {
            state.place(rng.below(12), *rng.pick(&ALL_CLASSES));
        }
        let cand = *rng.pick(&ALL_CLASSES);
        let cpu_only = rng.chance(0.5);
        let a = xla.score(&state, cand, bank, 1.2, cpu_only);
        let b = native.score(&state, cand, bank, 1.2, cpu_only);
        for core in 0..12 {
            assert!(
                (a.ol_after()[core] - b.ol_after()[core]).abs() < 1e-3,
                "case {case} core {core} ol_after: {} vs {}",
                a.ol_after()[core],
                b.ol_after()[core]
            );
            assert!(
                (a.ic_after()[core] - b.ic_after()[core]).abs() < 1e-3,
                "case {case} core {core} ic_after: {} vs {}",
                a.ic_after()[core],
                b.ic_after()[core]
            );
        }
    }
}

#[test]
fn xla_backed_scenario_matches_native_decisions() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = random::build(cfg.host.cores, 1.0, 5).unwrap();

    let native = run_scenario(&cfg, &spec, Policy::Ias, bank).unwrap();
    let backend = Box::new(XlaScoring::new(rt).unwrap());
    let xla = run_scenario_with_backend(&cfg, &spec, Policy::Ias, bank, backend).unwrap();

    // Identical decisions -> identical accounting.
    assert_eq!(native.repin_count, xla.repin_count);
    assert!((native.core_hours - xla.core_hours).abs() < 1e-9);
    assert!((native.avg_perf - xla.avg_perf).abs() < 1e-9);
}

#[test]
fn xla_scheduler_integrates_with_all_dynamic_policies() {
    let Some(_) = runtime_or_skip() else { return };
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = random::build(cfg.host.cores, 0.5, 11).unwrap();
    for policy in [Policy::Cas, Policy::Ras, Policy::Ias] {
        let rt = Runtime::new().unwrap();
        let backend = Box::new(XlaScoring::new(rt).unwrap());
        let sched = scheduler::build_with_backend(policy, bank, 1.2, None, backend);
        assert_eq!(sched.policy(), policy);
        let r = run_scenario_with_backend(
            &cfg,
            &spec,
            policy,
            bank,
            Box::new(XlaScoring::new(Runtime::new().unwrap()).unwrap()),
        )
        .unwrap();
        assert!(r.avg_perf > 0.5, "{policy:?}");
    }
}

#[test]
fn compute_kernels_run_and_converge() {
    use vmcd::runtime::compute::{BlackscholesWork, JacobiWork};
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut bs = BlackscholesWork::new(1);
    let c = bs.run_batch(&mut rt).unwrap();
    assert!(c.is_finite() && c > 0.0);
    let mut jc = JacobiWork::new(2);
    let r1 = jc.run_batch(&mut rt).unwrap();
    let r2 = jc.run_batch(&mut rt).unwrap();
    assert!(r2 < r1, "jacobi must relax: {r1} -> {r2}");
}

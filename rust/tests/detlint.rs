//! Tier-1 determinism gates (see `DETERMINISM.md`).
//!
//! Three layers, weakest to strongest:
//!
//! 1. **Static** — `detlint` over the live tree: no hash-iteration,
//!    wall-clock, panic, or thread-boundary violations outside the
//!    documented annotations and the `rust/detlint.allow` burn-down
//!    list (which may only shrink — stale entries fail here too).
//! 2. **Fixtures** — every rule is pinned by positive / negative /
//!    annotated fixture sources, so a lint regression (a rule silently
//!    matching nothing) fails loudly instead of passing vacuously.
//! 3. **Dynamic** — the two-process digest audit: the built `vmcd`
//!    binary replays the same seeded trace twice in separate processes
//!    (fresh ASLR, fresh hash seeds, fresh allocator) with the
//!    migrator enabled, and both must print the same 64-bit FNV-1a
//!    result digest.

use std::path::Path;
use std::process::Command;
use vmcd::analysis::detlint::{
    self, lint_with_tier, parse_allowlist, render_allowlist, Rule, Tier,
};

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there).
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

// ---------------------------------------------------------------------
// 1. The live-tree gate
// ---------------------------------------------------------------------

#[test]
fn live_tree_satisfies_the_determinism_contract() {
    let report = detlint::run(repo_root()).expect("detlint walks rust/src");
    assert!(report.files_scanned > 30, "suspiciously few files scanned");

    if !report.is_clean() {
        let mut msg = String::new();
        if !report.violations.is_empty() {
            msg.push_str("determinism-contract violations (see DETERMINISM.md):\n");
            for v in &report.violations {
                msg.push_str(&format!("  {v}\n"));
            }
            msg.push_str(
                "\nfix the site, add `// detlint: allow(<rule>): <why>`, or (for\n\
                 a deliberate legacy carry-over) append to rust/detlint.allow:\n\n",
            );
            msg.push_str(&render_allowlist(&report.violations));
        }
        if !report.stale.is_empty() {
            msg.push_str("\nstale rust/detlint.allow entries (no matching violation —\n");
            msg.push_str("the site was fixed or moved; delete these lines):\n");
            for a in &report.stale {
                msg.push_str(&format!("  {a}\n"));
            }
        }
        panic!("{msg}");
    }
}

#[test]
fn allowlist_is_a_burn_down_not_a_dumping_ground() {
    // The seeded backlog was 20 entries at PR 9. It may shrink, never
    // grow: new code must use Result or an inline annotation.
    let text = std::fs::read_to_string(repo_root().join("rust/detlint.allow"))
        .expect("rust/detlint.allow exists");
    let entries = parse_allowlist(&text).expect("allowlist parses");
    assert!(
        entries.len() <= 20,
        "rust/detlint.allow grew to {} entries (max 20): fix new sites \
         instead of allowlisting them",
        entries.len()
    );
    // Every entry is rule `panic` — R1/R2/R4 violations are never
    // allowlisted, only converted or annotated inline.
    for e in &entries {
        assert_eq!(e.rule, Rule::Panic, "{e}: only panic entries may be allowlisted");
    }
}

// ---------------------------------------------------------------------
// 2. Per-rule fixtures
// ---------------------------------------------------------------------

/// Shorthand: lint a fixture as a core-tier non-seam file.
fn core_lint(src: &str) -> Vec<detlint::Violation> {
    lint_with_tier("fixture.rs", src, Tier::Core, false)
}

#[test]
fn fixture_hash_iter_positive_negative_annotated() {
    // Positive: a HashMap in core code is flagged.
    let bad = "use std::collections::HashMap;\n";
    let v = core_lint(bad);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::HashIter);
    assert_eq!(v[0].line, 1);

    // Negative: BTreeMap is the sanctioned replacement.
    assert!(core_lint("use std::collections::BTreeMap;\n").is_empty());

    // Negative: a HashMap in a string literal is scrubbed.
    assert!(core_lint("let s = \"HashMap::new()\";\n").is_empty());

    // Annotated: a justified membership-only use passes...
    let annotated =
        "// detlint: allow(hash-iter): membership-only, never iterated\nuse std::collections::HashSet;\n";
    assert!(core_lint(annotated).is_empty());

    // ...but the annotation grammar demands a reason.
    let bare = "// detlint: allow(hash-iter):\nuse std::collections::HashSet;\n";
    assert_eq!(core_lint(bare).len(), 1, "reasonless annotation must not suppress");

    // And edge-tier files are exempt wholesale.
    assert!(lint_with_tier("main.rs", bad, Tier::Edge, false).is_empty());
}

#[test]
fn fixture_wall_clock_positive_negative_annotated() {
    let bad = "let t = Instant::now();\n";
    let v = core_lint(bad);
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].rule, Rule::WallClock);

    let v = core_lint("let e = std::env::var(\"SEED\");\n");
    assert_eq!(v.len(), 1, "env reads are OS entropy");
    assert_eq!(v[0].rule, Rule::WallClock);

    // Negative: simulated time is the deterministic clock.
    assert!(core_lint("let t = sim.now();\n").is_empty());

    // Trailing annotation on the same line.
    let annotated =
        "let t = Instant::now(); // detlint: allow(wall-clock): reporting only\n";
    assert!(core_lint(annotated).is_empty());

    // Lib tier doesn't run R2 at all.
    assert!(lint_with_tier("util/x.rs", bad, Tier::Lib, false).is_empty());
}

#[test]
fn fixture_panic_positive_negative_annotated() {
    for bad in [
        "let x = opt.unwrap();\n",
        "let x = opt.expect(\"always some\");\n",
        "panic!(\"boom\");\n",
        "todo!()\n",
    ] {
        let v = core_lint(bad);
        assert_eq!(v.len(), 1, "{bad:?} must flag");
        assert_eq!(v[0].rule, Rule::Panic, "{bad:?}");
    }

    // Negative: `?` propagation and unwrap_or are fine.
    assert!(core_lint("let x = fallible()?;\n").is_empty());
    assert!(core_lint("let x = opt.unwrap_or(0);\n").is_empty());
    assert!(core_lint("let x = opt.unwrap_or_else(Vec::new);\n").is_empty());

    // Negative: test code is skipped entirely.
    let test_mod = "#[cfg(test)]\nmod tests {\n    fn f() { opt.unwrap(); }\n}\n";
    assert!(core_lint(test_mod).is_empty());

    // Annotated invariant passes.
    let annotated = "// detlint: allow(panic): len checked above\nlet x = v.pop().unwrap();\n";
    assert!(core_lint(annotated).is_empty());

    // The annotation names ONE rule: it must not leak onto others.
    let wrong_rule = "// detlint: allow(panic): why\nuse std::collections::HashMap;\n";
    let v = core_lint(wrong_rule);
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].rule, Rule::HashIter);
}

#[test]
fn fixture_thread_positive_negative_seam() {
    let bad = "let h = std::thread::spawn(work);\n";
    let v = core_lint(bad);
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].rule, Rule::Thread);

    let v = core_lint("use std::sync::mpsc::channel;\n");
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].rule, Rule::Thread);

    // The sanctioned seams may thread (the TSan job watches them).
    assert!(lint_with_tier("cluster/pool.rs", bad, Tier::Core, true).is_empty());
    assert!(lint_with_tier("vmcd/actuator.rs", bad, Tier::Lib, true).is_empty());

    // Lib tier (non-seam) is also confined.
    let v = lint_with_tier("util/x.rs", bad, Tier::Lib, false);
    assert_eq!(v.len(), 1);
}

#[test]
fn fixture_seeded_violation_fails_the_gate_shape() {
    // The acceptance fixture: a core file with one of each violation
    // produces exactly four findings, in line order, and the rendered
    // allowlist block round-trips through the parser.
    let seeded = "\
use std::collections::HashMap;
let t = Instant::now();
let x = opt.unwrap();
let h = std::thread::spawn(work);
";
    let v = core_lint(seeded);
    let rules: Vec<Rule> = v.iter().map(|f| f.rule).collect();
    assert_eq!(
        rules,
        vec![Rule::HashIter, Rule::WallClock, Rule::Panic, Rule::Thread]
    );
    assert_eq!(v.iter().map(|f| f.line).collect::<Vec<_>>(), vec![1, 2, 3, 4]);

    let rendered = render_allowlist(&v);
    let parsed = parse_allowlist(&rendered).expect("rendered block parses");
    assert_eq!(parsed.len(), 4);
    assert_eq!(parsed[0].file, "fixture.rs");
    assert_eq!(parsed[0].rule, Rule::HashIter);
}

#[test]
fn stale_allowlist_entries_are_detected() {
    // Build a throwaway tree with one real violation and an allowlist
    // holding that entry plus a stale one: run() must suppress the
    // first and surface the second.
    let dir = std::env::temp_dir().join(format!(
        "detlint-stale-{}-{}",
        std::process::id(),
        line!()
    ));
    let src = dir.join("rust").join("src");
    std::fs::create_dir_all(&src).expect("mkdir fixture tree");
    // hostsim/ is a core dir, so the fixture is linted as Tier::Core.
    std::fs::create_dir_all(src.join("hostsim")).expect("mkdir hostsim");
    std::fs::write(
        src.join("hostsim").join("fix.rs"),
        "let x = opt.unwrap();\n",
    )
    .expect("write fixture");
    std::fs::write(
        dir.join("rust").join("detlint.allow"),
        "hostsim/fix.rs:1: panic\nhostsim/gone.rs:9: panic\n",
    )
    .expect("write allowlist");

    let report = detlint::run(&dir).expect("fixture tree lints");
    std::fs::remove_dir_all(&dir).ok();

    assert!(report.violations.is_empty(), "{:?}", report.violations);
    assert_eq!(report.suppressed, 1);
    assert_eq!(report.stale.len(), 1, "{:?}", report.stale);
    assert_eq!(report.stale[0].file, "hostsim/gone.rs");
    assert!(!report.is_clean(), "stale entries must fail the gate");
}

// ---------------------------------------------------------------------
// 3. The two-process digest audit
// ---------------------------------------------------------------------

/// Run the built `vmcd` binary and return the `digest : <hex>` line.
fn run_digest(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_vmcd"))
        .args(args)
        .output()
        .expect("spawn vmcd");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "vmcd {:?} failed:\n{}\n{}",
        args,
        stdout,
        String::from_utf8_lossy(&out.stderr)
    );
    stdout
        .lines()
        .find(|l| l.starts_with("digest"))
        .unwrap_or_else(|| panic!("no digest line in:\n{stdout}"))
        .to_string()
}

#[test]
fn same_seed_runs_in_separate_processes_are_bit_identical() {
    // The strongest gate: same seed, two OS processes (different ASLR,
    // hash seeds, allocation order), migrator ON so the continuous
    // manager's planning path is inside the audited surface. Any
    // surviving HashMap iteration or address-keyed ordering in a
    // decision path flips a float somewhere and changes the digest.
    let args = [
        "cluster",
        "--hosts",
        "6",
        "--trace",
        "synth:vms=80,rate=6,life=30",
        "--migrator",
        "0.85:0.35:4:10",
        "--seed",
        "7",
        "--digest",
    ];
    let first = run_digest(&args);
    let second = run_digest(&args);
    assert_eq!(
        first, second,
        "two same-seed processes diverged — a nondeterminism leak is \
         inside the replay/migrator path (see DETERMINISM.md)"
    );

    // And the digest is seed-sensitive, not a constant.
    let mut other_args = args;
    other_args[8] = "8"; // --seed 8
    let other = run_digest(&other_args);
    assert_ne!(first, other, "digest ignores the seed");
}

#[test]
fn scenario_path_digest_is_stable_across_processes() {
    // Same audit through the random-scenario path (ClusterResult
    // digest) rather than trace replay.
    let args = [
        "cluster", "--hosts", "4", "--vms", "24", "--sr", "1.5", "--seed", "13",
        "--digest",
    ];
    let first = run_digest(&args);
    let second = run_digest(&args);
    assert_eq!(first, second, "scenario-path digest diverged across processes");
}

//! Integration: full scenario runs across all policies — the paper's
//! qualitative claims as executable assertions.

use vmcd::profiling::ProfileBank;
use vmcd::scenarios::{dynamic, latency, random, run_scenario, ScenarioResult};
use vmcd::testkit;
use vmcd::vmcd::scheduler::Policy;

fn run_all(
    spec: &vmcd::scenarios::ScenarioSpec,
) -> Vec<(Policy, ScenarioResult)> {
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    Policy::ALL
        .iter()
        .map(|&p| (p, run_scenario(&cfg, spec, p, bank).unwrap()))
        .collect()
}

fn by(results: &[(Policy, ScenarioResult)], p: Policy) -> &ScenarioResult {
    &results.iter().find(|(q, _)| *q == p).unwrap().1
}

#[test]
fn abstract_claim_cpu_time_reductions_up_to_50_percent() {
    // "Both methodologies achieve significant reductions of the CPU time
    // consumed, reaching up to 50%, while at the same time maintaining
    // workload performance."
    let spec = random::build(12, 0.5, 42).unwrap();
    let results = run_all(&spec);
    let rrs = by(&results, Policy::Rrs);
    for p in [Policy::Ras, Policy::Ias] {
        let r = by(&results, p);
        let saving = r.cpu_saving_vs(rrs);
        assert!(
            saving > 0.30,
            "{p:?} must save >30% CPU time at SR 0.5, got {saving:.3}"
        );
        let perf = r.perf_vs(rrs);
        assert!(perf > 0.90, "{p:?} perf ratio {perf:.3} below the 10% bound");
    }
}

#[test]
fn random_scenario_savings_grow_with_undersubscription() {
    // More headroom -> more consolidation opportunity.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let mut savings = Vec::new();
    for sr in [0.5, 2.0] {
        let spec = random::build(12, sr, 42).unwrap();
        let rrs = run_scenario(&cfg, &spec, Policy::Rrs, bank).unwrap();
        let ias = run_scenario(&cfg, &spec, Policy::Ias, bank).unwrap();
        savings.push(ias.cpu_saving_vs(&rrs));
    }
    assert!(
        savings[0] > savings[1],
        "relative savings at SR 0.5 ({:.3}) must exceed SR 2 ({:.3})",
        savings[0],
        savings[1]
    );
}

#[test]
fn latency_scenario_degradation_bounded() {
    // §V-C.2: "performance degradation never exceeding 10%" (up to SR 1.5);
    // allow a small margin for the simulated substrate.
    for sr in [0.5, 1.0, 1.5] {
        let spec = latency::build(12, sr, 42).unwrap();
        let results = run_all(&spec);
        let rrs = by(&results, Policy::Rrs);
        // IAS holds the paper's 10% bound cleanly; RAS packs harder on our
        // substrate and sits a few points over it (see EXPERIMENTS.md
        // §Deviations), so it gets a slightly wider band.
        let ias = by(&results, Policy::Ias).perf_vs(rrs);
        assert!(ias > 0.90, "IAS at SR {sr}: perf ratio {ias:.3}");
        let ras = by(&results, Policy::Ras).perf_vs(rrs);
        assert!(ras > 0.82, "RAS at SR {sr}: perf ratio {ras:.3}");
    }
}

#[test]
fn latency_scenario_ias_saves_at_least_30_percent() {
    // §V-C.2: "significant reduction in core hours consumption of at least
    // 30% and up to 50% for IAS in SR = 1".
    let spec = latency::build(12, 1.0, 42).unwrap();
    let results = run_all(&spec);
    let rrs = by(&results, Policy::Rrs);
    let saving = by(&results, Policy::Ias).cpu_saving_vs(rrs);
    assert!(saving > 0.30, "IAS saving {saving:.3}");
}

#[test]
fn dynamic_scenario_rrs_reserves_whole_server() {
    // §V-C.3: "RRS … needs to reserve the whole server continuously
    // regardless of VMs' state."
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = dynamic::build(6, 42).unwrap();
    let rrs = run_scenario(&cfg, &spec, Policy::Rrs, bank).unwrap();
    // From the first scheduling cycle on, (almost) the whole server stays
    // reserved: a core only parks once BOTH its batch VMs complete; idle
    // services keep theirs forever because RRS cannot detect idleness.
    let after_warmup: Vec<f64> = rrs
        .busy_series
        .points
        .iter()
        .filter(|(t, _)| *t > 60.0)
        .map(|(_, v)| *v)
        .collect();
    let min_busy = after_warmup.iter().copied().fold(f64::MAX, f64::min);
    let mean_busy = after_warmup.iter().sum::<f64>() / after_warmup.len() as f64;
    assert!(
        min_busy >= 9.0,
        "RRS dropped to {min_busy} busy cores in the dynamic scenario"
    );
    assert!(mean_busy > 11.0, "RRS mean busy {mean_busy:.2}");
    // …while IAS tracks the active envelope far below.
    let ias = run_scenario(&cfg, &spec, Policy::Ias, bank).unwrap();
    assert!(
        ias.busy_series.time_mean() < mean_busy - 3.0,
        "IAS mean busy {:.2} vs RRS {mean_busy:.2}",
        ias.busy_series.time_mean()
    );
}

#[test]
fn dynamic_scenario_schedulers_track_the_active_envelope() {
    // Figs. 4/5: the dynamic policies release cores between activation
    // batches — their mean busy-core count is well below RRS's 12.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    for batch in [6, 12] {
        let spec = dynamic::build(batch, 42).unwrap();
        for p in [Policy::Cas, Policy::Ras, Policy::Ias] {
            let r = run_scenario(&cfg, &spec, p, bank).unwrap();
            let mean_busy = r.busy_series.time_mean();
            assert!(
                mean_busy < 9.0,
                "{p:?} dynamic-{batch}: mean busy {mean_busy:.2} too close to 12"
            );
        }
    }
}

#[test]
fn dynamic_scenario_dynamic_policies_hold_perf_while_saving() {
    // Fig. 6 reports RAS +18% / IAS +13% perf over RRS; on our substrate
    // RRS is cushioned by the SMT yield so the dynamic policies land near
    // parity instead (EXPERIMENTS.md §Deviations) — but they must do so
    // while using FAR fewer core-hours, which is the figure's point.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = dynamic::build(6, 42).unwrap();
    let rrs = run_scenario(&cfg, &spec, Policy::Rrs, bank).unwrap();
    for p in [Policy::Ras, Policy::Ias] {
        let r = run_scenario(&cfg, &spec, p, bank).unwrap();
        let ratio = r.perf_vs(&rrs);
        assert!(
            ratio > 0.85,
            "{p:?} dynamic perf ratio {ratio:.3} collapsed below RRS"
        );
        let saving = r.cpu_saving_vs(&rrs);
        assert!(saving > 0.25, "{p:?} dynamic saving {saving:.3}");
    }
}

#[test]
fn results_are_deterministic_across_runs() {
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = random::build(12, 1.5, 7).unwrap();
    for p in Policy::ALL {
        let a = run_scenario(&cfg, &spec, p, bank).unwrap();
        let b = run_scenario(&cfg, &spec, p, bank).unwrap();
        assert_eq!(a.core_hours, b.core_hours, "{p:?}");
        assert_eq!(a.avg_perf, b.avg_perf, "{p:?}");
        assert_eq!(a.repin_count, b.repin_count, "{p:?}");
    }
}

#[test]
fn oversubscribed_host_still_completes_and_accounts() {
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = random::build(12, 2.0, 99).unwrap();
    for p in Policy::ALL {
        let r = run_scenario(&cfg, &spec, p, bank).unwrap();
        assert!(r.completion_time < cfg.sim.max_time, "{p:?} hit max_time");
        assert!(r.avg_perf > 0.3 && r.avg_perf <= 1.0, "{p:?} perf {}", r.avg_perf);
        // Busy cores never exceed the physical core count.
        assert!(r.busy_series.max() <= 12.0);
    }
}

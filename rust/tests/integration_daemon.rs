//! Integration: the VMCd daemon loop (Alg. 1) against the simulated
//! hypervisor — idle consolidation, arrival placement, monitor windows,
//! profiling persistence.

use vmcd::hostsim::{ActivityModel, Hypervisor, SimEngine, Vm, VmId, VmState};
use vmcd::profiling::ProfileBank;
use vmcd::scenarios::{dynamic, run_scenario};
use vmcd::testkit;
use vmcd::vmcd::scheduler::{self, Policy};
use vmcd::vmcd::{daemon::IDLE_CORE, Daemon};
use vmcd::workloads::{WorkloadClass, ALL_CLASSES};

fn resident(id: u32, class: WorkloadClass, activity: ActivityModel, core: usize) -> Vm {
    let mut vm = Vm::new(VmId(id), class, 0.0, activity);
    vm.state = VmState::Running;
    vm.started = Some(0.0);
    vm.pinned = Some(core);
    vm
}

fn daemon_for(policy: Policy) -> Daemon {
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let sched = scheduler::build(policy, bank, cfg.sched.ras_threshold, None);
    Daemon::new(cfg.sched.clone(), sched, cfg.host.cores)
}

#[test]
fn idle_churn_moves_vms_between_core0_and_running_set() {
    // A service with a 50% duty cycle must oscillate in the monitor's
    // view: idle-flagged (and parked on core 0, with running workloads
    // kept off the idle core) during quiet phases, running otherwise.
    let cfg = testkit::quiet_config();
    let service = resident(
        0,
        WorkloadClass::LampHeavy,
        ActivityModel::OnOff {
            period: 120.0,
            duty: 0.5,
            phase: 0.0,
        },
        3,
    );
    let hog = resident(1, WorkloadClass::Blackscholes, ActivityModel::AlwaysOn, 4);
    let mut engine = SimEngine::new(cfg, vec![service, hog]);
    let mut daemon = daemon_for(Policy::Ras);
    let mut probe = vmcd::vmcd::Monitor::new(0.025);

    let mut idle_ticks = 0;
    let mut running_ticks = 0;
    for _ in 0..360 {
        daemon.maybe_cycle(&mut engine).unwrap();
        engine.step();
        let snap = probe.poll(&engine);
        let view = snap.domains.iter().find(|d| d.id == VmId(0)).unwrap();
        if view.idle {
            idle_ticks += 1;
            // After the next cycle the daemon parks it on core 0 and keeps
            // the hog off the idle core.
        } else {
            running_ticks += 1;
        }
    }
    assert!(idle_ticks > 60, "service never went idle ({idle_ticks})");
    assert!(running_ticks > 60, "service never ran ({running_ticks})");

    // Land in a quiet phase and force a cycle: parked on core 0, the hog
    // elsewhere.
    while engine.vms[0].is_active(engine.t) || engine.vms[0].cpu_window_avg() >= 0.025 {
        engine.step();
    }
    daemon.run_cycle(&mut engine).unwrap();
    assert_eq!(engine.vms[0].pinned, Some(IDLE_CORE));
    assert_ne!(engine.vms[1].pinned, Some(IDLE_CORE));
}

#[test]
fn finished_batch_jobs_release_their_cores() {
    let cfg = testkit::quiet_config();
    let batch = resident(0, WorkloadClass::Blackscholes, ActivityModel::AlwaysOn, 2);
    let work = batch.spec.perf.work_units;
    let mut engine = SimEngine::new(cfg, vec![batch]);
    let mut daemon = daemon_for(Policy::Ias);
    let mut steps = 0;
    while engine.vms[0].state == VmState::Running && steps < 10_000 {
        daemon.maybe_cycle(&mut engine).unwrap();
        engine.step();
        steps += 1;
    }
    assert_eq!(engine.vms[0].state, VmState::Finished);
    assert!(engine.t >= work);
    // After completion the host runs idle: busy cores drop to 0.
    engine.step();
    let (_, busy) = *engine.ledger.busy_series.points.last().unwrap();
    assert_eq!(busy, 0.0);
}

#[test]
fn monitor_window_lags_idle_transitions() {
    // Idle detection uses the windowed average: a VM that just went quiet
    // is still "running" until the window drains — no flapping.
    let cfg = testkit::quiet_config();
    let window = cfg.sched.monitor_window;
    let service = resident(
        0,
        WorkloadClass::LampHeavy,
        ActivityModel::Windows(vec![(0.0, 100.0)]),
        1,
    );
    let mut engine = SimEngine::new(cfg, vec![service]);
    let mut daemon = daemon_for(Policy::Ras);
    // Run through the active phase.
    for _ in 0..100 {
        daemon.maybe_cycle(&mut engine).unwrap();
        engine.step();
    }
    // Just after going idle, the windowed average is still high.
    let snap = daemon.monitor.poll(&engine);
    assert!(!snap.domains[0].idle, "idle flagged instantly (flapping risk)");
    for _ in 0..(window as usize + 2) {
        engine.step();
    }
    let snap = daemon.monitor.poll(&engine);
    assert!(snap.domains[0].idle, "idle not detected after the window");
}

#[test]
fn profile_bank_round_trips_through_disk() {
    let bank = testkit::shared_bank();
    let path = std::env::temp_dir().join("vmcd_test_profiles.json");
    bank.save(path.to_str().unwrap()).unwrap();
    let loaded = ProfileBank::load(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded.classes, bank.classes);
    for i in 0..bank.n() {
        for j in 0..bank.n() {
            assert!((loaded.s[i][j] - bank.s[i][j]).abs() < 1e-9);
        }
        for m in 0..4 {
            assert!((loaded.u[i][m] - bank.u[i][m]).abs() < 1e-9);
        }
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn dynamic_scenario_idle_consolidation_is_visible_in_repins() {
    // The dynamic policies must actually re-pin as batches activate and
    // deactivate; RRS must not re-pin at all after initial placement.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let spec = dynamic::build(6, 42).unwrap();
    let rrs = run_scenario(&cfg, &spec, Policy::Rrs, bank).unwrap();
    let ias = run_scenario(&cfg, &spec, Policy::Ias, bank).unwrap();
    assert_eq!(rrs.repin_count, 24, "RRS re-pins only at arrival");
    assert!(
        ias.repin_count > 50,
        "IAS must keep re-pinning with phase churn, got {}",
        ias.repin_count
    );
}

#[test]
fn long_lived_state_matches_rebuild_through_100_mixed_events() {
    // The event-API acceptance test: a host with staggered arrivals,
    // on/off services (idle/wake churn) and finite batch jobs
    // (departures) is driven through the event-driven daemon for a long
    // window. After EVERY step the long-lived placement state must agree
    // with a from-scratch rebuild, and the run must actually exercise
    // well over 100 lifecycle events.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let sched = scheduler::build(Policy::Ias, bank, cfg.sched.ras_threshold, None);
    let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);

    let mut vms = Vec::new();
    for i in 0..12u32 {
        let activity = match i % 3 {
            0 => ActivityModel::AlwaysOn,
            1 => ActivityModel::OnOff {
                period: 80.0,
                duty: 0.5,
                phase: (i as f64) * 7.0,
            },
            _ => ActivityModel::Windows(vec![(0.0, 150.0 + (i as f64) * 40.0)]),
        };
        let class = ALL_CLASSES[i as usize % ALL_CLASSES.len()];
        vms.push(Vm::new(VmId(i), class, (i as f64) * 15.0, activity));
    }
    let mut engine = SimEngine::new(cfg, vms);

    for _ in 0..2400 {
        for id in engine.process_arrivals() {
            daemon.on_arrival(&mut engine, id).unwrap();
        }
        daemon.step(&mut engine).unwrap();
        engine.step();
        assert!(
            daemon.state_matches_rebuild(1e-6),
            "long-lived state drifted from event deltas at t={}",
            engine.t
        );
    }
    assert!(
        daemon.events_handled >= 100,
        "churn too quiet to prove the event API: {} events",
        daemon.events_handled
    );
    // The placement state tracks exactly the non-idle residents. (One
    // more daemon step so its view covers the final engine tick.)
    daemon.step(&mut engine).unwrap();
    let placed = daemon.placement_state().placed();
    let running = daemon.monitor.poll(&engine).running_workloads().len();
    assert_eq!(placed, running, "state members must be the running set");
}

#[test]
fn monitor_polled_once_per_step_even_with_arrivals() {
    // Regression for the double-poll: the old daemon polled in both
    // on_arrival and run_cycle; the event API polls exactly once per
    // step, and arrival placement reuses per-domain stats instead.
    let cfg = testkit::quiet_config();
    let bank = testkit::shared_bank();
    let sched = scheduler::build(Policy::Ras, bank, cfg.sched.ras_threshold, None);
    let mut daemon = Daemon::new(cfg.sched.clone(), sched, cfg.host.cores);
    let mut vms = Vec::new();
    for i in 0..6u32 {
        vms.push(Vm::new(
            VmId(i),
            WorkloadClass::Hadoop,
            (i as f64) * 5.0,
            ActivityModel::AlwaysOn,
        ));
    }
    let mut engine = SimEngine::new(cfg, vms);
    let steps = 60u64;
    for _ in 0..steps {
        for id in engine.process_arrivals() {
            daemon.on_arrival(&mut engine, id).unwrap();
        }
        daemon.step(&mut engine).unwrap();
        engine.step();
    }
    assert_eq!(
        daemon.monitor.poll_count(),
        steps,
        "exactly one monitor pass per step"
    );
}

#[test]
fn daemon_survives_empty_host() {
    let cfg = testkit::quiet_config();
    let mut engine = SimEngine::new(cfg, vec![]);
    let mut daemon = daemon_for(Policy::Ias);
    for _ in 0..50 {
        daemon.maybe_cycle(&mut engine).unwrap();
        engine.step();
    }
    assert_eq!(engine.ledger.repin_count, 0);
    assert_eq!(engine.busy_cores(), 0);
}

#[test]
fn hypervisor_rejects_bad_pins_without_corrupting_state() {
    let cfg = testkit::quiet_config();
    let vm = resident(0, WorkloadClass::Hadoop, ActivityModel::AlwaysOn, 0);
    let mut engine = SimEngine::new(cfg, vec![vm]);
    assert!(engine.pin_vcpu(VmId(0), 999).is_err());
    assert_eq!(engine.vms[0].pinned, Some(0));
    assert!(engine.pin_vcpu(VmId(42), 1).is_err());
    assert_eq!(engine.ledger.repin_count, 0);
}

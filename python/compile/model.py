# L2: JAX compute graphs lowered to the artifacts the rust runtime executes.
#
# Three entry points, each calling an L1 Pallas kernel:
#   * score_fn        — fused RAS/IAS scheduler scoring over all cores
#                       (the VMCd decision hot path, paper Eq. 2-4).
#   * blackscholes_fn — the CPU-intensive workload class's real compute.
#   * jacobi_fn       — the membw-intensive workload class's real compute;
#                       SWEEPS_PER_CALL sweeps fused in one executable via
#                       lax.fori_loop so the rust side pays one dispatch for
#                       a whole simulation quantum.
#
# Everything here is shape-static: the rust runtime pads its live state to
# these shapes (see rust/src/runtime/artifacts.rs).
import jax
import jax.numpy as jnp

from .kernels import blackscholes as _bs
from .kernels import jacobi as _jacobi
from .kernels import score as _score

SWEEPS_PER_CALL = 10


def score_fn(assign, u, s, cand_u, s_vc, s_cv, thr):
    """Returns (ol_before, ol_after, ic_before, ic_after), f32[C,1] each."""
    return _score.score(assign, u, s, cand_u, s_vc, s_cv, thr)


def blackscholes_fn(spot, strike, ttm, rate, vol):
    """Returns (call, put) prices plus a checksum used by the host simulator
    as the unit-of-work receipt."""
    call, put = _bs.blackscholes(spot, strike, ttm, rate, vol)
    checksum = jnp.sum(call) + jnp.sum(put)
    return call, put, checksum.reshape(1)


def jacobi_fn(grid):
    """SWEEPS_PER_CALL Jacobi sweeps; returns (grid', residual-norm[1])."""
    def body(_, g):
        return _jacobi.jacobi_sweep(g)

    out = jax.lax.fori_loop(0, SWEEPS_PER_CALL, body, grid)
    resid = jnp.sqrt(jnp.sum((out - grid) ** 2)).reshape(1)
    return out, resid


def specs():
    """ShapeDtypeStructs for each entry point, keyed by artifact name."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    c, v, m = _score.C_MAX, _score.V_MAX, _score.M_METRICS
    n = _bs.N_OPTIONS
    return {
        "score": (
            score_fn,
            (
                sd((c, v), f32),   # assign
                sd((v, m), f32),   # U
                sd((v, v), f32),   # S
                sd((1, m), f32),   # cand_u
                sd((1, v), f32),   # s_vc
                sd((1, v), f32),   # s_cv
                sd((1, 1), f32),   # thr
            ),
        ),
        "blackscholes": (
            blackscholes_fn,
            tuple(sd((n,), f32) for _ in range(5)),
        ),
        "jacobi": (
            jacobi_fn,
            (sd((_jacobi.H, _jacobi.W), f32),),
        ),
    }

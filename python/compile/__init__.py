# Build-time-only package: JAX model + Pallas kernels + AOT lowering.
# Nothing in here is imported at runtime — rust loads artifacts/*.hlo.txt.

# AOT lowering: JAX model -> HLO *text* artifacts for the rust runtime.
#
# HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()) is the
# interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
# instruction ids which xla_extension 0.5.1 (what the published `xla` 0.1.6
# crate links) rejects with `proto.id() <= INT_MAX`. The HLO text parser
# reassigns ids, so text round-trips cleanly. See /opt/xla-example.
#
# Usage: python -m compile.aot --out-dir ../artifacts
import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, in_specs) in model.specs().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_shapes, tuple):
            out_shapes = (out_shapes,)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
            ],
            "outputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in out_shapes
            ],
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")

    man_path = os.path.join(args.out_dir, "manifest.json")
    existing = {}
    if args.only and os.path.exists(man_path):
        with open(man_path) as f:
            existing = json.load(f)
    existing.update(manifest)
    with open(man_path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"manifest -> {man_path}")


if __name__ == "__main__":
    main()

# L1 Pallas kernel: Black-Scholes closed-form option pricing.
#
# This is the arithmetic core of the paper's CPU-intensive workload class
# (PARSEC `blackscholes`, §V-B): a FLOP-bound, embarrassingly-parallel sweep
# over a batch of European options. The rust host simulator executes this
# kernel through PJRT when a `Blackscholes` VM runs in real-compute mode, so
# the "VM" burns genuine compute through the full three-layer stack.
#
# TPU mapping (DESIGN.md §Hardware-Adaptation): pure element-wise VPU work,
# no MXU. The batch is tiled into BLOCK-sized lanes-aligned chunks; each grid
# step streams one block HBM->VMEM (5 inputs + 2 outputs, BLOCK=2048 f32
# => 56 KiB VMEM per step).
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_OPTIONS = 65536  # compiled batch size — see runtime/artifacts.rs
BLOCK = 2048

_INV_SQRT2 = 0.7071067811865476


def _erf(x):
    """Abramowitz & Stegun 7.1.26 polynomial erf (|err| < 1.5e-7).

    jax.lax.erf lowers to the `erf` HLO opcode, which the pinned
    xla_extension 0.5.1 text parser predates — this expansion lowers to
    plain mul/exp/select ops that round-trip through HLO text.
    """
    a1, a2, a3, a4, a5 = (
        0.254829592,
        -0.284496736,
        1.421413741,
        -1.453152027,
        1.061405429,
    )
    p = 0.3275911
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = t * (a1 + t * (a2 + t * (a3 + t * (a4 + t * a5))))
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _ncdf(x):
    return 0.5 * (1.0 + _erf(x * _INV_SQRT2))


def _bs_kernel(spot_ref, strike_ref, ttm_ref, rate_ref, vol_ref,
               call_ref, put_ref):
    s = spot_ref[...]
    k = strike_ref[...]
    t = ttm_ref[...]
    r = rate_ref[...]
    v = vol_ref[...]

    sqrt_t = jnp.sqrt(t)
    vst = v * sqrt_t
    d1 = (jnp.log(s / k) + (r + 0.5 * v * v) * t) / vst
    d2 = d1 - vst
    disc = k * jnp.exp(-r * t)

    call_ref[...] = s * _ncdf(d1) - disc * _ncdf(d2)
    put_ref[...] = disc * _ncdf(-d2) - s * _ncdf(-d1)


def blackscholes(spot, strike, ttm, rate, vol):
    """Price a batch of European options. Returns (call, put), f32[N]."""
    n = spot.shape[0]
    assert n % BLOCK == 0, f"batch {n} must be a multiple of {BLOCK}"
    blk = pl.BlockSpec((BLOCK,), lambda i: (i,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        _bs_kernel,
        grid=(n // BLOCK,),
        in_specs=[blk] * 5,
        out_specs=(blk, blk),
        out_shape=(out, out),
        interpret=True,
    )(spot, strike, ttm, rate, vol)

# Pure-numpy correctness oracles for the Pallas kernels.
#
# Deliberately written as naive, loop-heavy numpy — an independent code path
# from the vectorised kernels, so agreement is meaningful.
import math

import numpy as np


def score_ref(assign, u, s, cand_u, s_vc, s_cv, thr):
    """Naive per-core reference for kernels/score.py.

    assign: f32[C,V] one-hot; u: f32[V,M]; s: f32[V,V]; cand_u: f32[1,M];
    s_vc, s_cv: f32[1,V]; thr: f32[1,1].
    Returns (ol_before, ol_after, ic_before, ic_after), each f32[C,1].
    """
    eps = 1e-6
    s = np.maximum(np.asarray(s, np.float64), eps)
    s_vc = np.maximum(np.asarray(s_vc, np.float64).ravel(), eps)
    s_cv = np.maximum(np.asarray(s_cv, np.float64).ravel(), eps)
    u = np.asarray(u, np.float64)
    cand_u = np.asarray(cand_u, np.float64).ravel()
    thr = float(np.asarray(thr).ravel()[0])
    c_n, v_n = assign.shape

    ol_b = np.zeros((c_n, 1))
    ol_a = np.zeros((c_n, 1))
    ic_b = np.zeros((c_n, 1))
    ic_a = np.zeros((c_n, 1))

    def wi(i, others, with_cand):
        """Paper Eq. 3 for resident VM i with co-runner set `others`."""
        ssum, sprod = 0.0, 1.0
        for j in others:
            if j == i:
                continue
            ssum += s[i, j]
            sprod *= s[i, j]
        if with_cand:
            ssum += s_vc[i]
            sprod *= s_vc[i]
        return 0.5 * (ssum + sprod)

    for c in range(c_n):
        members = [v for v in range(v_n) if assign[c, v] > 0.5]
        # RAS overload (Eq. 2)
        for m in range(u.shape[1]):
            load = sum(u[v, m] for v in members)
            ol_b[c] += max(0.0, load - thr)
            ol_a[c] += max(0.0, load + cand_u[m] - thr)
        # IAS interference (Eq. 3 + 4)
        ic_b[c] = max((wi(i, members, False) for i in members), default=0.0)
        cs, cp = 0.0, 1.0
        for j in members:
            cs += s_cv[j]
            cp *= s_cv[j]
        wi_cand = 0.5 * (cs + cp)
        ic_a[c] = max(
            max((wi(i, members, True) for i in members), default=0.0), wi_cand
        )
    return (
        ol_b.astype(np.float32),
        ol_a.astype(np.float32),
        ic_b.astype(np.float32),
        ic_a.astype(np.float32),
    )


def blackscholes_ref(spot, strike, ttm, rate, vol):
    """Scalar-loop reference for kernels/blackscholes.py."""
    n = len(spot)
    call = np.zeros(n)
    put = np.zeros(n)
    for i in range(n):
        s, k, t, r, v = (
            float(spot[i]),
            float(strike[i]),
            float(ttm[i]),
            float(rate[i]),
            float(vol[i]),
        )
        st = math.sqrt(t)
        d1 = (math.log(s / k) + (r + 0.5 * v * v) * t) / (v * st)
        d2 = d1 - v * st
        ncdf = lambda x: 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))
        disc = k * math.exp(-r * t)
        call[i] = s * ncdf(d1) - disc * ncdf(d2)
        put[i] = disc * ncdf(-d2) - s * ncdf(-d1)
    return call.astype(np.float32), put.astype(np.float32)


def jacobi_ref(grid, sweeps=1):
    """Loop reference for kernels/jacobi.py (PolyBench jacobi-2d)."""
    a = np.asarray(grid, np.float64).copy()
    h, w = a.shape
    for _ in range(sweeps):
        b = a.copy()
        for i in range(1, h - 1):
            for j in range(1, w - 1):
                b[i, j] = 0.2 * (
                    a[i, j] + a[i - 1, j] + a[i + 1, j] + a[i, j - 1] + a[i, j + 1]
                )
        a = b
    return a.astype(np.float32)

# L1: Pallas kernels for the paper's compute hot-spots.
# Import the submodules (not the entry-point functions) so module names and
# function names don't shadow each other: use kernels.score.score(...), etc.
from . import blackscholes, jacobi, ref, score  # noqa: F401

# L1 Pallas kernel: fused scheduler scoring for RAS + IAS.
#
# Given the current vCPU->core assignment, the utilisation matrix U
# (paper §IV-A) and the pairwise-slowdown matrix S (paper Eq. 1), compute
# for EVERY core in one call:
#   * ol_before[c], ol_after[c] — the RAS core-overload metric (paper Eq. 2)
#     without / with a candidate workload added to core c,
#   * ic_before[c], ic_after[c] — the IAS core interference (paper Eq. 3+4)
#     without / with the candidate.
#
# The rust coordinator pads its live state to the fixed compiled shapes
# (C_MAX cores, V_MAX resident VMs, M_METRICS resources). Padding is inert:
# padded VMs have assign==0 rows, S==1 (log S == 0) so they contribute
# nothing to any sum/product; padded metric columns carry zero utilisation.
#
# TPU mapping note (DESIGN.md §Hardware-Adaptation): the heavy ops are two
# [C,V]x[V,V] matmuls — MXU-shaped work. Everything fits in one VMEM-resident
# block (32x64 + 64x64 f32 ~= 25 KiB), so no grid is needed; the kernel is a
# single fused block.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Compiled shapes — keep in sync with rust/src/runtime/artifacts.rs.
C_MAX = 32  # physical cores
V_MAX = 64  # resident VMs
M_METRICS = 4  # CPU, DiskIO, NetIO, MemBW (paper §III)

_EPS = 1e-6


def _score_kernel(
    assign_ref,  # f32[C, V]  one-hot: vm v pinned on core c
    u_ref,       # f32[V, M]  per-VM utilisation profile (fraction of host)
    s_ref,       # f32[V, V]  pairwise slowdown S[i, j] (>= _EPS)
    cand_u_ref,  # f32[1, M]  candidate workload utilisation
    s_vc_ref,    # f32[1, V]  slowdown of resident VM i when co-run w/ cand
    s_cv_ref,    # f32[1, V]  slowdown of cand when co-run w/ resident VM j
    thr_ref,     # f32[1, 1]  RAS threshold (paper: 1.2)
    ol_b_ref,    # f32[C, 1] out
    ol_a_ref,    # f32[C, 1] out
    ic_b_ref,    # f32[C, 1] out
    ic_a_ref,    # f32[C, 1] out
):
    assign = assign_ref[...]
    u = u_ref[...]
    s = jnp.maximum(s_ref[...], _EPS)
    cand_u = cand_u_ref[...]
    s_vc = jnp.maximum(s_vc_ref[...], _EPS)
    s_cv = jnp.maximum(s_cv_ref[...], _EPS)
    thr = thr_ref[0, 0]

    # ---- RAS overload (Eq. 2): per-core composite load beyond `thr`. ----
    core_u = jnp.dot(assign, u, preferred_element_type=jnp.float32)  # [C,M]
    ol_b_ref[...] = jnp.sum(jnp.maximum(core_u - thr, 0.0), axis=1, keepdims=True)
    ol_a_ref[...] = jnp.sum(
        jnp.maximum(core_u + cand_u - thr, 0.0), axis=1, keepdims=True
    )

    # ---- IAS interference (Eq. 3): WI_i = (sum_{j!=i} S[i,j]
    #                                         + prod_{j!=i} S[i,j]) / 2 ----
    # rs[c, i] = sum_{j on c} S[i, j]; subtract the self term S[i, i] so the
    # sum runs over co-runners only (see the worked example in §IV-B.2 of
    # the paper: 3 co-runners with S == 1 must yield WI == 2).
    logs = jnp.log(s)
    v = assign.shape[1]
    eye = (
        jax.lax.broadcasted_iota(jnp.int32, (v, v), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (v, v), 1)
    )
    sdiag = jnp.sum(jnp.where(eye, s, 0.0), axis=1)[None, :]       # [1,V]
    logsdiag = jnp.sum(jnp.where(eye, logs, 0.0), axis=1)[None, :]

    rs = jnp.dot(assign, s.T, preferred_element_type=jnp.float32)     # [C,V]
    lp = jnp.dot(assign, logs.T, preferred_element_type=jnp.float32)  # [C,V]
    active = assign > 0.5

    # Subtract the self term unconditionally; rows where vm i is inactive on
    # core c are masked out before the max, so the garbage there is inert.
    rs_ex = rs - sdiag
    lp_ex = lp - logsdiag
    wi_b = 0.5 * (rs_ex + jnp.exp(lp_ex))
    ic_b_ref[...] = jnp.max(
        jnp.where(active, wi_b, 0.0), axis=1, keepdims=True
    )

    # After adding the candidate to core c: every resident VM on c gains one
    # co-runner (the candidate), and the candidate itself gets a WI.
    wi_a_exist = 0.5 * (rs_ex + s_vc + jnp.exp(lp_ex + jnp.log(s_vc)))
    rs_cand = jnp.sum(assign * s_cv, axis=1, keepdims=True)            # [C,1]
    lp_cand = jnp.sum(assign * jnp.log(s_cv), axis=1, keepdims=True)
    wi_cand = 0.5 * (rs_cand + jnp.exp(lp_cand))
    ic_a_ref[...] = jnp.maximum(
        jnp.max(jnp.where(active, wi_a_exist, 0.0), axis=1, keepdims=True),
        wi_cand,
    )


def score(assign, u, s, cand_u, s_vc, s_cv, thr):
    """Fused RAS+IAS scoring over all cores.

    Returns (ol_before, ol_after, ic_before, ic_after), each f32[C, 1].
    """
    c = assign.shape[0]
    out = jax.ShapeDtypeStruct((c, 1), jnp.float32)
    return pl.pallas_call(
        _score_kernel,
        out_shape=(out, out, out, out),
        interpret=True,
    )(assign, u, s, cand_u, s_vc, s_cv, thr)

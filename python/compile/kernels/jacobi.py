# L1 Pallas kernel: 2-D 5-point Jacobi sweep (PolyBench/C `jacobi-2d`).
#
# The paper's CPU + memory-bandwidth-intensive HPC workload class (§V-B).
# One call performs one sweep: interior points become the 5-point average
# (0.2 coefficient as in PolyBench), boundary rows/cols are held fixed.
#
# TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is tiled into
# row slabs; the halo exchange a CUDA version would do through shared
# memory is expressed through three overlapping BlockSpecs on the *same*
# input operand (previous / current / next slab), so each grid step keeps
# only 3*BH rows + 1 output slab in VMEM (BH=32, W=256 f32 => 128 KiB).
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

H = 256  # compiled grid height — see runtime/artifacts.rs
W = 256  # compiled grid width
BH = 32  # rows per slab
_NBLK = H // BH


def _jacobi_kernel(prev_ref, cur_ref, nxt_ref, out_ref):
    i = pl.program_id(0)
    prev = prev_ref[...]  # slab i-1 (clamped at the top edge)
    cur = cur_ref[...]    # slab i
    nxt = nxt_ref[...]    # slab i+1 (clamped at the bottom edge)

    # Assemble the haloed slab: last row of prev, cur, first row of nxt.
    # At the clamped edges the halo rows are wrong, but those output rows
    # are boundary rows and get overwritten by `cur` below.
    slab = jnp.concatenate([prev[-1:, :], cur, nxt[:1, :]], axis=0)

    up = slab[:-2, :]
    down = slab[2:, :]
    left = jnp.concatenate([cur[:, :1], cur[:, :-1]], axis=1)
    right = jnp.concatenate([cur[:, 1:], cur[:, -1:]], axis=1)
    res = 0.2 * (cur + up + down + left + right)

    # Boundary condition: global first/last rows and first/last columns
    # keep their original values.
    grow = i * BH + jax.lax.broadcasted_iota(jnp.int32, (BH, W), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (BH, W), 1)
    border = (grow == 0) | (grow == H - 1) | (gcol == 0) | (gcol == W - 1)
    out_ref[...] = jnp.where(border, cur, res)


def jacobi_sweep(grid):
    """One Jacobi sweep over an f32[H, W] grid."""
    assert grid.shape == (H, W), grid.shape
    slab = lambda im: pl.BlockSpec((BH, W), im)
    return pl.pallas_call(
        _jacobi_kernel,
        grid=(_NBLK,),
        in_specs=[
            slab(lambda i: (jnp.maximum(i - 1, 0), 0)),
            slab(lambda i: (i, 0)),
            slab(lambda i: (jnp.minimum(i + 1, _NBLK - 1), 0)),
        ],
        out_specs=slab(lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=True,
    )(grid, grid, grid)

import os
import sys

# Make the build-time `compile` package importable when pytest is invoked
# either from the repo root or from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# AOT pipeline: HLO text is parseable-era, manifest is consistent with the
# compiled shapes the rust runtime hardcodes.
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
PYDIR = os.path.dirname(HERE)
REPO = os.path.dirname(PYDIR)
ARTIFACTS = os.path.join(REPO, "artifacts")


def ensure_artifacts():
    if not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ARTIFACTS],
            cwd=PYDIR,
            check=True,
        )


class TestManifest:
    def test_manifest_lists_all_artifacts_with_hashes(self):
        ensure_artifacts()
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        assert set(man) >= {"score", "blackscholes", "jacobi"}
        for name, entry in man.items():
            path = os.path.join(ARTIFACTS, entry["file"])
            assert os.path.exists(path), name
            assert len(entry["sha256"]) == 64
            assert entry["inputs"] and entry["outputs"]

    def test_shapes_match_rust_runtime_constants(self):
        # Mirror of rust/src/runtime/mod.rs::shapes — a drift here breaks
        # the rust runtime's padding logic.
        ensure_artifacts()
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            man = json.load(f)
        assert man["score"]["inputs"][0]["shape"] == [32, 64]
        assert man["score"]["inputs"][1]["shape"] == [64, 4]
        assert len(man["score"]["outputs"]) == 4
        assert man["blackscholes"]["inputs"][0]["shape"] == [65536]
        assert man["jacobi"]["inputs"][0]["shape"] == [256, 256]


class TestHloText:
    def test_hlo_is_text_and_free_of_new_opcodes(self):
        """xla_extension 0.5.1's parser predates several opcodes (erf,
        topk, …); the lowered text must avoid the ones we know break."""
        ensure_artifacts()
        for name in ["score", "blackscholes", "jacobi"]:
            with open(os.path.join(ARTIFACTS, f"{name}.hlo.txt")) as f:
                text = f.read()
            assert text.startswith("HloModule"), name
            for opcode in [" erf(", " topk(", " tan("]:
                assert opcode not in text, f"{name} uses unparseable {opcode!r}"

    def test_entry_computation_returns_tuple(self):
        # aot.py lowers with return_tuple=True; the rust side unpacks with
        # to_tuple().
        ensure_artifacts()
        with open(os.path.join(ARTIFACTS, "score.hlo.txt")) as f:
            text = f.read()
        assert "ENTRY" in text
        root_line = [l for l in text.splitlines() if "ROOT" in l]
        assert any("tuple" in l for l in root_line), "entry must return a tuple"

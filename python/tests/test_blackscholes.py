# Pallas Black-Scholes kernel vs the math.erf scalar-loop oracle.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blackscholes as bs
from compile.kernels import ref


def run_kernel(spot, strike, ttm, rate, vol):
    import jax.numpy as jnp

    args = [jnp.asarray(a, jnp.float32) for a in (spot, strike, ttm, rate, vol)]
    call, put = bs.blackscholes(*args)
    return np.asarray(call), np.asarray(put)


def random_batch(rng, n):
    return (
        rng.uniform(5.0, 200.0, n).astype(np.float32),     # spot
        rng.uniform(5.0, 200.0, n).astype(np.float32),     # strike
        rng.uniform(0.05, 3.0, n).astype(np.float32),      # ttm (years)
        rng.uniform(0.0, 0.1, n).astype(np.float32),       # rate
        rng.uniform(0.05, 0.9, n).astype(np.float32),      # vol
    )


class TestBlackscholes:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_matches_oracle(self, seed):
        rng = np.random.default_rng(seed)
        batch = random_batch(rng, bs.BLOCK)  # one block
        call, put = run_kernel(*batch)
        call_w, put_w = ref.blackscholes_ref(*[b[:64] for b in batch])
        np.testing.assert_allclose(call[:64], call_w, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(put[:64], put_w, rtol=2e-3, atol=2e-3)

    def test_multi_block_grid(self):
        """Grid iteration must tile the batch correctly (4 blocks)."""
        rng = np.random.default_rng(11)
        n = 4 * bs.BLOCK
        batch = random_batch(rng, n)
        call, put = run_kernel(*batch)
        # Spot-check one element inside each block against the oracle.
        for blk in range(4):
            i = blk * bs.BLOCK + 17
            cw, pw = ref.blackscholes_ref(*[b[i : i + 1] for b in batch])
            assert call[i] == pytest.approx(cw[0], rel=2e-3, abs=2e-3)
            assert put[i] == pytest.approx(pw[0], rel=2e-3, abs=2e-3)

    def test_put_call_parity(self):
        """C - P = S - K e^{-rT} — an analytic invariant of the model."""
        rng = np.random.default_rng(3)
        spot, strike, ttm, rate, vol = random_batch(rng, bs.BLOCK)
        call, put = run_kernel(spot, strike, ttm, rate, vol)
        lhs = call - put
        rhs = spot - strike * np.exp(-rate * ttm)
        np.testing.assert_allclose(lhs, rhs, rtol=3e-3, atol=3e-3)

    def test_deep_itm_call_approaches_intrinsic(self):
        n = bs.BLOCK
        spot = np.full(n, 150.0, np.float32)
        strike = np.full(n, 50.0, np.float32)
        ttm = np.full(n, 0.1, np.float32)
        rate = np.full(n, 0.01, np.float32)
        vol = np.full(n, 0.1, np.float32)
        call, _ = run_kernel(spot, strike, ttm, rate, vol)
        intrinsic = 150.0 - 50.0 * np.exp(-0.01 * 0.1)
        np.testing.assert_allclose(call, intrinsic, rtol=1e-3)

    def test_compiled_batch_size(self):
        rng = np.random.default_rng(5)
        batch = random_batch(rng, bs.N_OPTIONS)
        call, put = run_kernel(*batch)
        assert call.shape == (bs.N_OPTIONS,)
        assert np.all(call >= -1e-3) and np.all(put >= -1e-3)
        assert np.all(np.isfinite(call)) and np.all(np.isfinite(put))

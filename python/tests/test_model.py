# L2 model graphs: shapes, fused-sweep semantics, spec table consistency.
import numpy as np

from compile import model
from compile.kernels import jacobi as jc
from compile.kernels import ref, score


class TestSpecs:
    def test_specs_cover_all_artifacts(self):
        specs = model.specs()
        assert set(specs) == {"score", "blackscholes", "jacobi"}

    def test_score_spec_shapes(self):
        _, ins = model.specs()["score"]
        c, v, m = score.C_MAX, score.V_MAX, score.M_METRICS
        assert [tuple(s.shape) for s in ins] == [
            (c, v), (v, m), (v, v), (1, m), (1, v), (1, v), (1, 1)
        ]
        assert all(str(s.dtype) == "float32" for s in ins)

    def test_eval_shape_matches_runtime_expectations(self):
        import jax

        for name, (fn, ins) in model.specs().items():
            outs = jax.eval_shape(fn, *ins)
            if not isinstance(outs, tuple):
                outs = (outs,)
            assert len(outs) >= 1, name
            for o in outs:
                assert str(o.dtype) == "float32", name


class TestJacobiModel:
    def test_fused_sweeps_equal_repeated_single_sweeps(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        grid = rng.uniform(-1, 1, (jc.H, jc.W)).astype(np.float32)
        out, resid = model.jacobi_fn(jnp.asarray(grid))
        # Reference: apply the single-sweep kernel SWEEPS_PER_CALL times.
        cur = jnp.asarray(grid)
        for _ in range(model.SWEEPS_PER_CALL):
            cur = jc.jacobi_sweep(cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(cur), rtol=1e-5, atol=1e-5)
        assert resid.shape == (1,)
        assert float(resid[0]) > 0.0

    def test_residual_decreases_across_calls(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(1)
        grid = jnp.asarray(rng.uniform(-1, 1, (jc.H, jc.W)).astype(np.float32))
        out1, r1 = model.jacobi_fn(grid)
        _, r2 = model.jacobi_fn(out1)
        assert float(r2[0]) < float(r1[0])


class TestBlackscholesModel:
    def test_checksum_is_sum_of_prices(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(2)
        n = 65536
        args = [
            jnp.asarray(rng.uniform(lo, hi, n).astype(np.float32))
            for lo, hi in [(5, 200), (5, 200), (0.05, 3), (0, 0.1), (0.05, 0.9)]
        ]
        call, put, checksum = model.blackscholes_fn(*args)
        expect = float(np.sum(np.asarray(call)) + np.sum(np.asarray(put)))
        assert abs(float(checksum[0]) - expect) / abs(expect) < 1e-5


class TestScoreModel:
    def test_score_fn_delegates_to_kernel(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(3)
        c, v, m = 8, 8, score.M_METRICS
        assign = np.zeros((c, v), np.float32)
        for j in range(v):
            assign[rng.integers(0, c), j] = 1.0
        args_np = (
            assign,
            rng.uniform(0, 0.9, (v, m)).astype(np.float32),
            rng.uniform(0.9, 2.5, (v, v)).astype(np.float32),
            rng.uniform(0, 0.9, (1, m)).astype(np.float32),
            rng.uniform(0.9, 2.5, (1, v)).astype(np.float32),
            rng.uniform(0.9, 2.5, (1, v)).astype(np.float32),
            np.array([[1.2]], np.float32),
        )
        got = model.score_fn(*[jnp.asarray(a) for a in args_np])
        want = ref.score_ref(*args_np)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), w, rtol=2e-4, atol=2e-4)

# Pallas Jacobi stencil (haloed row-slab tiling) vs the loop oracle.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import jacobi as jc
from compile.kernels import ref


def run_sweep(grid):
    import jax.numpy as jnp

    return np.asarray(jc.jacobi_sweep(jnp.asarray(grid, jnp.float32)))


class TestJacobi:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_one_sweep_matches_oracle(self, seed):
        rng = np.random.default_rng(seed)
        grid = rng.uniform(-1.0, 1.0, (jc.H, jc.W)).astype(np.float32)
        got = run_sweep(grid)
        # Loop oracle on the full 256x256 grid is slow; check the halo-critical
        # rows exactly: slab boundaries (BH-1, BH, BH+1) and global borders.
        want = ref.jacobi_ref(grid[: 3 * jc.BH + 2, :], sweeps=1)
        rows = [0, 1, jc.BH - 1, jc.BH, jc.BH + 1, 2 * jc.BH - 1, 2 * jc.BH]
        np.testing.assert_allclose(
            got[rows, :], want[rows, :], rtol=1e-5, atol=1e-5
        )

    def test_boundary_rows_and_cols_fixed(self):
        rng = np.random.default_rng(1)
        grid = rng.uniform(-1.0, 1.0, (jc.H, jc.W)).astype(np.float32)
        got = run_sweep(grid)
        np.testing.assert_array_equal(got[0, :], grid[0, :])
        np.testing.assert_array_equal(got[-1, :], grid[-1, :])
        np.testing.assert_array_equal(got[:, 0], grid[:, 0])
        np.testing.assert_array_equal(got[:, -1], grid[:, -1])

    def test_constant_grid_is_fixed_point(self):
        grid = np.full((jc.H, jc.W), 0.7, np.float32)
        got = run_sweep(grid)
        np.testing.assert_allclose(got, grid, rtol=1e-6)

    def test_smoothing_contracts_towards_mean(self):
        """A Jacobi sweep is an averaging operator: the interior range
        must shrink monotonically."""
        rng = np.random.default_rng(2)
        grid = rng.uniform(-1.0, 1.0, (jc.H, jc.W)).astype(np.float32)
        # Zero boundary so the interior relaxes toward 0.
        grid[0, :] = grid[-1, :] = grid[:, 0] = grid[:, -1] = 0.0
        cur = grid
        prev_amp = np.abs(cur[1:-1, 1:-1]).max()
        for _ in range(3):
            cur = run_sweep(cur)
            amp = np.abs(cur[1:-1, 1:-1]).max()
            assert amp <= prev_amp + 1e-6
            prev_amp = amp

    def test_interior_five_point_average(self):
        """Point-check the stencil arithmetic away from any slab edge."""
        rng = np.random.default_rng(4)
        grid = rng.uniform(0.0, 1.0, (jc.H, jc.W)).astype(np.float32)
        got = run_sweep(grid)
        i, j = 100, 37
        want = 0.2 * (
            grid[i, j] + grid[i - 1, j] + grid[i + 1, j] + grid[i, j - 1] + grid[i, j + 1]
        )
        assert got[i, j] == pytest.approx(want, rel=1e-5)

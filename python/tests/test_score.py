# Pallas scoring kernel vs the naive numpy oracle (ref.score_ref).
#
# The scoring kernel is the VMCd decision hot path; these tests pin down the
# paper's Eq. 2 (RAS overload), Eq. 3 (WI) and Eq. 4 (core interference)
# semantics, including the worked example from §IV-B.2.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, score


def run_kernel(assign, u, s, cand_u, s_vc, s_cv, thr):
    import jax.numpy as jnp

    args = [jnp.asarray(a, jnp.float32) for a in (assign, u, s, cand_u, s_vc, s_cv, thr)]
    return [np.asarray(o) for o in score.score(*args)]


def pad_case(assign, u, s, cand_u, s_vc, s_cv, thr, c_max=8, v_max=8):
    """Embed a small case into padded matrices the way rust does."""
    c, v = assign.shape
    m = u.shape[1]
    a_p = np.zeros((c_max, v_max), np.float32)
    a_p[:c, :v] = assign
    u_p = np.zeros((v_max, m), np.float32)
    u_p[:v] = u
    s_p = np.ones((v_max, v_max), np.float32)
    s_p[:v, :v] = s
    vc_p = np.ones((1, v_max), np.float32)
    vc_p[0, :v] = s_vc
    cv_p = np.ones((1, v_max), np.float32)
    cv_p[0, :v] = s_cv
    return a_p, u_p, s_p, cand_u, vc_p, cv_p, thr


class TestPaperSemantics:
    def test_worked_example_from_paper(self):
        """§IV-B.2: candidate with S == 1 vs 3 residents must get WI == 2
        (sum-only would say 3, product-only would say 1)."""
        assign = np.zeros((2, 4), np.float32)
        assign[0, :3] = 1.0  # three residents on core 0
        u = np.full((4, 4), 0.1, np.float32)
        s = np.ones((4, 4), np.float32)
        cand_u = np.full((1, 4), 0.1, np.float32)
        s_vc = np.ones((1, 4), np.float32)
        s_cv = np.ones((1, 4), np.float32)
        thr = np.array([[1.2]], np.float32)
        _, _, _, ic_a = run_kernel(assign, u, s, cand_u, s_vc, s_cv, thr)
        assert ic_a[0, 0] == pytest.approx(2.0, abs=1e-5)
        # Empty core: the candidate alone has WI = (0 + 1)/2 = 0.5.
        assert ic_a[1, 0] == pytest.approx(0.5, abs=1e-5)

    def test_overload_zero_below_threshold(self):
        assign = np.zeros((2, 2), np.float32)
        assign[0, 0] = 1.0
        u = np.array([[0.5, 0.1, 0.0, 0.2], [0.3, 0.0, 0.0, 0.1]], np.float32)
        cand_u = np.array([[0.3, 0.0, 0.0, 0.1]], np.float32)
        s = np.ones((2, 2), np.float32)
        ones = np.ones((1, 2), np.float32)
        thr = np.array([[1.2]], np.float32)
        ol_b, ol_a, _, _ = run_kernel(assign, u, s, cand_u, ones, ones, thr)
        assert ol_b[0, 0] == pytest.approx(0.0)
        assert ol_a[0, 0] == pytest.approx(0.0)  # 0.8 CPU still under 1.2

    def test_overload_counts_every_saturated_metric(self):
        """Eq. 2 sums the beyond-threshold load over all M resources."""
        assign = np.zeros((1, 2), np.float32)
        assign[0, :] = 1.0
        u = np.array(
            [[0.9, 0.9, 0.0, 0.0], [0.9, 0.9, 0.0, 0.0]], np.float32
        )
        cand_u = np.zeros((1, 4), np.float32)
        s = np.ones((2, 2), np.float32)
        ones = np.ones((1, 2), np.float32)
        thr = np.array([[1.2]], np.float32)
        ol_b, _, _, _ = run_kernel(assign, u, s, cand_u, ones, ones, thr)
        # CPU: 1.8 - 1.2 = 0.6 over; DiskIO: same. Total 1.2.
        assert ol_b[0, 0] == pytest.approx(1.2, abs=1e-5)

    def test_interference_is_max_over_workloads(self):
        """Eq. 4: I_c is the WORST workload's WI, not the mean."""
        assign = np.zeros((1, 3), np.float32)
        assign[0, :] = 1.0
        u = np.full((3, 4), 0.1, np.float32)
        # vm0 suffers 3.0 slowdown with vm1; everything else is 1.0
        s = np.ones((3, 3), np.float32)
        s[0, 1] = 3.0
        cand_u = np.zeros((1, 4), np.float32)
        ones = np.ones((1, 3), np.float32)
        thr = np.array([[1.2]], np.float32)
        _, _, ic_b, _ = run_kernel(assign, u, s, cand_u, ones, ones, thr)
        # WI_0 = ((3.0 + 1.0) + 3.0*1.0)/2 = 3.5 — the max.
        assert ic_b[0, 0] == pytest.approx(3.5, abs=1e-5)


class TestVsOracle:
    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(1, 6),   # cores
        st.integers(1, 8),   # vms
        st.integers(0, 2**31 - 1),
    )
    def test_random_states_match_oracle(self, c, v, seed):
        rng = np.random.default_rng(seed)
        assign = np.zeros((c, v), np.float32)
        for j in range(v):
            if rng.random() < 0.8:  # some VMs not yet placed
                assign[rng.integers(0, c), j] = 1.0
        u = rng.uniform(0.0, 0.9, (v, 4)).astype(np.float32)
        s = rng.uniform(0.8, 3.0, (v, v)).astype(np.float32)
        cand_u = rng.uniform(0.0, 0.9, (1, 4)).astype(np.float32)
        s_vc = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        s_cv = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        thr = np.array([[1.2]], np.float32)

        got = run_kernel(assign, u, s, cand_u, s_vc, s_cv, thr)
        want = ref.score_ref(assign, u, s, cand_u, s_vc, s_cv, thr)
        for g, w, name in zip(got, want, ["ol_b", "ol_a", "ic_b", "ic_a"]):
            np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4, err_msg=name)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_padding_is_inert(self, seed):
        """Padded rows (assign=0, S=1) must not change any score."""
        rng = np.random.default_rng(seed)
        c, v = 3, 4
        assign = np.zeros((c, v), np.float32)
        for j in range(v):
            assign[rng.integers(0, c), j] = 1.0
        u = rng.uniform(0.0, 0.9, (v, 4)).astype(np.float32)
        s = rng.uniform(0.8, 3.0, (v, v)).astype(np.float32)
        cand_u = rng.uniform(0.0, 0.9, (1, 4)).astype(np.float32)
        s_vc = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        s_cv = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        thr = np.array([[1.2]], np.float32)

        small = run_kernel(assign, u, s, cand_u, s_vc, s_cv, thr)
        padded = run_kernel(*pad_case(assign, u, s, cand_u, s_vc, s_cv, thr))
        for g, w in zip(padded, small):
            np.testing.assert_allclose(g[:c], w, rtol=2e-4, atol=2e-4)

    def test_full_compiled_shape(self):
        """Exercise the exact (C_MAX, V_MAX) shape rust compiles against."""
        rng = np.random.default_rng(7)
        c, v, m = score.C_MAX, score.V_MAX, score.M_METRICS
        assign = np.zeros((c, v), np.float32)
        for j in range(40):
            assign[rng.integers(0, c), j] = 1.0
        u = rng.uniform(0.0, 0.9, (v, m)).astype(np.float32)
        s = rng.uniform(0.8, 3.0, (v, v)).astype(np.float32)
        cand_u = rng.uniform(0.0, 0.9, (1, m)).astype(np.float32)
        s_vc = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        s_cv = rng.uniform(0.8, 3.0, (1, v)).astype(np.float32)
        thr = np.array([[1.2]], np.float32)
        got = run_kernel(assign, u, s, cand_u, s_vc, s_cv, thr)
        want = ref.score_ref(assign, u, s, cand_u, s_vc, s_cv, thr)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=5e-3, atol=5e-3)

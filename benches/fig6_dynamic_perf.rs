//! Fig. 6 — performance of workloads for the job batches of the dynamic
//! scenario (paper §V-C.3): RAS best, IAS close behind with fewer cores,
//! CAS worst of the dynamic policies.

mod common;

use vmcd::report;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    let fig = report::fig6(&cfg, &bank, &seeds)?;
    println!("{}", fig.render());
    fig.write_csv(&common::out_dir())?;
    Ok(())
}

//! Ablation: the IAS WI formula (paper Eq. 3, motivated in §IV-B.2).
//!
//! Compares IAS built on (Σ+Π)/2 against sum-only and product-only
//! estimators across the random scenario. The paper argues the mean avoids
//! both the sum's overestimation (spreads too much, wasting cores) and the
//! product's underestimation (packs insensitive workloads too deep).

mod common;

use vmcd::scenarios::{random, runner::run_scenario_with_backend};
use vmcd::vmcd::scheduler::{scoring::WiMode, NativeScoring, Policy};

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    println!("=== ablation: IAS WI formula (random scenario) ===");
    println!(
        "{:<6} {:<14} {:>10} {:>12}",
        "SR", "wi-formula", "perf", "core-hours"
    );
    for sr in [1.0, 1.5, 2.0] {
        for (label, mode) in [
            ("mean(Σ,Π)", WiMode::MeanSumProd),
            ("sum-only", WiMode::SumOnly),
            ("prod-only", WiMode::ProdOnly),
        ] {
            let mut perf_sum = 0.0;
            let mut hours_sum = 0.0;
            for &seed in &seeds {
                let spec = random::build(cfg.host.cores, sr, seed)?;
                let backend = Box::new(NativeScoring::with_wi_mode(mode));
                let r =
                    run_scenario_with_backend(&cfg, &spec, Policy::Ias, &bank, backend)?;
                perf_sum += r.avg_perf;
                hours_sum += r.core_hours;
            }
            let n = seeds.len() as f64;
            println!(
                "{:<6} {:<14} {:>10.3} {:>12.3}",
                sr,
                label,
                perf_sum / n,
                hours_sum / n
            );
        }
    }
    println!(
        "\nexpected shape: sum-only uses the most cores (overestimates WI);\n\
         prod-only packs deepest and degrades perf; mean(Σ,Π) sits between."
    );
    Ok(())
}

//! Fig. 2 — random scenario: workloads' performance and CPU time consumed
//! for RRS / CAS / RAS / IAS at SR ∈ {0.5, 1, 1.5, 2} (paper §V-C.1).
//!
//! Prints the regenerated figure rows (perf + CPU time vs RRS) and times
//! one full scenario simulation per policy.

mod common;

use vmcd::bench::Bench;
use vmcd::report;
use vmcd::scenarios::{random, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    let fig = report::fig2(&cfg, &bank, &seeds)?;
    println!("{}", fig.render());
    fig.write_csv(&common::out_dir())?;

    // Micro: wall time of one full SR=1 scenario per policy.
    let mut b = Bench::new();
    b.section("fig2: end-to-end scenario simulation time (SR=1)");
    let spec = random::build(cfg.host.cores, 1.0, seeds[0])?;
    for policy in Policy::ALL {
        b.run(&format!("simulate/random-sr1/{}", policy.name()), || {
            run_scenario(&cfg, &spec, policy, &bank).unwrap();
        });
    }
    Ok(())
}

//! Extension: actuation-lag sensitivity of the dynamic policies.
//!
//! The paper's enforcement path (§III, the libvirt abstraction) is
//! synchronous; real pin adjustments take time. With the command-queue
//! actuation API the lag is a knob: `Deferred{latency_ticks}` lands every
//! pin N simulator ticks after the decision (optionally budgeted per
//! tick), so freshly-arrived VMs stall unpinned and re-pin passes act on
//! a host whose enacted placement trails their intent. This bench sweeps
//! the lag for RAS and IAS on the random scenario (SR 1.5 — enough
//! contention that re-pinning matters) and reports how much of the
//! schedulers' §IV advantage survives slow actuation.

mod common;

use vmcd::bench::Bench;
use vmcd::scenarios::{random, run_scenario_with_actuation};
use vmcd::vmcd::scheduler::Policy;
use vmcd::vmcd::ActuationSpec;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();
    let sr = 1.5;

    println!(
        "{:<6} {:<10} {:>8} {:>12} {:>14}",
        "policy", "lag", "perf", "core-hours", "perf vs lag0"
    );
    for policy in [Policy::Ras, Policy::Ias] {
        let mut base: Option<f64> = None;
        for lag in [0u64, 1, 2, 4, 8, 16] {
            let actuation = if lag == 0 {
                ActuationSpec::Inline
            } else {
                ActuationSpec::Deferred {
                    latency_ticks: lag,
                    budget_per_tick: 0,
                }
            };
            let (mut perf, mut hours) = (0.0, 0.0);
            for &seed in &seeds {
                let spec = random::build(cfg.host.cores, sr, seed)?;
                let r = run_scenario_with_actuation(&cfg, &spec, policy, &bank, actuation)?;
                perf += r.avg_perf;
                hours += r.core_hours;
            }
            let n = seeds.len() as f64;
            perf /= n;
            hours /= n;
            let b = *base.get_or_insert(perf);
            println!(
                "{:<6} {:<10} {:>8.3} {:>12.3} {:>14.3}",
                policy.name(),
                if lag == 0 {
                    "inline".to_string()
                } else {
                    format!("deferred:{lag}")
                },
                perf,
                hours,
                perf / b
            );
        }
    }

    // Wall-time rows: what the queue + staging machinery itself costs.
    let mut b = Bench::new();
    b.section("single-host scenario wall time (SR 1.5, IAS)");
    let spec = random::build(cfg.host.cores, sr, 42)?;
    for (label, actuation) in [
        ("inline", ActuationSpec::Inline),
        (
            "deferred8",
            ActuationSpec::Deferred {
                latency_ticks: 8,
                budget_per_tick: 0,
            },
        ),
    ] {
        b.run(&format!("actuation/{label}"), || {
            run_scenario_with_actuation(&cfg, &spec, Policy::Ias, &bank, actuation).unwrap();
        });
    }
    Ok(())
}

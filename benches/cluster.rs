//! Extension bench: local-VMCd vs global-migration consolidation across a
//! cluster, swept over per-host subscription ratio (paper §VI future
//! work; DESIGN.md §7).

mod common;

use vmcd::bench::Bench;
use vmcd::cluster::{ClusterSim, ClusterSpec, Strategy};
use vmcd::scenarios::random;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let hosts = 3;

    println!(
        "{:<8} {:<18} {:>7} {:>12} {:>12} {:>11}",
        "SR/host", "strategy", "perf", "core-hours", "host-hours", "migrations"
    );
    for sr in [0.6, 1.2, 1.8, 2.4] {
        let scen = random::build(hosts * cfg.host.cores, sr, 42)?;
        for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
            let spec = ClusterSpec::new(hosts, strategy);
            let r = ClusterSim::new(spec, &scen, &bank).run(&bank, scen.min_duration)?;
            println!(
                "{:<8} {:<18} {:>7.3} {:>12.3} {:>12.3} {:>5} ({} failed)",
                sr,
                strategy.name(),
                r.avg_perf,
                r.core_hours,
                r.host_hours,
                r.migrations_started,
                r.migrations_failed
            );
        }
    }

    let mut b = Bench::new();
    b.section("cluster simulation wall time (3 hosts, SR 1.2)");
    let scen = random::build(hosts * cfg.host.cores, 1.2, 42)?;
    for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
        b.run(&format!("cluster/{}", strategy.name()), || {
            let spec = ClusterSpec::new(hosts, strategy);
            ClusterSim::new(spec, &scen, &bank)
                .run(&bank, scen.min_duration)
                .unwrap();
        });
    }

    // Sharded host stepping (HostHandle workers) vs lockstep on one
    // thread. Results are bit-identical; only wall time may differ.
    b.section("sharded vs single-thread stepping (8 hosts, SR 1.5, local-vmcd)");
    let big_hosts = 8;
    let big_scen = random::build(big_hosts * cfg.host.cores, 1.5, 42)?;
    for threads in [0usize, 4] {
        b.run(&format!("cluster/local-vmcd/shard-threads{threads}"), || {
            let mut spec = ClusterSpec::new(big_hosts, Strategy::LocalVmcd);
            spec.shard_threads = threads;
            ClusterSim::new(spec, &big_scen, &bank)
                .run(&bank, big_scen.min_duration)
                .unwrap();
        });
    }
    Ok(())
}

//! Extension bench: local-VMCd vs global-migration consolidation across a
//! cluster (paper §VI future work), plus the host-stepping backends —
//! persistent [`StepMode::Pool`] vs per-tick scoped threads vs single
//! thread — at 64 and 256 hosts, where the per-tick spawn cost the pool
//! amortises actually shows.

mod common;

use vmcd::bench::Bench;
use vmcd::cluster::{ClusterSpec, StepMode, Strategy};
use vmcd::scenarios::{random, run_cluster};
use vmcd::vmcd::ActuationSpec;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let hosts = 3;

    println!(
        "{:<8} {:<18} {:>7} {:>12} {:>12} {:>10} {:>8} {:>11}",
        "SR/host", "strategy", "perf", "core-hours", "host-hours", "energy Wh", "SLAV", "migrations"
    );
    for sr in [0.6, 1.2, 1.8, 2.4] {
        let scen = random::build(hosts * cfg.host.cores, sr, 42)?;
        for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
            let spec = ClusterSpec::new(hosts, strategy);
            let r = run_cluster(&spec, &scen, &bank)?;
            println!(
                "{:<8} {:<18} {:>7.3} {:>12.3} {:>12.3} {:>10.1} {:>8.4} {:>5} ({} failed)",
                sr,
                strategy.name(),
                r.avg_perf,
                r.core_hours,
                r.host_hours,
                r.energy_wh,
                r.slav,
                r.migrations_started,
                r.migrations_failed
            );
        }
    }

    let mut b = Bench::new();
    b.section("cluster simulation wall time (3 hosts, SR 1.2)");
    let scen = random::build(hosts * cfg.host.cores, 1.2, 42)?;
    for strategy in [Strategy::LocalVmcd, Strategy::GlobalMigration] {
        b.run(&format!("cluster/{}", strategy.name()), || {
            let spec = ClusterSpec::new(hosts, strategy);
            run_cluster(&spec, &scen, &bank).unwrap();
        });
    }

    // The step-mode matrix the pool redesign targets: at 64 and 256
    // hosts a scoped scope() pays thread spawn + join every tick, the
    // persistent pool pays it once per run. Results are bit-identical
    // across modes; only wall time differs. A 600-simulated-second
    // window keeps one iteration affordable at 256 hosts — and per-host
    // work small, which is exactly the regime where per-tick spawn
    // overhead dominates.
    let mut big_cfg = cfg.clone();
    big_cfg.sim.max_time = 600.0;
    for big_hosts in [64usize, 256] {
        b.section(&format!(
            "step modes ({big_hosts} hosts, SR 0.4, 600 s window, local-vmcd)"
        ));
        let big_scen = random::build(big_hosts * big_cfg.host.cores, 0.4, 42)?;
        let workers = 4;
        for mode in [
            StepMode::Single,
            StepMode::Scoped(workers),
            StepMode::Pool(workers),
        ] {
            let label = match mode {
                StepMode::Single => "single".to_string(),
                StepMode::Scoped(n) => format!("scoped{n}"),
                StepMode::Pool(n) => format!("pool{n}"),
            };
            b.run(&format!("cluster/{big_hosts}hosts/{label}"), || {
                let mut spec = ClusterSpec::new(big_hosts, Strategy::LocalVmcd);
                spec.cfg = big_cfg.clone();
                spec.step_mode = mode;
                run_cluster(&spec, &big_scen, &bank).unwrap();
            });
        }
    }

    // Actuation backends at 64 hosts: steady-state tick cost of the
    // command-queue pipeline. Inline enforces within the deciding pass;
    // Deferred pays queue staging plus the per-step due scan — and with
    // a lag its placements differ, so this row measures cost, not
    // bit-identity (that's test-gated at lag 0).
    let actuation_hosts = 64usize;
    b.section(&format!(
        "actuation backends ({actuation_hosts} hosts, SR 0.4, 600 s window, pool4)"
    ));
    let act_scen = random::build(actuation_hosts * big_cfg.host.cores, 0.4, 42)?;
    for (label, actuation) in [
        ("inline", ActuationSpec::Inline),
        (
            "deferred4",
            ActuationSpec::Deferred {
                latency_ticks: 4,
                budget_per_tick: 0,
            },
        ),
        (
            "deferred4b32",
            ActuationSpec::Deferred {
                latency_ticks: 4,
                budget_per_tick: 32,
            },
        ),
    ] {
        b.run(&format!("cluster/{actuation_hosts}hosts/actuation-{label}"), || {
            let mut spec = ClusterSpec::new(actuation_hosts, Strategy::LocalVmcd);
            spec.cfg = big_cfg.clone();
            spec.step_mode = StepMode::Pool(4);
            spec.actuation = actuation;
            run_cluster(&spec, &act_scen, &bank).unwrap();
        });
    }
    Ok(())
}

//! Ablation: RAS `thr` sensitivity (the paper fixes 120% and defers a
//! sweep to future work — §IV-B.1) and context-switch overhead κ.

mod common;

use vmcd::scenarios::{random, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let base_cfg = common::config();
    let bank = common::bank(&base_cfg);
    let seeds = common::seeds();

    println!("=== ablation: RAS threshold thr (random scenario, SR=1) ===");
    println!("{:<8} {:>10} {:>12}", "thr", "perf", "core-hours");
    for thr in [0.8, 1.0, 1.2, 1.5, 2.0] {
        let mut cfg = base_cfg.clone();
        cfg.sched.ras_threshold = thr;
        let (mut perf, mut hours) = (0.0, 0.0);
        for &seed in &seeds {
            let spec = random::build(cfg.host.cores, 1.0, seed)?;
            let r = run_scenario(&cfg, &spec, Policy::Ras, &bank)?;
            perf += r.avg_perf;
            hours += r.core_hours;
        }
        let n = seeds.len() as f64;
        println!("{:<8} {:>10.3} {:>12.3}", thr, perf / n, hours / n);
    }
    println!("(higher thr = more aggressive consolidation: fewer hours, lower perf)");

    println!("\n=== ablation: context-switch overhead κ (random, SR=1.5, IAS) ===");
    println!("{:<8} {:>10} {:>12}", "kappa", "perf", "core-hours");
    for kappa in [0.0, 0.005, 0.02, 0.05, 0.10] {
        let mut cfg = base_cfg.clone();
        cfg.host.ctx_switch_overhead = kappa;
        // Re-profile: κ changes the S matrix the scheduler sees.
        let bank_k = vmcd::profiling::ProfileBank::generate(&cfg);
        let spec = random::build(cfg.host.cores, 1.5, seeds[0])?;
        let r = run_scenario(&cfg, &spec, Policy::Ias, &bank_k)?;
        println!("{:<8} {:>10.3} {:>12.3}", kappa, r.avg_perf, r.core_hours);
    }
    Ok(())
}

//! Fig. 4 — time series of CPU consumption for the 6-job-batch dynamic
//! scenario (paper §V-C.3). RRS reserves the whole server for the entire
//! run; the dynamic schedulers track the active-batch envelope.

mod common;

use vmcd::bench::Bench;
use vmcd::report;
use vmcd::scenarios::{dynamic, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    let fig = report::fig45(&cfg, &bank, 6, seeds[0])?;
    println!("{}", fig.render());
    fig.write_csv(&common::out_dir())?;

    let mut b = Bench::new();
    b.section("fig4: dynamic-6 scenario simulation time");
    let spec = dynamic::build(6, seeds[0])?;
    for policy in Policy::ALL {
        b.run(&format!("simulate/dynamic6/{}", policy.name()), || {
            run_scenario(&cfg, &spec, policy, &bank).unwrap();
        });
    }
    Ok(())
}

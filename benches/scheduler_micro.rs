//! Scheduler micro-benchmarks: latency of one `SelectPinning` decision per
//! policy at increasing host occupancy, and of a full Alg. 1 re-pin cycle.
//!
//! DESIGN.md §Perf target: ≤ 10 µs per native placement decision — VMCd
//! runs every 30 s, so the scheduler must be nowhere near the bottleneck.
//! States come from `Scheduler::new_state`, so the scoring policies run on
//! the incremental placement-scoring engine exactly as the daemon does.

mod common;

use vmcd::bench::Bench;
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::{self, Policy};
use vmcd::workloads::ALL_CLASSES;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let mut b = Bench::new();
    b.opts.measure_iters = 50;

    for occupancy in [0usize, 12, 24, 48] {
        b.section(&format!("select_pinning with {occupancy} resident VMs"));
        for policy in Policy::ALL {
            let mut sched = scheduler::build(policy, &bank, 1.2, None);
            let mut rng = Rng::new(7);
            let mut state = sched.new_state(cfg.host.cores, false);
            for _ in 0..occupancy {
                let core = rng.below(cfg.host.cores);
                state.place(core, *rng.pick(&ALL_CLASSES));
            }
            let mut class_rng = Rng::new(11);
            b.run(
                &format!("select/{}/occ{}", policy.name(), occupancy),
                || {
                    let class = *class_rng.pick(&ALL_CLASSES);
                    std::hint::black_box(sched.select_pinning(&state, class));
                },
            );
        }
    }

    b.section("full re-pin cycle (24 running VMs, RAS)");
    {
        let mut sched = scheduler::build(Policy::Ras, &bank, 1.2, None);
        let mut rng = Rng::new(3);
        let classes: Vec<_> = (0..24).map(|_| *rng.pick(&ALL_CLASSES)).collect();
        b.run("cycle/ras/24vms", || {
            let mut state = sched.new_state(cfg.host.cores, true);
            for &class in &classes {
                let core = sched.select_pinning(&state, class);
                state.place(core, class);
            }
            std::hint::black_box(state.placed());
        });
    }
    Ok(())
}

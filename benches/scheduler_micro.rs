//! Scheduler micro-benchmarks: latency of one `SelectPinning` decision per
//! policy at increasing host occupancy, and of a full Alg. 1 re-pin cycle.
//!
//! DESIGN.md §Perf target: ≤ 10 µs per native placement decision — VMCd
//! runs every 30 s, so the scheduler must be nowhere near the bottleneck.
//! States come from `Scheduler::new_state`, so the scoring policies run on
//! the incremental placement-scoring engine exactly as the daemon does.

mod common;

use vmcd::bench::Bench;
use vmcd::util::rng::Rng;
use vmcd::vmcd::scheduler::{self, Policy};
use vmcd::workloads::ALL_CLASSES;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let mut b = Bench::new();
    b.opts.measure_iters = 50;

    for occupancy in [0usize, 12, 24, 48] {
        b.section(&format!("select_pinning with {occupancy} resident VMs"));
        for policy in Policy::ALL {
            let mut sched = scheduler::build(policy, &bank, 1.2, None);
            let mut rng = Rng::new(7);
            let mut state = sched.new_state(cfg.host.cores, false);
            for _ in 0..occupancy {
                let core = rng.below(cfg.host.cores);
                state.place(core, *rng.pick(&ALL_CLASSES));
            }
            let mut class_rng = Rng::new(11);
            b.run(
                &format!("select/{}/occ{}", policy.name(), occupancy),
                || {
                    let class = *class_rng.pick(&ALL_CLASSES);
                    std::hint::black_box(sched.select_pinning(&state, class));
                },
            );
        }
    }

    b.section("full re-pin cycle (24 running VMs, RAS)");
    {
        let mut sched = scheduler::build(Policy::Ras, &bank, 1.2, None);
        let mut rng = Rng::new(3);
        let classes: Vec<_> = (0..24).map(|_| *rng.pick(&ALL_CLASSES)).collect();
        b.run("cycle/ras/24vms", || {
            let mut state = sched.new_state(cfg.host.cores, true);
            for &class in &classes {
                let core = sched.select_pinning(&state, class);
                state.place(core, class);
            }
            std::hint::black_box(state.placed());
        });
    }

    // The event-API comparison the redesign is about: one daemon cycle as
    // (a) the old rebuild — fresh state, place everything again — vs (b)
    // remove+place deltas on one long-lived state.
    b.section("daemon cycle: rebuild-per-cycle vs event deltas (24 VMs, IAS)");
    {
        let mut rng = Rng::new(5);
        let classes: Vec<_> = (0..24).map(|_| *rng.pick(&ALL_CLASSES)).collect();

        let mut sched = scheduler::build(Policy::Ias, &bank, 1.2, None);
        b.run("cycle/rebuild/ias/24vms", || {
            let mut state = sched.new_state(cfg.host.cores, true);
            for &class in &classes {
                let core = sched.select_pinning(&state, class);
                state.place(core, class);
            }
            std::hint::black_box(state.placed());
        });

        let mut sched = scheduler::build(Policy::Ias, &bank, 1.2, None);
        let mut state = sched.new_state(cfg.host.cores, true);
        let mut cores_now: Vec<usize> = Vec::with_capacity(classes.len());
        for &class in &classes {
            let core = sched.select_pinning(&state, class);
            state.place(core, class);
            cores_now.push(core);
        }
        b.run("cycle/event-delta/ias/24vms", || {
            for (i, &class) in classes.iter().enumerate() {
                state.remove(cores_now[i], class);
                let core = sched.select_pinning(&state, class);
                state.place(core, class);
                cores_now[i] = core;
            }
            std::hint::black_box(state.placed());
        });
    }

    b.section("lifecycle churn: place+remove round-trip on a 24-VM state");
    {
        let mut sched = scheduler::build(Policy::Ias, &bank, 1.2, None);
        let mut rng = Rng::new(9);
        let mut state = sched.new_state(cfg.host.cores, false);
        let mut members: Vec<(usize, _)> = Vec::new();
        for _ in 0..24 {
            let class = *rng.pick(&ALL_CLASSES);
            let core = sched.select_pinning(&state, class);
            state.place(core, class);
            members.push((core, class));
        }
        let mut k = 0usize;
        b.run("churn/remove+place/occ24", || {
            let (core, class) = members[k % members.len()];
            state.remove(core, class);
            state.place(core, class);
            k += 1;
            std::hint::black_box(state.placed());
        });
    }
    Ok(())
}

//! Extension: larger subscription ratios (paper §VI: "Further study of
//! resource-aware and interference-aware schedulers for larger
//! subscription ratios is planned in order to validate the savings
//! observed"). Sweeps SR up to 4 on the random scenario.

mod common;

use vmcd::scenarios::{random, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    println!(
        "{:<6} {:<6} {:>8} {:>12} {:>14} {:>14}",
        "SR", "policy", "perf", "core-hours", "perf vs RRS", "CPU vs RRS"
    );
    for sr in [1.0, 2.0, 3.0, 4.0] {
        let mut base: Option<(f64, f64)> = None;
        for policy in Policy::ALL {
            let (mut perf, mut hours) = (0.0, 0.0);
            for &seed in &seeds {
                let spec = random::build(cfg.host.cores, sr, seed)?;
                let r = run_scenario(&cfg, &spec, policy, &bank)?;
                perf += r.avg_perf;
                hours += r.core_hours;
            }
            let n = seeds.len() as f64;
            perf /= n;
            hours /= n;
            match &base {
                None => {
                    base = Some((perf, hours));
                    println!(
                        "{:<6} {:<6} {:>8.3} {:>12.3} {:>14} {:>14}",
                        sr, policy.name(), perf, hours, "-", "-"
                    );
                }
                Some((bp, bh)) => println!(
                    "{:<6} {:<6} {:>8.3} {:>12.3} {:>13.1}% {:>13.1}%",
                    sr,
                    policy.name(),
                    perf,
                    hours,
                    (perf / bp - 1.0) * 100.0,
                    (hours / bh - 1.0) * 100.0
                ),
            }
        }
    }
    println!(
        "\nexpected: relative savings shrink as SR grows (no headroom left);\n\
         IAS keeps the best performance preservation throughout."
    );
    Ok(())
}

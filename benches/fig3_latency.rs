//! Fig. 3 — latency-critical heavy scenario: performance and CPU time for
//! each scheduler at SR ∈ {0.5, 1, 1.5, 2} (paper §V-C.2).

mod common;

use vmcd::bench::Bench;
use vmcd::report;
use vmcd::scenarios::{latency, run_scenario};
use vmcd::vmcd::scheduler::Policy;

fn main() -> anyhow::Result<()> {
    let cfg = common::config();
    let bank = common::bank(&cfg);
    let seeds = common::seeds();

    let fig = report::fig3(&cfg, &bank, &seeds)?;
    println!("{}", fig.render());
    fig.write_csv(&common::out_dir())?;

    let mut b = Bench::new();
    b.section("fig3: end-to-end scenario simulation time (SR=2)");
    let spec = latency::build(cfg.host.cores, 2.0, seeds[0])?;
    for policy in Policy::ALL {
        b.run(&format!("simulate/latency-sr2/{}", policy.name()), || {
            run_scenario(&cfg, &spec, policy, &bank).unwrap();
        });
    }
    Ok(())
}
